"""Elastic restart end-to-end: checkpoint on one mesh topology, restore
onto a SMALLER one (node loss), continue training — in a subprocess with 8
fake devices so the main process stays single-device."""
import os
import subprocess
import sys
import textwrap


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import restore, save
        from repro.runtime import build_mesh, plan_elastic_mesh, \\
            shrink_after_failure

        # train on a (4, 2) mesh
        plan = plan_elastic_mesh(8, model_parallel=2)
        assert plan.shape == (4, 2)
        mesh = build_mesh(plan)
        w = jax.device_put(
            jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
            NamedSharding(mesh, P("data", "model")))
        state = {{"params": {{"w": w}}, "step": jnp.int32(7)}}
        save(state, 7, {str(tmp_path)!r})

        # lose 2 devices -> re-plan onto 6 -> (3, 2) mesh
        smaller = shrink_after_failure(plan, n_dead=2)
        assert smaller.shape == (3, 2), smaller
        mesh2 = build_mesh(smaller)
        shardings = {{"params": {{"w": NamedSharding(mesh2,
                                                     P("data", "model"))}},
                      "step": NamedSharding(mesh2, P())}}
        # 64 % 3 != 0 would fail; reshard data-dim onto model-compatible spec
        shardings["params"]["w"] = NamedSharding(mesh2, P(None, "model"))
        meta, restored = restore({str(tmp_path)!r}, template=state,
                                 shardings=shardings)
        assert meta["step"] == 7
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        # restored array really lives on the new mesh
        assert restored["params"]["w"].sharding.mesh.shape == \\
            {{"data": 3, "model": 2}}
        # and trains: one sgd step under the new mesh
        def loss(p):
            return jnp.sum(p["w"] ** 2)
        g = jax.grad(loss)(restored["params"])
        new_w = restored["params"]["w"] - 0.1 * g["w"]
        assert bool(jnp.all(jnp.isfinite(new_w)))
        print("ELASTIC_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ELASTIC_OK" in proc.stdout

"""Deterministic crash injection for the durable-engine recovery tests.

:class:`FaultInjector` plugs into ``DurableEngine(..., injector=...)``:
the wrapper calls ``fire(point)`` at every durability boundary, and the
injector raises :class:`InjectedCrash` (a ``BaseException``, so no
``except Exception`` handler can accidentally swallow the "process
death") the ``after``-th time the configured point is reached. The test
then abandons the wrapper object — exactly what a killed process leaves
behind on disk — and drives recovery from the directory alone.

Crash points (in ingest/commit/checkpoint order):

==========================  ===============================================
``wal.pre-append``          before the operation's WAL record is written
``wal.post-append``         record written (+fsynced in synchronous mode)
``ingest.post-dispatch``    engine dispatched, MVCC chain mid-flight
``commit.pre``              journal fsynced, engine commit not yet run
``commit.post``             commit acknowledged
``ckpt.pre-save``           canonical snapshot built, save not yet handed
                            to the async writer (mid-checkpoint publish)
==========================  ===============================================

The replication tier (``repro.core.replication``) fires its own points
on every ship/apply/promote boundary:

==========================  ===============================================
``ship.pre-send``           WAL tail read, bytes not yet delivered
                            (primary dies mid-segment)
``ship.post-send``          follower accepted the span, cursor not moved
``replica.pre-apply``       record journaled on the follower, not applied
                            (replica dies mid-replay)
``replica.post-apply``      record applied, commit possibly pending
``promote.pre-fence``       failover chosen a candidate, nothing done yet
``promote.post-fence``      old primary fenced + epoch bumped, candidate
                            not yet drained or promoted
``promote.post-drain``      candidate caught up, directory not re-opened
                            as the new primary
==========================  ===============================================

Disk-damage helpers complete the harness: :func:`tear_wal_tail`
truncates the last WAL segment mid-record (simulating a crash during a
buffered write), :func:`corrupt_wal_record` flips a byte inside a
record's payload, :func:`corrupt_checkpoint_shard` flips a byte in a
published shard so restore's CRC validation must reject the step, and
:func:`tear_ship` truncates an in-flight shipped span (install it as
``ReplicatedEngine.ship_filter``) so the follower must accept exactly
the valid prefix and re-request the rest.
"""
from __future__ import annotations

import os

from repro.core import wal as wal_mod

#: every point DurableEngine fires, for parametrized crash matrices
CRASH_POINTS = ("wal.pre-append", "wal.post-append", "ingest.post-dispatch",
                "commit.pre", "commit.post", "ckpt.pre-save")

#: every point the replication tier fires (ship/apply/promote boundaries)
REPLICATION_CRASH_POINTS = ("ship.pre-send", "ship.post-send",
                            "replica.pre-apply", "replica.post-apply",
                            "promote.pre-fence", "promote.post-fence",
                            "promote.post-drain")


class InjectedCrash(BaseException):
    """Simulated process death. Derives from BaseException so engine code
    can't swallow it with a broad ``except Exception`` — the test harness
    is the only legal handler."""


class FaultInjector:
    """Raise :class:`InjectedCrash` the ``after``-th time ``crash_at`` is
    reached (``after=1`` = first hit). ``crash_at=None`` never fires but
    still records ``seen`` — useful to assert a path hits its points."""

    def __init__(self, crash_at: str = None, after: int = 1):
        self.crash_at = crash_at
        self.after = int(after)
        self.seen: list = []
        self.fired = False

    def fire(self, point: str) -> None:
        self.seen.append(point)
        if self.fired or self.crash_at != point:
            return
        if self.seen.count(point) >= self.after:
            self.fired = True
            raise InjectedCrash(f"injected crash at {point!r} "
                                f"(hit #{self.seen.count(point)})")


def _last_segment(wal_dir: str) -> str:
    segs = wal_mod._segment_files(wal_dir)
    assert segs, f"no WAL segments under {wal_dir}"
    return os.path.join(wal_dir, segs[-1][1])


def tear_wal_tail(wal_dir: str, drop_bytes: int = 7) -> str:
    """Truncate the newest segment mid-record (a torn buffered write).
    Returns the damaged path."""
    path = _last_segment(wal_dir)
    size = os.path.getsize(path)
    assert size > drop_bytes, "segment too small to tear"
    with open(path, "r+b") as f:
        f.truncate(size - drop_bytes)
    return path

def corrupt_wal_record(wal_dir: str, index: int = 0) -> str:
    """Flip one payload byte of the ``index``-th record in the newest
    segment (bit rot / partial overwrite). Returns the damaged path."""
    path = _last_segment(wal_dir)
    with open(path, "rb") as f:
        data = bytearray(f.read())
    off = 0
    hsize = wal_mod._HEADER_SIZE
    for _ in range(index):
        length = wal_mod._HEADER.unpack_from(data, off)[4]
        off += hsize + length
    length = wal_mod._HEADER.unpack_from(data, off)[4]
    assert length > 0, "cannot corrupt an empty payload"
    data[off + hsize] ^= 0xFF
    with open(path, "wb") as f:
        f.write(data)
    return path


def tear_ship(drop_bytes: int = 7, times: int = 1):
    """A ``ReplicatedEngine.ship_filter`` that truncates the first
    ``times`` non-empty shipped spans by ``drop_bytes`` — the wire twin
    of :func:`tear_wal_tail`. The follower must CRC-reject the torn
    suffix, journal only the valid prefix, and catch up from the re-ship
    on the next tick."""
    state = {"left": int(times)}

    def _filter(node_id: int, data: bytes) -> bytes:
        if data and state["left"] > 0:
            state["left"] -= 1
            return data[:max(0, len(data) - drop_bytes)]
        return data

    return _filter


def corrupt_checkpoint_shard(step_dir: str) -> str:
    """Flip a byte in the middle of a published checkpoint shard so the
    CRC validation in ``ckpt.restore`` must reject the step."""
    shards = sorted(f for f in os.listdir(step_dir) if f.endswith(".npz"))
    assert shards, f"no shards under {step_dir}"
    path = os.path.join(step_dir, shards[0])
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    return path

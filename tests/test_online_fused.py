"""Single-dispatch fused ingest: the whole delta pipeline as ONE program.

Contracts under test:

  * STEADY STATE IS ONE DISPATCH — once shapes stabilize, every ingest of
    both engines issues exactly one compiled-program launch (the
    ``repro.launch.trace`` counter) and never retraces (the program's jit
    cache size stays constant).
  * DONATION IS REAL — the fused program donates the state buffers: the
    pre-ingest arrays are dead after the call (in-place update, not
    copy-merge-copy), yet a failed retraction still leaves the LOGICAL
    state untouched (pass-through outputs).
  * GROWTH STAYS ON DEVICE — novel keys that fit the current capacity take
    the in-program re-sort branch (no recompile); keys beyond capacity
    trigger the capacity-doubling recompile and a second dispatch, after
    which the steady state is one dispatch again.
  * TOUCH-STAMP RENORMALIZATION — the int32 ingest counter renormalizes
    (subtract min live stamp) before it can wrap, preserving TTL eviction
    semantics.
  * K-PARTITIONS-PER-DEVICE — ``n_parts`` may exceed the device count;
    hash-skewed streams keep every partition's occupancy under capacity.
  * the fused Pallas scatter-merge-parts kernel matches the vmapped oracle.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
from repro.core import cube, fused
from repro.core.online import BASE_VIEW
from repro.data.columnar import Table
from repro.launch.trace import count_dispatches

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}


def _frame(n, seed=0, x0_hi=5):
    rng = np.random.default_rng(seed)
    cols = {
        "x0": rng.integers(0, x0_hi, n).astype(np.int32),
        "x1": rng.integers(0, 4, n).astype(np.int32),
        "x2": rng.integers(0, 3, n).astype(np.int32),
    }
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)
    return cols, rng.random(n) > 0.08


def _stat_map(cub):
    gv = (np.asarray(cub.group_valid)
          & (np.asarray(cub.stats["one"]) != 0)).reshape(-1)
    hi = np.asarray(cub.key_hi).reshape(-1)[gv]
    lo = np.asarray(cub.key_lo).reshape(-1)[gv]
    c = {k: np.asarray(v).reshape(-1)[gv]
         for k, v in sorted(cub.stats.items())}
    return {(int(h), int(l)): tuple(float(c[k][i]) for k in c)
            for i, (h, l) in enumerate(zip(hi, lo))}


def _batches(n_batches, size, seed0=100, x0_hi=5):
    out = []
    for i in range(n_batches):
        cols, valid = _frame(size, seed=seed0 + i, x0_hi=x0_hi)
        out.append(Table.from_numpy(cols, valid))
    return out


@pytest.mark.parametrize("make", [
    lambda: OnlineEngine(SPECS, TREATMENTS, "y", granule=256),
    lambda: PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                    n_parts=3),
])
def test_steady_state_is_one_dispatch_and_no_retrace(make):
    eng = make()
    feed = _batches(6, 500)
    for b in feed[:3]:
        eng.ingest(b)            # warm: traces + capacity settle
    prog = eng._fused_program(False)
    cache_before = prog._cache_size()
    for b in feed[3:]:
        # bucket-pad OUTSIDE the guard: the transfer-clean contract covers
        # bucket-sized steady-state batches (non-bucket sizes pay the
        # documented eager jnp.pad pre-step, which materializes constants)
        b = eng._bucket_pad(b)
        with count_dispatches() as n, jax.transfer_guard("disallow"):
            eng.ingest(b)
        assert n() == 1, f"steady-state ingest issued {n()} dispatches"
    assert prog._cache_size() == cache_before, "steady-state ingest retraced"


def test_fused_state_buffers_are_donated_in_place():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    feed = _batches(3, 400)
    eng.ingest(feed[0])
    old_stats = eng.base.stats["one"]   # keep a reference, then ingest
    eng.ingest(feed[1])
    with pytest.raises(RuntimeError):
        _ = np.asarray(old_stats)       # donated: buffer is dead
    # and the new state is alive and correct
    assert int(eng.base.n_groups()) > 0


def test_failed_retraction_passes_state_through_unchanged():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    cols, valid = _frame(800, seed=7)
    eng.ingest(Table.from_numpy(cols, valid))
    before = _stat_map(eng.base)
    bogus = Table.from_numpy({k: np.repeat(v[:1], 300) for k, v in
                              cols.items()}, np.ones(300, bool))
    with pytest.raises(ValueError, match="never ingested"):
        eng.ingest(bogus, retract=True)
    # donated buffers were swapped for pass-through outputs: values equal
    assert _stat_map(eng.base) == before
    # and the engine still ingests normally afterwards
    eng.ingest(Table.from_numpy(cols, valid))


def test_in_program_growth_and_capacity_doubling_recompile():
    # granule=64 but the key space holds 240 combos: the stream must grow
    # capacity mid-stream (recompile) and keep the state exact vs offline
    specs = {"x0": CoarsenSpec.categorical(8),
             "x1": CoarsenSpec.categorical(6),
             "x2": CoarsenSpec.categorical(5)}
    treatments = {"t": ["x0", "x1", "x2"]}
    rng = np.random.default_rng(0)

    def frame(n, seed):
        r = np.random.default_rng(seed)
        c = {"x0": r.integers(0, 8, n).astype(np.int32),
             "x1": r.integers(0, 6, n).astype(np.int32),
             "x2": r.integers(0, 5, n).astype(np.int32)}
        c["t"] = (r.random(n) < 0.5).astype(np.int32)
        c["y"] = np.round(r.normal(0, 1, n)).astype(np.float32)
        return c

    del rng
    eng = OnlineEngine(specs, treatments, "y", granule=64,
                       delta_granule=1024)
    frames = [frame(600, seed=i) for i in range(4)]
    for c in frames:
        eng.ingest(Table.from_numpy(c))
    assert eng.base.capacity > 64          # grew past the initial granule
    full = Table.from_numpy({k: np.concatenate([c[k] for c in frames])
                             for k in frames[0]})
    off = cube.build_cuboid(full, specs, sorted(treatments), "y")
    assert _stat_map(eng.base) == _stat_map(off)
    # post-growth steady state: one dispatch again
    with count_dispatches() as n:
        eng.ingest(Table.from_numpy(frame(600, seed=99)))
    assert n() == 1


def test_bucketed_batch_padding_bounds_retraces():
    # an irregular stream (every batch a different row count) must NOT
    # trace the fused program once per size: batches pad to power-of-two
    # row buckets, so the trace count is bounded by log2(max batch)
    from repro.core.online import BATCH_BUCKET_GRANULE, _bucket_rows
    assert _bucket_rows(1) == BATCH_BUCKET_GRANULE
    assert _bucket_rows(BATCH_BUCKET_GRANULE) == BATCH_BUCKET_GRANULE
    assert _bucket_rows(BATCH_BUCKET_GRANULE + 1) == 2 * BATCH_BUCKET_GRANULE
    assert _bucket_rows(1000) == 1024

    # programs are cached module-wide per schema: start from a fresh one
    # so the trace count below belongs to THIS stream alone
    fused.get_fused_ingest.cache_clear()
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    rng = np.random.default_rng(0)
    sizes = [int(s) for s in rng.integers(1, 1000, 24)]
    for i, sz in enumerate(sizes):
        cols, valid = _frame(sz, seed=200 + i)
        eng.ingest(Table.from_numpy(cols, valid))
    prog = eng._fused_program(False)
    # sizes in [1, 1000) span at most the 5 buckets {64,128,256,512,1024}
    n_buckets = len({_bucket_rows(s) for s in sizes})
    assert prog._cache_size() <= n_buckets <= 5, (
        prog._cache_size(), sorted(set(sizes)))
    # padding rows are invisible to the maintained state: same stream,
    # one engine fed exact-bucket batches, bit-identical stats
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    for i, sz in enumerate(sizes):
        cols, valid = _frame(sz, seed=200 + i)
        pad = _bucket_rows(sz) - sz
        cols = {k: np.pad(v, (0, pad)) for k, v in cols.items()}
        ref.ingest(Table.from_numpy(cols, np.pad(valid, (0, pad))))
    assert _stat_map(eng.base) == _stat_map(ref.base)
    # reservoir state is bit-identical across PIPELINES too (all pad to
    # the same bucket before the streaming-propensity update)
    legacy = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                          pipeline="planner")
    eng2 = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    for i, sz in enumerate(sizes[:6]):
        cols, valid = _frame(sz, seed=200 + i)
        b = Table.from_numpy(cols, valid)
        legacy.ingest(b)
        eng2.ingest(b)
    np.testing.assert_array_equal(np.asarray(eng2.stream.priority),
                                  np.asarray(legacy.stream.priority))
    assert float(eng2.stream.n) == float(legacy.stream.n)


def test_touch_renormalization_before_int32_wraparound():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    feed = _batches(3, 300)
    for b in feed[:2]:
        eng.ingest(b)
    # fast-forward the stream to the renormalization threshold: shift the
    # counter AND every live stamp by the same offset (a legal state — it
    # is exactly what 2^31 - eps committed ingests would produce)
    shift = fused.TOUCH_RENORM_LIMIT + 5 - eng._ingest_count
    eng._ingest_count += shift
    eng._touch = {
        name: jnp.asarray(np.where(
            np.asarray(eng._view_table(name).group_valid),
            np.asarray(t) + shift, 0).astype(np.int32))
        for name, t in eng._touch.items()}
    assert eng._ingest_count >= fused.TOUCH_RENORM_LIMIT
    eng.ingest(feed[2])     # triggers the renormalization
    assert eng._ingest_count < fused.TOUCH_RENORM_LIMIT, \
        "counter was not renormalized"
    assert eng._ingest_count >= 0
    touch = np.asarray(eng._touch[BASE_VIEW])
    gv = np.asarray(eng.base.group_valid)
    assert touch[gv].min() >= 0
    assert touch[gv].max() <= eng._ingest_count
    # TTL semantics survive the shift: only the just-ingested batch's
    # groups survive ttl=0
    evicted = eng.evict(ttl=0)
    assert evicted[BASE_VIEW] >= 0
    survivors = np.asarray(eng._touch[BASE_VIEW])[
        np.asarray(eng.base.group_valid)]
    assert (survivors == eng._ingest_count).all()


def test_skewed_hash_distribution_keeps_partitions_under_capacity():
    # >90% of ROWS land in ONE partition's key range: mine the key space
    # for combos owned by partition 0 of 8 and concentrate the stream on
    # them. k-per-device partitioning must keep every partition's
    # occupancy within its (grown) capacity and stay exact.
    n_parts = 8
    codec = cube.make_codec(SPECS)
    combos = np.stack(np.meshgrid(np.arange(5), np.arange(4), np.arange(3),
                                  indexing="ij"), -1).reshape(-1, 3)
    hi, lo = codec.pack({"x0": jnp.asarray(combos[:, 0]),
                         "x1": jnp.asarray(combos[:, 1]),
                         "x2": jnp.asarray(combos[:, 2])},
                        jnp.ones((len(combos),), bool))
    pid = np.asarray(cube.partition_ids(np.asarray(hi), np.asarray(lo),
                                        n_parts))
    target = np.bincount(pid, minlength=n_parts).argmax()
    hot = combos[pid == target]
    cold = combos[pid != target]
    assert len(hot) >= 2

    rng = np.random.default_rng(3)
    n = 2000
    n_hot = int(n * 0.92)
    rows = np.concatenate([hot[rng.integers(0, len(hot), n_hot)],
                           cold[rng.integers(0, len(cold), n - n_hot)]])
    rng.shuffle(rows)
    cols = {"x0": rows[:, 0].astype(np.int32),
            "x1": rows[:, 1].astype(np.int32),
            "x2": rows[:, 2].astype(np.int32)}
    cols["ta"] = (rng.random(n) < 0.5).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.5).astype(np.int32)
    cols["y"] = np.round(rng.normal(0, 1, n)).astype(np.float32)

    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=64)
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=64,
                                  n_parts=n_parts)
    for s in range(0, n, 500):
        b = Table.from_numpy({k: v[s:s + 500] for k, v in cols.items()})
        ref.ingest(b)
        eng.ingest(b)
    # per-partition occupancy bounded by the per-partition capacity
    for name in (BASE_VIEW, *TREATMENTS):
        tab = eng._view_table(name)
        occ = np.asarray(tab.group_valid).sum(axis=1)
        assert occ.max() <= tab.capacity, (name, occ, tab.capacity)
        # the skew target partition really is hot
        assert occ.sum() > 0
    assert _stat_map(eng.base) == _stat_map(ref.base)
    for t in TREATMENTS:
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate)
    # NOTE: capacity under skew is maintained by per-partition growth;
    # range REBALANCING (splitting hot ranges) is documented follow-up
    # work in ROADMAP.md.


def test_scatter_merge_parts_fused_kernel_matches_ref():
    from repro.kernels import ref
    from repro.kernels.ops import scatter_merge_parts_op
    rng = np.random.default_rng(9)
    p, c, s, b = 3, 256, 5, 130
    tables = rng.normal(0, 1, (p, c, s)).astype(np.float32)
    pos = rng.integers(0, c, (p, b)).astype(np.int32)
    vals = rng.normal(0, 1, (p, b, s)).astype(np.float32)
    got = scatter_merge_parts_op(jnp.asarray(tables), jnp.asarray(pos),
                                 jnp.asarray(vals), block=64)
    want = np.stack([np.asarray(ref.scatter_merge_ref(
        jnp.asarray(tables[i]), jnp.asarray(pos[i]), jnp.asarray(vals[i])))
        for i in range(p)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
    # empty delta: no-op
    out = scatter_merge_parts_op(jnp.asarray(tables),
                                 jnp.zeros((p, 0), jnp.int32),
                                 jnp.zeros((p, 0, s), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), tables)


def test_use_pallas_fused_ingest_matches_default():
    a = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    b = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, use_pallas=True)
    pa = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                 n_parts=2, use_pallas=True)
    for t in _batches(3, 400, seed0=50):
        a.ingest(t)
        b.ingest(t)
        pa.ingest(t)
    assert _stat_map(a.base) == _stat_map(b.base)
    assert _stat_map(a.base) == _stat_map(pa.base)
    for t in TREATMENTS:
        assert float(a.ate(t).ate) == float(b.ate(t).ate)
        assert float(a.ate(t).ate) == float(pa.ate(t).ate)


# --------------------------- k partitions per device (mesh, subprocess) ----
def _run_subprocess(body: str):
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_k_partitions_per_device_bit_identical_on_mesh():
    out = _run_subprocess("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    assert jax.device_count() == 4
    from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
    from repro.data.columnar import Table
    from repro.launch.mesh import make_data_mesh

    SPECS = {"x0": CoarsenSpec.categorical(5),
             "x1": CoarsenSpec.categorical(4),
             "x2": CoarsenSpec.categorical(3)}
    TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}

    def frame(n, seed, x0_hi=5):
        rng = np.random.default_rng(seed)
        cols = {"x0": rng.integers(0, x0_hi, n).astype(np.int32),
                "x1": rng.integers(0, 4, n).astype(np.int32),
                "x2": rng.integers(0, 3, n).astype(np.int32)}
        cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4
                      ).astype(np.int32)
        cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
        cols["y"] = np.round(2.0 * cols["ta"] + 1.5 * cols["x0"]
                             + rng.normal(0, 0.5, n)).astype(np.float32)
        return cols, rng.random(n) > 0.08

    def stat_map(cub):
        gv = (np.asarray(cub.group_valid)
              & (np.asarray(cub.stats["one"]) != 0)).reshape(-1)
        hi = np.asarray(cub.key_hi).reshape(-1)[gv]
        lo = np.asarray(cub.key_lo).reshape(-1)[gv]
        c = {k: np.asarray(v).reshape(-1)[gv]
             for k, v in sorted(cub.stats.items())}
        return {(int(h), int(l)): tuple(float(c[k][i]) for k in c)
                for i, (h, l) in enumerate(zip(hi, lo))}

    mesh = make_data_mesh(4)
    c1, v1 = frame(3000, seed=1, x0_hi=2)
    c2, v2 = frame(2024, seed=2)
    cols = {k: np.concatenate([c1[k], c2[k]]) for k in c1}
    valid = np.concatenate([v1, v2])
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    sharded = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, mesh=mesh)
    # k = 2 and k = 3 partitions per device
    engines = {8: PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                          granule=256, mesh=mesh,
                                          n_parts=8),
               12: PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                           granule=256, mesh=mesh,
                                           n_parts=12)}
    s = 0
    # 999/1001 exercise the in-program batch padding (not % 4 == 0)
    for sz in [999, 1001, 1000, 1000, 1024]:
        b = Table.from_numpy({k: v[s:s + sz] for k, v in cols.items()},
                             valid[s:s + sz])
        r0 = ref.ingest(b)
        sharded.ingest(b)
        for np_, eng in engines.items():
            r = eng.ingest(b)
            assert r.n_delta_groups == r0.n_delta_groups, np_
        s += sz
    full = Table.from_numpy(cols, valid)
    import jax.sharding as shd
    # streaming-propensity state must cover the FULL batch on a mesh
    # (regression: the fused shard_map body once updated the reservoir
    # from the local row shard only), bit-identically to the no-mesh ref
    for label, eng in (("sharded", sharded),
                       *((n, e) for n, e in engines.items())):
        assert float(eng.stream.n) == float(ref.stream.n), label
        for c in ref.stream.names:
            assert float(eng.stream.sums[c]) == float(ref.stream.sums[c]), \
                (label, c)
        np.testing.assert_array_equal(np.asarray(eng.stream.priority),
                                      np.asarray(ref.stream.priority),
                                      err_msg=str(label))
    for np_, eng in engines.items():
        assert stat_map(eng.base) == stat_map(ref.base), np_
        assert isinstance(eng.base.key_hi.sharding, shd.NamedSharding)
        assert eng.base.key_hi.shape[0] == np_
        for t in TREATMENTS:
            cub, _ = eng._view_state(t)
            assert stat_map(cub) == stat_map(ref.views[t].cuboid), (np_, t)
            assert float(eng.ate(t).ate) == float(ref.ate(t).ate)
            assert float(eng.ate(t).variance) == float(ref.ate(t).variance)
            np.testing.assert_array_equal(
                np.asarray(eng.matched_rows(t, full)),
                np.asarray(ref.matched_rows(t, full)))
        # per-device resident state is ~1/4 of the total (k rows/device)
        sb = eng.state_bytes()
        assert sb["per_device"] * 4 <= sb["total"] * 1.01, (np_, sb)
    # n_parts not a multiple of the device count is rejected
    try:
        PartitionedOnlineEngine(SPECS, TREATMENTS, "y", mesh=mesh,
                                n_parts=6)
        raise SystemExit("n_parts=6 on 4 devices was not rejected")
    except ValueError:
        pass
    print("K_PER_DEVICE_OK")
    """)
    assert "K_PER_DEVICE_OK" in out

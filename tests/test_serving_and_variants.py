"""Serving stack + optimization-variant equivalence tests.

These pin down the beyond-paper optimizations numerically:
  * MoE gather dispatch == naive scatter dispatch (same outputs);
  * head padding is a no-op mathematically (single-device check of the
    padded attention math);
  * greedy generate(prefill+decode) == argmax over the full forward;
  * the slot batcher serves every request the right number of tokens;
  * single-word group-by == lexicographic group-by for narrow keys.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.launch.serve import Batcher, Request
from repro.launch.train import PRESETS
from repro.models import forward, init_params
from repro.train import generate


def test_moe_gather_equals_scatter_dispatch():
    cfg = REGISTRY["deepseek-v2-lite-16b"].reduced()
    cfg_s = dataclasses.replace(cfg, moe_dispatch="scatter")
    cfg_g = dataclasses.replace(cfg, moe_dispatch="gather")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg_s)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    ls, _, auxs = forward(params, cfg_s, batch)
    lg, _, auxg = forward(params, cfg_g, batch)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lg), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(float(auxs), float(auxg), rtol=1e-5)


def test_padded_heads_attention_is_noop():
    """Zero-padded attention heads must not change the real heads' output."""
    from repro.models import attention as A
    cfg = REGISTRY["qwen2-7b"].reduced()  # 4 heads after reduce
    key = jax.random.PRNGKey(1)
    p = A.init_gqa(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    out_plain, _ = A.gqa_forward(p, x, pos, cfg, q_chunk=8, kv_chunk=8)
    # emulate padding by hand: extend q/k/v with zero heads via the public
    # path (padded_heads only activates under hints; check the math by
    # comparing a manually padded flash call)
    b, s = 2, 16
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hkv
    dt = x.dtype
    q = (jnp.einsum("bsd,de->bse", x, p["wq"]) + p.get("bq", 0)
         ).reshape(b, s, hkv, g, dh)
    k = (jnp.einsum("bsd,de->bse", x, p["wk"]) + p.get("bk", 0)
         ).reshape(b, s, hkv, dh)
    v = (jnp.einsum("bsd,de->bse", x, p["wv"]) + p.get("bv", 0)
         ).reshape(b, s, hkv, dh)
    from repro.models.layers import apply_rope
    q = apply_rope(q.reshape(b, s, h, dh), pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    ke = jnp.repeat(k, g, axis=2)
    ve = jnp.repeat(v, g, axis=2)
    pad = 2
    z = jnp.zeros((b, s, pad, dh), dt)
    qp = jnp.concatenate([q, z], 2)[:, :, :, None, :]
    kp = jnp.concatenate([ke, z], 2)
    vp = jnp.concatenate([ve, z], 2)
    outp = A.flash_attention(qp, kp, vp, scale=dh ** -0.5, causal=True,
                             q_chunk=8, kv_chunk=8)[:, :, :h, 0, :]
    out_pad = jnp.einsum("bse,ed->bsd", outp.reshape(b, s, h * dh), p["wo"])
    np.testing.assert_allclose(np.asarray(out_plain), np.asarray(out_pad),
                               rtol=2e-4, atol=2e-4)


def test_generate_greedy_matches_full_forward():
    cfg = PRESETS["lm-tiny"]
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    out = generate(params, cfg, {"tokens": prompt}, n_new=1, max_seq=16)
    logits, _, _ = forward(params, cfg, {"tokens": prompt})
    want = jnp.argmax(logits[:, -1], -1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))


def test_batcher_serves_all_requests():
    cfg = PRESETS["lm-tiny"]
    params = init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6,
                                               dtype=np.int32), max_new=5)
            for i in range(6)]
    b = Batcher(cfg, params, n_slots=4, max_seq=16)
    results = b.serve(reqs)
    assert sorted(results) == list(range(6))
    assert all(len(v) == 5 for v in results.values())


def test_single_word_groupby_matches_lexicographic():
    from repro.core import groupby
    from repro.core.keys import KeyCodec
    rng = np.random.default_rng(4)
    codec = KeyCodec.from_cardinalities({"a": 16, "b": 11})  # 8 bits
    vals = {"a": jnp.asarray(rng.integers(0, 16, 500)),
            "b": jnp.asarray(rng.integers(0, 11, 500))}
    valid = jnp.asarray(rng.random(500) > 0.2)
    hi, lo = codec.pack(vals, valid)
    g2 = groupby.group_by_key(hi, lo)
    g1 = groupby.group_by_key(hi, lo, single_word=True)
    assert int(g1.n_groups) == int(g2.n_groups)
    s2 = groupby.segment_sums(g2, {"one": valid.astype(jnp.float32)})
    s1 = groupby.segment_sums(g1, {"one": valid.astype(jnp.float32)})
    np.testing.assert_allclose(
        np.sort(np.asarray(s1["one"])), np.sort(np.asarray(s2["one"])))
    # per-row group assignment identical up to relabeling
    r1 = np.asarray(g1.row_group())
    r2 = np.asarray(g2.row_group())
    v = np.asarray(valid)
    m = {}
    for a, b in zip(r1[v], r2[v]):
        assert m.setdefault(a, b) == b


def test_distributed_cem_single_word_matches():
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import CoarsenSpec, cem, estimate_ate
        from repro.core.cem import pack_keys
        from repro.core.distributed import make_distributed_cem
        from repro.data.columnar import Table
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(7)
        n = 2048
        x0 = rng.integers(0, 6, n).astype(np.int32)
        t = (rng.random(n) < 0.4).astype(np.int32)
        y = (1.5 * t + x0 + rng.normal(0, .3, n)).astype(np.float32)
        table = Table.from_numpy(dict(x0=x0, t=t, y=y))
        specs = {"x0": CoarsenSpec.categorical(6)}   # 3-bit keys
        want = estimate_ate(cem(table, "t", "y", specs).groups)
        codec, hi, lo = pack_keys(table, specs)
        f = make_distributed_cem(mesh, capacity=64, key_bits=codec.total_bits)
        ate, *_ = f(hi, lo, table["t"], table["y"], table.valid)
        np.testing.assert_allclose(float(ate), float(want.ate), rtol=1e-4)
        print("SINGLEWORD_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr
    assert "SINGLEWORD_OK" in proc.stdout

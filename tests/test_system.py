"""End-to-end behaviour tests: FLIGHTDELAY analysis on synthetic data.

Mirrors the paper's §5.2 end-to-end experiment: generate flights+weather,
join, define treatments with discard bands, run CEM, check (a) the naive
estimator is fooled by the low-pressure trap while CEM is not, and (b) CEM
recovers the planted effects within tolerance.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (CoarsenSpec, cem, difference_in_means, estimate_ate,
                        raw_imbalance, awmd)
from repro.data import flightgen
from repro.data.join import fk_join
from repro.data.columnar import Table


@pytest.fixture(scope="module")
def data():
    return flightgen.generate(n_flights=30000, n_airports=6, n_days=365,
                              seed=0)


def _covariate_specs(for_treatment):
    """Minimal d-separating covariate sets per the paper's CDAG (Fig. 7):
    season+traffic block the confounding path; airport/carrier block unit
    heterogeneity; weather co-drivers block weather-weather paths."""
    specs = {
        "airport": CoarsenSpec.categorical(16),
        "carrier": CoarsenSpec.categorical(16),
        "traffic": CoarsenSpec.equal_width(0, 40, 8),
        "w_season": CoarsenSpec.equal_width(0, 1, 4),
    }
    co_weather = {
        "thunder": ["w_precipm", "w_wspdm"],
        "lowvis": ["w_precipm", "w_hum"],
        "highwind": ["w_precipm", "w_tempm"],
        "snow": ["w_tempm", "w_wspdm"],
        "lowpressure": ["w_precipm", "w_wspdm", "w_tempm"],
    }[for_treatment]
    ranges = {"w_precipm": (0, 3), "w_wspdm": (0, 80), "w_hum": (0, 100),
              "w_tempm": (-20, 40)}
    for name in co_weather:
        lo, hi = ranges[name]
        specs[name] = CoarsenSpec.equal_width(lo, hi, 5)
    return specs


def _run_cem(data, treatment):
    table = data.integrated
    mask = flightgen.treatment_valid_mask(data, treatment)
    table = Table(dict(table.columns), table.valid & jnp.asarray(mask))
    res = cem(table, treatment, "dep_delay", _covariate_specs(treatment))
    est = estimate_ate(res.groups)
    return table, res, est


def test_join_matches_integrated(data):
    joined = fk_join(data.flights, data.weather,
                     on={"airport": 64, "hour": 1 << 17}, prefix="w_")
    for col in ("w_thunder", "w_visim", "w_pressurem"):
        np.testing.assert_allclose(
            np.asarray(joined[col]), np.asarray(data.integrated[col]),
            rtol=1e-6)
    assert bool(jnp.all(joined.valid == data.integrated.valid))


def test_cem_recovers_thunder_effect(data):
    table, res, est = _run_cem(data, "thunder")
    true = data.true_sate["thunder"]
    naive = float(difference_in_means(table["dep_delay"], table["thunder"],
                                      table.valid))
    assert abs(float(est.ate) - true) < abs(naive - true) + 1.0
    assert abs(float(est.ate) - true) < 5.0
    # decent matched fraction, as in the paper (>75% of treated matched)
    n_treated = float(jnp.sum(table["thunder"] * table.valid))
    assert float(est.n_matched_treated) > 0.5 * n_treated


def test_low_pressure_trap(data):
    """Low pressure predicts delay (correlation) but has ~zero causal effect;
    the naive estimator reports a large effect, CEM reports ~0 (Example 2)."""
    table, res, est = _run_cem(data, "lowpressure")
    naive = float(difference_in_means(table["dep_delay"], table["lowpressure"],
                                      table.valid))
    assert naive > 4.0                     # the trap: strong association
    assert abs(float(est.ate)) < naive / 3  # CEM kills most of it
    assert abs(float(est.ate)) < 2.5


def test_cem_improves_balance(data):
    """CEM's guarantee (Iacus-King-Porro): post-match imbalance of each
    coarsened-on covariate is bounded by its bucket width — and the planted
    confounder (season) must actually improve vs the raw data."""
    table, res, est = _run_cem(data, "thunder")
    covs = {n: table[n] for n in ("traffic", "w_season", "w_precipm")}
    bucket_width = {"traffic": 40 / 8, "w_season": 1 / 4, "w_precipm": 3 / 5}
    raw = raw_imbalance(covs, table["thunder"], table.valid)
    matched = awmd(res.groups, covs, table["thunder"], res.table.valid)
    for name in covs:
        assert float(matched[name]) <= bucket_width[name] + 1e-5
    assert float(matched["w_season"]) < 0.5 * float(raw["w_season"])


def test_snow_effect_largest_at_cold_airports(data):
    """Sanity: planted snow effect (largest) is ranked above wind by CEM."""
    _, _, est_snow = _run_cem(data, "snow")
    _, _, est_wind = _run_cem(data, "highwind")
    if float(est_snow.n_matched_treated) > 50:
        assert float(est_snow.ate) > float(est_wind.ate)

"""Key-range partitioned materialized views: partitioned == replicated.

The contract: `PartitionedOnlineEngine` changes WHERE materialized state
lives (each device owns one contiguous hash/key-range partition of every
stat table; deltas are routed to owners with an all-to-all), never WHAT is
maintained — cuboid stats are bit-identical (integer outcomes), matched
sets identical, and ATE / ATT / Neyman variance bit-identical to the
replicated engine across 1/2/4-device meshes, including retraction,
eviction and the delta-capacity overflow fallback. Per-device resident
state must drop ~1/N on an N-device mesh.

Runs in a SUBPROCESS with --xla_force_host_platform_device_count so the
main pytest process keeps seeing exactly 1 device (same isolation rule as
tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.devices()
from repro.launch.mesh import make_data_mesh
from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
from repro.data.columnar import Table

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}


def frame(n, seed, x0_hi=5):
    rng = np.random.default_rng(seed)
    cols = {
        "x0": rng.integers(0, x0_hi, n).astype(np.int32),
        "x1": rng.integers(0, 4, n).astype(np.int32),
        "x2": rng.integers(0, 3, n).astype(np.int32),
    }
    p = 0.15 + 0.6 * cols["x0"] / 4
    cols["ta"] = (rng.random(n) < p).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)  # exact f32 sums
    return cols, rng.random(n) > 0.08


def stat_map(cub):
    # works for Cuboid (C,) and PartitionedCuboid (P, C) alike
    gv = (np.asarray(cub.group_valid)
          & (np.asarray(cub.stats["one"]) != 0)).reshape(-1)
    hi = np.asarray(cub.key_hi).reshape(-1)[gv]
    lo = np.asarray(cub.key_lo).reshape(-1)[gv]
    c = {k: np.asarray(v).reshape(-1)[gv] for k, v in sorted(cub.stats.items())}
    return {(int(h), int(l)): tuple(float(c[k][i]) for k in c)
            for i, (h, l) in enumerate(zip(hi, lo))}
"""


def _run(body: str):
    code = SCRIPT_HEADER + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_partitioned_bit_identical_across_device_counts():
    out = _run("""
    # early batches restricted to x0 < 2 -> later batches add new group
    # keys mid-stream, exercising the per-partition grow path too
    c1, v1 = frame(3000, seed=1, x0_hi=2)
    c2, v2 = frame(2024, seed=2)
    cols = {k: np.concatenate([c1[k], c2[k]]) for k in c1}
    valid = np.concatenate([v1, v2])
    sizes = [1000, 1000, 1000, 1000, 1024]

    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    engines = {}
    for ndev in (1, 2, 4):
        mesh = make_data_mesh(ndev) if ndev > 1 else None
        # ndev=1: no mesh, but still 2 key-range partitions on one device
        engines[ndev] = PartitionedOnlineEngine(
            SPECS, TREATMENTS, "y", granule=256, mesh=mesh,
            n_parts=None if ndev > 1 else 2)
    s = 0
    saw_slow = False
    for sz in sizes:
        b = Table.from_numpy({k: v[s:s + sz] for k, v in cols.items()},
                             valid[s:s + sz])
        r = ref.ingest(b)
        for ndev, eng in engines.items():
            rp = eng.ingest(b)
            assert rp.n_delta_groups == r.n_delta_groups, ndev
        if s > 0 and not all(r.fast_path.values()):
            saw_slow = True
        s += sz
    assert saw_slow, "stream never exercised the grow path"

    full = Table.from_numpy(cols, valid)
    ref_matched = {t: np.asarray(ref.matched_rows(t, full))
                   for t in TREATMENTS}
    for ndev, eng in engines.items():
        assert stat_map(eng.base) == stat_map(ref.base), ndev
        for t in TREATMENTS:
            cub, _ = eng._view_state(t)
            assert stat_map(cub) == stat_map(ref.views[t].cuboid), (ndev, t)
            got, want = eng.ate(t), ref.ate(t)
            assert float(got.ate) == float(want.ate), (ndev, t)
            assert float(got.att) == float(want.att), (ndev, t)
            assert float(got.variance) == float(want.variance), (ndev, t)
            assert int(got.n_groups) == int(want.n_groups)
            np.testing.assert_array_equal(
                np.asarray(eng.matched_rows(t, full)), ref_matched[t])
            gs = eng.ate(t, subpopulation={"x0": [0, 1]})
            ws = ref.ate(t, subpopulation={"x0": [0, 1]})
            assert float(gs.ate) == float(ws.ate), (ndev, t, "subpop")
    print("PARTITIONED_EQUIV_OK")
    """)
    assert "PARTITIONED_EQUIV_OK" in out


def test_partitioned_state_is_sharded_one_over_n_per_device():
    out = _run("""
    cols, valid = frame(6000, seed=5)
    mesh = make_data_mesh(4)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                  mesh=mesh)
    for s in range(0, 6000, 1500):
        b = Table.from_numpy({k: v[s:s + 1500] for k, v in cols.items()},
                             valid[s:s + 1500])
        ref.ingest(b)
        eng.ingest(b)
    rb, pb = ref.state_bytes(), eng.state_bytes()
    # replicated: every device holds the full tables
    assert rb["per_device"] == rb["total"]
    # partitioned: the leading partition axis is sharded over the mesh —
    # per-device resident state is ~1/4 of the total
    assert pb["per_device"] * 4 <= pb["total"] * 1.01, pb
    # and maintained state is not larger overall than the replicated engine's
    assert pb["total"] <= rb["total"] * 1.5, (pb, rb)
    import jax.sharding as shd
    assert isinstance(eng.base.key_hi.sharding, shd.NamedSharding)
    print("PARTITIONED_BYTES_OK", pb, rb)
    """)
    assert "PARTITIONED_BYTES_OK" in out


def test_partitioned_retraction_eviction_and_guard():
    out = _run("""
    cols, valid = frame(4000, seed=3)
    mesh = make_data_mesh(4)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                  mesh=mesh)
    for s in range(0, 4000, 1000):
        b = Table.from_numpy({k: v[s:s + 1000] for k, v in cols.items()},
                             valid[s:s + 1000])
        ref.ingest(b)
        eng.ingest(b)
    # retract the second batch on both: still bit-identical
    b1 = Table.from_numpy({k: v[1000:2000] for k, v in cols.items()},
                          valid[1000:2000])
    ref.ingest(b1, retract=True)
    eng.ingest(b1, retract=True)
    assert stat_map(eng.base) == stat_map(ref.base)
    for t in TREATMENTS:
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate), t
    # the never-ingested guard fires through the routed path too
    bogus = Table.from_numpy({k: np.repeat(v[:1], 600) for k, v in
                              cols.items()}, np.ones(600, bool))
    before = stat_map(eng.base)
    try:
        eng.ingest(bogus, retract=True)
        raise SystemExit("guard did not fire")
    except ValueError:
        pass
    assert stat_map(eng.base) == before
    # per-partition TTL eviction drops the same groups as replicated
    rng = np.random.default_rng(28)
    for i in range(5):
        cc = {"x0": np.full(200, i % 5, np.int32),
              "x1": rng.integers(0, 4, 200).astype(np.int32),
              "x2": rng.integers(0, 3, 200).astype(np.int32)}
        cc["ta"] = (rng.random(200) < 0.5).astype(np.int32)
        cc["tb"] = (rng.random(200) < 0.5).astype(np.int32)
        cc["y"] = np.round(rng.normal(0, 1, 200)).astype(np.float32)
        b = Table.from_numpy(cc)
        ref.ingest(b)
        eng.ingest(b)
    ev_r, ev_p = ref.evict(ttl=2), eng.evict(ttl=2)
    assert ev_r == ev_p, (ev_r, ev_p)
    assert stat_map(eng.base) == stat_map(ref.base)
    for t in TREATMENTS:
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate), t
    print("PARTITIONED_RETRACT_EVICT_OK")
    """)
    assert "PARTITIONED_RETRACT_EVICT_OK" in out


def test_partitioned_delta_capacity_overflow_falls_back_exactly():
    out = _run("""
    # tiny delta capacity: the first wide batch overflows the routed delta
    # tables, forcing the exact host rebuild + re-route + geometric growth
    cols, valid = frame(4096, seed=4)
    mesh = make_data_mesh(4)
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                  mesh=mesh, delta_granule=8)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       delta_granule=8)
    for s in range(0, 4096, 1024):
        b = Table.from_numpy({k: v[s:s + 1024] for k, v in cols.items()},
                             valid[s:s + 1024])
        eng.ingest(b)
        ref.ingest(b)
    assert eng._delta_cap > 8  # capacity grew past the forced overflow
    assert stat_map(eng.base) == stat_map(ref.base)
    for t in TREATMENTS:
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate)
    print("PARTITIONED_OVERFLOW_OK")
    """)
    assert "PARTITIONED_OVERFLOW_OK" in out

"""Per-architecture smoke tests on REDUCED configs (CPU-sized):
forward pass + one SGD train step + (where applicable) prefill/decode,
asserting output shapes and finiteness. Full configs are exercised only by
the dry-run (launch/dryrun.py) via ShapeDtypeStructs.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.models import forward, init_cache, init_params, param_count

ARCHS = sorted(REGISTRY)
B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[1], (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        # stub vision frontend: 3-D positions (t/h/w), text tail
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                               (B, S))
        batch["positions"] = jnp.stack([pos, pos // 4, pos % 4])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = REGISTRY[arch].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    assert param_count(params) > 0
    logits, _, aux = forward(params, cfg, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    """One step of SGD on next-token CE must produce finite grads that
    change the loss (sanity for the whole backward path)."""
    cfg = REGISTRY[arch].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, _, aux = forward(p, cfg, batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return jnp.mean(nll) + 0.01 * aux

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params,
                           grads)
    l1 = loss_fn(params2)
    assert bool(jnp.isfinite(l1))
    assert float(l1) != float(l0)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Prefill S-1 tokens, decode token S-1; its logits must match the
    full-sequence forward at that position (cache correctness)."""
    cfg = REGISTRY[arch].reduced()
    if not cfg.supports_decode:
        pytest.skip("no decode step for this arch")
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    full_logits, _, _ = forward(params, cfg, batch)

    max_seq = S
    cache = init_cache(cfg, B, max_seq)
    prefill = {k: (v[:, :S - 1] if k == "tokens" else
                   (v[..., :S - 1] if k == "positions" else v))
               for k, v in batch.items()}
    if cfg.family == "encdec":
        _, cache, _ = forward(params, cfg, prefill, cache=cache,
                              cache_pos=jnp.zeros((B,), jnp.int32))
    else:
        _, cache, _ = forward(params, cfg, prefill, cache=cache,
                              cache_pos=jnp.zeros((B,), jnp.int32))
    step = {k: (v[:, S - 1:S] if k == "tokens" else
                (v[..., S - 1:S] if k == "positions" else v))
            for k, v in batch.items()}
    pos = jnp.full((B,), S - 1, jnp.int32)
    dec_logits, cache, _ = forward(params, cfg, step, cache=cache,
                                   cache_pos=pos)
    got = np.asarray(dec_logits[:, 0])
    want = np.asarray(full_logits[:, S - 1])
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_registry_complete():
    assert len(REGISTRY) == 10
    fams = {c.family for c in REGISTRY.values()}
    assert fams == {"dense", "moe", "ssm", "hybrid", "encdec", "vlm"}

"""Device-resident query pipeline: one compiled dispatch per causal query.

Contracts under test:

  * STEADY-STATE ``ate()`` IS ONE DISPATCH — on BOTH engines the uncached
    query (subpopulation filter + keep mask + canonical reduction) is one
    compiled program launch plus one scalar-sized ``device_get``; a cached
    repeat issues ZERO dispatches and zero transfers (the version-tagged
    host cache — the residual ``np.asarray(keep)`` host sync of the legacy
    estimate path is gone).
  * BIT-IDENTITY ACROSS PIPELINES — the fused query, the planner-era
    ``assemble`` baseline (canonical reassembly first) and the offline
    recompute agree: fused vs assemble bitwise (shared canonical
    estimator, capacity-invariant chunked reduction), vs offline to float
    tolerance.
  * ROUTED ROW LOOKUP — ``matched_rows`` probes hash to their owning
    partition and binary-search only that partition's table (all-to-all
    routed on a mesh); masks are identical to the broadcast-search
    baseline and the offline CEM row mask.
  * CAPACITY SHRINK AFTER EVICTION — when TTL eviction collapses the live
    set below 1/4 of grown capacity, the engine compacts into a smaller
    capacity and ``state_bytes()`` decreases; the stream then continues
    exactly (and at one dispatch per ingest) at the smaller shape.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
from repro.core import cem as cem_fn
from repro.core.ate import estimate_ate
from repro.core.online import BASE_VIEW
from repro.data.columnar import Table
from repro.launch.trace import count_dispatches

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}
SUBPOPS = (None, {"x2": [0]}, {"x2": [1, 2]}, {"x0": [0, 1], "x2": [0, 2]})


def _frame(n, seed=0, x0_hi=5):
    rng = np.random.default_rng(seed)
    cols = {
        "x0": rng.integers(0, x0_hi, n).astype(np.int32),
        "x1": rng.integers(0, 4, n).astype(np.int32),
        "x2": rng.integers(0, 3, n).astype(np.int32),
    }
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)
    return cols, rng.random(n) > 0.08


def _engines():
    kw = dict(query_dims=("x2",))
    return {
        "replicated": OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                   **kw),
        "partitioned": PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                               granule=64, n_parts=3, **kw),
    }


def _feed(engines, n_batches=3, size=500, seed0=10):
    batches = []
    for i in range(n_batches):
        cols, valid = _frame(size, seed=seed0 + i)
        b = Table.from_numpy(cols, valid)
        batches.append((cols, valid))
        for eng in engines.values():
            eng.ingest(b)
    return batches


EST_FIELDS = ("ate", "att", "variance", "n_matched_treated",
              "n_matched_control", "n_groups")


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_steady_state_ate_is_one_dispatch_and_cached_is_zero(label):
    engines = _engines()
    _feed(engines)
    eng = engines[label]
    for t in sorted(TREATMENTS):
        for sub in SUBPOPS:
            eng.ate(t, subpopulation=sub)     # warm the program traces
    # mutate state so every cache entry drops, then query steady-state
    cols, valid = _frame(400, seed=77)
    eng.ingest(Table.from_numpy(cols, valid))
    for t in sorted(TREATMENTS):
        for sub in SUBPOPS:
            # the guard proves the query path's only host<->device moves
            # are the explicit device_put/device_get it owns
            with count_dispatches() as n, jax.transfer_guard("disallow"):
                est = eng.ate(t, subpopulation=sub)
            assert n() == 1, (label, t, sub, n())
            # the estimate was fetched with the query's single device_get:
            # reading it is free (host scalars, no implicit transfer)
            assert isinstance(float(est.ate), float)
            with count_dispatches() as n, jax.transfer_guard("disallow"):
                est2 = eng.ate(t, subpopulation=sub)
            assert n() == 0, (label, t, sub, "cached query dispatched")
            assert float(est2.ate) == float(est.ate)
    # the query label sees exactly the fused query program
    eng._cache.clear()
    with count_dispatches(label="query") as n:
        eng.ate("ta")
    assert n() == 1


def test_fused_query_bit_identical_to_assemble_and_close_to_offline():
    engines = _engines()
    history = _feed(engines, n_batches=4, size=600)
    cols = {k: np.concatenate([c[k] for c, _ in history])
            for k in history[0][0]}
    valid = np.concatenate([v for _, v in history])
    full = Table.from_numpy(cols, valid)
    for t in sorted(TREATMENTS):
        ests = {}
        for label, eng in engines.items():
            ests[f"{label}/fused"] = eng._estimate(t, None, pipeline="fused")
            ests[f"{label}/assemble"] = eng._estimate(t, None,
                                                      pipeline="assemble")
        vals = {k: {f: float(getattr(e, f)) for f in EST_FIELDS}
                for k, e in ests.items()}
        first = next(iter(vals.values()))
        for k, v in vals.items():
            assert v == first, (t, k, v, first)
        # and the maintained state agrees with the offline recompute
        dims = sorted(set(TREATMENTS[t]) | {"x2"})
        want = estimate_ate(cem_fn(
            full, t, "y", {d: SPECS[d] for d in dims}).groups)
        np.testing.assert_allclose(first["ate"], float(want.ate),
                                   rtol=1e-5, atol=1e-6)
        assert first["n_groups"] == int(want.n_groups)


def test_matched_rows_routed_equals_assemble_and_offline():
    engines = _engines()
    history = _feed(engines, n_batches=3, size=700, seed0=40)
    cols = {k: np.concatenate([c[k] for c, _ in history])
            for k in history[0][0]}
    valid = np.concatenate([v for _, v in history])
    probe = Table.from_numpy(cols, valid)
    for t in sorted(TREATMENTS):
        dims = sorted(set(TREATMENTS[t]) | {"x2"})
        offline = cem_fn(probe, t, "y", {d: SPECS[d] for d in dims})
        want = np.asarray(offline.table.valid)
        for label, eng in engines.items():
            fused = np.asarray(eng.matched_rows(t, probe))
            assemble = np.asarray(
                eng.matched_rows(t, probe, pipeline="assemble"))
            np.testing.assert_array_equal(fused, assemble,
                                          err_msg=f"{label}/{t}")
            np.testing.assert_array_equal(fused, want,
                                          err_msg=f"{label}/{t} offline")
    # steady state: the fused row lookup is one compiled dispatch
    for label, eng in engines.items():
        eng.matched_rows("ta", probe)                   # warm trace
        with count_dispatches() as n:
            eng.matched_rows("ta", probe)
        assert n() == 1, (label, n())


def test_cem_groups_served_from_version_memoized_assembly():
    engines = _engines()
    _feed(engines)
    rep, part = engines["replicated"], engines["partitioned"]
    for t in sorted(TREATMENTS):
        a = rep.cem_groups(t)
        b = part.cem_groups(t)
        ka = np.asarray(a.keep)[np.asarray(a.keep)].shape
        kb = np.asarray(b.keep)[np.asarray(b.keep)].shape
        assert ka == kb
        assert float(estimate_ate(a).ate) == float(estimate_ate(b).ate)
    # repeated partitioned queries reuse the memoized assembly: no new
    # dispatches until the next committed state mutation
    part.cem_groups("ta")
    with count_dispatches() as n:
        part.cem_groups("ta")
        part.cem_groups("ta")
    assert n() == 0
    cols, valid = _frame(300, seed=5)
    part.ingest(Table.from_numpy(cols, valid))
    with count_dispatches() as n:
        part.cem_groups("ta")
    assert n() >= 1          # version bumped -> assembly recomputed


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_capacity_shrink_after_eviction_reclaims_memory(label):
    # wide key space (240 combos) at granule 64 -> capacity grows; then
    # the live set collapses to a handful of groups and eviction + the
    # shrink pass must hand the memory back
    specs = {"x0": CoarsenSpec.categorical(8),
             "x1": CoarsenSpec.categorical(6),
             "x2": CoarsenSpec.categorical(5)}
    treatments = {"t": ["x0", "x1", "x2"]}

    def frame(n, seed, hi=(8, 6, 5)):
        r = np.random.default_rng(seed)
        c = {"x0": r.integers(0, hi[0], n).astype(np.int32),
             "x1": r.integers(0, hi[1], n).astype(np.int32),
             "x2": r.integers(0, hi[2], n).astype(np.int32)}
        c["t"] = (r.random(n) < 0.5).astype(np.int32)
        c["y"] = np.round(r.normal(0, 1, n)).astype(np.float32)
        return c

    if label == "replicated":
        eng = OnlineEngine(specs, treatments, "y", granule=64,
                           delta_granule=1024)
    else:
        eng = PartitionedOnlineEngine(specs, treatments, "y", granule=64,
                                      delta_granule=1024, n_parts=2)
    for i in range(4):
        eng.ingest(Table.from_numpy(frame(600, seed=i)))
    cap_before = eng._view_table(BASE_VIEW).capacity
    bytes_before = eng.state_bytes()["total"]
    assert cap_before > eng._shrink_granule()   # the stream really grew
    # last batch touches only 2 combos; ttl=0 evicts everything else
    eng.ingest(Table.from_numpy(frame(200, seed=99, hi=(1, 2, 1))))
    evicted = eng.evict(ttl=0)
    assert evicted[BASE_VIEW] > 0
    assert eng._view_table(BASE_VIEW).capacity < cap_before
    assert eng.state_bytes()["total"] < bytes_before
    # surviving stats are exact: the 2 live groups carry their FULL
    # accumulated sums (eviction compaction is a gather, shrink a slice)
    live = {}
    for i in list(range(4)) + [99]:
        c = frame(600 if i < 4 else 200, seed=i,
                  hi=(8, 6, 5) if i < 4 else (1, 2, 1))
        for j in range(len(c["t"])):
            key = (c["x0"][j], c["x1"][j], c["x2"][j])
            acc = live.setdefault(key, [0.0, 0.0])
            acc[0] += 1.0
            acc[1] += float(c["y"][j])
    survivors = {(0, 0, 0), (0, 1, 0)}
    tab = eng._view_table(BASE_VIEW)
    gv = np.asarray(tab.group_valid).reshape(-1)
    one = np.asarray(tab.stats["one"]).reshape(-1)[gv]
    ysum = np.asarray(tab.stats["y"]).reshape(-1)[gv]
    assert gv.sum() == len(survivors)
    want = sorted((live[k][0], live[k][1]) for k in survivors)
    got = sorted(zip(one.tolist(), ysum.tolist()))
    assert got == want
    # the stream continues exactly at the smaller shape, one dispatch
    eng.ingest(Table.from_numpy(frame(600, seed=5)))
    eng.ingest(Table.from_numpy(frame(600, seed=6)))
    with count_dispatches() as n:
        eng.ingest(Table.from_numpy(frame(600, seed=7)))
    assert n() == 1
    # queries still answer (and for the partitioned engine the fused and
    # assemble paths still agree bitwise post-shrink)
    f = eng._estimate("t", None, pipeline="fused")
    a = eng._estimate("t", None, pipeline="assemble")
    assert float(f.ate) == float(a.ate)
    assert float(f.variance) == float(a.variance)


# ----------------------------- mesh (subprocess, forced host devices) -------
def _run_subprocess(body: str):
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_mesh_query_single_dispatch_and_routed_lookup_bit_identical():
    out = _run_subprocess("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    assert jax.device_count() == 4
    from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
    from repro.data.columnar import Table
    from repro.launch.mesh import make_data_mesh
    from repro.launch.trace import count_dispatches

    SPECS = {"x0": CoarsenSpec.categorical(5),
             "x1": CoarsenSpec.categorical(4),
             "x2": CoarsenSpec.categorical(3)}
    TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}

    def frame(n, seed):
        rng = np.random.default_rng(seed)
        cols = {"x0": rng.integers(0, 5, n).astype(np.int32),
                "x1": rng.integers(0, 4, n).astype(np.int32),
                "x2": rng.integers(0, 3, n).astype(np.int32)}
        cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4
                      ).astype(np.int32)
        cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
        cols["y"] = np.round(2.0 * cols["ta"] + 1.5 * cols["x0"]
                             + rng.normal(0, 0.5, n)).astype(np.float32)
        return cols, rng.random(n) > 0.08

    mesh = make_data_mesh(4)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       query_dims=("x2",))
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                  mesh=mesh, n_parts=8, query_dims=("x2",))
    feeds = []
    for i in range(3):
        cols, valid = frame(1000, seed=i)
        feeds.append((cols, valid))
        b = Table.from_numpy(cols, valid)
        ref.ingest(b)
        eng.ingest(b)
    probe = Table.from_numpy(
        {k: np.concatenate([c[k] for c, _ in feeds]) for k in feeds[0][0]},
        np.concatenate([v for _, v in feeds]))
    subpops = (None, {"x2": [0]}, {"x0": [0, 1], "x2": [1, 2]})
    for t in sorted(TREATMENTS):
        for sub in subpops:
            eng.ate(t, subpopulation=sub)      # warm
        eng.matched_rows(t, probe)             # warm
    cols, valid = frame(1000, seed=9)
    b = Table.from_numpy(cols, valid)
    ref.ingest(b)
    eng.ingest(b)
    probe2 = Table.from_numpy(cols, valid)
    for t in sorted(TREATMENTS):
        for sub in subpops:
            with count_dispatches() as n:
                got = eng.ate(t, subpopulation=sub)
            assert n() == 1, (t, sub, n())
            want = ref.ate(t, subpopulation=sub)
            for f in ("ate", "att", "variance", "n_matched_treated",
                      "n_groups"):
                assert float(getattr(got, f)) == float(getattr(want, f)), \
                    (t, sub, f)
        # routed row lookup on the mesh == single-device broadcast search
        with count_dispatches() as n:
            routed = np.asarray(eng.matched_rows(t, probe2))
        assert n() == 1, (t, n())
        np.testing.assert_array_equal(routed,
                                      np.asarray(ref.matched_rows(t, probe2)))
        np.testing.assert_array_equal(
            np.asarray(eng.matched_rows(t, probe)),
            np.asarray(ref.matched_rows(t, probe)))
    # eviction (with the shrink pass wired in) stays bit-identical on
    # sharded (P, C) state; this schema's key space (60 combos) cannot
    # outgrow the per-partition granule floor, so no shrink triggers here
    # (the strict state_bytes-decrease regression runs in-process in
    # test_capacity_shrink_after_eviction_reclaims_memory)
    narrow = {k: v[:200].copy() for k, v in cols.items()}
    for k in ("x0", "x1", "x2"):
        narrow[k][:] = 0
    nb = Table.from_numpy(narrow, np.ones(200, bool))
    ref.ingest(nb)
    eng.ingest(nb)
    before = eng.state_bytes()
    ref.evict(ttl=0)
    eng.evict(ttl=0)
    after = eng.state_bytes()
    assert after["total"] <= before["total"], (before, after)
    for t in sorted(TREATMENTS):
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate), t
    print("MESH_QUERY_OK")
    """)
    assert "MESH_QUERY_OK" in out


def test_chunked_sum_is_padding_invariant():
    from repro.kernels.segment_stats import chunked_sum
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, 700).astype(np.float32)
    a = float(chunked_sum(jnp.asarray(x)))
    for pad in (0, 324, 1024, 3000):
        b = float(chunked_sum(jnp.asarray(
            np.concatenate([x, np.zeros(pad, np.float32)]))))
        assert a == b, pad
    # and it agrees with plain sums to float tolerance
    np.testing.assert_allclose(a, float(np.sum(x.astype(np.float64))),
                               rtol=1e-5)

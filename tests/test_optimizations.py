"""Paper §4 optimizations: factoring (Prop. 3), cube, pushdown (Prop. 2),
offline preparation (Alg. 2) — equivalence against direct CEM."""
import numpy as np
import pytest

from repro.core import (CoarsenSpec, cem, cem_join_pushdown, covariate_factoring,
                        cube, estimate_ate, mcem, partition_treatments,
                        phi_matrix, prepare)
from repro.data.columnar import Table, compact
from repro.data.join import fk_join


def _multi_treatment_frame(n=3000, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.integers(0, 4, n).astype(np.int32)      # shared
    x1 = rng.integers(0, 3, n).astype(np.int32)      # shared
    x2 = rng.integers(0, 5, n).astype(np.int32)      # t_a only
    x3 = rng.integers(0, 5, n).astype(np.int32)      # t_b only
    latent = rng.normal(0, 1, n)
    t_a = ((x0 + latent) > 2.2).astype(np.int32)
    t_b = ((x0 + latent + 0.3 * rng.normal(0, 1, n)) > 2.4).astype(np.int32)
    t_c = (rng.random(n) < 0.5).astype(np.int32)     # independent
    y = (3 * t_a + 1 * t_b + x0 + rng.normal(0, .3, n)).astype(np.float32)
    valid = rng.random(n) > 0.05
    table = Table.from_numpy(dict(x0=x0, x1=x1, x2=x2, x3=x3, t_a=t_a,
                                  t_b=t_b, t_c=t_c, y=y), valid)
    specs = {f"x{i}": CoarsenSpec.categorical(c)
             for i, c in enumerate((4, 3, 5, 5))}
    covsets = {"t_a": ["x0", "x1", "x2"], "t_b": ["x0", "x1", "x3"],
               "t_c": ["x1", "x3"]}
    return table, specs, covsets


def test_prop3_factoring_equivalence():
    """MCEM_Ti(P_S) == CEM(R_Ti) — matched masks identical (Prop. 3)."""
    table, specs, covsets = _multi_treatment_frame()
    group = ["t_a", "t_b"]
    shared = sorted(set(covsets["t_a"]) & set(covsets["t_b"]))
    view = covariate_factoring(table, group, specs, shared)
    for tname in group:
        tspecs = {n: specs[n] for n in covsets[tname]}
        direct = cem(table, tname, "y", tspecs)
        via = mcem(view, tname, "y", tspecs)
        np.testing.assert_array_equal(np.asarray(via.table.valid),
                                      np.asarray(direct.table.valid))
        d = estimate_ate(direct.groups)
        v = estimate_ate(via.groups)
        np.testing.assert_allclose(float(v.ate), float(d.ate), rtol=1e-5)


def test_factoring_prunes():
    table, specs, covsets = _multi_treatment_frame()
    view = covariate_factoring(table, ["t_a", "t_b"], specs, ["x0", "x1"])
    assert int(view.table.count()) <= int(table.count())


def test_alg1_partitions_correlated_treatments_together():
    table, specs, covsets = _multi_treatment_frame()
    covsets = {k: set(v) for k, v in covsets.items()}
    names, M = phi_matrix({t: table[t] for t in ("t_a", "t_b", "t_c")},
                          table.valid)
    # t_a and t_b are strongly correlated by construction
    ia, ib = names.index("t_a"), names.index("t_b")
    assert M[ia, ib] > 0.4
    groups = partition_treatments(names, M, covsets, max_group=2)
    gmap = {t: i for i, g in enumerate(groups) for t in g}
    assert gmap["t_a"] == gmap["t_b"]


def test_cuboid_rollup_equals_direct_cem():
    table, specs, covsets = _multi_treatment_frame()
    cub = cube.build_cuboid(table, specs, ["t_a", "t_b", "t_c"], "y")
    for tname, dims in covsets.items():
        rolled = cube.rollup(cub, sorted(dims))
        got = estimate_ate(cube.cem_groups_from_cuboid(rolled, tname))
        want = estimate_ate(
            cem(table, tname, "y", {n: specs[n] for n in dims}).groups)
        np.testing.assert_allclose(float(got.ate), float(want.ate), rtol=1e-4)
        assert int(got.n_groups) == int(want.n_groups)
        np.testing.assert_allclose(float(got.n_matched_treated),
                                   float(want.n_matched_treated))


def test_cuboid_compact_preserves_stats():
    table, specs, _ = _multi_treatment_frame()
    cub = cube.build_cuboid(table, specs, ["t_a"], "y")
    small = cube.compact_cuboid(cub)
    assert small.capacity < cub.capacity
    rolled_a = cube.rollup(cub, ["x0", "x1"])
    rolled_b = cube.rollup(small, ["x0", "x1"])
    ga = estimate_ate(cube.cem_groups_from_cuboid(rolled_a, "t_a"))
    gb = estimate_ate(cube.cem_groups_from_cuboid(rolled_b, "t_a"))
    np.testing.assert_allclose(float(ga.ate), float(gb.ate), rtol=1e-5)


def _fk_frame(seed=0, n_dim=300, n_fact=2000):
    rng = np.random.default_rng(seed)
    # dimension: holds treatment + its covariates
    d_x = rng.integers(0, 4, n_dim).astype(np.int32)
    d_t = ((d_x + rng.normal(0, 1, n_dim)) > 2.0).astype(np.int32)
    dim = Table.from_numpy(dict(key=np.arange(n_dim, dtype=np.int32),
                                d_x=d_x, t=d_t),
                           rng.random(n_dim) > 0.05)
    # fact: outcome + extra covariates, FK to dim
    f_key = rng.integers(0, n_dim, n_fact).astype(np.int32)
    f_x = rng.integers(0, 3, n_fact).astype(np.int32)
    y = (2.0 * d_t[f_key] + d_x[f_key] + 0.5 * f_x
         + rng.normal(0, .2, n_fact)).astype(np.float32)
    fact = Table.from_numpy(dict(key=f_key, f_x=f_x, y=y),
                            rng.random(n_fact) > 0.05)
    dim_specs = {"d_x": CoarsenSpec.categorical(4)}
    fact_specs = {"f_x": CoarsenSpec.categorical(3)}
    return dim, fact, dim_specs, fact_specs, n_dim


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_prop2_pushdown_equivalence(seed):
    """CEM(CEM(dim) |><| fact) == CEM(dim |><| fact): same matched units,
    same ATE (Prop. 2)."""
    dim, fact, dim_specs, fact_specs, n_dim = _fk_frame(seed)
    on = {"key": n_dim}
    # direct: integrate first, then CEM on all covariates
    joined = fk_join(fact, dim, on=on)
    all_specs = {**fact_specs, **dim_specs}
    direct = cem(joined, "t", "y", all_specs)
    d_est = estimate_ate(direct.groups)
    # pushdown (without compaction so row alignment is preserved)
    pd = cem_join_pushdown(dim, dim_specs, fact, fact_specs, on=on,
                           treatment="t", outcome="y", do_compact=False)
    p_est = estimate_ate(pd.result.groups)
    np.testing.assert_array_equal(np.asarray(pd.result.table.valid),
                                  np.asarray(direct.table.valid))
    np.testing.assert_allclose(float(p_est.ate), float(d_est.ate), rtol=1e-5)
    # and with compaction: same estimates (row order differs)
    pd2 = cem_join_pushdown(dim, dim_specs, fact, fact_specs, on=on,
                            treatment="t", outcome="y", do_compact=True)
    p2 = estimate_ate(pd2.result.groups)
    np.testing.assert_allclose(float(p2.ate), float(d_est.ate), rtol=1e-5)
    np.testing.assert_allclose(float(p2.n_matched_treated),
                               float(d_est.n_matched_treated))
    assert pd2.dim_rows_after <= pd2.dim_rows_before


def test_prepared_database_answers_online_queries():
    table, specs, covsets = _multi_treatment_frame(n=5000, seed=3)
    db = prepare(table, covsets, specs, outcome="y", query_dims=("x1",))
    for tname in covsets:
        dims = covsets[tname]
        want = estimate_ate(
            cem(table, tname, "y", {n: specs[n] for n in dims}).groups)
        got = db.ate(tname)
        np.testing.assert_allclose(float(got.ate), float(want.ate), rtol=1e-4)
    # sub-population query: restrict to x1 == 0
    sub = db.ate("t_a", subpopulation={"x1": [0]})
    table0 = table.filter(table["x1"] == 0)
    want0 = estimate_ate(
        cem(table0, "t_a", "y",
            {n: specs[n] for n in covsets["t_a"]}).groups)
    np.testing.assert_allclose(float(sub.ate), float(want0.ate), rtol=1e-4)


def test_compact_preserves_estimates():
    table, specs, covsets = _multi_treatment_frame(seed=9)
    small = compact(table, granule=256)
    assert int(small.count()) == int(table.count())
    assert small.nrows - int(small.count()) < 256  # tight padding
    for tname in ("t_a",):
        dims = covsets[tname]
        a = estimate_ate(cem(table, tname, "y",
                             {n: specs[n] for n in dims}).groups)
        b = estimate_ate(cem(small, tname, "y",
                             {n: specs[n] for n in dims}).groups)
        np.testing.assert_allclose(float(a.ate), float(b.ate), rtol=1e-5)

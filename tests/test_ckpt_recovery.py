"""Checkpoint-layer robustness: tmp-dir GC races and async-save retry.

Regressions pinned here:

* a crashed save leaves ``step_<n>.tmp<p>``; the old GC filter
  (``endswith(".tmp")``) missed the process-suffixed form, so the orphan
  leaked forever AND — sorting after ``step_<n>`` — pushed the newest
  GOOD checkpoint out of the keep-last window;
* ``latest_step`` must never report an unpublished tmp dir;
* the next ``save()`` cleans this process's orphans (other processes may
  legitimately be mid-write, so only OUR suffix is touched);
* :class:`AsyncSaver` retries transient ``OSError`` with backoff and
  surfaces retry/failure counts both on the instance and through the
  process-global ``launch.trace`` event counters (the writer thread is
  invisible to the thread-local dispatch accounting).
"""
import os

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.launch import trace


def _state(v=0.0):
    return {"w": np.full((4,), v, np.float32), "b": np.arange(3)}


# ------------------------------------------------------------- GC races
def test_orphan_tmp_dir_does_not_evict_newest_good_step(tmp_path):
    d = str(tmp_path)
    ckpt.save(_state(1.0), 1, d, keep_last=1)
    ckpt.save(_state(2.0), 2, d, keep_last=1)
    # a crashed save for step 3 left its tmp dir behind
    os.makedirs(os.path.join(d, "step_00000003.tmp0"))
    # keep-last GC with the orphan present must keep step 2 (the newest
    # PUBLISHED step), not count the orphan into the window
    ckpt._gc(d, keep_last=1)
    assert os.path.isdir(os.path.join(d, "step_00000002"))
    meta, arrays = ckpt.restore(d)
    assert meta["step"] == 2
    np.testing.assert_array_equal(arrays["w"], _state(2.0)["w"])


def test_latest_step_ignores_tmp_dirs(tmp_path):
    d = str(tmp_path)
    ckpt.save(_state(), 5, d)
    os.makedirs(os.path.join(d, "step_00000009.tmp0"))
    assert ckpt.latest_step(d) == 5


def test_save_cleans_own_orphans_only(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000001.tmp0"))   # ours, stale
    os.makedirs(os.path.join(d, "step_00000001.tmp1"))   # another process
    ckpt.save(_state(), 2, d, process_index=0)
    assert not os.path.exists(os.path.join(d, "step_00000001.tmp0"))
    assert os.path.isdir(os.path.join(d, "step_00000001.tmp1"))
    assert os.path.isdir(os.path.join(d, "step_00000002"))


def test_gc_keeps_last_k_published(tmp_path):
    d = str(tmp_path)
    for s in range(1, 5):
        ckpt.save(_state(float(s)), s, d, keep_last=2)
    kept = sorted(f for f in os.listdir(d) if ckpt._STEP_RE.match(f))
    assert kept == ["step_00000003", "step_00000004"]


# ------------------------------------------------------- async retries
_REAL_SAVE = ckpt.save


class _FlakyFS:
    """Monkeypatchable ``ckpt.save`` stand-in failing the first N calls."""

    def __init__(self, failures, exc=None):
        self.failures = failures
        self.exc = exc if exc is not None else OSError("EIO: injected")
        self.calls = 0

    def __call__(self, state, step, directory, **kw):
        self.calls += 1
        if self.calls <= self.failures:
            raise self.exc
        return _REAL_SAVE(state, step, directory, **kw)


def test_async_saver_retries_transient_oserror(tmp_path, monkeypatch):
    flaky = _FlakyFS(failures=2)
    monkeypatch.setattr(ckpt, "save", flaky)
    base = trace.event_count("ckpt_save_retry")
    saver = ckpt.AsyncSaver(max_retries=3, backoff=0.001)
    saver.save(_state(7.0), 1, str(tmp_path))
    saver.wait()                                # must NOT raise
    assert flaky.calls == 3
    assert saver.n_retries == 2 and saver.n_failures == 0
    assert trace.event_count("ckpt_save_retry") - base == 2
    meta, arrays = ckpt.restore(str(tmp_path))
    np.testing.assert_array_equal(arrays["w"], _state(7.0)["w"])
    # a successful retry must not leave a stale error for the next wait()
    saver.save(_state(8.0), 2, str(tmp_path))
    saver.wait()


def test_async_saver_terminal_failure_counts_and_raises(tmp_path,
                                                        monkeypatch):
    flaky = _FlakyFS(failures=99)
    monkeypatch.setattr(ckpt, "save", flaky)
    base = trace.event_count("ckpt_save_failure")
    saver = ckpt.AsyncSaver(max_retries=2, backoff=0.001)
    saver.save(_state(), 1, str(tmp_path))
    with pytest.raises(OSError, match="injected"):
        saver.wait()
    assert flaky.calls == 3                     # 1 attempt + 2 retries
    assert saver.n_retries == 2 and saver.n_failures == 1
    assert trace.event_count("ckpt_save_failure") - base == 1
    # the failure is surfaced ONCE; the saver is reusable afterwards
    monkeypatch.setattr(ckpt, "save", _FlakyFS(failures=0))
    saver.save(_state(3.0), 2, str(tmp_path))
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_async_saver_non_oserror_is_not_retried(tmp_path, monkeypatch):
    flaky = _FlakyFS(failures=99, exc=RuntimeError("logic bug"))
    monkeypatch.setattr(ckpt, "save", flaky)
    saver = ckpt.AsyncSaver(max_retries=3, backoff=0.001)
    saver.save(_state(), 1, str(tmp_path))
    with pytest.raises(RuntimeError, match="logic bug"):
        saver.wait()
    assert flaky.calls == 1                     # no retry for non-OSError
    assert saver.n_failures == 1

"""Per-kernel interpret-mode validation: Pallas vs pure-jnp/numpy oracles,
swept over shapes, dtypes and block sizes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.coarsen import CoarsenSpec
from repro.core.keys import KeyCodec
from repro.core import cem, estimate_ate
from repro.core import oracle
from repro.kernels import (cem_keys_op, knn_topk_op,
                           logistic_newton_terms_op, segment_sums_op)
from repro.kernels import ref
from repro.kernels.ops import local_seg_ids


# ---------------------------------------------------------------- cem_keys
@pytest.mark.parametrize("n,d,block", [(512, 3, 128), (1000, 5, 512),
                                       (64, 1, 64), (4096, 8, 512)])
def test_cem_keys_matches_codec(n, d, block):
    rng = np.random.default_rng(n + d)
    X = rng.normal(0, 3, (n, d)).astype(np.float32)
    valid = rng.random(n) > 0.2
    specs = {}
    cutlists = []
    for j in range(d):
        k = int(rng.integers(1, 6))
        cuts = sorted(rng.normal(0, 2, k).tolist())
        specs[f"c{j}"] = CoarsenSpec.from_cutpoints(cuts)
        cutlists.append(cuts)
    # engine path: coarsen + codec pack (sorted field order = c0..c9 asc)
    codec = KeyCodec.from_cardinalities(
        {f"c{j}": specs[f"c{j}"].n_buckets for j in range(d)})
    from repro.core.coarsen import coarsen
    buckets = {f"c{j}": coarsen(jnp.asarray(X[:, j]), specs[f"c{j}"])
               for j in range(d)}
    want_hi, want_lo = codec.pack(buckets, jnp.asarray(valid))
    widths = [codec.widths[f"c{j}"] for j in range(d)]
    got_hi, got_lo = cem_keys_op(jnp.asarray(X), cutlists, widths,
                                 jnp.asarray(valid), block=block)
    np.testing.assert_array_equal(np.asarray(got_hi), np.asarray(want_hi))
    np.testing.assert_array_equal(np.asarray(got_lo), np.asarray(want_lo))
    # and against the standalone jnp ref
    cmax = max(len(c) for c in cutlists)
    cp = np.full((d, cmax), np.inf, np.float32)
    for j, c in enumerate(cutlists):
        cp[j, :len(c)] = c
    rh, rl = ref.cem_keys_ref(jnp.asarray(X), jnp.asarray(cp),
                              [len(c) for c in cutlists], widths,
                              jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(got_hi), np.asarray(rh))
    np.testing.assert_array_equal(np.asarray(got_lo), np.asarray(rl))


# ----------------------------------------------------------- segment_stats
@pytest.mark.parametrize("n,s,block", [(512, 4, 128), (2048, 7, 256),
                                       (300, 2, 128), (1024, 1, 512)])
def test_segment_sums_matches_segment_sum(n, s, block):
    rng = np.random.default_rng(n + s)
    # sorted segment ids with random run lengths
    n_segs = max(2, n // 7)
    seg = np.sort(rng.integers(0, n_segs, n)).astype(np.int32)
    vals = rng.normal(0, 1, (n, s)).astype(np.float32)
    got = segment_sums_op(jnp.asarray(vals), jnp.asarray(seg), n_segs,
                          block=block)
    want = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(seg),
                               num_segments=n_segs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_segment_partials_ref_identity():
    """The partials oracle itself reduces to segment_sum after combine."""
    rng = np.random.default_rng(0)
    n, s, block = 512, 3, 128
    seg = np.sort(rng.integers(0, 40, n)).astype(np.int32)
    vals = rng.normal(0, 1, (n, s)).astype(np.float32)
    local = np.asarray(local_seg_ids(jnp.asarray(seg), block))
    assert local.min() >= 0 and local.max() < block
    partials = ref.segment_partials_ref(jnp.asarray(vals),
                                        jnp.asarray(local), block)
    from repro.kernels.segment_stats import combine_partials
    base = jnp.asarray(seg.reshape(-1, block)[:, 0])
    got = combine_partials(jnp.asarray(partials), base, 40)
    want = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(seg),
                               num_segments=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------- knn_topk
@pytest.mark.parametrize("nq,nc,d,k,bq,bc", [
    (128, 256, 2, 1, 64, 128), (200, 333, 4, 3, 128, 128),
    (64, 64, 1, 5, 64, 64), (256, 1024, 8, 2, 128, 512)])
def test_knn_topk_matches_oracle(nq, nc, d, k, bq, bc):
    rng = np.random.default_rng(nq + nc + d + k)
    Q = rng.normal(0, 1, (nq, d)).astype(np.float32)
    C = rng.normal(0, 1, (nc, d)).astype(np.float32)
    cv = rng.random(nc) > 0.2
    dist, idx = knn_topk_op(jnp.asarray(Q), jnp.asarray(C), jnp.asarray(cv),
                            k, block_q=bq, block_c=bc)
    wd, wi = oracle.knn_oracle(Q, C, cv, k, caliper=np.inf)
    got = np.asarray(dist)
    ok = np.isfinite(wd)
    np.testing.assert_allclose(got[ok], wd[ok], rtol=1e-3, atol=3e-3)
    assert np.all(got[~ok] >= 1e30)
    # exact distance set agreement on clear-margin rows
    clear = ok & (np.abs(got - wd) < 1e-4)
    agree = np.asarray(idx)[clear] == wi[clear]
    assert agree.mean() > 0.98


def test_knn_topk_matches_jnp_ref():
    rng = np.random.default_rng(7)
    Q = rng.normal(0, 1, (128, 3)).astype(np.float32)
    C = rng.normal(0, 1, (256, 3)).astype(np.float32)
    cv = np.ones(256, bool)
    d2, idx = knn_topk_op(jnp.asarray(Q), jnp.asarray(C), jnp.asarray(cv),
                          k=4)
    rd, ri = ref.knn_topk_ref(jnp.asarray(Q), jnp.asarray(C),
                              jnp.asarray(cv), k=4)
    np.testing.assert_allclose(np.asarray(d2) ** 2, np.asarray(rd),
                               rtol=1e-3, atol=3e-3)


# ----------------------------------------------------------- logistic_grad
@pytest.mark.parametrize("n,d,block", [(1024, 4, 256), (3000, 9, 1024),
                                       (256, 2, 128)])
def test_logistic_newton_terms(n, d, block):
    rng = np.random.default_rng(n + d)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    t = (rng.random(n) < 0.4).astype(np.float32)
    m = (rng.random(n) > 0.1).astype(np.float32)
    w = rng.normal(0, 0.5, d).astype(np.float32)
    g, H = logistic_newton_terms_op(jnp.asarray(X), jnp.asarray(t),
                                    jnp.asarray(m), jnp.asarray(w),
                                    block=block)
    rg, rH = ref.logistic_newton_terms_ref(jnp.asarray(X), jnp.asarray(t),
                                           jnp.asarray(m), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=2e-4,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(H), np.asarray(rH), rtol=2e-4,
                               atol=2e-3)


# --------------------------------------------- kernels wired into the engine
def test_kernel_backed_cem_equals_engine():
    """End-to-end: CEM computed with kernel front-end (cem_keys_op +
    segment_sums_op) gives the same matched set as the jnp engine."""
    rng = np.random.default_rng(42)
    n = 2000
    x0 = rng.normal(0, 2, n).astype(np.float32)
    x1 = rng.normal(0, 2, n).astype(np.float32)
    t = (rng.random(n) < 0.3).astype(np.int32)
    y = rng.normal(0, 1, n).astype(np.float32)
    valid = rng.random(n) > 0.1
    from repro.data.columnar import Table
    table = Table.from_numpy(dict(x0=x0, x1=x1, t=t, y=y), valid)
    cuts = [[-2.0, 0.0, 2.0], [-1.0, 1.0]]
    specs = {"x0": CoarsenSpec.from_cutpoints(cuts[0]),
             "x1": CoarsenSpec.from_cutpoints(cuts[1])}
    engine = cem(table, "t", "y", specs)

    codec = KeyCodec.from_cardinalities(
        {k: s.n_buckets for k, s in specs.items()})
    X = np.stack([x0, x1], axis=1)
    widths = [codec.widths["x0"], codec.widths["x1"]]
    hi, lo = cem_keys_op(jnp.asarray(X), cuts, widths, jnp.asarray(valid))
    from repro.core.cem import cem_from_keys
    matched, _, groups = cem_from_keys(hi, lo, table["t"], table["y"],
                                       table.valid)
    np.testing.assert_array_equal(np.asarray(matched),
                                  np.asarray(engine.table.valid))
    a = estimate_ate(groups)
    b = estimate_ate(engine.groups)
    np.testing.assert_allclose(float(a.ate), float(b.ate), rtol=1e-5)


def test_chunk_sums_pallas_matches_chunked_sum():
    # the MXU/VPU chunk-partials kernel of the canonical query reduction
    # must agree with the pure-jnp bit-exactness reference
    from repro.kernels.segment_stats import chunk_sums_pallas, chunked_sum
    rng = np.random.default_rng(3)
    n, s, block = 2048, 4, 256
    vals = rng.normal(0, 1, (n, s)).astype(np.float32)
    partials = np.asarray(chunk_sums_pallas(jnp.asarray(vals), block=block))
    assert partials.shape == (n // block, s)
    for j in range(s):
        want = float(chunked_sum(jnp.asarray(vals[:, j]), block=block))
        got = float(np.sum(partials[:, j].astype(np.float64)))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # per-chunk partials match plain chunk sums exactly in f32
    ref = vals.reshape(n // block, block, s).sum(axis=1)
    np.testing.assert_allclose(partials, ref, rtol=1e-6, atol=1e-6)

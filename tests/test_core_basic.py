"""Unit tests: Table, coarsening, key codec, group-by engine."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CoarsenSpec, KeyCodec, coarsen, groupby
from repro.core import oracle
from repro.data.columnar import Table, concat


def test_table_filter_and_count():
    t = Table.from_dict({"a": jnp.arange(10), "b": jnp.ones(10)})
    assert t.nrows == 10
    t2 = t.filter(t["a"] < 5)
    assert int(t2.count()) == 5
    t3 = t2.filter(t2["a"] >= 3)  # masks AND together
    assert int(t3.count()) == 2
    np.testing.assert_allclose(float(t3.mean("a")), 3.5)


def test_table_concat_and_numpy_roundtrip():
    t1 = Table.from_numpy({"a": np.arange(3)})
    t2 = Table.from_numpy({"a": np.arange(3, 6)})
    t = concat([t1, t2])
    out = t.to_numpy(compact=True)
    np.testing.assert_array_equal(out["a"], np.arange(6))


def test_coarsen_matches_oracle():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 10, 257).astype(np.float32)
    cp = [-5.0, 0.0, 2.5, 9.0]
    spec = CoarsenSpec.from_cutpoints(cp)
    got = np.asarray(coarsen(jnp.asarray(x), spec))
    want = oracle.coarsen_oracle(x, cp)
    np.testing.assert_array_equal(got, want)
    assert spec.n_buckets == 5


def test_coarsen_equal_width_and_quantile():
    spec = CoarsenSpec.equal_width(0.0, 10.0, 5)
    assert spec.n_buckets == 5
    assert np.allclose(spec.cutpoints, [2, 4, 6, 8])
    rng = np.random.default_rng(1)
    x = rng.normal(size=1000)
    q = CoarsenSpec.quantile(x, 4)
    b = np.asarray(coarsen(jnp.asarray(x), q))
    counts = np.bincount(b, minlength=4)
    assert counts.min() > 200  # roughly equal mass


def test_keycodec_roundtrip():
    codec = KeyCodec.from_cardinalities({"a": 7, "b": 300, "c": 2, "d": 100000})
    rng = np.random.default_rng(2)
    n = 500
    vals = {"a": rng.integers(0, 7, n), "b": rng.integers(0, 300, n),
            "c": rng.integers(0, 2, n), "d": rng.integers(0, 100000, n)}
    valid = rng.random(n) > 0.1
    hi, lo = codec.pack({k: jnp.asarray(v) for k, v in vals.items()},
                        jnp.asarray(valid))
    for name in vals:
        got = np.asarray(codec.extract(hi, lo, name))
        np.testing.assert_array_equal(got[valid], vals[name][valid])
    # invalid rows carry the all-ones marker
    assert np.all(np.asarray(hi)[~valid] == 0xFFFFFFFF)
    assert np.all(np.asarray(lo)[~valid] == 0xFFFFFFFF)


def test_keycodec_distinct_keys_distinct_tuples():
    codec = KeyCodec.from_cardinalities({"x": 5, "y": 11})
    xs, ys = np.meshgrid(np.arange(5), np.arange(11))
    hi, lo = codec.pack({"x": jnp.asarray(xs.ravel()),
                         "y": jnp.asarray(ys.ravel())},
                        jnp.ones(55, bool))
    keys = set(zip(np.asarray(hi).tolist(), np.asarray(lo).tolist()))
    assert len(keys) == 55


def test_keycodec_rejects_wide_keys():
    with pytest.raises(ValueError):
        KeyCodec.from_cardinalities({"a": 2 ** 32, "b": 2 ** 32})


def test_keycodec_rollup():
    codec = KeyCodec.from_cardinalities({"a": 4, "b": 8, "c": 16})
    rng = np.random.default_rng(3)
    n = 200
    vals = {k: rng.integers(0, c, n) for k, c in
            (("a", 4), ("b", 8), ("c", 16))}
    valid = np.ones(n, bool)
    hi, lo = codec.pack({k: jnp.asarray(v) for k, v in vals.items()},
                        jnp.asarray(valid))
    sub, shi, slo = codec.rollup(hi, lo, ["a", "c"], jnp.asarray(valid))
    want_hi, want_lo = sub.pack({"a": jnp.asarray(vals["a"]),
                                 "c": jnp.asarray(vals["c"])},
                                jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(shi), np.asarray(want_hi))
    np.testing.assert_array_equal(np.asarray(slo), np.asarray(want_lo))


def test_group_by_key_counts():
    codec = KeyCodec.from_cardinalities({"g": 10})
    rng = np.random.default_rng(4)
    g_vals = rng.integers(0, 10, 300)
    valid = rng.random(300) > 0.2
    hi, lo = codec.pack({"g": jnp.asarray(g_vals)}, jnp.asarray(valid))
    g = groupby.group_by_key(hi, lo)
    n_distinct = len(set(g_vals[valid].tolist()))
    assert int(g.n_groups) == n_distinct
    # per-group counts match numpy
    sums = groupby.segment_sums(g, {"one": jnp.asarray(valid, jnp.float32)})
    counts = np.asarray(sums["one"])
    want = np.bincount(g_vals[valid], minlength=10)
    got = sorted(c for c in counts[:int(g.n_groups) + 1].tolist() if c > 0)
    assert got == sorted(c for c in want.tolist() if c > 0)


def test_group_minmax_and_broadcast():
    codec = KeyCodec.from_cardinalities({"g": 4})
    g_vals = np.array([0, 0, 1, 1, 2, 3, 3, 3])
    t = np.array([0, 1, 1, 1, 0, 0, 0, 1])
    hi, lo = codec.pack({"g": jnp.asarray(g_vals)}, jnp.ones(8, bool))
    g = groupby.group_by_key(hi, lo)
    mn, mx = groupby.group_minmax(g, jnp.asarray(t))
    per_row_min = np.asarray(groupby.broadcast_to_rows(g, mn))
    per_row_max = np.asarray(groupby.broadcast_to_rows(g, mx))
    want_min = np.array([0, 0, 1, 1, 0, 0, 0, 0])
    want_max = np.array([1, 1, 1, 1, 0, 1, 1, 1])
    np.testing.assert_array_equal(per_row_min, want_min)
    np.testing.assert_array_equal(per_row_max, want_max)


def test_lookup_rows_in_table():
    codec = KeyCodec.from_cardinalities({"g": 50})
    table_keys = np.arange(0, 50, 2)  # even keys present
    thi, tlo = codec.pack({"g": jnp.asarray(table_keys)},
                          jnp.ones(25, bool))
    # table from group_by_key is sorted already; these are sorted by design
    query = np.array([0, 1, 2, 3, 48, 49, 24])
    qhi, qlo = codec.pack({"g": jnp.asarray(query)}, jnp.ones(7, bool))
    pos, found = groupby.lookup_rows_in_table(qhi, qlo, thi, tlo)
    np.testing.assert_array_equal(np.asarray(found),
                                  [True, False, True, False, True, False, True])
    np.testing.assert_array_equal(np.asarray(pos)[np.asarray(found)],
                                  [0, 1, 24, 12])

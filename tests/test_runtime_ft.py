"""Fault tolerance, checkpointing, elasticity, stragglers, optimizers."""
import os
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import AsyncSaver, latest_step, restore, save
from repro.optim import AdamWConfig, adamw, grad_compress, quantized
from repro.runtime import (HeartbeatMonitor, StepTimeMonitor, Supervisor,
                           plan_elastic_mesh, shrink_after_failure)


# ------------------------------- checkpoint --------------------------------
def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (16, 8)),
              "b": jnp.zeros((8,)),
              "nested": {"e": jax.random.normal(k, (4, 4),
                                                dtype=jnp.float32)}}
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}


def test_checkpoint_roundtrip_bitexact(tmp_path):
    state = _state()
    save(state, 7, str(tmp_path))
    assert latest_step(str(tmp_path)) == 7
    _, restored = restore(str(tmp_path), template=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_gc(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        save(state, s, str(tmp_path), keep_last=2)
    steps = sorted(os.listdir(str(tmp_path)))
    assert steps == ["step_00000004", "step_00000005"]


def test_checkpoint_corruption_detected(tmp_path):
    state = _state()
    path = save(state, 1, str(tmp_path))
    # flip bytes in the shard
    shard = os.path.join(path, "shard_0.npz")
    shard_path = pathlib.Path(shard)
    data = bytearray(shard_path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    shard_path.write_bytes(bytes(data))
    # the corruption failure mode is format-dependent (zlib/zip/npz
    # layer), so any raise is the contract here
    with pytest.raises(Exception):  # noqa: B017
        restore(str(tmp_path), template=state)


def test_async_saver(tmp_path):
    state = _state()
    saver = AsyncSaver()
    saver.save(state, 3, str(tmp_path))
    saver.wait()
    assert latest_step(str(tmp_path)) == 3
    _, restored = restore(str(tmp_path), template=state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


# ---------------------------- supervisor resume -----------------------------
def _toy_step():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def step_fn(state, batch):
        def loss(p):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)
        l, g = jax.value_and_grad(loss)(state["params"])
        new_p, new_opt, _ = adamw.update(g, state["opt"], state["params"],
                                         cfg)
        return ({"params": new_p, "opt": new_opt,
                 "step": state["step"] + 1}, {"loss": l})
    return jax.jit(step_fn)


def _batches():
    def batch_for_step(i):
        k = jax.random.PRNGKey(i)
        x = jax.random.normal(k, (8, 4))
        return {"x": x, "y": x @ jnp.ones((4, 2))}
    return batch_for_step


def test_supervisor_bitexact_resume(tmp_path):
    """A crash + restore must reproduce the exact no-crash trajectory."""
    params = {"w": jnp.zeros((4, 2))}
    mk = lambda: {"params": params, "opt": adamw.init(params),
                  "step": jnp.zeros((), jnp.int32)}
    step_fn = _toy_step()
    batches = _batches()

    sup_a = Supervisor(step_fn, str(tmp_path / "a"), ckpt_every=5)
    state_a, _ = sup_a.run(mk(), batches, n_steps=20)

    crashed = {17}
    sup_b = Supervisor(step_fn, str(tmp_path / "b"), ckpt_every=5)
    state_b, _ = sup_b.run(mk(), batches, n_steps=20,
                           fail_at=lambda s: s in crashed and not
                           crashed.discard(s))
    assert sup_b.restarts == 1
    np.testing.assert_array_equal(np.asarray(state_a["params"]["w"]),
                                  np.asarray(state_b["params"]["w"]))


# ------------------------------- heartbeats --------------------------------
def test_heartbeat_detects_dead_host(tmp_path):
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=10.0, clock=lambda: t[0])
    for h in range(4):
        mon.beat(h, step=1)
    t[0] = 5.0
    for h in (0, 1, 3):
        mon.beat(h, step=2)
    t[0] = 14.0
    assert mon.dead_hosts() == [2]
    plan = mon.plan(ckpt_dir=None, min_hosts=2)
    assert plan.action == "elastic_restart"
    assert plan.survivor_hosts == [0, 1, 3]


# -------------------------------- stragglers -------------------------------
def test_straggler_flag_and_rebalance():
    mon = StepTimeMonitor(4)
    for _ in range(10):
        mon.record({0: 1.0, 1: 1.05, 2: 2.4, 3: 0.95})
    assert mon.stragglers() == [2]
    w = mon.shard_weights()
    assert w[2] < w[0]
    assert abs(w.mean() - 1.0) < 1e-9
    # 10x slow host -> eviction candidate
    mon2 = StepTimeMonitor(4)
    for _ in range(10):
        mon2.record({0: 1.0, 1: 1.0, 2: 10.0, 3: 1.0})
    assert mon2.evictions() == [2]


# --------------------------------- elastic ---------------------------------
def test_elastic_plan_shrinks_data_axis():
    plan = plan_elastic_mesh(512, model_parallel=16, pods=2)
    assert plan.shape == (2, 16, 16)
    smaller = shrink_after_failure(plan, n_dead=40)
    assert smaller.shape[-1] == 16          # model degree preserved
    assert smaller.n_devices <= 512 - 40
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16)


# ------------------------------ int8 optimizer -----------------------------
def test_int8_adam_tracks_f32_adam():
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (64, 32)) * 0.1}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    s32 = adamw.init(params)
    s8 = quantized.init(params)
    p32 = p8 = params
    for i in range(10):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (64, 32)) * 0.01}
        p32, s32, _ = adamw.update(g, s32, p32, cfg)
        p8, s8, _ = quantized.update(g, s8, p8, cfg)
    diff = float(jnp.linalg.norm(p32["w"] - p8["w"])
                 / jnp.linalg.norm(p32["w"]))
    assert diff < 0.05  # int8 states track f32 closely


def test_int8_state_memory_is_small():
    params = {"w": jnp.zeros((1024, 1024))}
    s8 = quantized.init(params)
    q_bytes = sum(a.size * a.dtype.itemsize
                  for a in jax.tree.leaves(s8["m"])) + \
        sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(s8["v"]))
    f32_bytes = 2 * 1024 * 1024 * 4
    assert q_bytes < 0.40 * f32_bytes


# ----------------------------- grad compression ----------------------------
def test_grad_compress_roundtrip_error_small():
    k = jax.random.PRNGKey(1)
    g = jax.random.normal(k, (1000, 37)) * 0.02
    err = float(grad_compress.roundtrip_error(g))
    assert err < 0.01

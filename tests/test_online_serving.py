"""Multi-tenant batched query serving: one dispatch for MANY queries.

Contracts under test:

  * ONE DISPATCH PER BATCH — ``ate_batch`` answers B heterogeneous
    uncached specs (mixed treatments/views, subpopulations, estimands)
    with exactly ONE compiled launch of the ``"query"`` family, on both
    engines; results are BITWISE identical to B sequential uncached
    ``ate()`` calls.
  * NO RETRACE INSIDE A POW2 BUCKET — the batched program is cached on
    shapes only (spec predicates are data); any B inside one pow2 bucket
    reuses the single trace.
  * IN-FLIGHT DEDUPE — identical specs inside one batch window collapse
    to one slot (the duplicate-dashboard regression), and cache hits
    never occupy a slot (zero dispatches when everything is cached).
  * SERVING LAYER — ``ServingEngine`` waves respect the slot budget,
    estimand selection matches the full estimate bitwise, and a committed
    ingest invalidates exactly the touched cache entries so the next wave
    re-dispatches instead of serving stale answers.
  * MESH — the partitioned engine on a forced multi-device mesh answers
    batched queries bit-identically to the single-device replicated
    engine, still one dispatch per batch.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
from repro.core import fused as fused_mod
from repro.core.online import _bucket_specs
from repro.core.serving import QuerySpec, ServingEngine, run_poisson_load
from repro.data.columnar import Table
from repro.launch.trace import batched_served, count_dispatches

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}
EST_FIELDS = ("ate", "att", "variance", "n_matched_treated",
              "n_matched_control", "n_groups")

MIXED_SPECS = [
    ("ta", None), ("tb", None),
    ("ta", {"x2": [0]}), ("tb", {"x2": [1, 2]}),
    ("ta", {"x0": [0, 1], "x2": [0, 2]}), ("tb", {"x0": [2], "x2": [0]}),
    ("ta", {"x1": [3]}), ("tb", {"x0": [0, 1, 2, 3]}),
]


def _frame(n, seed=0):
    rng = np.random.default_rng(seed)
    cols = {"x0": rng.integers(0, 5, n).astype(np.int32),
            "x1": rng.integers(0, 4, n).astype(np.int32),
            "x2": rng.integers(0, 3, n).astype(np.int32)}
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    cols["y"] = np.round(2.0 * cols["ta"] + 1.5 * cols["x0"]
                         + rng.normal(0, 0.5, n)).astype(np.float32)
    return cols, rng.random(n) > 0.08


def _engines():
    kw = dict(query_dims=("x0", "x1", "x2"))
    return {
        "replicated": OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                   **kw),
        "partitioned": PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                               granule=64, n_parts=3, **kw),
    }


def _feed(engines, n_batches=3, size=500, seed0=10):
    for i in range(n_batches):
        cols, valid = _frame(size, seed=seed0 + i)
        b = Table.from_numpy(cols, valid)
        for eng in engines.values():
            eng.ingest(b)


def _assert_bitwise(got, want, ctx):
    for f in EST_FIELDS:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert g.tobytes() == w.tobytes(), (ctx, f, g, w)


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_batched_queries_one_dispatch_bitwise_vs_sequential(label):
    engines = _engines()
    _feed(engines)
    eng = engines[label]
    eng.ate_batch(MIXED_SPECS)                  # warm the trace
    eng._cache.clear()
    served0 = batched_served("query")
    with count_dispatches(label="query") as n:
        batch = eng.ate_batch(MIXED_SPECS)
    assert n() == 1, (label, n())
    assert batched_served("query") - served0 == len(MIXED_SPECS)
    eng._cache.clear()
    for got, (t, sub) in zip(batch, MIXED_SPECS):
        with count_dispatches(label="query") as n1:
            want = eng.ate(t, subpopulation=sub)
        assert n1() == 1
        _assert_bitwise(got, want, (label, t, sub))


def test_changing_batch_size_within_pow2_bucket_does_not_retrace():
    engines = _engines()
    _feed(engines)
    eng = engines["replicated"]
    eng.ate_batch(MIXED_SPECS[:5])              # bucket 8
    prog = fused_mod.get_fused_query_batch(
        eng._batch_view_schema(), eng._spec_cards(), 8,
        *eng._batch_query_flags())
    assert _bucket_specs(5) == _bucket_specs(8) == 8
    assert prog._cache_size() == 1
    for b in (6, 7, 8):
        eng._cache.clear()
        with count_dispatches(label="query") as n:
            eng.ate_batch(MIXED_SPECS[:b])
        assert n() == 1, b
    assert prog._cache_size() == 1, "retraced inside a pow2 bucket"


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_duplicate_inflight_specs_collapse_to_one_slot(label):
    engines = _engines()
    _feed(engines)
    eng = engines[label]
    dup = [("ta", {"x2": [0]})] * 6 + [("tb", None)] * 2
    eng.ate_batch(dup)                          # warm
    eng._cache.clear()
    deduped0 = eng.batch_deduped
    served0 = batched_served("query")
    with count_dispatches(label="query") as n:
        out = eng.ate_batch(dup)
    # one dispatch, and only the two UNIQUE specs occupied slots
    assert n() == 1, (label, n())
    assert eng.batch_deduped - deduped0 == 6
    assert batched_served("query") - served0 == 2
    for a in out[1:6]:
        _assert_bitwise(a, out[0], label)


def test_cache_hits_never_occupy_a_slot():
    engines = _engines()
    _feed(engines)
    eng = engines["replicated"]
    eng.ate("ta")
    eng.ate("tb", subpopulation={"x2": [0]})
    with count_dispatches(label="query") as n:
        out = eng.ate_batch([("ta", None), ("tb", {"x2": [0]})])
    assert n() == 0, "fully cached batch still dispatched"
    _assert_bitwise(out[0], eng.ate("ta"), "cached")
    # a mixed batch dispatches once, sized by the MISSES only
    eng.ate_batch([("ta", {"x1": [0]})])        # warm bucket-1 trace
    eng._cache.pop(("ta", (("x1", (0,)),)))
    served0 = batched_served("query")
    with count_dispatches(label="query") as n:
        eng.ate_batch([("ta", None), ("ta", {"x1": [0]}), ("tb", {"x2": [0]})])
    assert n() == 1
    assert batched_served("query") - served0 == 1


def test_estimand_is_part_of_the_spec():
    engines = _engines()
    _feed(engines)
    eng = engines["replicated"]
    ref = eng.ate("ta", subpopulation={"x2": [0]})
    got = eng.ate_batch([QuerySpec("ta", {"x2": [0]}, "ate"),
                         QuerySpec("ta", {"x2": [0]}, "att")])
    spec_att = QuerySpec("ta", {"x2": [0]}, "att")
    assert np.asarray(spec_att.select(got[1])).tobytes() \
        == np.asarray(ref.att).tobytes()
    with pytest.raises(ValueError):
        QuerySpec("ta", None, "median")


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_serving_engine_waves_counters_and_invalidation(label):
    engines = _engines()
    _feed(engines)
    eng = engines[label]
    srv = ServingEngine(eng, n_slots=3)
    qs = ([QuerySpec(t, sub) for t, sub in MIXED_SPECS]
          + [QuerySpec("ta", None), QuerySpec("ta", {"x2": [0]}, "att")])
    for q in qs:
        eng.ate(q.treatment, q.subpopulation)   # warm every trace/ref
    refs = {q: eng.ate(q.treatment, q.subpopulation) for q in qs}
    eng._cache.clear()
    res = srv.serve(qs)
    # 8 unique (treatment, subpop) keys over 3 slots -> 3 waves; the
    # duplicate unrestricted-ta spec and the att twin collapsed in flight
    assert srv.n_waves == 3 and srv.n_slots_used == 8
    assert srv.n_deduped == 2 and srv.n_cache_served == 0
    assert srv.n_served == len(qs)
    for q, r in zip(qs, res):
        _assert_bitwise(r.estimate, refs[q], (label, q))
        want = refs[q].ate if q.estimand == "ate" else refs[q].att
        assert np.asarray(r.value).tobytes() == np.asarray(want).tobytes()
        assert not r.cached
    # repeat: everything cached, zero dispatches, no slots used
    with count_dispatches(label="query") as n:
        res2 = srv.serve(qs)
    assert n() == 0 and all(r.cached for r in res2)
    # a committed ingest invalidates the touched entries: the next wave
    # re-dispatches instead of serving stale cache
    cols, valid = _frame(300, seed=99)
    eng.ingest(Table.from_numpy(cols, valid))
    with count_dispatches(label="query") as n:
        res3 = srv.serve([QuerySpec("ta", None)])
    assert n() == 1 and not res3[0].cached
    eng._cache.clear()
    _assert_bitwise(res3[0].estimate, eng.ate("ta"), label)


def test_deadline_expired_queries_drop_slot_free():
    """A query whose deadline passed before wave assembly is dropped with
    ``n_expired`` bumped — it never occupies a slot, never dispatches,
    and never appears in a result; live queries in the same wave are
    unaffected. Deadlines are judged against the injectable clock AT wave
    assembly, so a query that expires while queued behind a full wave is
    dropped by the LATER wave that would have admitted it."""
    engines = _engines()
    _feed(engines, n_batches=2)
    eng = engines["replicated"]
    now = {"t": 100.0}
    srv = ServingEngine(eng, n_slots=2, clock=lambda: now["t"])
    eng.ate("ta")                               # warm the trace
    eng._cache.clear()
    live = srv.submit(QuerySpec("ta"), deadline=200.0)
    dead = srv.submit(QuerySpec("tb"), deadline=99.0)   # already expired
    forever = srv.submit(QuerySpec("ta", {"x2": [0]}))  # no deadline
    with count_dispatches(label="query") as n:
        out = {}
        while srv.pending():
            out.update(srv.step())
    assert n() == 1                             # one wave, 2 live slots
    assert srv.n_waves == 1 and srv.n_slots_used == 2
    assert srv.n_expired == 1
    assert set(out) == {live, forever} and dead not in out
    assert srv.n_served == 2
    # expiry-while-queued: 3 unique specs on 1 slot; the clock jumps past
    # the last query's deadline while it waits behind the first waves
    srv2 = ServingEngine(eng, n_slots=1, clock=lambda: now["t"])
    eng._cache.clear()
    a = srv2.submit(QuerySpec("ta"))
    b = srv2.submit(QuerySpec("tb"))
    c = srv2.submit(QuerySpec("ta", {"x2": [0]}), deadline=150.0)
    out = dict(srv2.step())                     # serves a; b, c requeued
    assert set(out) == {a}
    now["t"] = 151.0                            # c expires in the queue
    while srv2.pending():
        out.update(srv2.step())
    assert set(out) == {a, b} and c not in out
    assert srv2.n_expired == 1
    # an expired CACHE HIT is also dropped: the caller stopped waiting
    srv3 = ServingEngine(eng, clock=lambda: now["t"])
    eng.ate("ta")                               # populate the cache
    gone = srv3.submit(QuerySpec("ta"), deadline=now["t"] - 1.0)
    assert srv3.step() == {} and srv3.n_expired == 1
    assert srv3.n_cache_served == 0 and gone is not None


def test_poisson_load_serves_everything():
    engines = _engines()
    _feed(engines, n_batches=2)
    eng = engines["replicated"]
    srv = ServingEngine(eng, n_slots=8)
    qs = [QuerySpec("ta", {"x0": [i % 5]}) for i in range(24)]
    lat = run_poisson_load(srv, qs, rate_qps=500.0, seed=1)
    assert srv.n_served == len(qs) and srv.pending() == 0
    assert (lat > 0).all()


def test_subpop_dim_not_in_view_raises():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       query_dims=("x2",))
    cols, valid = _frame(200, seed=3)
    eng.ingest(Table.from_numpy(cols, valid))
    with pytest.raises(ValueError, match="not materialized"):
        eng.ate_batch([("ta", {"x2": [0]}), ("tb", {"x1": [0]})])


# ----------------------------- mesh (subprocess, forced host devices) -------
def _run_subprocess(body: str):
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_mesh_batched_queries_one_dispatch_bit_identical():
    out = _run_subprocess("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    assert jax.device_count() == 4
    from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
    from repro.core.serving import QuerySpec, ServingEngine
    from repro.data.columnar import Table
    from repro.launch.mesh import make_data_mesh
    from repro.launch.trace import count_dispatches

    SPECS = {"x0": CoarsenSpec.categorical(5),
             "x1": CoarsenSpec.categorical(4),
             "x2": CoarsenSpec.categorical(3)}
    TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}

    def frame(n, seed):
        rng = np.random.default_rng(seed)
        cols = {"x0": rng.integers(0, 5, n).astype(np.int32),
                "x1": rng.integers(0, 4, n).astype(np.int32),
                "x2": rng.integers(0, 3, n).astype(np.int32)}
        cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4
                      ).astype(np.int32)
        cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
        cols["y"] = np.round(2.0 * cols["ta"] + 1.5 * cols["x0"]
                             + rng.normal(0, 0.5, n)).astype(np.float32)
        return cols, rng.random(n) > 0.08

    kw = dict(query_dims=("x0", "x1", "x2"))
    mesh = make_data_mesh(4)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, **kw)
    eng = PartitionedOnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                  mesh=mesh, n_parts=8, **kw)
    for i in range(3):
        cols, valid = frame(1000, seed=i)
        b = Table.from_numpy(cols, valid)
        ref.ingest(b)
        eng.ingest(b)
    qs = [("ta", None), ("tb", None), ("ta", {"x2": [0]}),
          ("tb", {"x2": [1, 2]}), ("ta", {"x0": [0, 1], "x2": [0, 2]}),
          ("tb", {"x1": [0, 3]})]
    eng.ate_batch(qs)                       # warm
    eng._cache.clear()
    with count_dispatches(label="query") as n:
        batch = eng.ate_batch(qs)
    assert n() == 1, n()
    for got, (t, sub) in zip(batch, qs):
        want = ref.ate(t, subpopulation=sub)
        for f in ("ate", "att", "variance", "n_matched_treated",
                  "n_matched_control", "n_groups"):
            g = np.asarray(getattr(got, f))
            w = np.asarray(getattr(want, f))
            assert g.tobytes() == w.tobytes(), (t, sub, f, g, w)
    # serving layer on the mesh engine: cache hits, dedupe, waves
    srv = ServingEngine(eng, n_slots=4)
    res = srv.serve([QuerySpec(t, s) for t, s in qs]
                    + [QuerySpec("ta", None, "att")])
    assert srv.n_cache_served == len(qs) + 1   # ate_batch filled the cache
    assert res[-1].value == float(ref.ate("ta").att)
    print("MESH_SERVE_OK")
    """)
    assert "MESH_SERVE_OK" in out

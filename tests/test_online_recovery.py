"""Crash-recovery bit-identity for the durable engines.

The contract under test (docs/architecture.md — Durability & recovery):
after a process death at ANY boundary — before/after a WAL append, with
the MVCC chain mid-flight, mid-commit, or mid-checkpoint-publish — the
recovered engine (restore newest good checkpoint, replay the WAL tail in
order) answers every query BITWISE equal to a twin that never crashed.
Because estimates are deterministic functions of canonical group content
alone, the guarantee holds across layouts too: a replicated checkpoint
restores into a partitioned engine at a different ``n_parts`` (and, on
the CI device matrix, onto 1/2/4-device meshes) with bit-identical
queries.

Torn WAL tails are discarded (crash mid-buffered-write); a corrupt
record WITH valid records after it refuses replay (silently skipping an
op would break bit-identity); CRC-corrupt checkpoint shards fall back to
the previous step plus a longer replay; the log tail of an unpublished
checkpoint is never garbage-collected.
"""
import os

import numpy as np
import jax
import pytest

from fault_injection import (CRASH_POINTS, FaultInjector, InjectedCrash,
                             corrupt_checkpoint_shard, corrupt_wal_record,
                             tear_wal_tail)
from repro.checkpoint import ckpt as ckpt_mod
from repro.core import (BatchLog, CoarsenSpec, DurableEngine, OnlineEngine,
                        PartitionedOnlineEngine, PoisonBatchError,
                        WalCorruption)
from repro.core import wal as wal_mod
from repro.core.durability import _pack_snapshot, _unpack_snapshot
from repro.core.serving import ServingEngine
from repro.data.columnar import Table
from repro.launch.mesh import make_data_mesh
from repro.launch.trace import count_dispatches, count_host_syncs

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4)}
TREATMENTS = {"ta": ["x0", "x1"]}
EST_FIELDS = ("ate", "att", "n_matched_treated", "n_matched_control",
              "n_groups", "variance")
KW = dict(granule=64, delta_granule=16)


def _batch(n, seed, x0_hi=5):
    """Integer outcomes => exact f32 sums => bitwise-comparable answers."""
    rng = np.random.default_rng(seed)
    cols = {"x0": rng.integers(0, x0_hi, n).astype(np.int32),
            "x1": rng.integers(0, 4, n).astype(np.int32)}
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)
    return Table.from_numpy(cols, rng.random(n) > 0.1)


def _fresh(layout, **kw):
    merged = dict(KW)
    merged.update(kw)
    if layout == "replicated":
        return OnlineEngine(SPECS, TREATMENTS, "y", **merged)
    if layout == "overlap":
        merged.setdefault("max_inflight", 2)
        return OnlineEngine(SPECS, TREATMENTS, "y", overlap=True, **merged)
    if layout == "partitioned":
        ndev = jax.device_count()
        mesh = make_data_mesh(ndev) if ndev > 1 else None
        return PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                       n_parts=max(2, ndev), mesh=mesh,
                                       **merged)
    raise AssertionError(layout)


def _assert_bitwise(got, want, ctx):
    for f in EST_FIELDS:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert g.tobytes() == w.tobytes(), (ctx, f, g, w)


def _assert_twin_equal(recovered, twin, ctx, probe_seed=999):
    """Full query-surface comparison: ate, ate_batch, matched_rows."""
    _assert_bitwise(recovered.ate("ta"), twin.ate("ta"), (ctx, "ate"))
    sub = {"x1": [0, 2]}
    _assert_bitwise(recovered.ate("ta", subpopulation=sub),
                    twin.ate("ta", subpopulation=sub), (ctx, "ate-sub"))
    got = recovered.ate_batch([("ta", None), ("ta", sub)])
    want = twin.ate_batch([("ta", None), ("ta", sub)])
    for g, w in zip(got, want):
        _assert_bitwise(g, w, (ctx, "ate_batch"))
    probe = _batch(64, probe_seed)
    np.testing.assert_array_equal(
        np.asarray(recovered.matched_rows("ta", probe)),
        np.asarray(twin.matched_rows("ta", probe)),
        err_msg=f"{ctx}: matched_rows diverged after recovery")


# ------------------------------------------------------------ WAL basics
def test_wal_roundtrip_rotate_gc(tmp_path):
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    b1 = _batch(32, 1)
    cols = {k: np.asarray(v) for k, v in b1.columns.items()}
    log.append_batch(wal_mod.KIND_INGEST, cols, np.asarray(b1.valid))
    log.append_evict(ttl=2)
    log.rotate()
    log.append_batch(wal_mod.KIND_RETRACT, cols, np.asarray(b1.valid))
    log.close()

    recs = wal_mod.read_log(d)
    assert [r.kind for r in recs] == [wal_mod.KIND_INGEST,
                                      wal_mod.KIND_EVICT,
                                      wal_mod.KIND_RETRACT]
    assert [r.seq for r in recs] == [1, 2, 3]
    rcols, rvalid = recs[0].batch()
    for k in cols:
        np.testing.assert_array_equal(rcols[k], cols[k])
        assert rcols[k].dtype == cols[k].dtype
    np.testing.assert_array_equal(rvalid, np.asarray(b1.valid))
    assert recs[1].evict_ttl() == 2

    # a reopened log continues the sequence, never reuses one
    log2 = BatchLog(d)
    assert log2.last_seq == 3
    log2.append_evict(ttl=1)
    assert wal_mod.read_log(d)[-1].seq == 4
    # gc keeps every segment with records beyond the durable point
    log2.gc(upto_seq=2)
    assert [r.seq for r in wal_mod.read_log(d)] == [3, 4]
    log2.close()


def test_wal_torn_tail_discarded(tmp_path):
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    for i in range(3):
        log.append_evict(ttl=i)
    log.close()
    tear_wal_tail(d)
    recs = wal_mod.read_log(d)
    assert [r.seq for r in recs] == [1, 2]      # torn record 3 dropped


def test_wal_midlog_corruption_refuses_replay(tmp_path):
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    for i in range(3):
        log.append_evict(ttl=i)
    log.close()
    corrupt_wal_record(d, index=0)
    with pytest.raises(WalCorruption):
        wal_mod.read_log(d)


def test_wal_rollback_removes_failed_op_record(tmp_path):
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    log.append_evict(ttl=1)
    mark = log.mark()
    log.append_evict(ttl=9)
    log.rollback(mark)
    assert log.last_seq == 1
    log.append_evict(ttl=2)                     # seq 2 reused cleanly
    log.close()
    assert [(r.seq, r.evict_ttl()) for r in wal_mod.read_log(d)] == [
        (1, 1), (2, 2)]


def test_wal_rollback_first_record_of_rotated_segment(tmp_path):
    """Rolling back the record that OPENED a freshly rotated segment must
    delete the segment file entirely — an empty wal-N.log would make the
    next reopen see a bogus start seq."""
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    log.append_evict(ttl=1)
    log.rotate()
    mark = log.mark()
    log.append_evict(ttl=9)                     # first record of wal-2
    assert len(wal_mod._segment_files(d)) == 2
    log.rollback(mark)
    assert log.last_seq == 1
    assert len(wal_mod._segment_files(d)) == 1  # the new segment is gone
    log.append_evict(ttl=2)                     # seq 2 reused cleanly
    log.close()
    assert [(r.seq, r.evict_ttl()) for r in wal_mod.read_log(d)] == [
        (1, 1), (2, 2)]
    assert BatchLog(d).last_seq == 2


def test_wal_gc_boundary_exactly_on_segment_start(tmp_path):
    """gc(upto_seq) landing exactly on a segment-start seq: the PREVIOUS
    segment (whose last record is upto_seq's predecessor) is covered and
    dropped; the segment STARTING at upto_seq keeps its uncovered tail."""
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    for _ in range(3):
        log.append_evict(ttl=0)                 # wal-1: seqs 1..3
    log.rotate()
    for _ in range(3):
        log.append_evict(ttl=0)                 # wal-4: seqs 4..6
    log.rotate()
    log.append_evict(ttl=0)                     # wal-7: seq 7
    log.rotate()
    log.gc(upto_seq=4)                          # exactly wal-4's start
    assert [s for s, _ in wal_mod._segment_files(d)] == [4, 7]
    assert [r.seq for r in wal_mod.read_log(d)] == [4, 5, 6, 7]
    log.gc(upto_seq=6)                          # wal-4 fully covered now
    assert [s for s, _ in wal_mod._segment_files(d)] == [7]
    assert [r.seq for r in wal_mod.read_log(d)] == [7]
    log.close()


def test_wal_read_after_seq_spans_rotation(tmp_path):
    """read(after_seq) with the cut INSIDE one segment returns the rest
    of that segment plus everything in later segments, in order — and
    read_tail resumes across the same rotation boundary."""
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    for i in range(3):
        log.append_evict(ttl=i)                 # wal-1: 1..3
    log.rotate()
    for i in range(3):
        log.append_evict(ttl=i)                 # wal-4: 4..6
    assert [r.seq for r in log.read(after_seq=2)] == [3, 4, 5, 6]
    cur = wal_mod.TailCursor()
    recs, cur = log.read_tail(cur, max_records=2)   # stops inside wal-1
    assert [r.seq for r in recs] == [1, 2]
    recs, cur = log.read_tail(cur)                  # resumes across rotate
    assert [r.seq for r in recs] == [3, 4, 5, 6]
    log.close()


def test_wal_tail_cursor_scans_only_new_bytes(tmp_path):
    """The shipping/replay regression: repeated tail reads must cost
    O(new bytes), not O(log) — an idle re-read scans ZERO bytes, and a
    read after one small append scans exactly that record."""
    d = str(tmp_path / "wal")
    log = BatchLog(d)
    b = _batch(512, 1)
    cols = {k: np.asarray(v) for k, v in b.columns.items()}
    for _ in range(4):                          # ~4 large batch records
        log.append_batch(wal_mod.KIND_INGEST, cols, np.asarray(b.valid))
    cur = wal_mod.TailCursor()
    recs, cur = log.read_tail(cur)
    assert len(recs) == 4
    base = log.bytes_scanned
    recs, cur = log.read_tail(cur)              # idle: nothing new
    assert recs == [] and log.bytes_scanned == base
    small = log.append_evict(ttl=1)
    recs, cur = log.read_tail(cur)
    assert [r.seq for r in recs] == [small]
    delta = log.bytes_scanned - base
    assert delta == wal_mod._HEADER_SIZE + len(recs[0].payload), \
        f"tail read scanned {delta} bytes for one small record"
    log.rotate()                                # and across a rotation
    log.append_evict(ttl=2)
    base = log.bytes_scanned
    recs, cur = log.read_tail(cur)
    assert len(recs) == 1
    assert log.bytes_scanned - base == (wal_mod._HEADER_SIZE
                                        + len(recs[0].payload))
    log.close()


def test_snapshot_pack_unpack_rejects_dirty_keys():
    snap = dict(views={}, scalars={"state_version": 1, "ingest_count": 0,
                                   "n_rows_ingested": 0, "delta_cap": 16},
                fingerprint="f", cache=())
    tree = _pack_snapshot(snap, wal_seq=7)
    back, seq = _unpack_snapshot(
        {k: v for k, v in _flatten(tree).items()})
    assert seq == 7 and back["fingerprint"] == "f"
    snap["views"] = {"v": {"hi": np.zeros(1), "lo": np.zeros(1),
                           "touch": np.zeros(1),
                           "stats": {"a__b": np.zeros(1)}}}
    with pytest.raises(ValueError):
        _pack_snapshot(snap, wal_seq=0)


def _flatten(tree, prefix=""):
    out = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out.update(_flatten(v, prefix + k + "/"))
        else:
            out[prefix + k] = v
    return out


# -------------------------------------------------- crash-point matrix
#: the scripted stream of every crash test, in wrapper-call order
_SCRIPT = (("ingest", (90, 0, 3)), ("ingest", (80, 1, 3)),
           ("commit", None), ("checkpoint", None),
           ("ingest", (70, 2, 5)), ("ingest", (60, 3, 5)),
           ("commit", None), ("evict", 10),
           ("ingest", (50, 4, 5)), ("commit", None))

#: per-point injector hit count targeting the LAST occurrence in the
#: script (evict fires wal.pre/post-append too, hence 6 not 5), and the
#: number of leading state-mutating script ops (ingest/evict) whose
#: effect must be visible after recovery — a record is on disk iff its
#: append completed (buffered writes survive PROCESS death; fsync only
#: matters for OS crash, which this harness does not simulate)
_CRASH_PLAN = {
    "wal.pre-append": (6, 5),       # final ingest's record never written
    "wal.post-append": (6, 6),
    "ingest.post-dispatch": (5, 6),
    "commit.pre": (3, 6),
    "commit.post": (3, 6),
    "ckpt.pre-save": (1, 2),        # crash mid-checkpoint: only ops 1-2
}


def _mutations(script):
    return [(kind, arg) for kind, arg in script
            if kind in ("ingest", "evict")]


def _drive(layout, directory, injector=None):
    """Run the scripted stream through a DurableEngine; a crashed wrapper
    is abandoned exactly as a killed process would leave it."""
    eng = DurableEngine(_fresh(layout), directory, injector=injector)
    try:
        for kind, arg in _SCRIPT:
            if kind == "ingest":
                n, seed, hi = arg
                eng.ingest(_batch(n, seed, x0_hi=hi))
            elif kind == "evict":
                eng.evict(ttl=arg)
            elif kind == "commit":
                eng.commit()
            else:
                eng.checkpoint(wait=True)
    except InjectedCrash:
        return eng, True
    return eng, False


@pytest.mark.parametrize("layout", ["replicated", "overlap", "partitioned"])
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crash_recovery_bitwise_equals_never_crashed_twin(
        tmp_path, layout, point):
    """Kill the engine at a chosen boundary of the last operation of each
    kind; recover from the directory alone; compare the full query
    surface bitwise against a twin that never crashed, then keep
    streaming both sides and compare again."""
    after, survived = _CRASH_PLAN[point]
    inj = FaultInjector(crash_at=point, after=after)
    _, did_crash = _drive(layout, str(tmp_path / "crash"), injector=inj)
    assert did_crash, (point, inj.seen)

    rec = DurableEngine.recover(_fresh(layout), str(tmp_path / "crash"))

    twin = _fresh(layout)
    for kind, arg in _mutations(_SCRIPT)[:survived]:
        if kind == "ingest":
            n, seed, hi = arg
            twin.ingest(_batch(n, seed, x0_hi=hi))
        else:
            twin.evict(ttl=arg)
    twin.commit()
    _assert_twin_equal(rec, twin, (layout, point))

    # recovered engines keep streaming: continue both sides and re-check
    for cont_seed in (11, 12):
        b = _batch(40, cont_seed)
        rec.ingest(b)
        twin.ingest(b)
    rec.commit()
    twin.commit()
    _assert_twin_equal(rec, twin, (layout, point, "continued"))
    rec.close()


def test_evict_fsync_covers_buffered_records(tmp_path):
    # evict is journaled sync=True: its record must cover everything
    # buffered before it even in overlap mode (fsync is file-wide)
    d = str(tmp_path / "ev")
    eng = DurableEngine(_fresh("overlap"), d)
    eng.ingest(_batch(64, 0))
    eng.evict(ttl=5)                            # commit barrier + fsync
    est = eng.ate("ta")
    eng.close()
    rec = DurableEngine.recover(_fresh("overlap"), d)
    _assert_bitwise(rec.ate("ta"), est, "evict-tail")
    rec.close()


# ------------------------------------------- cross-layout checkpoint
@pytest.mark.parametrize("src,dst,dst_kw", [
    ("replicated", "partitioned", {}),
    ("partitioned", "replicated", {}),
    ("replicated", "replicated", dict(granule=128)),
])
def test_cross_layout_restore_bitwise(tmp_path, src, dst, dst_kw):
    """A checkpoint written by one layout restores into another (different
    n_parts / device placement / granule) bitwise, via the canonical
    compaction contract."""
    d = str(tmp_path / "x")
    eng = DurableEngine(_fresh(src), d)
    for i in range(3):
        eng.ingest(_batch(90, i, x0_hi=3))
    eng.checkpoint(wait=True)
    eng.ingest(_batch(70, 9))                   # WAL tail past the ckpt
    eng.commit()
    est = eng.ate("ta")
    eng.close()

    if dst == "partitioned":
        ndev = jax.device_count()
        tgt = PartitionedOnlineEngine(
            SPECS, TREATMENTS, "y",
            n_parts=max(2, ndev) * 2,   # deliberately different n_parts
            mesh=make_data_mesh(ndev) if ndev > 1 else None, **KW)
    else:
        tgt = _fresh(dst, **dst_kw)
    rec = DurableEngine.recover(tgt, d)
    _assert_twin_equal(rec, _twin_of(src), (src, dst))
    _assert_bitwise(rec.ate("ta"), est, (src, dst, "vs-live"))
    rec.close()


def _twin_of(src):
    twin = _fresh(src)
    for i in range(3):
        twin.ingest(_batch(90, i, x0_hi=3))
    twin.ingest(_batch(70, 9))
    twin.commit()
    return twin


def test_schema_mismatch_refuses_restore(tmp_path):
    d = str(tmp_path / "s")
    eng = DurableEngine(_fresh("replicated"), d)
    eng.ingest(_batch(64, 0))
    eng.checkpoint(wait=True)
    eng.close()
    other = OnlineEngine({"x0": CoarsenSpec.categorical(7),
                          "x1": CoarsenSpec.categorical(4)},
                         TREATMENTS, "y", **KW)
    with pytest.raises(ValueError, match="schema mismatch"):
        DurableEngine.recover(other, d)


# ------------------------------------------------- damaged-disk recovery
def test_corrupt_newest_checkpoint_falls_back(tmp_path):
    d = str(tmp_path / "c")
    eng = DurableEngine(_fresh("replicated"), d)
    eng.ingest(_batch(90, 0, x0_hi=3))
    eng.checkpoint(wait=True)
    eng.ingest(_batch(80, 1))
    eng.checkpoint(wait=True)
    eng.ingest(_batch(70, 2))
    eng.commit()
    est = eng.ate("ta")
    eng.close()

    steps = sorted(f for f in os.listdir(os.path.join(d, "ckpt"))
                   if f.startswith("step_"))
    corrupt_checkpoint_shard(os.path.join(d, "ckpt", steps[-1]))
    rec = DurableEngine.recover(_fresh("replicated"), d)
    _assert_bitwise(rec.ate("ta"), est, "ckpt-fallback")
    rec.close()


def test_all_checkpoints_corrupt_falls_back_to_full_replay(tmp_path):
    d = str(tmp_path / "c2")
    eng = DurableEngine(_fresh("replicated"), d)
    eng.ingest(_batch(90, 0, x0_hi=3))
    eng.checkpoint(wait=True)
    eng.ingest(_batch(80, 1))
    eng.commit()
    est = eng.ate("ta")
    eng.close()
    # corrupting the ONLY checkpoint forces empty-engine full-log replay;
    # its covered segment must still be on disk (gc runs only after the
    # NEXT checkpoint observes a durable publish)
    steps = os.listdir(os.path.join(d, "ckpt"))
    corrupt_checkpoint_shard(
        os.path.join(d, "ckpt", sorted(steps)[-1]))
    rec = DurableEngine.recover(_fresh("replicated"), d)
    _assert_bitwise(rec.ate("ta"), est, "full-replay-fallback")
    rec.close()


def test_mid_publish_rename_crash_recovers_from_previous(tmp_path,
                                                         monkeypatch):
    """Kill the checkpoint publish between shard write and atomic rename:
    the tmp dir is left behind, the step never appears, recovery uses the
    previous checkpoint + the UN-garbage-collected WAL tail."""
    d = str(tmp_path / "p")
    eng = DurableEngine(_fresh("replicated"), d,
                        saver=ckpt_mod.AsyncSaver(max_retries=0))
    eng.ingest(_batch(90, 0, x0_hi=3))
    eng.checkpoint(wait=True)                   # good step 1
    eng.ingest(_batch(80, 1))

    real_rename = os.rename
    def boom(src, dst):
        if ".tmp" in src:
            raise OSError("injected: crash mid-publish")
        return real_rename(src, dst)
    monkeypatch.setattr(ckpt_mod.os, "rename", boom)
    eng.checkpoint()                            # async save will fail
    eng.saver._thread.join()                    # "crash": abandon wrapper
    monkeypatch.setattr(ckpt_mod.os, "rename", real_rename)
    est_twin = _fresh("replicated")
    est_twin.ingest(_batch(90, 0, x0_hi=3))
    est_twin.ingest(_batch(80, 1))
    est_twin.commit()

    assert ckpt_mod.latest_step(os.path.join(d, "ckpt")) == 1
    rec = DurableEngine.recover(_fresh("replicated"), d)
    _assert_bitwise(rec.ate("ta"), est_twin.ate("ta"), "mid-publish")
    rec.close()


# --------------------------------------------- degraded-mode serving
def test_degraded_serving_tags_and_drains(tmp_path):
    d = str(tmp_path / "deg")
    eng = DurableEngine(_fresh("replicated"), d)
    eng.ingest(_batch(90, 0, x0_hi=3))
    eng.checkpoint(wait=True)
    for i in range(3):
        eng.ingest(_batch(60, 1 + i))
    eng.commit()
    final = eng.ate("ta")
    eng.close()

    rec = DurableEngine.recover(_fresh("replicated"), d,
                                degraded_replay=True)
    assert rec.degraded
    snap_version = rec.snapshot_version()
    serving = ServingEngine(rec, n_slots=4)
    out = serving.serve([("ta", None), ("ta", {"x1": [0]})])
    assert all(r.degraded for r in out)
    assert all(r.state_version == snap_version for r in out)
    with pytest.raises(RuntimeError, match="degraded"):
        rec.ingest(_batch(10, 9))

    while rec.replay_step(1):
        pass
    assert not rec.degraded
    out2 = serving.serve([("ta", None)])
    assert not out2[0].degraded
    _assert_bitwise(out2[0].estimate, final, "post-drain")
    rec.close()


def test_bounded_queue_sheds_oldest():
    eng = _fresh("replicated")
    eng.ingest(_batch(64, 0))
    serving = ServingEngine(eng, n_slots=4, max_queue=3)
    qids = [serving.submit(("ta", {"x1": [i % 4]})) for i in range(5)]
    assert serving.n_shed == 2
    assert serving.pending() == 3
    done = {}
    while serving.pending():
        done.update(serving.step())
    assert set(done) == set(qids[2:])           # oldest two never answered


# ------------------------------------------ steady-state hot-path cost
def test_wal_and_async_ckpt_keep_ingest_single_dispatch(tmp_path):
    """The durability layer must be free on the hot path: with the WAL
    journaling every batch and an async checkpoint save in flight, a
    steady-state overlap ingest is still ONE dispatch, ZERO host syncs,
    and clean under jax.transfer_guard("disallow")."""
    eng = DurableEngine(_fresh("overlap", max_inflight=8),
                        str(tmp_path / "hot"))
    warm = _batch(256, 1)
    eng.ingest(warm)
    eng.commit()
    eng.ingest(_batch(256, 2))                  # retrace both wave sizes
    eng.commit()
    eng.checkpoint()                            # async write in flight
    with count_dispatches() as n, count_host_syncs() as s:
        with jax.transfer_guard("disallow"):
            eng.ingest(_batch(256, 3))
    assert n() == 1, "WAL journaling must not add dispatches"
    assert s() == 0, "WAL journaling must not sync the host"
    eng.checkpoint(wait=True)
    eng.close()


def test_poison_batch_never_reaches_wal_or_state(tmp_path):
    """S3 quarantine on the durable path: a rejected batch leaves the
    WAL, the snapshot version, the estimate cache and the in-flight MVCC
    chain untouched, on both engines."""
    for layout in ("replicated", "overlap", "partitioned"):
        eng = DurableEngine(_fresh(layout), str(tmp_path / f"q-{layout}"))
        eng.ingest(_batch(64, 0))
        eng.commit()
        before = eng.ate("ta")                  # populates the cache
        v = eng.snapshot_version()
        seq = eng.wal.last_seq
        inflight = len(getattr(eng.engine, "_inflight", ()))

        good = _batch(32, 1)
        cols = {k: np.asarray(v2).copy() for k, v2 in good.columns.items()}
        cols["y"][0] = np.inf
        valid = np.ones(32, bool)
        with pytest.raises(PoisonBatchError):
            eng.ingest(Table.from_numpy(cols, valid))
        cols2 = {k: a.copy() for k, a in cols.items()}
        cols2["y"][0] = 0.0
        cols2["x0"][1] = 99                     # out-of-range code
        with pytest.raises(PoisonBatchError):
            eng.ingest(Table.from_numpy(cols2, valid))

        assert eng.wal.last_seq == seq, layout
        assert eng.snapshot_version() == v, layout
        assert len(getattr(eng.engine, "_inflight", ())) == inflight
        after = eng.cached_estimate("ta", None)
        assert after is not None
        _assert_bitwise(after, before, (layout, "cache"))
        eng.close()

"""MVCC snapshot versioning: overlap ingest vs query serving.

Contracts under test (PR 8 — the cross-version stale/torn read fixes):

  * DISPATCH-ONLY INGEST — with ``overlap=True`` every steady ingest is
    ONE compiled dispatch and ZERO host syncs (the verdict scalars are
    checked lazily at ``commit()``); the committed snapshot version does
    not move while hops are in flight.
  * SNAPSHOT ISOLATION — queries interleaved with uncommitted in-flight
    ingests answer from (and are tagged with) the committed version,
    bitwise equal to a twin engine that never saw the pending batches.
  * ATOMIC COMMIT / ROLLBACK-AND-REPLAY — ``commit()`` advances the
    version once per batch; a failed hop (delta overflow, capacity
    growth) rolls back to the committed snapshot and replays every
    in-flight batch in order, so the committed state is ALWAYS bitwise
    identical to the synchronous pipeline's.
  * ONE VERSION PER WAVE — a ``ServingEngine.step()`` whose wave
    assembly straddles a commit REQUEUES the assembled slots instead of
    mixing snapshots; every ``ServedQuery`` of one wave shares one
    ``state_version``.
  * SCOPED EVICTION INVALIDATION — ``evict()`` drops estimate-cache
    entries ONLY for views with a nonzero evicted count; untouched-view
    entries keep serving at zero dispatches. The eviction counts
    themselves are fetched lazily (no blocking ``device_get`` on the
    evict path).
"""
import numpy as np
import pytest

from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine
from repro.core.serving import QuerySpec, ServingEngine
from repro.data.columnar import Table
from repro.launch.trace import (count_dispatches, count_host_syncs,
                                host_sync_count)

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}
EST_FIELDS = ("ate", "att", "variance", "n_matched_treated",
              "n_matched_control", "n_groups")


def _frame(n, seed=0, x0_hi=5):
    rng = np.random.default_rng(seed)
    cols = {"x0": rng.integers(0, x0_hi, n).astype(np.int32),
            "x1": rng.integers(0, 4, n).astype(np.int32),
            "x2": rng.integers(0, 3, n).astype(np.int32)}
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    cols["y"] = np.round(2.0 * cols["ta"] + 1.5 * cols["x0"]
                         + rng.normal(0, 0.5, n)).astype(np.float32)
    return Table.from_numpy(cols, rng.random(n) > 0.08)


def _twins(label, **kw):
    """(overlap engine, synchronous twin) on one layout."""
    if label == "replicated":
        mk = lambda **k: OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                                      **k)
    else:
        mk = lambda **k: PartitionedOnlineEngine(SPECS, TREATMENTS, "y",
                                                 granule=64, n_parts=2, **k)
    return mk(overlap=True, **kw), mk(**kw)


def _assert_bitwise(got, want, ctx):
    for f in EST_FIELDS:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert g.tobytes() == w.tobytes(), (ctx, f, g, w)


# -------------------------------------------------- dispatch-only ingest
@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_overlap_ingest_is_sync_free_and_snapshot_isolated(label):
    eng, twin = _twins(label)
    warm = _frame(256, seed=1)
    eng.ingest(warm)
    eng.commit()
    twin.ingest(warm)
    v0 = eng.snapshot_version()
    before = eng.ate("ta")
    assert before.state_version == v0

    pendings = []
    for i in range(3):
        b = _frame(256, seed=10 + i)
        with count_host_syncs() as s, count_dispatches() as n:
            p = eng.ingest(b)
        assert s() == 0, "overlap ingest must not sync the host"
        assert n() == 1, "overlap ingest is one dispatch"
        assert not p.committed
        pendings.append((p, b))
        # in-flight hops are invisible to queries: same version, same bits
        assert eng.snapshot_version() == v0
        mid = eng.ate("ta")
        assert mid.state_version == v0
        _assert_bitwise(mid, before, (label, "in-flight", i))

    reports = eng.commit()
    assert len(reports) == 3
    assert all(p.committed for p, _ in pendings)
    assert eng.snapshot_version() > v0
    for _, b in pendings:
        twin.ingest(b)
    after = eng.ate("ta")
    assert after.state_version == eng.snapshot_version()
    _assert_bitwise(after, twin.ate("ta"), (label, "post-commit"))
    # second commit with nothing in flight is a no-op
    assert eng.commit() == []


@pytest.mark.parametrize("label", ["replicated", "partitioned"])
def test_overlap_rollback_replay_is_bit_identical(label):
    # tiny delta capacity: wide batches overflow the in-flight delta, so
    # commit() must roll back and replay every hop synchronously — the
    # committed state is still bitwise the synchronous pipeline's
    kw = dict(delta_granule=16)
    eng, twin = _twins(label, **kw)
    batches = [_frame(64, seed=2, x0_hi=1), _frame(460, seed=3),
               _frame(128, seed=4)]
    for b in batches:
        eng.ingest(b)
        twin.ingest(b)
    eng.commit()
    for t in sorted(TREATMENTS):
        _assert_bitwise(eng.ate(t), twin.ate(t), (label, "replay", t))
    assert eng.n_rows_ingested == twin.n_rows_ingested


def test_overlap_pending_report_is_lazy_and_forces_commit():
    eng, _ = _twins("replicated")
    p1 = eng.ingest(_frame(256, seed=5))
    p2 = eng.ingest(_frame(256, seed=6))
    assert not p1.committed and not p2.committed
    # reading any report field is a commit barrier for the WHOLE chain
    assert p1.n_delta_groups > 0
    assert p1.committed and p2.committed
    assert len(eng._inflight) == 0


def test_overlap_max_inflight_bounds_the_pipeline():
    eng, _ = _twins("replicated", max_inflight=2)
    for i in range(2):
        eng.ingest(_frame(256, seed=20 + i))
    assert len(eng._inflight) == 2
    p = eng.ingest(_frame(256, seed=22))   # full: auto-commits, redispatches
    assert len(eng._inflight) == 1 and not p.committed
    eng.commit()


def test_overlap_retract_flushes_the_pipeline_first():
    eng, twin = _twins("replicated")
    b0, b1 = _frame(256, seed=7), _frame(256, seed=8)
    for b in (b0, b1):
        eng.ingest(b)
        twin.ingest(b)
    eng.ingest(b1, retract=True)           # commit barrier + sync retract
    twin.ingest(b1, retract=True)
    assert len(eng._inflight) == 0
    _assert_bitwise(eng.ate("ta"), twin.ate("ta"), "retract")


# ---------------------------------------------------- one version per wave
def test_serving_wave_requeues_when_a_commit_straddles_it():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       query_dims=("x0", "x1", "x2"))
    eng.ingest(_frame(400, seed=30))
    serving = ServingEngine(eng, n_slots=8)
    specs = [QuerySpec.make("ta"), QuerySpec.make("tb"),
             QuerySpec.make("ta", {"x2": [0]}),
             QuerySpec.make("tb", {"x0": [1, 2]})]
    qids = [serving.submit(s) for s in specs]

    # a concurrent writer commits an ingest in the middle of wave
    # assembly (modeled by hooking the per-query cache probe)
    real = eng.cached_estimate
    fired = {}

    def racing_probe(treatment, subpopulation=None):
        if not fired:
            fired["yes"] = True
            eng.ingest(_frame(300, seed=31))
        return real(treatment, subpopulation)

    eng.cached_estimate = racing_probe
    done = serving.step()
    eng.cached_estimate = real

    assert done == {}                      # nothing mixed across versions
    assert serving.n_requeued == len(specs)
    assert serving.n_waves == 0 and serving.n_slots_used == 0
    assert serving.pending() == len(specs)

    v = eng.snapshot_version()
    done = serving.step()                  # clean wave at the new version
    assert sorted(done) == sorted(qids)
    assert {r.state_version for r in done.values()} == {v}
    assert serving.n_waves == 1 and serving.n_requeued == len(specs)
    for qid, spec in zip(qids, specs):
        _assert_bitwise(done[qid].estimate,
                        eng.ate(spec.treatment,
                                subpopulation=spec.subpopulation),
                        ("requeued wave", qid))


def test_serving_waves_share_one_version_over_an_overlap_engine():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, overlap=True,
                       query_dims=("x0", "x1", "x2"))
    eng.ingest(_frame(400, seed=32))
    eng.commit()
    v0 = eng.snapshot_version()
    serving = ServingEngine(eng, n_slots=8)
    eng.ingest(_frame(300, seed=33))       # in flight, uncommitted
    done = serving.serve([QuerySpec.make("ta"), QuerySpec.make("tb"),
                          QuerySpec.make("ta")])
    # in-flight hop is invisible: the wave serves the committed snapshot
    assert {r.state_version for r in done} == {v0}
    assert serving.n_requeued == 0
    eng.commit()
    done2 = serving.serve([QuerySpec.make("ta")])
    assert done2[0].state_version == eng.snapshot_version() > v0


# ----------------------------------------------- scoped, lazy eviction
def _slice_frame(n, x1, seed):
    """All rows in the (x0=4, x1=x1) slice: ta groups differ per x1,
    tb groups (x0, x2) are shared across slices."""
    rng = np.random.default_rng(seed)
    cols = {"x0": np.full(n, 4, np.int32),
            "x1": np.full(n, x1, np.int32),
            "x2": rng.integers(0, 3, n).astype(np.int32)}
    cols["ta"] = (rng.random(n) < 0.5).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.5).astype(np.int32)
    cols["y"] = rng.integers(0, 6, n).astype(np.float32)
    return Table.from_numpy(cols)


def test_evict_invalidation_is_scoped_to_touched_views():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=64)
    eng.ingest(_slice_frame(120, x1=3, seed=40))     # ta group (4,3): stale
    eng.ingest(_slice_frame(120, x1=0, seed=41))     # keeps tb groups fresh
    eng.ingest(_slice_frame(120, x1=0, seed=42))
    cached_tb = eng.ate("tb")
    eng.ate("ta")
    ev = eng.evict(ttl=1)
    assert ev["ta"] > 0 and ev["tb"] == 0
    # tb was untouched by the eviction: its entry still serves from cache
    with count_dispatches() as n:
        again = eng.ate("tb")
    assert n() == 0, "untouched-view cache entry must survive evict()"
    _assert_bitwise(again, cached_tb, "tb cache after scoped evict")
    # ta lost groups: its entry is gone and the query recomputes
    with count_dispatches(label="query") as n:
        est = eng.ate("ta")
    assert n() == 1
    assert int(est.n_groups) > 0


def test_evict_counts_are_fetched_lazily():
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=64)
    for i in range(3):
        eng.ingest(_slice_frame(120, x1=i, seed=50 + i))
    with count_host_syncs() as s:
        ev = eng.evict(ttl=10_000)         # nothing stale: pure pass
    assert s() == 0, "evict() must not block on the eviction counts"
    base = host_sync_count("evict")
    assert ev == {"__base__": 0, "ta": 0, "tb": 0}   # forces ONE fetch
    assert host_sync_count("evict") == base + 1
    # resolved reports are plain mappings; a second read is free
    with count_host_syncs() as s:
        assert dict(ev) == {"__base__": 0, "ta": 0, "tb": 0}
    assert s() == 0
    # a query is a sync point too: pending evictions settle before probe
    eng.evict(ttl=10_000)
    eng.ate("ta")
    assert eng._pending_evict is None

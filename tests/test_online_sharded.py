"""Multi-device online engine: sharded ingest == single-device ingest.

The contract: attaching a mesh changes WHERE the per-batch delta stat table
is computed (per-device local aggregation + all-gather + combine), never
WHAT is maintained — cuboid stats are bit-identical (integer outcomes) and
matched sets / ATEs identical across 1/2/4-device meshes.

Runs in a SUBPROCESS with --xla_force_host_platform_device_count so the
main pytest process keeps seeing exactly 1 device (same isolation rule as
tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.devices()
from repro.launch.mesh import make_data_mesh
from repro.core import CoarsenSpec, OnlineEngine
from repro.data.columnar import Table

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}


def frame(n, seed, x0_hi=5):
    rng = np.random.default_rng(seed)
    cols = {
        "x0": rng.integers(0, x0_hi, n).astype(np.int32),
        "x1": rng.integers(0, 4, n).astype(np.int32),
        "x2": rng.integers(0, 3, n).astype(np.int32),
    }
    p = 0.15 + 0.6 * cols["x0"] / 4
    cols["ta"] = (rng.random(n) < p).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)  # exact f32 sums
    return cols, rng.random(n) > 0.08


def stat_map(cub):
    gv = np.asarray(cub.group_valid) & (np.asarray(cub.stats["one"]) > 0)
    hi = np.asarray(cub.key_hi)[gv]
    lo = np.asarray(cub.key_lo)[gv]
    c = {k: np.asarray(v)[gv] for k, v in sorted(cub.stats.items())}
    return {(int(h), int(l)): tuple(float(c[k][i]) for k in c)
            for i, (h, l) in enumerate(zip(hi, lo))}
"""


def _run(body: str):
    code = SCRIPT_HEADER + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_sharded_ingest_bit_identical_across_device_counts():
    out = _run("""
    # early batches restricted to x0 < 2 -> later batches add new group
    # keys mid-stream, exercising the grow path under sharding too
    c1, v1 = frame(3000, seed=1, x0_hi=2)
    c2, v2 = frame(2024, seed=2)
    cols = {k: np.concatenate([c1[k], c2[k]]) for k in c1}
    valid = np.concatenate([v1, v2])
    # batch sizes deliberately not divisible by the device count: the
    # sharded build pads with invalid rows
    sizes = [1000, 1000, 1000, 1000, 1024]

    engines = {}
    for ndev in (1, 2, 4):
        mesh = make_data_mesh(ndev) if ndev > 1 else None
        eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, mesh=mesh)
        s = 0
        saw_slow = False
        for sz in sizes:
            b = Table.from_numpy({k: v[s:s + sz] for k, v in cols.items()},
                                 valid[s:s + sz])
            rep = eng.ingest(b)
            if s > 0 and not all(rep.fast_path.values()):
                saw_slow = True
            s += sz
        assert saw_slow, "stream never exercised the grow path"
        engines[ndev] = eng

    ref = engines[1]
    full = Table.from_numpy(cols, valid)
    ref_matched = {t: np.asarray(ref.matched_rows(t, full))
                   for t in TREATMENTS}
    for ndev in (2, 4):
        eng = engines[ndev]
        assert stat_map(eng.base) == stat_map(ref.base), ndev
        for t in TREATMENTS:
            assert (stat_map(eng.views[t].cuboid)
                    == stat_map(ref.views[t].cuboid)), (ndev, t)
            got, want = eng.ate(t), ref.ate(t)
            assert float(got.ate) == float(want.ate), (ndev, t)
            assert float(got.variance) == float(want.variance), (ndev, t)
            assert int(got.n_groups) == int(want.n_groups)
            np.testing.assert_array_equal(
                np.asarray(eng.matched_rows(t, full)), ref_matched[t])
    print("SHARDED_EQUIV_OK")
    """)
    assert "SHARDED_EQUIV_OK" in out


def test_sharded_retraction_and_guard():
    out = _run("""
    cols, valid = frame(4000, seed=3)
    sizes = [1000] * 4
    engines = {}
    for ndev in (1, 4):
        mesh = make_data_mesh(ndev) if ndev > 1 else None
        eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, mesh=mesh)
        for s in range(0, 4000, 1000):
            eng.ingest(Table.from_numpy(
                {k: v[s:s + 1000] for k, v in cols.items()},
                valid[s:s + 1000]))
        engines[ndev] = eng
    # retract the second batch on both: still bit-identical
    b1 = Table.from_numpy({k: v[1000:2000] for k, v in cols.items()},
                          valid[1000:2000])
    for eng in engines.values():
        eng.ingest(b1, retract=True)
    assert stat_map(engines[4].base) == stat_map(engines[1].base)
    for t in TREATMENTS:
        assert float(engines[4].ate(t).ate) == float(engines[1].ate(t).ate)
    # the never-ingested guard fires through the sharded path too
    bogus = Table.from_numpy({k: np.repeat(v[:1], 600) for k, v in
                              cols.items()}, np.ones(600, bool))
    before = stat_map(engines[4].base)
    try:
        engines[4].ingest(bogus, retract=True)
        raise SystemExit("guard did not fire")
    except ValueError:
        pass
    assert stat_map(engines[4].base) == before
    print("SHARDED_RETRACT_OK")
    """)
    assert "SHARDED_RETRACT_OK" in out


def test_sharded_delta_capacity_overflow_falls_back_exactly():
    out = _run("""
    # tiny delta capacity: the first wide batch overflows the sliced delta
    # table, forcing the exact host fallback + geometric capacity growth
    cols, valid = frame(4096, seed=4)
    mesh = make_data_mesh(4)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256, mesh=mesh,
                       delta_granule=8)
    ref = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       delta_granule=8)
    for s in range(0, 4096, 1024):
        b = Table.from_numpy({k: v[s:s + 1024] for k, v in cols.items()},
                             valid[s:s + 1024])
        eng.ingest(b)
        ref.ingest(b)
    assert eng._delta_cap > 8  # capacity grew past the forced overflow
    assert stat_map(eng.base) == stat_map(ref.base)
    for t in TREATMENTS:
        assert float(eng.ate(t).ate) == float(ref.ate(t).ate)
    print("SHARDED_OVERFLOW_OK")
    """)
    assert "SHARDED_OVERFLOW_OK" in out

"""CEM + ATE + balance vs numpy oracles, and ATE recovery on planted data."""
import numpy as np
import jax.numpy as jnp

from repro.core import (CoarsenSpec, awmd, cem, difference_in_means,
                        estimate_ate, exact_matching, raw_imbalance,
                        cem_weights)
from repro.core import oracle
from repro.data.columnar import Table


def _random_frame(n=800, seed=0, n_cov=3, card=4):
    rng = np.random.default_rng(seed)
    cols = {f"x{i}": rng.integers(0, card, n).astype(np.int32)
            for i in range(n_cov)}
    # treatment probability depends on x0 -> confounding
    p = 0.15 + 0.6 * cols["x0"] / (card - 1)
    t = (rng.random(n) < p).astype(np.int32)
    y = (2.0 * t + 1.5 * cols["x0"] + rng.normal(0, 0.3, n)).astype(np.float32)
    valid = rng.random(n) > 0.05
    return cols, t, y, valid


def test_cem_matches_oracle_exactly():
    cols, t, y, valid = _random_frame()
    table = Table.from_numpy({**cols, "t": t, "y": y}, valid)
    specs = {k: CoarsenSpec.categorical(4) for k in cols}
    res = cem(table, "t", "y", specs)
    want_mask, want_groups = oracle.cem_oracle(cols, t, valid)
    got_mask = np.asarray(res.table.valid)
    np.testing.assert_array_equal(got_mask, want_mask)
    # group count matches
    est = estimate_ate(res.groups)
    assert int(est.n_groups) == len(want_groups)
    # ATE matches Eq. 4 oracle
    want_ate = oracle.ate_oracle(want_groups, t, y)
    np.testing.assert_allclose(float(est.ate), want_ate, rtol=1e-5)
    want_att = oracle.att_oracle(want_groups, t, y)
    np.testing.assert_allclose(float(est.att), want_att, rtol=1e-5)


def test_cem_awmd_matches_oracle():
    cols, t, y, valid = _random_frame(seed=3)
    rng = np.random.default_rng(7)
    xc = (cols["x0"] + rng.normal(0, 0.1, len(t))).astype(np.float32)
    table = Table.from_numpy({**cols, "xc": xc, "t": t, "y": y}, valid)
    specs = {k: CoarsenSpec.categorical(4) for k in cols}
    res = cem(table, "t", "y", specs)
    _, want_groups = oracle.cem_oracle(cols, t, valid)
    got = awmd(res.groups, {"xc": jnp.asarray(xc)}, table["t"],
               res.table.valid)
    want = oracle.awmd_oracle(want_groups, t, xc)
    np.testing.assert_allclose(float(got["xc"]), want, rtol=1e-4)
    # matching on x0 balances xc (they're correlated)
    raw = raw_imbalance({"xc": jnp.asarray(xc)}, table["t"], table.valid)
    assert float(got["xc"]) < float(raw["xc"])


def test_cem_recovers_planted_effect():
    """Naive diff-in-means is confounded; CEM on the confounder is not."""
    cols, t, y, valid = _random_frame(n=6000, seed=5)
    table = Table.from_numpy({**cols, "t": t, "y": y}, valid)
    naive = float(difference_in_means(table["y"], table["t"], table.valid))
    assert abs(naive - 2.0) > 0.25  # visibly confounded
    res = cem(table, "t", "y",
              {"x0": CoarsenSpec.categorical(4)})
    est = estimate_ate(res.groups)
    assert abs(float(est.ate) - 2.0) < 0.1


def test_exact_matching_equals_cem_categorical():
    cols, t, y, valid = _random_frame(seed=9)
    table = Table.from_numpy({**cols, "t": t, "y": y}, valid)
    em = exact_matching(table, "t", "y", {k: 4 for k in cols})
    specs = {k: CoarsenSpec.categorical(4) for k in cols}
    via_cem = cem(table, "t", "y", specs)
    np.testing.assert_array_equal(np.asarray(em.table.valid),
                                  np.asarray(via_cem.table.valid))


def test_cem_weights_sum():
    """CEM weights: treated weights are 1; control weights sum to N_c."""
    cols, t, y, valid = _random_frame(seed=11)
    table = Table.from_numpy({**cols, "t": t, "y": y}, valid)
    res = cem(table, "t", "y", {k: CoarsenSpec.categorical(4) for k in cols})
    w = np.asarray(cem_weights(res.groups, table["t"], res.table.valid))
    mask = np.asarray(res.table.valid)
    nc = int((t[mask] == 0).sum())
    np.testing.assert_allclose(w[mask & (t == 1)], 1.0)
    np.testing.assert_allclose(w[mask & (t == 0)].sum(), nc, rtol=1e-4)
    assert np.all(w[~mask] == 0)


def test_cem_continuous_coarsening():
    rng = np.random.default_rng(13)
    n = 2000
    x = rng.normal(0, 1, n).astype(np.float32)
    t = (rng.random(n) < 1 / (1 + np.exp(-x))).astype(np.int32)
    y = (3.0 * t + x + rng.normal(0, 0.2, n)).astype(np.float32)
    table = Table.from_numpy({"x": x, "t": t, "y": y})
    res = cem(table, "t", "y",
              {"x": CoarsenSpec.equal_width(-3, 3, 12)})
    est = estimate_ate(res.groups, table["y"], table["t"], res.table.valid)
    assert abs(float(est.ate) - 3.0) < 0.15
    assert float(est.variance) > 0

"""Property-based tests (hypothesis) for the system's core invariants:

  * key codec roundtrip for arbitrary field layouts;
  * CEM: every retained group has both arms; matched set is a subset of the
    input; CEM is idempotent; mask-invariance under row permutation;
  * Prop. 2 (join pushdown) on randomized FK schemas;
  * Prop. 3 (covariate factoring) on randomized treatment sets;
  * ntile produces balanced buckets.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev]) — skipped, "
           "not an error, where it is absent")
from hypothesis import given, settings, strategies as st

from repro.core import (CoarsenSpec, KeyCodec, cem, cem_join_pushdown,
                        covariate_factoring, estimate_ate, mcem, ntile)
from repro.core import oracle
from repro.data.columnar import Table
from repro.data.join import fk_join

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def codec_and_values(draw):
    n_fields = draw(st.integers(1, 5))
    cards = {}
    total_bits = 0
    for i in range(n_fields):
        c = draw(st.integers(2, 1 << 12))
        # keep within the 63-bit budget
        import math
        bits = max(1, math.ceil(math.log2(c)))
        if total_bits + bits > 60:
            break
        total_bits += bits
        cards[f"f{i}"] = c
    n_rows = draw(st.integers(1, 200))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    vals = {k: rng.integers(0, c, n_rows).astype(np.int32)
            for k, c in cards.items()}
    valid = rng.random(n_rows) > draw(st.floats(0.0, 0.5))
    return cards, vals, valid


@given(codec_and_values())
@settings(**SETTINGS)
def test_keycodec_roundtrip_property(cv):
    cards, vals, valid = cv
    codec = KeyCodec.from_cardinalities(cards)
    hi, lo = codec.pack({k: jnp.asarray(v) for k, v in vals.items()},
                        jnp.asarray(valid))
    for name, v in vals.items():
        got = np.asarray(codec.extract(hi, lo, name))
        np.testing.assert_array_equal(got[valid], v[valid])


@st.composite
def cem_frame(draw):
    n = draw(st.integers(10, 400))
    n_cov = draw(st.integers(1, 3))
    card = draw(st.integers(2, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    cols = {f"x{i}": rng.integers(0, card, n).astype(np.int32)
            for i in range(n_cov)}
    t = (rng.random(n) < draw(st.floats(0.1, 0.9))).astype(np.int32)
    y = rng.normal(0, 1, n).astype(np.float32)
    valid = rng.random(n) > draw(st.floats(0.0, 0.4))
    return cols, t, y, valid, card


@given(cem_frame())
@settings(**SETTINGS)
def test_cem_invariants(frame):
    cols, t, y, valid, card = frame
    table = Table.from_numpy({**cols, "t": t, "y": y}, valid)
    specs = {k: CoarsenSpec.categorical(card) for k in cols}
    res = cem(table, "t", "y", specs)
    matched = np.asarray(res.table.valid)
    # subset of input
    assert np.all(matched <= valid)
    # oracle agreement (both-arms invariant holds by oracle construction)
    want, _ = oracle.cem_oracle(cols, t, valid)
    np.testing.assert_array_equal(matched, want)
    # idempotence
    table2 = Table(dict(res.table.columns), res.table.valid)
    res2 = cem(table2, "t", "y", specs)
    np.testing.assert_array_equal(np.asarray(res2.table.valid), matched)
    # permutation invariance
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(t))
    ptable = Table.from_numpy(
        {**{k: v[perm] for k, v in cols.items()}, "t": t[perm],
         "y": y[perm]}, valid[perm])
    pres = cem(ptable, "t", "y", specs)
    np.testing.assert_array_equal(np.asarray(pres.table.valid), want[perm])
    if matched.any():
        a = estimate_ate(res.groups)
        b = estimate_ate(pres.groups)
        np.testing.assert_allclose(float(a.ate), float(b.ate),
                                   rtol=1e-4, atol=1e-5)


@st.composite
def fk_schema(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    n_dim = draw(st.integers(5, 80))
    n_fact = draw(st.integers(10, 300))
    card_d = draw(st.integers(2, 5))
    card_f = draw(st.integers(2, 4))
    d_x = rng.integers(0, card_d, n_dim).astype(np.int32)
    d_t = (rng.random(n_dim) < draw(st.floats(0.2, 0.8))).astype(np.int32)
    d_valid = rng.random(n_dim) > draw(st.floats(0.0, 0.3))
    f_key = rng.integers(0, n_dim, n_fact).astype(np.int32)
    f_x = rng.integers(0, card_f, n_fact).astype(np.int32)
    y = rng.normal(0, 1, n_fact).astype(np.float32)
    f_valid = rng.random(n_fact) > draw(st.floats(0.0, 0.3))
    return (n_dim, card_d, card_f, d_x, d_t, d_valid, f_key, f_x, y, f_valid)


@given(fk_schema())
@settings(**SETTINGS)
def test_prop2_pushdown_property(schema):
    (n_dim, card_d, card_f, d_x, d_t, d_valid, f_key, f_x, y,
     f_valid) = schema
    dim = Table.from_numpy(dict(key=np.arange(n_dim, dtype=np.int32),
                                d_x=d_x, t=d_t), d_valid)
    fact = Table.from_numpy(dict(key=f_key, f_x=f_x, y=y), f_valid)
    dim_specs = {"d_x": CoarsenSpec.categorical(card_d)}
    fact_specs = {"f_x": CoarsenSpec.categorical(card_f)}
    on = {"key": n_dim}
    joined = fk_join(fact, dim, on=on)
    direct = cem(joined, "t", "y", {**fact_specs, **dim_specs})
    pd = cem_join_pushdown(dim, dim_specs, fact, fact_specs, on=on,
                           treatment="t", outcome="y", do_compact=False)
    np.testing.assert_array_equal(np.asarray(pd.result.table.valid),
                                  np.asarray(direct.table.valid))


@st.composite
def factoring_frame(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    n = draw(st.integers(20, 400))
    x0 = rng.integers(0, 4, n).astype(np.int32)
    x1 = rng.integers(0, 3, n).astype(np.int32)
    x2 = rng.integers(0, 3, n).astype(np.int32)
    t_a = (rng.random(n) < 0.3 + 0.1 * x0).astype(np.int32)
    t_b = (rng.random(n) < 0.2 + 0.15 * x0).astype(np.int32)
    y = rng.normal(0, 1, n).astype(np.float32)
    valid = rng.random(n) > draw(st.floats(0.0, 0.3))
    return x0, x1, x2, t_a, t_b, y, valid


@given(factoring_frame())
@settings(**SETTINGS)
def test_prop3_factoring_property(frame):
    x0, x1, x2, t_a, t_b, y, valid = frame
    table = Table.from_numpy(dict(x0=x0, x1=x1, x2=x2, t_a=t_a, t_b=t_b,
                                  y=y), valid)
    specs = {"x0": CoarsenSpec.categorical(4),
             "x1": CoarsenSpec.categorical(3),
             "x2": CoarsenSpec.categorical(3)}
    covsets = {"t_a": ["x0", "x1"], "t_b": ["x0", "x2"]}
    view = covariate_factoring(table, ["t_a", "t_b"], specs, ["x0"])
    for tname, dims in covsets.items():
        tspecs = {n: specs[n] for n in dims}
        direct = cem(table, tname, "y", tspecs)
        via = mcem(view, tname, "y", tspecs)
        np.testing.assert_array_equal(np.asarray(via.table.valid),
                                      np.asarray(direct.table.valid))


@given(st.integers(0, 2 ** 31), st.integers(2, 10), st.integers(20, 300))
@settings(**SETTINGS)
def test_ntile_balanced_property(seed, n_tiles, n_rows):
    rng = np.random.default_rng(seed)
    ps = rng.random(n_rows).astype(np.float32)
    valid = rng.random(n_rows) > 0.2
    b = np.asarray(ntile(jnp.asarray(ps), jnp.asarray(valid), n_tiles))
    nv = valid.sum()
    if nv == 0:
        return
    counts = np.bincount(b[valid], minlength=n_tiles)[:n_tiles]
    # ntile invariant: bucket sizes differ by at most 1... our static variant
    # floor(rank*n/N) differs by at most ceil(N/n)-floor(N/n)+1 -> allow 2
    assert counts.max() - counts.min() <= 2
    assert np.all(b[~valid] == n_tiles)
    # monotone: higher ps -> same or later bucket
    order = np.argsort(ps[valid], kind="stable")
    bb = b[valid][order]
    assert np.all(np.diff(bb) >= 0)

"""NNM (WR/NR), subclassification, propensity vs oracles."""
import numpy as np
import jax.numpy as jnp

from repro.core import (fit_logistic, knn_quadratic, knn_sorted_1d,
                        mahalanobis_transform, nnmnr, nnmwr, nnmwr_att, ntile,
                        predict_ps, subclassify, estimate_ate)
from repro.core import oracle
from repro.core.matching import BIG, greedy_nnmnr
from repro.data.columnar import Table


def _matching_data(n=400, d=2, seed=0):
    rng = np.random.default_rng(seed)
    U = rng.normal(0, 1, (n, d)).astype(np.float32)
    t = (rng.random(n) < 0.3).astype(np.int32)
    valid = rng.random(n) > 0.05
    return U, t, valid


def test_knn_quadratic_matches_bruteforce():
    U, t, valid = _matching_data()
    control_valid = (t == 0) & valid
    for k in (1, 3):
        dist, idx = knn_quadratic(jnp.asarray(U), jnp.asarray(U),
                                  jnp.asarray(control_valid), k, caliper=2.0,
                                  block=64)
        wd, wi = oracle.knn_oracle(U, U, control_valid, k, caliper=2.0)
        got_d = np.asarray(dist)
        # f32 matmul distance has ~sqrt(eps)*|x| cancellation error near 0;
        # exclude a fuzz band around the caliper boundary.
        interior = np.isfinite(wd) & (wd < 2.0 - 1e-2)
        np.testing.assert_allclose(got_d[interior], wd[interior],
                                   rtol=1e-3, atol=3e-3)
        clearly_out = ~np.isfinite(wd)
        assert np.all(got_d[clearly_out] >= float(BIG) * 0.9)
        # indices agree where the distance gap to the next candidate is clear
        both = interior & (np.abs(got_d - wd) < 1e-4)
        agree = (np.asarray(idx)[both] == wi[both])
        assert agree.mean() > 0.98


def test_knn_sorted_1d_matches_bruteforce():
    rng = np.random.default_rng(3)
    n = 500
    x = rng.random(n).astype(np.float32)
    t = (rng.random(n) < 0.4).astype(np.int32)
    cv = (t == 0)
    for k in (1, 5):
        dist, idx = knn_sorted_1d(jnp.asarray(x), jnp.asarray(x),
                                  jnp.asarray(cv), k, caliper=0.1)
        wd, wi = oracle.knn_oracle(x[:, None], x[:, None], cv, k, caliper=0.1)
        got = np.asarray(dist)
        ok = np.isfinite(wd)
        np.testing.assert_allclose(got[ok], wd[ok], rtol=1e-4, atol=1e-6)
        assert np.all(got[~ok] >= float(BIG) * 0.9)


def test_nnmwr_att_direction():
    """Planted constant effect is recovered by 1:1 WR matching on x."""
    rng = np.random.default_rng(5)
    n = 3000
    x = rng.normal(0, 1, (n, 1)).astype(np.float32)
    p = 1 / (1 + np.exp(-1.2 * x[:, 0]))
    t = (rng.random(n) < p).astype(np.int32)
    y = (2.5 * t + 2.0 * x[:, 0] + rng.normal(0, 0.2, n)).astype(np.float32)
    res = nnmwr(jnp.asarray(x), jnp.asarray(t), jnp.ones(n, bool), k=1,
                caliper=0.05)
    att = float(nnmwr_att(jnp.asarray(y), res))
    assert abs(att - 2.5) < 0.15


def test_nnmnr_no_control_reuse():
    U, t, valid = _matching_data(n=300, d=1, seed=7)
    res = nnmnr(jnp.asarray(U), jnp.asarray(t), jnp.asarray(valid), k=2,
                caliper=1.0)
    ok = np.asarray(res.ok)
    idx = np.asarray(res.idx)
    used = idx[ok]
    assert len(used) == len(np.unique(used))  # each control used at most once
    # every used control really is a valid control
    assert np.all((t[used] == 0) & valid[used])
    # per-treated count <= k
    assert np.asarray(ok.sum(axis=1)).max() <= 2


def test_greedy_matches_oracle_sweep():
    rng = np.random.default_rng(11)
    nt, m, n_rows = 20, 4, 100
    dist = rng.random((nt, m)).astype(np.float32)
    dist = np.where(rng.random((nt, m)) < 0.2, np.float32(BIG), dist)
    idx = rng.integers(0, n_rows, (nt, m)).astype(np.int32)
    treated_rows = np.arange(nt, dtype=np.int32)
    take, _ = greedy_nnmnr(jnp.asarray(dist), jnp.asarray(idx),
                           jnp.asarray(treated_rows), n_rows, k=1)
    edges = [(float(dist[i, j]) if dist[i, j] < BIG else np.inf,
              int(idx[i, j]), int(treated_rows[i]))
             for i in range(nt) for j in range(m)]
    want = oracle.greedy_match_oracle(edges, n_rows, k=1)
    got = np.asarray(take)
    got_edges = sorted((float(dist[i, j]), int(idx[i, j]), i)
                       for i, j in zip(*np.nonzero(got)))
    # same multiset of matched controls and total distance
    assert len(got_edges) == len(want)
    np.testing.assert_allclose(sum(e[0] for e in got_edges),
                               sum(e[0] for e in want), rtol=1e-5)


def test_ntile_matches_oracle():
    rng = np.random.default_rng(13)
    ps = rng.random(157).astype(np.float32)
    valid = rng.random(157) > 0.15
    got = np.asarray(ntile(jnp.asarray(ps), jnp.asarray(valid), 5))
    want = oracle.ntile_oracle(ps, valid, 5)
    np.testing.assert_array_equal(got, want)


def test_logistic_matches_oracle_and_separates():
    rng = np.random.default_rng(17)
    n = 1000
    X = rng.normal(0, 1, (n, 3)).astype(np.float32)
    logits = 1.5 * X[:, 0] - 0.7 * X[:, 1] + 0.3
    t = (rng.random(n) < 1 / (1 + np.exp(-logits))).astype(np.int32)
    valid = np.ones(n, bool)
    model = fit_logistic(jnp.asarray(X), jnp.asarray(t), jnp.asarray(valid))
    ps = np.asarray(predict_ps(model, jnp.asarray(X)))
    want = oracle.logistic_oracle(X.astype(np.float64), t, valid)
    np.testing.assert_allclose(ps, want, atol=2e-3)
    assert ps[t == 1].mean() > ps[t == 0].mean() + 0.1


def test_subclassification_recovers_effect():
    rng = np.random.default_rng(19)
    n = 8000
    x = rng.normal(0, 1, (n, 2)).astype(np.float32)
    logits = 1.3 * x[:, 0] + 0.5 * x[:, 1]
    t = (rng.random(n) < 1 / (1 + np.exp(-logits))).astype(np.int32)
    y = (4.0 * t + 2.0 * x[:, 0] + x[:, 1]
         + rng.normal(0, 0.3, n)).astype(np.float32)
    table = Table.from_numpy({"x0": x[:, 0], "x1": x[:, 1], "t": t, "y": y})
    model = fit_logistic(jnp.asarray(x), table["t"], table.valid)
    ps = predict_ps(model, jnp.asarray(x))
    res = subclassify(table, "t", "y", ps, n_subclasses=20)
    est = estimate_ate(res.groups)
    # subclassification reduces the (large) confounding bias substantially
    naive = float(np.mean(y[t == 1]) - np.mean(y[t == 0]))
    assert abs(naive - 4.0) > 1.0
    assert abs(float(est.ate) - 4.0) < 0.35


def test_mahalanobis_transform_whitens():
    rng = np.random.default_rng(23)
    A = rng.normal(0, 1, (3, 3))
    X = (rng.normal(0, 1, (5000, 3)) @ A).astype(np.float32)
    U = np.asarray(mahalanobis_transform(jnp.asarray(X),
                                         jnp.ones(5000, bool)))
    cov = np.cov(U.T)
    np.testing.assert_allclose(cov, np.eye(3), atol=0.15)

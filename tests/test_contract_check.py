"""Contract checker: every rule fires on a seeded violation, stays quiet
on the clean idiom, and the machinery (suppressions, select/ignore,
baselines, CLI exit codes) behaves. The repo itself must scan clean.

Fixtures are tiny synthetic modules written under ``tmp_path``; each
declares ``__engine_owned__ = True`` so path-based scoping never matters
for the rule under test.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.lint import (Finding, load_baseline, run_lint,
                                 split_baselined, write_baseline)

REPO = Path(__file__).resolve().parent.parent

OWNED = "__engine_owned__ = True\n"
_D = textwrap.dedent


def _lint_snippet(tmp_path, source, name="mod.py", **kw):
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    return run_lint([f], root=tmp_path, **kw)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------ ZQL001
def test_zql001_fires_on_raw_jit(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax

        def build(fn):
            return jax.jit(fn)
        """))
    assert _rules(out) == ["ZQL001"]
    assert out[0].line == 5


def test_zql001_fires_on_pjit_and_aliases(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        from jax import jit as J
        from jax.experimental.pjit import pjit

        def build(fn):
            return J(fn), pjit(fn)
        """))
    assert [f.rule for f in out] == ["ZQL001", "ZQL001"]


def test_zql001_quiet_on_counted_jit_and_host_modules(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.launch.trace import counted_jit

        def build(fn):
            return counted_jit(fn, label="query")
        """)) == []
    # not engine-owned: raw jit is fine
    assert _lint_snippet(tmp_path, _D("""\
        __engine_owned__ = False
        import jax

        def build(fn):
            return jax.jit(fn)
        """)) == []


# ------------------------------------------------------------ ZQL002
def test_zql002_fires_on_host_sync_in_hot_path(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax
        import numpy as np
        from repro.launch.trace import hot_path

        @hot_path
        def body(x):
            a = jax.device_get(x)
            b = np.asarray(x)
            c = float(x)
            x.block_until_ready()
            return a, b, c
        """))
    assert [f.rule for f in out] == ["ZQL002"] * 4


def test_zql002_quiet_outside_hot_paths_and_on_constants(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        import numpy as np
        from repro.launch.trace import hot_path

        def host_side(x):
            return np.asarray(x)            # not a hot path: fine

        @hot_path
        def body(x):
            return x * float(1e-3)          # constant cast: fine
        """)) == []


# ------------------------------------------------------------ ZQL003
def test_zql003_fires_on_order_sensitive_sum_in_estimator(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax.numpy as jnp

        def estimate_view(y, m):
            return jnp.sum(jnp.where(m, y, 0.0))
        """))
    assert _rules(out) == ["ZQL003"]


def test_zql003_quiet_on_chunked_sum_and_exact_counts(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        import jax.numpy as jnp
        from repro.kernels.segment_stats import chunked_sum

        def estimate_view(y, m):
            n = jnp.sum(m.astype(jnp.int32))     # exact integer count
            return chunked_sum(jnp.where(m, y, 0.0)), n

        def merge_tables(a, b):
            return jnp.sum(a) + jnp.sum(b)       # not an estimator
        """)) == []


# ------------------------------------------------------------ ZQL004
def test_zql004_fires_on_donated_then_reused_local(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.core.fused import get_fused_ingest

        def step(cols, valid, state, counter, n_batches):
            prog = get_fused_ingest()
            new_state, verdicts = prog(cols, valid, state, counter,
                                       n_batches)
            return new_state, verdicts, state
        """))
    assert _rules(out) == ["ZQL004"]


def test_zql004_fires_on_duplicate_donate_argnums(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.launch.trace import counted_jit

        def build(fn):
            return counted_jit(fn, donate_argnums=(0, 0))
        """))
    assert _rules(out) == ["ZQL004"]


def test_zql004_quiet_when_donated_state_is_rebound(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.core.fused import get_fused_ingest

        def step(cols, valid, state, counter, n_batches):
            prog = get_fused_ingest()
            new_state, verdicts = prog(cols, valid, state, counter,
                                       n_batches)
            state = new_state
            return state, verdicts
        """)) == []


# ------------------------------------------------------------ ZQL005
_PALLAS_RMW = OWNED + _D("""\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _merge_kernel(pos_ref, table_ref, vals_ref, out_ref):
        out_ref[...] = table_ref[...]
        out_ref[...] += vals_ref[...]

    def merge(pos, table, vals):
        return pl.pallas_call(
            _merge_kernel,
            out_shape=jax.ShapeDtypeStruct(table.shape, jnp.float32),
            %s
        )(pos, table, vals)
    """)


def test_zql005_fires_on_unaliased_rmw_kernel(tmp_path):
    out = _lint_snippet(tmp_path, _PALLAS_RMW % "interpret=True,")
    assert _rules(out) == ["ZQL005"]


def test_zql005_quiet_when_aliased(tmp_path):
    src = _PALLAS_RMW % "input_output_aliases={1: 0}, interpret=True,"
    assert _lint_snippet(tmp_path, src) == []


# ------------------------------------------------------------ ZQL006
def test_zql006_fires_on_unbucketed_shape_capture(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax.numpy as jnp
        from repro.launch.trace import counted_jit

        def build(batch):
            n = batch.nrows

            def body(cols):
                return jnp.pad(cols, (0, n))

            return counted_jit(body)
        """))
    assert _rules(out) == ["ZQL006"]


def test_zql006_quiet_in_cached_factories(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        import functools
        import jax.numpy as jnp
        from repro.launch.trace import counted_jit

        @functools.lru_cache(maxsize=8)
        def build(capacity):
            def body(cols):
                return jnp.pad(cols, (0, capacity))

            return counted_jit(body)
        """)) == []


# ------------------------------------------------------------ ZQL007
def test_zql007_fires_on_sync_inside_dispatch_commit_window(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax

        class Engine:
            def ingest(self, cols, valid, state, counter, n_batches):
                prog = self._fused_program(False)
                new_state, verdicts = prog(cols, valid, state, counter,
                                           n_batches)
                f = jax.device_get(verdicts)       # sync before commit
                self._unpack_view_state(new_state)
                return f
        """))
    assert _rules(out) == ["ZQL007"]
    assert out[0].line == 9


def test_zql007_fires_on_device_fetch_and_direct_factory_call(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.core.fused import get_fused_ingest
        from repro.launch.trace import device_fetch

        class Engine:
            def ingest(self, cols, valid, state, counter, n_batches):
                new_state, verdicts = get_fused_ingest()(
                    cols, valid, state, counter, n_batches)
                f = device_fetch(verdicts)
                self.commit()
                return f
        """))
    assert _rules(out) == ["ZQL007"]


def test_zql007_quiet_when_commit_precedes_the_fetch(tmp_path):
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        import jax

        class Engine:
            def ingest(self, cols, valid, state, counter, n_batches):
                prog = self._fused_program(False)
                new_state, verdicts = prog(cols, valid, state, counter,
                                           n_batches)
                self._unpack_view_state(new_state)  # commit closes window
                return jax.device_get(verdicts)     # lazy verdict: fine

            def report(self, verdicts):
                return jax.device_get(verdicts)     # no open dispatch: fine
        """)) == []


# ------------------------------------------------------------ ZQL008
def test_zql008_fires_on_commit_before_wal_append(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        class Durable:
            def ingest(self, batch):
                rep = self.engine.ingest(batch)     # acked first: WRONG
                self.wal.append_batch(1, batch.columns, batch.valid)
                return rep
        """))
    assert _rules(out) == ["ZQL008"]
    assert out[0].line == 4


def test_zql008_fires_on_version_bump_before_fsync(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        class Durable:
            def commit(self):
                self._state_version += 1            # acked first: WRONG
                self.wal.sync()
        """))
    assert _rules(out) == ["ZQL008"]


def test_zql008_quiet_on_journal_first_and_no_wal(tmp_path):
    # the correct protocol: append/fsync, THEN dispatch/commit
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        class Durable:
            def ingest(self, batch):
                self.wal.append_batch(1, batch.columns, batch.valid)
                return self.engine.ingest(batch)

            def commit(self):
                self.wal.sync()
                out = self.engine.commit()
                self._state_version += 1
                return out

            def checkpoint(self):
                self.wal.sync()
                snap = self.engine.export_canonical()
                self.wal.rotate()                   # bookkeeping, no event
                return snap
        """)) == []
    # functions that never journal are out of scope (the engines
    # themselves bump _state_version freely)
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        class Engine:
            def _post_state_swap(self):
                self._state_version += 1
        """)) == []


# ------------------------------------------------------------ ZQL009
def test_zql009_fires_on_apply_without_verify(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        class Follower:
            def receive(self, records):
                for rec in records:
                    self._apply_one(rec)            # unverified: WRONG
        """))
    assert _rules(out) == ["ZQL009"]
    assert out[0].line == 5


def test_zql009_fires_on_apply_before_verify(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.core.replication import verify_records

        class Follower:
            def catch_up(self, records):
                self._apply_records(records)        # applied first: WRONG
                verify_records(records, 1, 0)
        """))
    assert _rules(out) == ["ZQL009"]


def test_zql009_quiet_on_verify_then_apply(tmp_path):
    # both verification shapes: the module gate, and a CRC-validating
    # read on a log-named receiver
    assert _lint_snippet(tmp_path, OWNED + _D("""\
        from repro.core.replication import verify_records

        class Follower:
            def catch_up(self, records):
                fresh = verify_records(records, self.epoch, self.seq)
                self._apply_records(fresh)

            def replay(self):
                records, cur = self.wal.read_tail(self.cursor)
                self._apply_records(records)

            def _apply_records(self, records):
                for rec in records:
                    self._apply_one(rec)
        """)) == []
    # non-engine-owned modules are out of scope
    assert _lint_snippet(tmp_path, _D("""\
        def helper(records, engine):
            for rec in records:
                engine._apply_one(rec)
        """)) == []


def test_inline_suppression_drops_the_finding(tmp_path):
    out = _lint_snippet(tmp_path, OWNED + _D("""\
        import jax

        def build(fn):
            return jax.jit(fn)  # zql: ok[ZQL001] fixture exercises raw jit
        """))
    assert out == []


def test_star_suppression_and_select_ignore(tmp_path):
    src = OWNED + _D("""\
        import jax

        def build(fn):
            a = jax.jit(fn)  # zql: ok[*] fixture
            return a, jax.jit(fn)
        """)
    out = _lint_snippet(tmp_path, src)
    assert [f.rule for f in out] == ["ZQL001"] and out[0].line == 6
    assert _lint_snippet(tmp_path, src, select=["ZQL002"]) == []
    assert _lint_snippet(tmp_path, src, ignore=["ZQL001"]) == []


# ------------------------------------------------------------- baseline
def test_baseline_roundtrip_partitions_findings(tmp_path):
    f1 = Finding("a.py", 3, 1, "ZQL001", "m", snippet="x = jax.jit(f)")
    f2 = Finding("b.py", 9, 1, "ZQL002", "m", snippet="y = float(v)")
    base = tmp_path / "base.json"
    write_baseline(base, [f1])
    fps = load_baseline(base)
    assert fps == {f1.fingerprint()}
    new, old = split_baselined([f1, f2], fps)
    assert new == [f2] and old == [f1]
    # fingerprint keys on content, not line number
    moved = Finding("a.py", 77, 1, "ZQL001", "m", snippet="x = jax.jit(f)")
    assert moved.fingerprint() == f1.fingerprint()
    assert load_baseline(tmp_path / "missing.json") == set()


# ------------------------------------------------------------------ CLI
def _cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "contract_check.py"), *args],
        capture_output=True, text=True, cwd=cwd)


def test_cli_repo_is_clean():
    r = _cli()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_cli_fails_on_violation_and_baseline_grandfathers(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(OWNED + "import jax\nprog = jax.jit(len)\n")
    r = _cli(str(bad))
    assert r.returncode == 1
    assert "ZQL001" in r.stderr
    base = tmp_path / "base.json"
    r = _cli(str(bad), "--baseline", str(base), "--update-baseline")
    assert r.returncode == 0
    assert json.loads(base.read_text())[0]["rule"] == "ZQL001"
    r = _cli(str(bad), "--baseline", str(base))
    assert r.returncode == 0
    assert "baselined" in r.stdout


def test_cli_select_ignore(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(OWNED + "import jax\nprog = jax.jit(len)\n")
    assert _cli(str(bad), "--select", "ZQL002").returncode == 0
    assert _cli(str(bad), "--ignore", "ZQL001").returncode == 0


# ------------------------------------------------------- path scoping
def test_path_scoping_defaults(tmp_path):
    pkg = tmp_path / "src" / "repro"
    core = pkg / "core"
    launch = pkg / "launch"
    core.mkdir(parents=True)
    launch.mkdir(parents=True)
    bad = "import jax\nprog = jax.jit(len)\n"
    (core / "engine.py").write_text(bad)
    (launch / "driver.py").write_text(bad)
    out = run_lint([tmp_path / "src"], root=tmp_path)
    assert [(f.rule, Path(f.path).name) for f in out] == [
        ("ZQL001", "engine.py")]


# ------------------------------------------------------- jaxpr audit
def test_jaxpr_audit_full_matrix_passes():
    from repro.analysis.jaxpr_audit import run_audit

    results = run_audit()
    assert len(results) == 24, [r.format() for r in results]
    bad = [r.format() for r in results if not r.ok]
    assert not bad, bad
    contracts = {r.contract for r in results}
    assert {"ingest-donation-static", "ingest-1-dispatch",
            "ingest-transfer-clean", "ingest-donation-runtime",
            "query-1-dispatch", "query-transfer-clean",
            "query-cached-0-dispatch", "batch-query-1-dispatch",
            "evict-donation-runtime", "overlap-ingest-0-sync",
            "overlap-committed-buffers-live",
            "overlap-commit-bit-identity"} == contracts
    assert {r.engine for r in results} == {"replicated", "partitioned"}

"""Differential property harness for the online engines.

Random interleaved streams of ingest / retract / evict / query ops run
simultaneously through the REPLICATED engine, the PARTITIONED engine and a
from-scratch pure-python oracle that re-derives every view's group stats
(dict-of-key accumulators, eviction stamps included). After every query
and at the end of the stream the harness asserts:

  * bit-identical cuboid stats per view (integer outcomes => exact f32),
  * identical matched sets (group level and row level),
  * bit-identical ATE / ATT / Neyman variance (the canonical query path
    makes estimates a deterministic function of the group stats alone),
  * the retraction guard fires exactly when the oracle says the stream is
    not retractable, leaving state untouched.

STREAM ENCODING (shrinking-friendly): a stream is a list of flat int
4-tuples ``(op, a, b, c)`` — hypothesis shrinks toward shorter lists and
smaller ints (smaller batches, earlier batch indices, fewer novel keys),
and the seeded fallback (always run; sole coverage when hypothesis is not
installed) generates the same encoding so failures replay identically.

  op 0 ingest   a: size bucket   b: x0 novelty cap   c: batch seed
  op 1 retract  a: live-batch index (guard asserted when invalid)
  op 2 evict    a: ttl bucket
  op 3 query    a: treatment     b: subpopulation selector
"""
import numpy as np
import pytest

from repro.core import (CoarsenSpec, DurableEngine, OnlineEngine,
                        PartitionedOnlineEngine)
from repro.core.cem import make_codec
from repro.core.online import BASE_VIEW, _estimate_view
from repro.core import cube
from repro.data.columnar import Table, _round_capacity
from repro.launch.trace import count_dispatches, count_host_syncs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}
QUERY_DIMS = ("x2",)
OUTCOME = "y"
TNAMES = tuple(sorted(TREATMENTS))
SUBPOPS = (None, {"x2": [0]}, {"x2": [1, 2]}, {"x0": [0, 1]})


def _view_dims():
    dims = {BASE_VIEW: tuple(sorted(set(QUERY_DIMS).union(
        *[set(c) for c in TREATMENTS.values()])))}
    for t, cov in TREATMENTS.items():
        dims[t] = tuple(sorted(set(cov) | set(QUERY_DIMS)))
    return dims


VIEW_DIMS = _view_dims()
STAT_NAMES = cube.stat_names(TNAMES)


def _batch(size: int, x0_hi: int, seed: int):
    """Random batch with INTEGER outcomes (exact f32 sums => the oracle's
    python arithmetic matches device arithmetic bit for bit)."""
    rng = np.random.default_rng(seed)
    cols = {
        "x0": rng.integers(0, x0_hi, size).astype(np.int32),
        "x1": rng.integers(0, 4, size).astype(np.int32),
        "x2": rng.integers(0, 3, size).astype(np.int32),
    }
    cols["ta"] = (rng.random(size) < 0.2 + 0.5 * cols["x0"] / 4).astype(
        np.int32)
    cols["tb"] = (rng.random(size) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, size)
    cols["y"] = np.round(y).astype(np.float32)
    return cols, rng.random(size) > 0.1


class Oracle:
    """From-scratch reference: per-view dict-of-key stat accumulators with
    last-touch stamps — the most obvious possible implementation of the
    maintained state, independent of the JAX engines."""

    def __init__(self):
        self.views = {name: {} for name in (BASE_VIEW, *TNAMES)}
        self.touch = {name: {} for name in (BASE_VIEW, *TNAMES)}
        self.count = 0

    @staticmethod
    def _deltas(cols, valid):
        """Per-view {key tuple: stat list} contributions of one batch."""
        out = {name: {} for name in VIEW_DIMS}
        y = cols[OUTCOME].astype(np.float64)
        for i in np.nonzero(valid)[0]:
            row = [1.0, float(y[i]), float(y[i]) ** 2]
            for t in TNAMES:
                tv = float(cols[t][i])
                row += [tv, tv * float(y[i]), tv * float(y[i]) ** 2]
            for name, dims in VIEW_DIMS.items():
                key = tuple(int(cols[d][i]) for d in dims)
                acc = out[name].setdefault(key, [0.0] * len(STAT_NAMES))
                for j, v in enumerate(row):
                    acc[j] += v
        return out

    def can_retract(self, cols, valid) -> bool:
        """Mirror of the engine guard: every delta key (at every view's
        granularity) still materialized, and no base count goes negative."""
        deltas = self._deltas(cols, valid)
        for name, d in deltas.items():
            for key in d:
                if key not in self.views[name]:
                    return False
        count_cols = [0] + [3 + 3 * i for i in range(len(TNAMES))]
        for key, row in deltas[BASE_VIEW].items():
            have = self.views[BASE_VIEW][key]
            for j in count_cols:
                if have[j] - row[j] < 0:
                    return False
        return True

    def apply(self, cols, valid, retract: bool = False):
        deltas = self._deltas(cols, valid)
        self.count += 1
        sign = -1.0 if retract else 1.0
        for name, d in deltas.items():
            view, touch = self.views[name], self.touch[name]
            for key, row in d.items():
                acc = view.setdefault(key, [0.0] * len(STAT_NAMES))
                for j, v in enumerate(row):
                    acc[j] += sign * v
                touch[key] = self.count

    def evict(self, ttl: int):
        cutoff = self.count - ttl
        for name in self.views:
            stale = [k for k, c in self.touch[name].items() if c < cutoff]
            for k in stale:
                del self.views[name][k]
                del self.touch[name][k]

    def stat_map(self, name):
        return {key: tuple(row) for key, row in self.views[name].items()
                if row[0] != 0.0}

    def cuboid(self, name) -> cube.Cuboid:
        """The view as a canonical (key-sorted) Cuboid — feeds the SAME
        query code the engines run, so estimate comparisons are bitwise."""
        dims = VIEW_DIMS[name]
        codec = make_codec({d: SPECS[d] for d in dims})
        keys = sorted(self.views[name])
        buckets = {d: np.asarray([k[i] for k in keys], np.int32)
                   for i, d in enumerate(dims)}
        import jax.numpy as jnp
        n = len(keys)
        hi, lo = codec.pack({d: jnp.asarray(v) for d, v in buckets.items()},
                            jnp.ones((n,), bool))
        order = np.lexsort((np.asarray(lo), np.asarray(hi)))
        cap = _round_capacity(n, 64)
        stats = {}
        for j, sname in enumerate(STAT_NAMES):
            col = np.zeros(cap, np.float32)
            col[:n] = np.asarray(
                [self.views[name][keys[i]][j] for i in order], np.float32)
            stats[sname] = jnp.asarray(col)
        from repro.core.keys import INVALID_HI, INVALID_LO
        phi = np.full(cap, np.uint32(INVALID_HI))
        plo = np.full(cap, np.uint32(INVALID_LO))
        phi[:n] = np.asarray(hi)[order]
        plo[:n] = np.asarray(lo)[order]
        gv = np.zeros(cap, bool)
        gv[:n] = True
        return cube.Cuboid(codec=codec, key_hi=jnp.asarray(phi),
                           key_lo=jnp.asarray(plo), stats=stats,
                           group_valid=jnp.asarray(gv), treatments=TNAMES)

    def ate(self, treatment, subpopulation):
        import jax.numpy as jnp
        cub = self.cuboid(treatment)
        nt = cub.stats[f"t_{treatment}"]
        keep = cub.group_valid & (nt > 0) & (cub.stats["one"] - nt > 0)
        return _estimate_view(cub, jnp.asarray(keep), treatment,
                              subpopulation)

    def matched_mask(self, treatment, cols, valid) -> np.ndarray:
        matched_keys = {k for k, row in self.views[treatment].items()
                        if row[0 + 3 + 3 * TNAMES.index(treatment)] > 0
                        and row[0] - row[3 + 3 * TNAMES.index(treatment)] > 0}
        dims = VIEW_DIMS[treatment]
        out = np.zeros(len(valid), bool)
        for i in np.nonzero(valid)[0]:
            out[i] = tuple(int(cols[d][i]) for d in dims) in matched_keys
        return out


def _engine_stat_map(cub):
    gv = (np.asarray(cub.group_valid)
          & (np.asarray(cub.stats["one"]) != 0)).reshape(-1)
    arr = {k: np.asarray(v).reshape(-1)[gv] for k, v in cub.stats.items()}
    hi = np.asarray(cub.key_hi).reshape(-1)[gv]
    lo = np.asarray(cub.key_lo).reshape(-1)[gv]
    out = {}
    for i, (h, l) in enumerate(zip(hi, lo)):
        dims = cub.codec.names
        key = tuple(int(cub.codec.extract(
            np.asarray([h], np.uint32), np.asarray([l], np.uint32), d)[0])
            for d in dims)
        out[key] = tuple(float(arr[s][i]) for s in STAT_NAMES)
    return out


def _check_state(oracle, engines, history):
    """Full differential check: stats, matched sets, estimates."""
    probe_cols = {k: np.concatenate([c[k] for c, _ in history])
                  for k in history[0][0]} if history else None
    probe_valid = (np.concatenate([v for _, v in history])
                   if history else None)
    for label, eng in engines.items():
        assert _engine_stat_map(eng.base if isinstance(
            eng.base, cube.Cuboid) else cube.unpartition_cuboid(eng.base)
            ) == oracle.stat_map(BASE_VIEW), (label, "base")
        for t in TNAMES:
            cub, _ = eng._view_state(t)
            assert _engine_stat_map(cub) == oracle.stat_map(t), (label, t)
            if history:
                probe = Table.from_numpy(probe_cols, probe_valid)
                want_mask = oracle.matched_mask(t, probe_cols, probe_valid)
                # fused (routed on the partitioned layout) AND assemble
                # row lookups must both reproduce the oracle's row mask
                np.testing.assert_array_equal(
                    np.asarray(eng.matched_rows(t, probe)), want_mask,
                    err_msg=f"{label}/{t} matched rows (fused)")
                np.testing.assert_array_equal(
                    np.asarray(eng.matched_rows(t, probe,
                                                pipeline="assemble")),
                    want_mask,
                    err_msg=f"{label}/{t} matched rows (assemble)")


def _check_query(oracle, engines, treatment, subpop, qseed: int = 0):
    """Every interleaved query is answered FOUR ways per engine — the
    cached ``ate()`` entry point, the uncached fused one-dispatch
    program, the planner-era assemble baseline, and the BATCHED spec-
    table program (the query embedded in a random-size batch of mixed
    specs, one dispatch for the whole batch) — and all must be
    bit-identical to the oracle's estimate (incl. post-eviction and
    subpopulation queries; the CI device matrix replays this at 1/2/4
    forced host devices)."""
    want = oracle.ate(treatment, subpop)
    for label, eng in engines.items():
        paths = {
            "ate": eng.ate(treatment, subpopulation=subpop),
            "fused": eng._estimate(treatment, subpop, pipeline="fused"),
            "assemble": eng._estimate(treatment, subpop,
                                      pipeline="assemble"),
        }
        for pname, got in paths.items():
            where = (label, pname, treatment, subpop)
            assert float(got.ate) == float(want.ate), where
            assert float(got.att) == float(want.att), where
            assert float(got.variance) == float(want.variance), where
            assert int(got.n_groups) == int(want.n_groups), where
            assert float(got.n_matched_treated) == float(
                want.n_matched_treated), where
    # batched path: the query rides in a random-B batch of mixed specs
    # (cache bypassed so the batched program really computes); the whole
    # batch is ONE dispatch and every slot is bitwise equal to its
    # single-spec fused answer (slot 0 additionally to the oracle)
    rng = np.random.default_rng(qseed)
    batch_specs = [(treatment, subpop)] + [
        (TNAMES[int(rng.integers(0, len(TNAMES)))],
         SUBPOPS[int(rng.integers(0, len(SUBPOPS)))])
        for _ in range(int(rng.integers(0, 4)))]
    for label, eng in engines.items():
        keys = [eng._normalize_spec(s) for s in batch_specs]
        with count_dispatches(label="query") as n:
            batch = eng._batched_estimate(keys)
        assert n() == 1, (label, len(batch_specs))
        assert float(batch[0].ate) == float(want.ate), (label, "batched")
        for got, (t, sub) in zip(batch, batch_specs):
            single = eng._estimate(t, sub, pipeline="fused")
            where = (label, "batched", t, sub)
            for f in ("ate", "att", "variance", "n_matched_treated",
                      "n_matched_control", "n_groups"):
                g = np.asarray(getattr(got, f))
                s = np.asarray(getattr(single, f))
                assert g.tobytes() == s.tobytes(), (*where, f, g, s)


def run_stream(ops, n_parts: int):
    """Decode + run one encoded op stream through both engines and the
    oracle, asserting differential equality along the way."""
    kw = dict(granule=64, delta_granule=16, query_dims=QUERY_DIMS,
              reservoir_size=256)
    engines = {
        "replicated": OnlineEngine(SPECS, TREATMENTS, OUTCOME, **kw),
        f"partitioned[{n_parts}]": PartitionedOnlineEngine(
            SPECS, TREATMENTS, OUTCOME, n_parts=n_parts, **kw),
    }
    oracle = Oracle()
    history = []          # every batch ever ingested (for row-level probes)
    n_checked_guard = 0
    for op, a, b, c in ops:
        if op == 0:
            size = 40 + 60 * (a % 8)
            x0_hi = 1 + (b % 5)
            cols, valid = _batch(size, x0_hi, c)
            for eng in engines.values():
                eng.ingest(Table.from_numpy(cols, valid))
            oracle.apply(cols, valid)
            history.append((cols, valid))
        elif op == 1:
            # retract ANY previously seen batch — already-retracted or
            # post-eviction targets are invalid, and the oracle decides
            if not history:
                continue
            cols, valid = history[a % len(history)]
            batch = Table.from_numpy(cols, valid)
            if oracle.can_retract(cols, valid):
                for eng in engines.values():
                    eng.ingest(batch, retract=True)
                oracle.apply(cols, valid, retract=True)
            else:
                # the guard must fire on BOTH engines and leave state alone
                for eng in engines.values():
                    with pytest.raises(ValueError):
                        eng.ingest(batch, retract=True)
                n_checked_guard += 1
                _check_state(oracle, engines, history)
        elif op == 2:
            ttl = a % 3
            for eng in engines.values():
                eng.evict(ttl=ttl)
            oracle.evict(ttl)
        else:
            _check_query(oracle, engines, TNAMES[a % len(TNAMES)],
                         SUBPOPS[b % len(SUBPOPS)], qseed=c)
    _check_state(oracle, engines, history)
    for i, t in enumerate(TNAMES):
        _check_query(oracle, engines, t, None, qseed=i)
    return n_checked_guard


def run_stream_durable(ops, n_parts: int, kill_at: int, tmp_path):
    """The crash twin of :func:`run_stream`: both engines run behind
    :class:`~repro.core.durability.DurableEngine` wrappers (every op
    journaled, a checkpoint taken mid-stream), the wrappers are KILLED at
    the ``kill_at``-th op boundary — abandoned without close(), exactly
    the disk state a dead process leaves — and recovery must rebuild
    FRESH engines that continue the stream bitwise: the dict oracle never
    notices the crash."""
    kw = dict(granule=64, delta_granule=16, query_dims=QUERY_DIMS,
              reservoir_size=256)

    def fresh():
        return {
            "replicated": OnlineEngine(SPECS, TREATMENTS, OUTCOME, **kw),
            f"partitioned[{n_parts}]": PartitionedOnlineEngine(
                SPECS, TREATMENTS, OUTCOME, n_parts=n_parts, **kw),
        }

    dirs = {lb: str(tmp_path / lb.replace("[", "-").replace("]", ""))
            for lb in fresh()}
    engines = {lb: DurableEngine(eng, dirs[lb])
               for lb, eng in fresh().items()}
    oracle = Oracle()
    history = []
    ckpt_at = max(0, kill_at // 2)
    killed = False
    for i, (op, a, b, c) in enumerate(ops):
        if i == ckpt_at:
            for d in engines.values():
                d.checkpoint(wait=True)
        if i == kill_at:
            engines = {lb: DurableEngine.recover(eng, dirs[lb])
                       for lb, eng in fresh().items()}
            killed = True
            _check_state(oracle, engines, history)
        if op == 0:
            size = 40 + 60 * (a % 8)
            cols, valid = _batch(size, 1 + (b % 5), c)
            for eng in engines.values():
                eng.ingest(Table.from_numpy(cols, valid))
            oracle.apply(cols, valid)
            history.append((cols, valid))
        elif op == 1:
            if not history:
                continue
            cols, valid = history[a % len(history)]
            batch = Table.from_numpy(cols, valid)
            if oracle.can_retract(cols, valid):
                for eng in engines.values():
                    eng.ingest(batch, retract=True)
                oracle.apply(cols, valid, retract=True)
            else:
                # the guard fires THROUGH the wrapper and must also roll
                # the journaled record back (replay would re-raise it)
                for eng in engines.values():
                    with pytest.raises(ValueError):
                        eng.ingest(batch, retract=True)
                _check_state(oracle, engines, history)
        elif op == 2:
            ttl = a % 3
            for eng in engines.values():
                eng.evict(ttl=ttl)
            oracle.evict(ttl)
        else:
            _check_query(oracle, engines, TNAMES[a % len(TNAMES)],
                         SUBPOPS[b % len(SUBPOPS)], qseed=c)
    assert killed, "kill_at beyond the stream: crash path not exercised"
    _check_state(oracle, engines, history)
    for i, t in enumerate(TNAMES):
        _check_query(oracle, engines, t, None, qseed=i)
    for eng in engines.values():
        eng.close()


def _seeded_ops(seed: int, n_ops: int = 10):
    """Seeded generator of the same encoding the hypothesis strategy
    draws — sole coverage where hypothesis is not installed."""
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        op = int(rng.integers(0, 4))
        ops.append((op, int(rng.integers(0, 8)), int(rng.integers(0, 8)),
                    int(rng.integers(0, 1 << 16))))
    return ops


def run_stream_overlap(ops, n_parts: int, max_inflight: int = 3):
    """The MVCC twin of :func:`run_stream`: the engines run with
    ``overlap=True`` so every ingest is a dispatch-only hop against the
    in-flight chain while the ORACLE DELIBERATELY LAGS — it applies a
    batch only when the engines' commit barrier fires (explicit flush,
    retract, evict, or the ``max_inflight`` auto-commit). Queries
    interleaved with uncommitted in-flight ingests must therefore match
    the lagging oracle bitwise AND carry the committed snapshot version;
    dispatch-only ingests must perform ZERO host syncs."""
    kw = dict(granule=64, delta_granule=16, query_dims=QUERY_DIMS,
              reservoir_size=256, overlap=True, max_inflight=max_inflight)
    engines = {
        "replicated": OnlineEngine(SPECS, TREATMENTS, OUTCOME, **kw),
        f"partitioned[{n_parts}]": PartitionedOnlineEngine(
            SPECS, TREATMENTS, OUTCOME, n_parts=n_parts, **kw),
    }
    oracle = Oracle()
    history = []
    pending = []      # dispatched, uncommitted — the oracle's lag window
    pendings = []     # every PendingIngest handed out, for end-of-stream
    versions = {lb: eng.snapshot_version() for lb, eng in engines.items()}

    def _sync_oracle():
        for cols, valid in pending:
            oracle.apply(cols, valid)
        pending.clear()
        for lb, eng in engines.items():
            versions[lb] = eng.snapshot_version()

    def flush():
        for eng in engines.values():
            eng.commit()
        _sync_oracle()

    for op, a, b, c in ops:
        if op == 0:
            cols, valid = _batch(40 + 60 * (a % 8), 1 + (b % 5), c)
            batch = Table.from_numpy(cols, valid)
            # a full pipeline auto-commits inside ingest() — a documented
            # (and counted) sync point; below depth the hop must be free
            will_auto = len(pending) >= max_inflight
            for lb, eng in engines.items():
                with count_host_syncs() as s:
                    rep = eng.ingest(batch)
                if not will_auto:
                    assert s() == 0, (lb, "in-flight ingest must not sync")
                assert not rep.committed, lb
                pendings.append((lb, rep))
            if will_auto:
                _sync_oracle()
            pending.append((cols, valid))
            history.append((cols, valid))
        elif op == 1:
            if not history:
                continue
            cols, valid = history[a % len(history)]
            flush()          # retraction is a commit barrier in the engine
            batch = Table.from_numpy(cols, valid)
            if oracle.can_retract(cols, valid):
                for eng in engines.values():
                    eng.ingest(batch, retract=True)
                oracle.apply(cols, valid, retract=True)
            else:
                for eng in engines.values():
                    with pytest.raises(ValueError):
                        eng.ingest(batch, retract=True)
                _check_state(oracle, engines, history)
            for lb, eng in engines.items():
                versions[lb] = eng.snapshot_version()
        elif op == 2:
            flush()
            if b % 2:
                continue     # plain commit barrier, no eviction
            ttl = a % 3
            for eng in engines.values():
                eng.evict(ttl=ttl)
            oracle.evict(ttl)
            for lb, eng in engines.items():
                versions[lb] = eng.snapshot_version()
        else:
            # queries serve the COMMITTED snapshot: bitwise equal to the
            # lagging oracle, tagged with the unchanged committed version
            t = TNAMES[a % len(TNAMES)]
            sub = SUBPOPS[b % len(SUBPOPS)]
            _check_query(oracle, engines, t, sub, qseed=c)
            for lb, eng in engines.items():
                assert eng.snapshot_version() == versions[lb], (
                    lb, "in-flight ingests must not move the snapshot")
                est = eng.ate(t, subpopulation=sub)
                assert est.state_version == versions[lb], lb
    flush()
    assert all(rep.committed for _, rep in pendings)
    _check_state(oracle, engines, history)
    for i, t in enumerate(TNAMES):
        _check_query(oracle, engines, t, None, qseed=i)


@pytest.mark.parametrize("seed,n_parts", [
    (0, 1), (1, 2), (2, 4), (3, 2), (4, 3), (5, 4), (6, 2), (7, 4),
])
def test_differential_stream_seeded(seed, n_parts):
    run_stream(_seeded_ops(seed), n_parts)


@pytest.mark.parametrize("seed,n_parts", [(0, 1), (1, 2), (2, 4), (5, 2)])
def test_differential_overlap_stream_seeded(seed, n_parts):
    run_stream_overlap(_seeded_ops(seed, n_ops=12), n_parts)


@pytest.mark.parametrize("seed,n_parts,kill_at", [
    (0, 2, 3), (3, 4, 5), (5, 2, 8),
])
def test_differential_durable_crash_stream_seeded(seed, n_parts, kill_at,
                                                  tmp_path):
    run_stream_durable(_seeded_ops(seed), n_parts, kill_at, tmp_path)


def test_differential_overlap_forced_paths():
    # deterministic overlap stream that provably exercises: queries with
    # 1 and 2 uncommitted hops in flight, the max_inflight auto-commit,
    # the retract commit barrier, a wide in-flight batch whose delta
    # overflow forces commit-time rollback-and-replay, a plain flush, and
    # post-eviction queries — all against the lagging oracle
    ops = [
        (0, 2, 0, 21),      # hop 1 in flight
        (3, 0, 1, 0),       # query at committed v0, 1 hop pending
        (0, 2, 4, 22),      # hop 2 (novel keys) chained on hop 1
        (3, 1, 2, 0),       # query still at v0, 2 hops pending
        (0, 3, 4, 23),      # hop 3: pipeline full
        (0, 1, 2, 24),      # 4th ingest -> auto-commit, then dispatch
        (3, 1, 0, 0),       # query at the auto-committed version
        (1, 0, 0, 0),       # retract batch 0: commit barrier + sync path
        (0, 7, 4, 25),      # wide 460-row hop -> overflow verdict in flight
        (2, 1, 1, 0),       # plain flush -> rollback-and-replay commits it
        (3, 0, 0, 0),
        (2, 1, 0, 0),       # evict ttl=1 (its own commit barrier)
        (3, 1, 3, 0),       # post-eviction query
    ]
    run_stream_overlap(ops, 2)


def test_differential_stream_forced_paths():
    # deterministic stream that provably hits every maintenance path:
    # grow (novel keys), retract, invalid retract (guard), evict,
    # delta-capacity overflow (wide batch >> delta_granule=16), queries
    ops = [
        (0, 2, 0, 11),      # narrow keys
        (3, 0, 1, 0),       # query subpop
        (0, 2, 4, 12),      # novel keys -> grow path
        (1, 0, 0, 0),       # retract first batch
        (1, 0, 0, 0),       # retract it AGAIN -> guard fires
        (0, 7, 4, 13),      # wide 460-row batch -> delta overflow fallback
        (3, 1, 2, 0),
        (2, 1, 0, 0),       # evict ttl=1
        (3, 0, 1, 0),       # post-eviction query (subpop), pre-resurrect
        (3, 1, 0, 0),       # post-eviction unrestricted query
        (0, 3, 4, 14),      # resurrection after evict
        (3, 0, 0, 0),
        (3, 1, 3, 0),
    ]
    guards = run_stream(ops, 4)
    assert guards >= 1     # the invalid retraction was actually checked


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 7), st.integers(0, 7),
                  st.integers(0, 1 << 16)),
        min_size=1, max_size=10)

    @given(ops=OPS, n_parts=st.integers(1, 4))
    @settings(max_examples=12, deadline=None)
    def test_differential_stream_hypothesis(ops, n_parts):
        run_stream(ops, n_parts)

    @given(ops=OPS, n_parts=st.integers(1, 4),
           max_inflight=st.integers(1, 4))
    @settings(max_examples=8, deadline=None)
    def test_differential_overlap_stream_hypothesis(ops, n_parts,
                                                    max_inflight):
        run_stream_overlap(ops, n_parts, max_inflight=max_inflight)

"""Replication tier: WAL shipping, bounded staleness, failover, chaos.

The contract under test (docs/architecture.md — Replication & failover):
a follower at applied-seq s is BITWISE identical to the primary at seq s
— and therefore to a never-crashed twin fed the same first s batches
(the lagging-oracle property). That must survive every chaos crash point
on the ship/apply/promote boundaries, torn shipped spans, follower
crashes, lagging-replica promotion, and a zombie primary waking up after
failover (whose appends must be fenced at BOTH layers: its own log and
any follower it tries to ship to). Steady-state primary ingest must stay
one dispatch / zero host syncs with shipping active.
"""
import os

import numpy as np
import jax
import pytest

from fault_injection import (REPLICATION_CRASH_POINTS, FaultInjector,
                             InjectedCrash, tear_ship)
from repro.core import ReplicatedEngine, StaleEpochError
from repro.core import wal as wal_mod
from repro.core.replication import (PrimaryDownError, ReplicationError,
                                    SplitBrainError, verify_records)
from repro.core.serving import QuerySpec
from repro.launch.trace import count_dispatches, count_host_syncs
from test_online_recovery import (_assert_bitwise, _assert_twin_equal,
                                  _batch, _fresh)

LAYOUTS = ("replicated", "replicated", "partitioned")


class _Clock:
    """Deterministic injectable time source for heartbeats/staleness."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _cluster(tmp_path, layouts=LAYOUTS, name="cluster", **kw):
    clock = _Clock()
    kw.setdefault("heartbeat_timeout_s", 5.0)
    eng = ReplicatedEngine([_fresh(lay) for lay in layouts],
                           str(tmp_path / name), clock=clock, **kw)
    return eng, clock


def _twin_at(batches, seq, layout="overlap"):
    """A never-crashed single-node oracle fed the first ``seq`` batches
    (each ingest is exactly one WAL seq)."""
    twin = _fresh(layout)
    for b in batches[:seq]:
        twin.ingest(b)
        twin.commit()
    return twin


def _feed(cluster, clock, batches, tick=True):
    for b in batches:
        cluster.ingest(b)
        cluster.commit()
        if tick:
            clock.advance(1.0)
            cluster.tick()


# ------------------------------------------------ steady-state shipping
def test_steady_state_followers_bitwise_and_lag_zero(tmp_path):
    """Cross-layout followers converge bitwise with the primary and a
    never-crashed twin; caught-up lag is exactly zero; the follower's
    journaled log is a byte-exact copy of the primary's records."""
    cluster, clock = _cluster(tmp_path)
    batches = [_batch(48, s) for s in range(6)]
    _feed(cluster, clock, batches)
    twin = _twin_at(batches, len(batches))
    _assert_twin_equal(cluster, twin, "primary")
    for nid, rep in cluster.replicas.items():
        assert rep.applied_seq == cluster.primary.wal.last_seq
        assert rep.replica_lag == 0
        assert rep.n_torn_ships == 0
        _assert_twin_equal(rep, twin, f"replica-{nid}")
        prim = wal_mod.read_log(os.path.join(str(tmp_path / "cluster"),
                                             "node0", "wal"))
        ship = wal_mod.read_log(os.path.join(str(tmp_path / "cluster"),
                                             f"node{nid}", "wal"))
        assert (wal_mod.encode_records(ship)
                == wal_mod.encode_records(prim)), \
            f"replica-{nid} log is not a byte-exact copy"


# ------------------------------------------------------ chaos matrix
@pytest.mark.parametrize("point", REPLICATION_CRASH_POINTS)
def test_chaos_crash_matrix_failover_bitwise(tmp_path, point):
    """Crash at EVERY ship/apply/promote boundary, then fail over: the
    promoted node must equal a never-crashed twin at its own applied seq
    and the zombie primary's appends must be fenced."""
    inj = FaultInjector(crash_at=point)
    cluster, clock = _cluster(tmp_path, injector=inj, name=f"c-{point}")
    batches = [_batch(48, s) for s in range(6)]
    for b in batches:
        cluster.ingest(b)
        cluster.commit()
        clock.advance(1.0)
        try:
            cluster.tick()
        except InjectedCrash:
            pass                         # ship/apply points fire here
    clock.advance(1.0)
    cluster.tick()                       # converge whatever the crash tore
    zombie = cluster.kill_primary()
    clock.advance(10.0)
    try:
        promoted = cluster.tick()        # promote points fire here
    except InjectedCrash:
        clock.advance(1.0)
        promoted = cluster.tick()        # promotion must be retryable
    assert inj.fired, f"crash point {point} never reached"
    assert promoted is not None and promoted == cluster.primary_id
    assert cluster.epoch >= 2
    seq = cluster.primary.wal.last_seq
    assert 0 < seq <= len(batches)
    _assert_twin_equal(cluster, _twin_at(batches, seq), point)
    with pytest.raises(StaleEpochError):
        zombie.ingest(_batch(16, 99))
    # post-failover writes + shipping keep the survivors converging
    more = _batch(48, 100)
    cluster.ingest(more)
    cluster.commit()
    clock.advance(1.0)
    cluster.tick()
    twin = _twin_at(batches, seq)
    twin.ingest(more)
    twin.commit()
    _assert_twin_equal(cluster, twin, (point, "post-failover"))
    for nid, rep in cluster.replicas.items():
        if rep.alive and rep.replica_lag == 0:
            _assert_twin_equal(rep, twin, (point, f"replica-{nid}"))


# ------------------------------------------------------ lagging oracle
def test_lagging_replica_promotion_is_a_lagging_oracle(tmp_path):
    """Acknowledged-but-unshipped records are lost on failover, exactly
    like any async log-shipping database: the promoted node equals the
    twin at ITS OWN applied seq — older answers, never wrong ones."""
    cluster, clock = _cluster(tmp_path)
    batches = [_batch(48, s) for s in range(6)]
    _feed(cluster, clock, batches[:4])                   # shipped
    _feed(cluster, clock, batches[4:], tick=False)       # acked, unshipped
    cluster.kill_primary()
    clock.advance(10.0)
    promoted = cluster.tick()
    assert promoted is not None
    assert cluster.primary.wal.last_seq == 4
    _assert_twin_equal(cluster, _twin_at(batches, 4), "lagging-oracle")


def test_failover_promotes_most_caught_up_follower(tmp_path):
    """The heartbeat plan's candidate is the live follower with the most
    durable WAL seqs; a partitioned (unshipped) follower never wins."""
    cluster, clock = _cluster(tmp_path)

    def drop_for_node2(nid, data):
        return b"" if nid == 2 else data

    cluster.ship_filter = drop_for_node2
    batches = [_batch(48, s) for s in range(5)]
    _feed(cluster, clock, batches)
    assert cluster.replicas[1].wal.last_seq == 5
    assert cluster.replicas[2].wal.last_seq == 0
    cluster.kill_primary()
    clock.advance(10.0)
    assert cluster.tick() == 1
    _assert_twin_equal(cluster, _twin_at(batches, 5), "most-caught-up")


def test_forced_promotion_of_lagging_follower_serves_its_own_seq(tmp_path):
    """Even promoting the WORST follower (operator override) yields a
    correct engine at that follower's applied seq."""
    cluster, clock = _cluster(tmp_path)
    batches = [_batch(48, s) for s in range(5)]
    _feed(cluster, clock, batches[:2])
    cluster.ship_filter = lambda nid, data: b"" if nid == 2 else data
    _feed(cluster, clock, batches[2:])
    cluster.kill_primary()
    assert cluster.failover(candidate=2) == 2
    assert cluster.primary.wal.last_seq == 2
    _assert_twin_equal(cluster, _twin_at(batches, 2), "forced-lagging")


# -------------------------------------------------- fencing / split brain
def test_zombie_fencing_both_layers(tmp_path):
    """After promotion the deposed primary is fenced twice over: its own
    log rejects appends (StaleEpochError at the WAL), and any follower
    it still reaches rejects its stale-epoch ships outright."""
    cluster, clock = _cluster(tmp_path)
    batches = [_batch(48, s) for s in range(4)]
    _feed(cluster, clock, batches)
    old_epoch = cluster.epoch
    zombie = cluster.kill_primary()
    clock.advance(10.0)
    promoted = cluster.tick()
    assert promoted is not None and cluster.epoch == old_epoch + 1
    # layer 1: the zombie's own WAL is fenced — no divergent history
    with pytest.raises(StaleEpochError):
        zombie.ingest(_batch(16, 50))
    with pytest.raises(StaleEpochError):
        zombie.evict(1)
    # layer 2: a surviving follower rejects a span shipped at the old term
    (nid, rep), = [kv for kv in cluster.replicas.items() if kv[1].alive]
    fake = wal_mod.Record(wal_mod.KIND_EVICT, rep.wal.last_seq + 1,
                          b'{"ttl": 1}', epoch=old_epoch)
    before = rep.applied_seq
    with pytest.raises(StaleEpochError):
        rep.receive(wal_mod.encode_records([fake]), ship_epoch=old_epoch)
    assert rep.n_stale_rejects == 1
    assert rep.wal.last_seq == before and rep.applied_seq == before


def test_double_promotion_same_epoch_is_split_brain(tmp_path):
    """The promotion CAS admits exactly one winner per epoch."""
    cluster, clock = _cluster(tmp_path)
    _feed(cluster, clock, [_batch(48, s) for s in range(3)])
    cluster.kill_primary()
    observed = cluster.epoch
    assert cluster.promote(1, expect_epoch=observed) == 1
    with pytest.raises(SplitBrainError):
        cluster.promote(2, expect_epoch=observed)


def test_writes_fail_fast_while_primary_is_down(tmp_path):
    cluster, clock = _cluster(tmp_path)
    _feed(cluster, clock, [_batch(48, 0)])
    cluster.kill_primary()
    with pytest.raises(PrimaryDownError):
        cluster.ingest(_batch(16, 1))
    clock.advance(10.0)
    assert cluster.tick() is not None       # failover restores writes
    cluster.ingest(_batch(16, 1))


def test_verify_records_rejects_gaps_and_epoch_regressions():
    recs = [wal_mod.Record(wal_mod.KIND_EVICT, s, b'{"ttl": 1}', epoch=1)
            for s in (1, 2, 3)]
    assert [r.seq for r in verify_records(recs, 1, after_seq=1)] == [2, 3]
    with pytest.raises(wal_mod.WalCorruption):
        verify_records([recs[0], recs[2]], 1, after_seq=0)   # seq gap
    bad = [wal_mod.Record(wal_mod.KIND_EVICT, 1, b'{}', epoch=2),
           wal_mod.Record(wal_mod.KIND_EVICT, 2, b'{}', epoch=1)]
    with pytest.raises(wal_mod.WalCorruption):
        verify_records(bad, 2, after_seq=0)                  # epoch drop
    with pytest.raises(StaleEpochError):
        verify_records([bad[0]], 1, after_seq=0)             # from future


# ------------------------------------------------------------ torn ships
def test_torn_ship_accepts_prefix_then_catches_up(tmp_path):
    """A truncated in-flight span journals only its CRC-valid prefix;
    the cursor does not advance past what landed, so the next tick
    re-ships the suffix and the follower converges bitwise."""
    cluster, clock = _cluster(tmp_path, layouts=("replicated",
                                                 "replicated"))
    batches = [_batch(48, s) for s in range(3)]
    _feed(cluster, clock, batches[:1])
    cluster.ship_filter = tear_ship(drop_bytes=7, times=1)
    cluster.ingest(batches[1])
    cluster.commit()
    clock.advance(1.0)
    cluster.tick()                                  # torn delivery
    rep = cluster.replicas[1]
    assert rep.n_torn_ships == 1
    assert rep.wal.last_seq == 1                    # prefix only
    clock.advance(1.0)
    cluster.tick()                                  # clean re-ship
    assert rep.wal.last_seq == 2 and rep.replica_lag == 0
    cluster.ingest(batches[2])
    cluster.commit()
    clock.advance(1.0)
    cluster.tick()
    _assert_twin_equal(rep, _twin_at(batches, 3), "torn-ship")


# --------------------------------------------- follower crash + rejoin
@pytest.mark.parametrize("layout", ["replicated", "partitioned"])
def test_replica_crash_recovers_from_own_directory(tmp_path, layout):
    """A crashed follower rebuilds from its OWN directory (bootstrap
    checkpoint + locally journaled shipped log), rejoins, and converges
    bitwise — across layouts."""
    cluster, clock = _cluster(tmp_path,
                              layouts=("replicated", layout, "replicated"))
    batches = [_batch(48, s) for s in range(6)]
    _feed(cluster, clock, batches[:3])
    cluster.kill_replica(1)
    _feed(cluster, clock, batches[3:5])             # misses two ships
    rep = cluster.reattach_replica(1, _fresh(layout))
    assert rep.applied_seq == 3                     # its durable history
    _feed(cluster, clock, batches[5:])
    assert rep.replica_lag == 0
    _assert_twin_equal(rep, _twin_at(batches, 6), f"rejoin-{layout}")


# ------------------------------------------------- bounded staleness
def test_router_bounded_staleness_seq_and_time(tmp_path):
    """Follower reads stay within max_lag_seqs AND max_lag_secs; outside
    either bound the router falls back to the primary. Every answer
    carries the answering node's actual replica_lag."""
    cluster, clock = _cluster(tmp_path,
                              layouts=("replicated", "replicated"),
                              max_lag_seqs=0, max_lag_secs=5.0)
    batches = [_batch(48, s) for s in range(3)]
    _feed(cluster, clock, batches[:2])
    spec = QuerySpec("ta")
    # caught up: the follower serves, tagged lag 0
    out = cluster.router.serve([spec])
    assert cluster.router.n_replica_waves == 1
    assert cluster.router.n_primary_waves == 0
    (sq,) = out.values()
    assert sq.replica_lag == 0
    _assert_bitwise(sq.estimate, cluster.ate("ta"), "router-fresh")
    # seq staleness: shipped but unapplied -> lag 1 > max_lag_seqs=0
    cluster.ingest(batches[2])
    cluster.commit()
    cluster.ship()
    rep = cluster.replicas[1]
    assert rep.replica_lag == 1
    (sq,) = cluster.router.serve([spec]).values()
    assert cluster.router.n_primary_waves == 1
    assert sq.replica_lag == 0                      # primary has no lag
    _assert_bitwise(sq.estimate, cluster.ate("ta"), "router-primary")
    # catch up -> follower serves again
    cluster.apply_all()
    (sq,) = cluster.router.serve([spec]).values()
    assert cluster.router.n_replica_waves == 2
    # time staleness: silent primary -> follower goes stale by TIME even
    # though its seq lag still reads zero
    clock.advance(100.0)
    assert not rep.fresh(clock(), cluster.max_lag_seqs,
                         cluster.max_lag_secs)
    cluster.router.serve([QuerySpec("ta", (("x1", (0, 2)),))])
    assert cluster.router.n_primary_waves == 2


def test_router_deadline_expiry_is_slot_free(tmp_path):
    """An expired routed query is dropped at wave assembly — counted,
    never dispatched, never answered."""
    cluster, clock = _cluster(tmp_path, layouts=("replicated",
                                                 "replicated"))
    _feed(cluster, clock, [_batch(48, 0)])
    live = cluster.router.submit(QuerySpec("ta"), deadline=clock() + 50.0)
    dead = cluster.router.submit(QuerySpec("ta", (("x1", (0, 2)),)),
                                 deadline=clock() - 1.0)
    out = {}
    while cluster.router.pending():
        out.update(cluster.router.step())
    assert live in out and dead not in out
    assert cluster.router.n_expired == 1


def test_router_survives_failover(tmp_path):
    """Reads keep flowing across a promotion: the router re-binds its
    serving engine to whatever node currently answers."""
    cluster, clock = _cluster(tmp_path)
    batches = [_batch(48, s) for s in range(4)]
    _feed(cluster, clock, batches)
    spec = QuerySpec("ta")
    before = cluster.router.serve([spec])
    cluster.kill_primary()
    clock.advance(10.0)
    assert cluster.tick() is not None
    after = cluster.router.serve([spec])
    (b,), (a,) = before.values(), after.values()
    _assert_bitwise(a.estimate, b.estimate, "router-failover")


# ------------------------------------------ steady-state hot-path cost
def test_shipping_keeps_primary_ingest_single_dispatch(tmp_path):
    """Shipping is pure host bytes: a steady-state primary ingest WITH a
    same-tick ship is still ONE dispatch, ZERO host syncs, clean under
    jax.transfer_guard("disallow"). Follower APPLY dispatches happen on
    the follower's own schedule, outside the primary's hot path."""
    clock = _Clock()
    cluster = ReplicatedEngine(
        [_fresh("overlap", max_inflight=8), _fresh("replicated")],
        str(tmp_path / "hot"), clock=clock, heartbeat_timeout_s=1e9)
    cluster.ingest(_batch(256, 1))
    cluster.commit()
    cluster.ingest(_batch(256, 2))                  # retrace both waves
    cluster.commit()
    cluster.ship()
    cluster.apply_all()
    with count_dispatches() as n, count_host_syncs() as s:
        with jax.transfer_guard("disallow"):
            cluster.ingest(_batch(256, 3))
            cluster.ship()
    assert n() == 1, "WAL shipping must not add dispatches"
    assert s() == 0, "WAL shipping must not sync the host"
    assert cluster.apply_all() == 0
    rep = cluster.replicas[1]
    assert rep.wal.last_seq == cluster.primary.wal.last_seq

"""Online incremental engine: exactness of delta maintenance.

The contract under test: after ANY interleaving of ingested (and retracted)
batches, every materialized cuboid stat, CEM matched set and ATE equals the
offline computation over the concatenated table — bit-identically when the
outcome sums are exact (integer-valued outcomes), and to float tolerance
otherwise (summation order is the only difference).
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (CoarsenSpec, OnlineEngine, cem, estimate_ate,
                        estimate_ate_from_stats)
from repro.core import cube, keys
from repro.core.cem import overlap_keep, update_overlap
from repro.core.propensity import (StreamStats, fit_logistic, predict_ps,
                                   warm_refit)
from repro.data.columnar import GrowableTable, Table


def _frame(n, seed=0, card=(5, 4, 3), int_outcome=False, x0_lo=0, x0_hi=None):
    """Confounded frame; x0 range restrictable to control key novelty."""
    rng = np.random.default_rng(seed)
    x0_hi = card[0] if x0_hi is None else x0_hi
    cols = {
        "x0": rng.integers(x0_lo, x0_hi, n).astype(np.int32),
        "x1": rng.integers(0, card[1], n).astype(np.int32),
        "x2": rng.integers(0, card[2], n).astype(np.int32),
    }
    p = 0.15 + 0.6 * cols["x0"] / (card[0] - 1)
    cols["ta"] = (rng.random(n) < p).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = (np.round(y) if int_outcome else y).astype(np.float32)
    valid = rng.random(n) > 0.08
    return cols, valid


SPECS = {"x0": CoarsenSpec.categorical(5), "x1": CoarsenSpec.categorical(4),
         "x2": CoarsenSpec.categorical(3)}
TREATMENTS = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}


def _batches(cols, valid, sizes):
    out, s = [], 0
    for sz in sizes:
        out.append(Table.from_numpy(
            {k: v[s:s + sz] for k, v in cols.items()}, valid[s:s + sz]))
        s += sz
    assert s == len(valid)
    return out


def _stat_map(cuboid):
    """{group key: stat tuple} over groups with mass, for exact compares."""
    gv = np.asarray(cuboid.group_valid) & (np.asarray(cuboid.stats["one"]) > 0)
    hi = np.asarray(cuboid.key_hi)[gv]
    lo = np.asarray(cuboid.key_lo)[gv]
    cols = {k: np.asarray(v)[gv] for k, v in sorted(cuboid.stats.items())}
    return {(int(h), int(l)): tuple(float(cols[k][i]) for k in cols)
            for i, (h, l) in enumerate(zip(hi, lo))}


def test_delta_batches_bit_identical_to_offline_cuboid():
    # later batches widen the x0 range -> new group keys mid-stream, so the
    # merge exercises BOTH the scatter fast path and the re-sort grow path
    c1, v1 = _frame(3000, seed=1, int_outcome=True, x0_hi=2)
    c2, v2 = _frame(2000, seed=2, int_outcome=True)
    cols = {k: np.concatenate([c1[k], c2[k]]) for k in c1}
    valid = np.concatenate([v1, v2])

    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    saw_slow_after_seed = False
    for i, b in enumerate(_batches(cols, valid, [1000] * 5)):
        rep = eng.ingest(b)
        if i > 0 and not all(rep.fast_path.values()):
            saw_slow_after_seed = True
    assert saw_slow_after_seed, "stream never exercised the grow path"

    full = Table.from_numpy(cols, valid)
    offline_base = cube.build_cuboid(full, eng.specs, sorted(TREATMENTS), "y")
    assert _stat_map(eng.base) == _stat_map(offline_base)  # bit-identical
    for view in eng.views.values():
        off = cube.build_cuboid(
            full, {d: SPECS[d] for d in view.dims}, sorted(TREATMENTS), "y")
        assert _stat_map(view.cuboid) == _stat_map(off)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_online_ate_and_matched_set_equal_offline(use_pallas):
    cols, valid = _frame(4000, seed=3)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       use_pallas=use_pallas)
    for b in _batches(cols, valid, [500] * 8):
        eng.ingest(b)
    full = Table.from_numpy(cols, valid)
    for t, cov in TREATMENTS.items():
        res = cem(full, t, "y", {c: SPECS[c] for c in cov})
        want = estimate_ate(res.groups)
        got = eng.ate(t)
        np.testing.assert_allclose(float(got.ate), float(want.ate),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(got.att), float(want.att),
                                   rtol=1e-5, atol=1e-6)
        assert int(got.n_groups) == int(want.n_groups)
        assert float(got.n_matched_treated) == float(want.n_matched_treated)
        assert float(got.n_matched_control) == float(want.n_matched_control)
        # row-level matched set identical
        np.testing.assert_array_equal(
            np.asarray(eng.matched_rows(t, full)),
            np.asarray(res.table.valid))
        # maintained group stats identical to offline CEMGroups
        want_est = estimate_ate(eng.cem_groups(t))
        np.testing.assert_allclose(float(want_est.ate), float(want.ate),
                                   rtol=1e-5, atol=1e-6)


def test_groups_gain_overlap_mid_stream():
    # group (x0=0, x1=0) gets ONLY treated units first -> not matched;
    # a later batch delivers its first control -> flips into the matched set
    n = 400
    x0 = np.zeros(n, np.int32)
    x1 = np.zeros(n, np.int32)
    x2 = np.zeros(n, np.int32)
    ta = np.ones(n, np.int32)
    ta[300:] = 0                       # controls only in the last quarter
    cols = dict(x0=x0, x1=x1, x2=x2, ta=ta,
                tb=np.zeros(n, np.int32),
                y=np.arange(n, dtype=np.float32))
    valid = np.ones(n, bool)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    b1, b2 = _batches(cols, valid, [300, 100])
    eng.ingest(b1)
    assert int(eng.ate("ta").n_groups) == 0
    eng.ingest(b2)
    est = eng.ate("ta")
    assert int(est.n_groups) == 1
    full = Table.from_numpy(cols, valid)
    want = estimate_ate(cem(full, "ta", "y",
                            {c: SPECS[c] for c in TREATMENTS["ta"]}).groups)
    np.testing.assert_allclose(float(est.ate), float(want.ate), rtol=1e-5)


def test_groups_lose_overlap_on_retraction():
    cols, valid = _frame(2000, seed=4, int_outcome=True)
    batches = _batches(cols, valid, [500] * 4)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    for b in batches:
        eng.ingest(b)
    before = eng.ate("ta")
    # retract batch 1 entirely: exact sign-flipped delta maintenance
    eng.ingest(batches[1], retract=True)
    after = eng.ate("ta")
    # offline truth over the surviving rows
    keep_rows = np.ones(len(valid), bool)
    keep_rows[500:1000] = False
    full = Table.from_numpy(cols, valid & keep_rows)
    want = estimate_ate(cem(full, "ta", "y",
                            {c: SPECS[c] for c in TREATMENTS["ta"]}).groups)
    np.testing.assert_allclose(float(after.ate), float(want.ate),
                               rtol=1e-5, atol=1e-6)
    assert int(after.n_groups) == int(want.n_groups)
    assert float(after.n_matched_treated) == float(want.n_matched_treated)
    assert (float(before.n_matched_treated)
            != float(after.n_matched_treated))
    # matched row set also matches offline over survivors
    np.testing.assert_array_equal(
        np.asarray(eng.matched_rows("ta", full)),
        np.asarray(cem(full, "ta", "y",
                       {c: SPECS[c] for c in TREATMENTS["ta"]}).table.valid))


def test_subpopulation_query_and_cache_invalidation():
    cols, valid = _frame(3000, seed=5)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", query_dims=("x2",),
                       granule=256)
    for b in _batches(cols, valid, [1000] * 3):
        eng.ingest(b)

    # subpopulation estimate == offline CEM over the row-filtered table
    # (grouping on covset | query_dims, as the prepared/offline path does)
    full = Table.from_numpy(cols, valid)
    sub = full.filter(jnp.asarray(cols["x2"] == 0))
    dims = sorted(set(TREATMENTS["ta"]) | {"x2"})
    want = estimate_ate(cem(sub, "ta", "y",
                            {c: SPECS[c] for c in dims}).groups)
    got = eng.ate("ta", subpopulation={"x2": [0]})
    np.testing.assert_allclose(float(got.ate), float(want.ate),
                               rtol=1e-5, atol=1e-6)
    assert int(got.n_groups) == int(want.n_groups)

    # cache: repeat query is a hit
    h0 = eng.cache_hits
    eng.ate("ta", subpopulation={"x2": [0]})
    assert eng.cache_hits == h0 + 1

    # a delta touching ONLY x2=1 groups leaves the x2=0 entry cached ...
    c2, v2 = _frame(500, seed=6)
    c2["x2"][:] = 1
    rep = eng.ingest(Table.from_numpy(c2, v2))
    assert ("ta", (("x2", (0,)),)) not in rep.invalidated
    assert ("ta", (("x2", (1,)),)) not in eng._cache  # never cached
    h0 = eng.cache_hits
    eng.ate("ta", subpopulation={"x2": [0]})
    assert eng.cache_hits == h0 + 1
    # ... and the cached value is still correct (x2=0 stats untouched)
    np.testing.assert_allclose(
        float(eng.ate("ta", subpopulation={"x2": [0]}).ate),
        float(want.ate), rtol=1e-5, atol=1e-6)

    # a delta touching x2=0 invalidates it (and the unrestricted entry)
    eng.ate("ta")
    c3, v3 = _frame(500, seed=7)
    c3["x2"][:] = 0
    rep = eng.ingest(Table.from_numpy(c3, v3))
    assert ("ta", (("x2", (0,)),)) in rep.invalidated
    assert ("ta", None) in rep.invalidated
    # post-invalidation estimate equals offline over everything ingested
    allc = {k: np.concatenate([cols[k], c2[k], c3[k]]) for k in cols}
    allv = np.concatenate([valid, v2, v3])
    sub = Table.from_numpy(allc, allv).filter(jnp.asarray(allc["x2"] == 0))
    want = estimate_ate(cem(sub, "ta", "y",
                            {c: SPECS[c] for c in dims}).groups)
    got = eng.ate("ta", subpopulation={"x2": [0]})
    np.testing.assert_allclose(float(got.ate), float(want.ate),
                               rtol=1e-5, atol=1e-6)


def test_update_overlap_flips_only_touched_positions():
    gv = jnp.asarray([True, True, True, False])
    nt = jnp.asarray([1.0, 0.0, 2.0, 0.0])
    nc = jnp.asarray([1.0, 3.0, 0.0, 0.0])
    keep = overlap_keep(gv, nt, nc)
    np.testing.assert_array_equal(np.asarray(keep),
                                  [True, False, False, False])
    # group 2 gains a control; group 1 unchanged but re-evaluated
    nc = nc.at[2].add(1.0)
    keep = update_overlap(keep, gv, nt, nc, jnp.asarray([1, 2]))
    np.testing.assert_array_equal(np.asarray(keep),
                                  [True, False, True, False])


@pytest.mark.parametrize("c,s,b,block", [(512, 3, 256, 128),
                                         (1024, 6, 300, 256),
                                         (256, 1, 64, 64)])
def test_scatter_merge_kernel_matches_ref(c, s, b, block):
    from repro.kernels import scatter_merge_op
    from repro.kernels import ref
    rng = np.random.default_rng(c + s + b)
    table = rng.normal(0, 1, (c, s)).astype(np.float32)
    pos = rng.integers(0, c, b).astype(np.int32)       # duplicates likely
    vals = rng.normal(0, 1, (b, s)).astype(np.float32)
    got = scatter_merge_op(jnp.asarray(table), jnp.asarray(pos),
                           jnp.asarray(vals), block=block)
    want = ref.scatter_merge_ref(jnp.asarray(table), jnp.asarray(pos),
                                 jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # empty delta: at[].add semantics, a no-op
    out = scatter_merge_op(jnp.asarray(table),
                           jnp.zeros((0,), jnp.int32),
                           jnp.zeros((0, s), jnp.float32), block=block)
    np.testing.assert_array_equal(np.asarray(out), table)


def test_growable_table_append_and_growth():
    cols, valid = _frame(700, seed=8)
    t0 = Table.from_numpy({k: v[:100] for k, v in cols.items()}, valid[:100])
    gt = GrowableTable.from_table(t0, granule=128)
    assert gt.capacity == 128 and gt.used == 100
    cap_before = gt.capacity
    gt = gt.append(Table.from_numpy(
        {k: v[100:120] for k, v in cols.items()}, valid[100:120]),
        granule=128)
    assert gt.capacity == cap_before        # fits: no reallocation
    gt = gt.append(Table.from_numpy(
        {k: v[120:700] for k, v in cols.items()}, valid[120:700]),
        granule=128)
    assert gt.used == 700
    assert gt.capacity >= 700 and gt.capacity % 128 == 0
    out = gt.table.to_numpy()
    for k in cols:
        np.testing.assert_array_equal(out[k][:700], cols[k][:700])
    np.testing.assert_array_equal(out["_valid"][:700], valid[:700])
    assert not out["_valid"][700:].any()    # dead slots stay invalid
    with pytest.raises(ValueError):
        gt.append(Table.from_numpy({"zz": np.zeros(3, np.float32)}))


def test_warm_started_propensity_refresh():
    rng = np.random.default_rng(9)
    n, d = 4096, 3
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    logits = 1.1 * X[:, 0] - 0.7 * X[:, 2]
    t = (rng.random(n) < 1 / (1 + np.exp(-logits))).astype(np.float32)
    m = rng.random(n) > 0.1
    half = n // 2
    cold = fit_logistic(jnp.asarray(X[:half]), jnp.asarray(t[:half]),
                        jnp.asarray(m[:half]))
    # warm refresh on the grown data with a small step budget ~= cold refit
    warm = warm_refit(cold, jnp.asarray(X), jnp.asarray(t), jnp.asarray(m),
                      n_iter=4)
    full = fit_logistic(jnp.asarray(X), jnp.asarray(t), jnp.asarray(m))
    ps_w = np.asarray(predict_ps(warm, jnp.asarray(X)))
    ps_f = np.asarray(predict_ps(full, jnp.asarray(X)))
    np.testing.assert_allclose(ps_w, ps_f, atol=5e-3)
    # standardization is frozen across the refresh
    np.testing.assert_array_equal(np.asarray(warm.mean),
                                  np.asarray(cold.mean))


def test_engine_propensity_warm_path():
    cols, valid = _frame(2000, seed=10)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", keep_rows=True, granule=256)
    batches = _batches(cols, valid, [1000, 1000])
    eng.ingest(batches[0])
    m1 = eng.refresh_propensity("ta", ["x0", "x1"])
    eng.ingest(batches[1])
    m2 = eng.refresh_propensity("ta", ["x0", "x1"], step_budget=4)
    full = Table.from_numpy(cols, valid)
    from repro.core.propensity import design_matrix
    X = design_matrix(full, ["x0", "x1"])
    ref_model = fit_logistic(X, full["ta"], full.valid, init=m1)
    np.testing.assert_allclose(np.asarray(predict_ps(m2, X)),
                               np.asarray(predict_ps(ref_model, X)),
                               atol=5e-3)
    with pytest.raises(ValueError):
        eng.ingest(batches[0], retract=True)   # row log is append-only


def test_fused_and_unfused_ingest_paths_agree():
    # the fused single-sync planner and the legacy one-sync-per-merge loop
    # must maintain identical state, including across the grow path
    c1, v1 = _frame(2000, seed=20, int_outcome=True, x0_hi=2)
    c2, v2 = _frame(1500, seed=21, int_outcome=True)
    cols = {k: np.concatenate([c1[k], c2[k]]) for k in c1}
    valid = np.concatenate([v1, v2])
    fused = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    legacy = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                          fused_host_sync=False)
    for b in _batches(cols, valid, [700] * 5):
        rf = fused.ingest(b)
        rl = legacy.ingest(b)
        assert rf.fast_path == rl.fast_path
        assert rf.n_delta_groups == rl.n_delta_groups
    assert _stat_map(fused.base) == _stat_map(legacy.base)
    for t in TREATMENTS:
        assert (_stat_map(fused.views[t].cuboid)
                == _stat_map(legacy.views[t].cuboid))
        np.testing.assert_array_equal(np.asarray(fused.views[t].keep),
                                      np.asarray(legacy.views[t].keep))
        assert float(fused.ate(t).ate) == float(legacy.ate(t).ate)


def test_online_variance_matches_offline_row_level():
    # the yy second-moment stat columns must reproduce estimate_ate's
    # row-level Neyman within-group variance from materialized state alone
    cols, valid = _frame(4000, seed=22)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    for b in _batches(cols, valid, [800] * 5):
        eng.ingest(b)
    full = Table.from_numpy(cols, valid)
    for t, cov in TREATMENTS.items():
        res = cem(full, t, "y", {c: SPECS[c] for c in cov})
        want = estimate_ate(res.groups, full["y"], full[t],
                            res.table.valid)
        got = eng.ate(t)
        assert float(want.variance) > 0.0
        np.testing.assert_allclose(float(got.variance),
                                   float(want.variance),
                                   rtol=1e-4, atol=1e-8)


def test_retracting_never_ingested_rows_raises_and_leaves_state():
    cols, valid = _frame(1200, seed=23, int_outcome=True)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256)
    for b in _batches(cols, valid, [600, 600]):
        eng.ingest(b)
    before_base = _stat_map(eng.base)
    before_ate = float(eng.ate("ta").ate)
    # same keys as ingested rows, but far more of them than ever existed:
    # counts would go negative
    bogus = Table.from_numpy(
        {k: np.repeat(v[:1], 400) for k, v in cols.items()},
        np.ones(400, bool))
    with pytest.raises(ValueError, match="never ingested"):
        eng.ingest(bogus, retract=True)
    # keys the engine has never seen at all -> slow-path retraction, raises
    novel = {k: v[:64].copy() for k, v in cols.items()}
    novel["x1"][:] = 3
    novel["x2"][:] = 2
    novel["x0"][:] = 4
    with pytest.raises(ValueError, match="never ingested"):
        eng.ingest(Table.from_numpy(novel, np.ones(64, bool)),
                   retract=True)
    assert _stat_map(eng.base) == before_base
    assert float(eng.ate("ta").ate) == before_ate


def test_compact_cuboid_pads_with_canonical_invalid_marker():
    cols, valid = _frame(300, seed=24)
    full = Table.from_numpy(cols, valid)
    cub = cube.compact_cuboid(
        cube.build_cuboid(full, SPECS, sorted(TREATMENTS), "y"),
        granule=128)
    gv = np.asarray(cub.group_valid)
    assert not gv.all()  # there is padding to check
    np.testing.assert_array_equal(np.asarray(cub.key_hi)[~gv],
                                  np.uint32(keys.INVALID_HI))
    np.testing.assert_array_equal(np.asarray(cub.key_lo)[~gv],
                                  np.uint32(keys.INVALID_LO))


def test_converged_flag_reflects_returned_coefficients():
    # gnorms[-1] used to be the gradient norm BEFORE the final Newton step:
    # a warm refit whose single step lands on the optimum was mis-reported
    # as unconverged. The flag must be computed at the returned w.
    rng = np.random.default_rng(25)
    n, d = 4096, 3
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    logits = 1.0 * X[:, 0] - 0.5 * X[:, 1]
    t = (rng.random(n) < 1 / (1 + np.exp(-logits))).astype(np.float32)
    m = np.ones(n, bool)
    full = fit_logistic(jnp.asarray(X), jnp.asarray(t), jnp.asarray(m))
    assert bool(full.converged)

    import dataclasses as dc
    perturbed = dc.replace(full, w=full.w + 5e-3)

    def gnorm(model):
        Xs = (jnp.asarray(X) - model.mean) / model.std
        Xb = jnp.concatenate([Xs, jnp.ones((n, 1), jnp.float32)], axis=1)
        p = 1 / (1 + jnp.exp(-(Xb @ model.w)))
        g = Xb.T @ (jnp.asarray(m, jnp.float32) * (p - jnp.asarray(t)))
        return float(jnp.linalg.norm(g + 1e-4 * model.w))

    thresh = 1e-3 * (1 + n) ** 0.5
    assert gnorm(perturbed) > thresh  # the pre-step norm is NOT converged
    refit = fit_logistic(jnp.asarray(X), jnp.asarray(t), jnp.asarray(m),
                         n_iter=1, init=perturbed)
    # one Newton step from a near-optimum re-converges (quadratic rate) ...
    assert gnorm(refit) < thresh
    # ... and the flag now agrees with the returned coefficients
    assert bool(refit.converged)


def test_stream_stats_moments_exact_and_retractable():
    rng = np.random.default_rng(26)
    n = 3000
    x = rng.normal(3.0, 2.0, n).astype(np.float32)
    t = (rng.random(n) < 0.5).astype(np.float32)
    valid = rng.random(n) > 0.2
    ss = StreamStats.empty(("x", "t"), capacity=512)
    for s in range(0, n, 500):
        ss = ss.update({"x": jnp.asarray(x[s:s + 500]),
                        "t": jnp.asarray(t[s:s + 500])},
                       jnp.asarray(valid[s:s + 500]))
    mean, std = ss.moments(["x"])
    np.testing.assert_allclose(float(mean[0]), x[valid].mean(), rtol=1e-5)
    np.testing.assert_allclose(float(std[0]), x[valid].std(), rtol=1e-4)
    # retraction reverses the moments exactly (reservoir is left alone)
    ss2 = ss.update({"x": jnp.asarray(x[:500]), "t": jnp.asarray(t[:500])},
                    jnp.asarray(valid[:500]), retract=True)
    keep = valid.copy()
    keep[:500] = False
    mean2, std2 = ss2.moments(["x"])
    np.testing.assert_allclose(float(mean2[0]), x[keep].mean(), rtol=1e-5)
    np.testing.assert_allclose(float(std2[0]), x[keep].std(), rtol=1e-3)
    # the reservoir never exceeds its bound and only holds valid rows
    _, rvalid = ss.reservoir()
    assert int(rvalid.sum()) == min(512, int(valid.sum()))


def test_reservoir_propensity_refresh_without_row_log():
    # keep_rows=False: refreshes run over the streaming reservoir with
    # stream-exact standardization. With capacity >= stream size the
    # reservoir holds every valid row, so the refit matches the full fit.
    cols, valid = _frame(2000, seed=27)
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                       reservoir_size=4096)
    assert eng.rows is None
    batches = _batches(cols, valid, [1000, 1000])
    eng.ingest(batches[0])
    m1 = eng.refresh_propensity("ta", ["x0", "x1"])
    eng.ingest(batches[1])
    m2 = eng.refresh_propensity("ta", ["x0", "x1"], step_budget=4)
    full = Table.from_numpy(cols, valid)
    from repro.core.propensity import design_matrix
    X = design_matrix(full, ["x0", "x1"])
    ref_model = fit_logistic(X, full["ta"], full.valid)
    np.testing.assert_allclose(np.asarray(predict_ps(m2, X)),
                               np.asarray(predict_ps(ref_model, X)),
                               atol=5e-3)
    # a bounded (sub-stream) reservoir still recovers the model to
    # statistical accuracy (deterministic: PRNG keys are fixed)
    small = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                         reservoir_size=512)
    for b in batches:
        small.ingest(b)
    m_small = small.refresh_propensity("ta", ["x0", "x1"])
    np.testing.assert_allclose(np.asarray(predict_ps(m_small, X)),
                               np.asarray(predict_ps(ref_model, X)),
                               atol=0.15)
    # reservoir_size=0 and no row log: refresh must refuse, not lie
    none = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                        reservoir_size=0)
    none.ingest(batches[0])
    with pytest.raises(ValueError, match="reservoir"):
        none.refresh_propensity("ta", ["x0", "x1"])


def test_reservoir_retraction_is_exact():
    # key-tagged reservoir: retraction deletes the exact sampled copies,
    # zeroes the slots and re-sorts by priority — retract-then-refit is
    # BIT-IDENTICAL to never-ingested-then-fit (content-unique rows with
    # integer values keep every sum exact)
    def uframe(n, seed, y0):
        rng = np.random.default_rng(seed)
        cols = {
            "x0": rng.integers(0, 5, n).astype(np.int32),
            "x1": rng.integers(0, 4, n).astype(np.int32),
            "x2": rng.integers(0, 3, n).astype(np.int32),
        }
        cols["ta"] = (rng.random(n) < 0.15 + 0.6 * cols["x0"] / 4).astype(
            np.int32)
        cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
        # unique integer outcomes: rows content-unique AND f32-sum exact
        cols["y"] = (y0 + np.arange(n)).astype(np.float32)
        return cols, rng.random(n) > 0.08

    A, vA = uframe(1500, seed=1, y0=0)
    B, vB = uframe(900, seed=2, y0=10_000)
    bA, bB = Table.from_numpy(A, vA), Table.from_numpy(B, vB)
    never = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                         reservoir_size=4096)
    never.ingest(bA)
    engine = OnlineEngine(SPECS, TREATMENTS, "y", granule=256,
                          reservoir_size=4096)
    engine.ingest(bA)
    engine.ingest(bB)
    engine.ingest(bB, retract=True)
    # the whole streaming-propensity state is bit-identical ...
    np.testing.assert_array_equal(np.asarray(never.stream.priority),
                                  np.asarray(engine.stream.priority))
    for c in never.stream.names:
        np.testing.assert_array_equal(np.asarray(never.stream.columns[c]),
                                      np.asarray(engine.stream.columns[c]))
    # ... so the (cold, moment-standardized) refit is too
    m_never = never.refresh_propensity("ta", ["x0", "x1"])
    m_retract = engine.refresh_propensity("ta", ["x0", "x1"])
    np.testing.assert_array_equal(np.asarray(m_never.w),
                                  np.asarray(m_retract.w))
    np.testing.assert_array_equal(np.asarray(m_never.mean),
                                  np.asarray(m_retract.mean))
    np.testing.assert_array_equal(np.asarray(m_never.std),
                                  np.asarray(m_retract.std))


def test_reservoir_retraction_multiplicity_and_displacement():
    import jax.numpy as jnp
    # duplicated row values: retracting ONE copy removes exactly one slot
    ss = StreamStats.empty(("x", "t"), capacity=64)
    ss = ss.update({"x": jnp.asarray([1.0, 2.0, 2.0, 3.0]),
                    "t": jnp.asarray([0.0, 1.0, 1.0, 0.0])},
                   jnp.ones(4, bool))
    ss2 = ss.update({"x": jnp.asarray([2.0]), "t": jnp.asarray([1.0])},
                    jnp.ones(1, bool), retract=True)
    cols, rvalid = ss2.reservoir()
    left = sorted(np.asarray(cols["x"])[np.asarray(rvalid)].tolist())
    assert left == [1.0, 2.0, 3.0]
    assert float(ss2.n) == 3.0
    # a row the bounded reservoir already displaced: nothing to delete,
    # moments still reverse exactly
    ss = StreamStats.empty(("x",), capacity=4, seed=3)
    xs = np.arange(16, dtype=np.float32)
    ss = ss.update({"x": jnp.asarray(xs)}, jnp.ones(16, bool))
    _, rvalid = ss.reservoir()
    sampled = set(np.asarray(ss.columns["x"])[np.asarray(rvalid)].tolist())
    missing = [v for v in xs if v not in sampled][0]
    ss3 = ss.update({"x": jnp.asarray([missing])}, jnp.ones(1, bool),
                    retract=True)
    _, rvalid3 = ss3.reservoir()
    assert int(rvalid3.sum()) == 4
    assert float(ss3.n) == 15.0


def test_eviction_ttl_bounds_unbounded_key_space():
    # each batch lives in its own x0 slice -> the key space keeps growing;
    # TTL eviction must drop groups whose last touch is stale
    n_per = 200
    eng = OnlineEngine(SPECS, TREATMENTS, "y", granule=64,
                       delta_granule=64)
    rng = np.random.default_rng(28)
    for i in range(5):
        cols = {
            "x0": np.full(n_per, i, np.int32),
            "x1": rng.integers(0, 4, n_per).astype(np.int32),
            "x2": rng.integers(0, 3, n_per).astype(np.int32),
        }
        cols["ta"] = (rng.random(n_per) < 0.5).astype(np.int32)
        cols["tb"] = (rng.random(n_per) < 0.5).astype(np.int32)
        cols["y"] = rng.normal(0, 1, n_per).astype(np.float32)
        eng.ingest(Table.from_numpy(cols))
    groups_before = int(eng.base.n_groups())
    # ttl=2 keeps touches at most 2 ingests old: batches 2, 3, 4
    evicted = eng.evict(ttl=2)
    assert evicted["__base__"] > 0
    assert int(eng.base.n_groups()) == groups_before - evicted["__base__"]
    x0_left = np.asarray(eng.codec.extract(
        eng.base.key_hi, eng.base.key_lo, "x0"))
    gv = np.asarray(eng.base.group_valid)
    assert set(x0_left[gv]) == {2, 3, 4}
    # queries keep working over the surviving groups; cache was dropped
    assert not eng._cache
    est = eng.ate("ta")
    assert int(est.n_groups) > 0
    # a second evict with nothing stale is a no-op
    assert eng.evict(ttl=2) == {k: 0 for k in evicted}
    # re-ingesting an evicted slice resurrects those groups fresh
    cols["x0"][:] = 0
    eng.ingest(Table.from_numpy(cols))
    x0_left = np.asarray(eng.codec.extract(
        eng.base.key_hi, eng.base.key_lo, "x0"))
    gv = np.asarray(eng.base.group_valid)
    assert 0 in set(x0_left[gv])


def test_estimate_ate_from_stats_matches_estimate_ate():
    cols, valid = _frame(1500, seed=11)
    full = Table.from_numpy(cols, valid)
    res = cem(full, "ta", "y", {c: SPECS[c] for c in TREATMENTS["ta"]})
    want = estimate_ate(res.groups)
    g = res.groups
    got = estimate_ate_from_stats(g.keep, g.n_treated, g.n_control,
                                  g.sum_y_t, g.sum_y_c)
    np.testing.assert_allclose(float(got.ate), float(want.ate), rtol=1e-6)
    np.testing.assert_allclose(float(got.att), float(want.att), rtol=1e-6)
    assert int(got.n_groups) == int(want.n_groups)


def test_merge_delta_empty_and_codec_mismatch():
    codec_specs = {"x0": SPECS["x0"], "x1": SPECS["x1"]}
    base = cube.empty_cuboid(cube.make_codec(codec_specs), ["ta"],
                             capacity=64)
    other = cube.empty_cuboid(cube.make_codec({"x0": SPECS["x0"]}), ["ta"],
                              capacity=64)
    with pytest.raises(ValueError):
        cube.merge_delta(base, other)
    # merging an all-invalid delta is a no-op fast path
    merged, _, fast = cube.merge_delta(
        base, dataclasses.replace(base), granule=64)
    assert fast
    assert int(merged.n_groups()) == 0

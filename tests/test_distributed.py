"""Multi-device tests for the distributed causal engine.

These run in a SUBPROCESS with --xla_force_host_platform_device_count=8 so
the main pytest process keeps seeing exactly 1 device (per the dry-run
isolation rule). Each scenario compares the distributed result against the
single-device engine.
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.devices()
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
"""


def _run(body: str):
    code = SCRIPT_HEADER + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_distributed_cem_matches_single_device():
    # equivalence with the single-device engine — the same outputs the
    # pre-unification (private n_t/n_c layout) path was checked against —
    # plus the Neyman variance the unified cuboid stat schema adds
    out = _run("""
    from repro.core import CoarsenSpec, cem, estimate_ate
    from repro.core.cem import pack_keys
    from repro.core.distributed import make_distributed_cem
    from repro.data.columnar import Table

    rng = np.random.default_rng(0)
    n = 4096
    x0 = rng.integers(0, 6, n).astype(np.int32)
    x1 = rng.integers(0, 5, n).astype(np.int32)
    t = (rng.random(n) < 0.25 + 0.1 * x0 / 5).astype(np.int32)
    y = (2.0 * t + x0 + rng.normal(0, .3, n)).astype(np.float32)
    valid = rng.random(n) > 0.1
    table = Table.from_numpy(dict(x0=x0, x1=x1, t=t, y=y), valid)
    specs = {"x0": CoarsenSpec.categorical(6), "x1": CoarsenSpec.categorical(5)}

    # single-device reference (row-level variance via estimate_ate)
    res = cem(table, "t", "y", specs)
    want = estimate_ate(res.groups, table["y"], table["t"],
                        res.table.valid)

    # distributed
    codec, hi, lo = pack_keys(table, specs)
    f = make_distributed_cem(mesh, capacity=256)
    ate, att, var, ng, nt, nc, matched, overflow = f(
        hi, lo, table["t"], table["y"], table.valid)
    assert not bool(overflow)
    np.testing.assert_allclose(float(ate), float(want.ate), rtol=1e-4)
    np.testing.assert_allclose(float(att), float(want.att), rtol=1e-4)
    assert float(want.variance) > 0.0
    np.testing.assert_allclose(float(var), float(want.variance), rtol=1e-3)
    assert int(ng) == int(want.n_groups)
    np.testing.assert_allclose(float(nt), float(want.n_matched_treated))
    np.testing.assert_allclose(float(nc), float(want.n_matched_control))
    np.testing.assert_array_equal(np.asarray(matched),
                                  np.asarray(res.table.valid))
    print("DIST_CEM_OK")
    """)
    assert "DIST_CEM_OK" in out


def test_distributed_cem_overflow_flag():
    out = _run("""
    from repro.core import CoarsenSpec
    from repro.core.cem import pack_keys
    from repro.core.distributed import make_distributed_cem
    from repro.data.columnar import Table

    rng = np.random.default_rng(1)
    n = 4096
    x0 = rng.integers(0, 4096, n).astype(np.int32)  # ~unique keys
    t = (rng.random(n) < 0.5).astype(np.int32)
    y = rng.normal(0, 1, n).astype(np.float32)
    table = Table.from_numpy(dict(x0=x0, t=t, y=y))
    codec, hi, lo = pack_keys(table, {"x0": CoarsenSpec.categorical(4096)})
    f = make_distributed_cem(mesh, capacity=64)  # deliberately too small
    *_, overflow = f(hi, lo, table["t"], table["y"], table.valid)
    assert bool(overflow)
    print("OVERFLOW_OK")
    """)
    assert "OVERFLOW_OK" in out


def test_ring_knn_matches_quadratic():
    out = _run("""
    from repro.core.distributed import make_ring_knn
    from repro.core.matching import knn_quadratic, BIG

    rng = np.random.default_rng(2)
    n, d, k = 1024, 3, 4
    U = rng.normal(0, 1, (n, d)).astype(np.float32)
    cv = rng.random(n) > 0.3
    f = make_ring_knn(mesh, k=k)
    dist, idx = f(jnp.asarray(U), jnp.asarray(U), jnp.asarray(cv))
    wd, wi = knn_quadratic(jnp.asarray(U), jnp.asarray(U), jnp.asarray(cv),
                           k, caliper=np.inf)
    got, want = np.asarray(dist), np.asarray(wd)
    ok = want < 1e30
    np.testing.assert_allclose(got[ok], want[ok], rtol=1e-3, atol=3e-3)
    assert np.all(got[~ok] > 1e30)
    print("RING_KNN_OK")
    """)
    assert "RING_KNN_OK" in out


def test_distributed_newton_matches_single():
    out = _run("""
    from repro.core.distributed import make_distributed_newton
    from repro.core.propensity import fit_logistic, predict_ps

    rng = np.random.default_rng(3)
    n, d = 4096, 4
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    logits = 1.2 * X[:, 0] - 0.5 * X[:, 2]
    t = (rng.random(n) < 1/(1+np.exp(-logits))).astype(np.float32)
    m = (rng.random(n) > 0.1).astype(np.float32)

    # single-device reference on standardized-with-bias features
    mu = (X * m[:, None]).sum(0) / m.sum()
    sd = np.sqrt((m[:, None] * (X - mu) ** 2).sum(0) / m.sum() + 1e-12)
    Xb = np.concatenate([(X - mu) / sd, np.ones((n, 1))], 1).astype(np.float32)
    f = make_distributed_newton(mesh)
    w = f(jnp.asarray(Xb), jnp.asarray(t), jnp.asarray(m))

    model = fit_logistic(jnp.asarray(X), jnp.asarray(t),
                         jnp.asarray(m > 0))
    np.testing.assert_allclose(np.asarray(w), np.asarray(model.w),
                               rtol=2e-3, atol=2e-3)
    print("NEWTON_OK")
    """)
    assert "NEWTON_OK" in out


def test_compressed_psum_close_to_exact():
    out = _run("""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.grad_compress import compressed_psum_mean

    rng = np.random.default_rng(4)
    g = rng.normal(0, 0.01, (8, 512)).astype(np.float32)

    def body(x):
        return compressed_psum_mean(x[0], "data")[None]

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data", None),),
                          out_specs=P(None), check_rep=False))
    got = np.asarray(f(jnp.asarray(g)))[0]
    want = g.mean(axis=0)
    err = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert err < 0.02, err
    print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out

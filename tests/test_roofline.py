"""Roofline HLO cost parser: validated against XLA's own cost_analysis on
unrolled graphs, and against ground truth on scanned (while-loop) graphs
where XLA undercounts."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import HloCostModel
from repro.roofline import analysis
from repro.configs import REGISTRY, SHAPES


def _cost(fn, *args, fallback_trip=1):
    compiled = jax.jit(fn).lower(*args).compile()
    model = HloCostModel(compiled.as_text(), fallback_trip=fallback_trip)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    return model.entry_cost(), ca or {}


def test_dot_flops_match_xla_unrolled():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 384), jnp.float32)

    def f(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w @ w.T)
        return x

    cost, ca = _cost(f, x, w)
    want = ca.get("flops", 0.0)
    # parser counts matmul flops only; XLA adds elementwise — within 10%
    assert cost.flops == pytest.approx(want, rel=0.10)
    assert cost.flops >= 4 * 2 * (256 * 512 * 384 + 256 * 384 * 512)


def test_while_loop_trip_count_multiplies():
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 512), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return y

    def unrolled(x, w):
        for _ in range(16):
            x = jnp.tanh(x @ w)
        return x

    c_scan, ca_scan = _cost(scanned, x, w)
    c_unroll, _ = _cost(unrolled, x, w)
    one_matmul = 2 * 128 * 512 * 512
    # XLA's own number counts the body once (the documented gap)
    assert ca_scan.get("flops", 0) < 2.1 * one_matmul
    # our parser recovers the full 16 iterations
    assert c_scan.flops == pytest.approx(16 * one_matmul, rel=0.05)
    assert c_scan.flops == pytest.approx(c_unroll.flops, rel=0.05)


def test_hbm_bytes_reasonable():
    x = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)

    def f(x):
        return jnp.sum(x * 2.0 + 1.0)

    cost, _ = _cost(f, x)
    nbytes = (1 << 20) * 4
    assert cost.hbm_bytes >= nbytes          # reads input at least once
    assert cost.hbm_bytes <= 6 * nbytes      # fusion bounds the traffic


def test_model_flops_sane():
    cfg = REGISTRY["qwen3-1.7b"]
    mf = analysis.model_flops(cfg, SHAPES["train_4k"])
    n = analysis.active_param_count(cfg)
    assert 1.4e9 < n < 2.6e9                 # ~1.7B + embeddings
    assert mf == pytest.approx(6 * n * 256 * 4096, rel=1e-6)
    moe = REGISTRY["deepseek-v2-lite-16b"]
    n_active = analysis.active_param_count(moe)
    assert n_active < 4e9                    # active << 16B total


def test_collective_bytes_counted():
    import subprocess, sys, textwrap, os
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.roofline.hlo_cost import HloCostModel
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        def f(x):
            return jnp.sum(x @ jnp.ones((1024, 512)))
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P(None, "data")),
                        out_shardings=NamedSharding(mesh, P())).lower(x).compile()
        cost = HloCostModel(c.as_text(), default_group=8).entry_cost()
        assert cost.collective_bytes > 0, "no collectives counted"
        assert "all-reduce" in cost.collective_breakdown or \
               "all-gather" in cost.collective_breakdown, \
               cost.collective_breakdown
        print("COLLECTIVES_OK", cost.collective_breakdown)
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=300,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr
    assert "COLLECTIVES_OK" in proc.stdout

"""Per-label dispatch accounting (``repro.launch.trace``): the counter
behind every 1-dispatch assertion in the suite gets its own coverage —
labels, nesting, snapshots, batch amortization accounting, and the
jit-attribute preservation the engines rely on.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.launch.trace import (batched_served, count_dispatches,
                                counted_jit, dispatch_count,
                                dispatch_counts, hot_path, record_batch,
                                record_dispatch)


def test_counted_jit_counts_each_call():
    f = counted_jit(lambda x: x + 1)
    with count_dispatches() as n:
        f(jnp.ones((4,)))
        f(jnp.ones((4,)))
    assert n() == 2


def test_counted_jit_label_attribution():
    f = counted_jit(lambda x: x * 2, label="alpha")
    g = counted_jit(lambda x: x * 3, label="beta")
    h = counted_jit(lambda x: x * 5)          # unlabeled
    x = jnp.ones((3,))
    before = dispatch_counts()
    with count_dispatches() as total, \
            count_dispatches(label="alpha") as na, \
            count_dispatches(label="beta") as nb:
        f(x)
        f(x)
        g(x)
        h(x)
    assert total() == 4
    assert na() == 2 and nb() == 1
    after = dispatch_counts()
    assert after.get("alpha", 0) - before.get("alpha", 0) == 2
    assert after.get("beta", 0) - before.get("beta", 0) == 1


def test_nested_and_overlapping_label_windows():
    f = counted_jit(lambda x: x + 1, label="outer")
    g = counted_jit(lambda x: x + 2, label="inner")
    x = jnp.zeros((2,))
    with count_dispatches() as total:
        f(x)
        with count_dispatches(label="inner") as ni:
            g(x)
            with count_dispatches(label="outer") as no:
                f(x)
            assert no() == 1          # only the f() inside its window
            g(x)
        assert ni() == 2              # both g() calls, not the f()s
    assert total() == 4


def test_record_dispatch_manual_accounting():
    start = dispatch_count()
    start_l = dispatch_count("manual")
    record_dispatch(3, label="manual")
    assert dispatch_count() - start == 3
    assert dispatch_count("manual") - start_l == 3


def test_record_batch_amortization_ratio():
    served = batched_served("bq")
    with count_dispatches(label="bq") as n:
        prog = counted_jit(lambda x: x.sum(axis=0), label="bq")
        prog(jnp.ones((8, 3)))
        record_batch(8, label="bq")
    assert n() == 1
    assert batched_served("bq") - served == 8


def test_unknown_label_counts_zero():
    assert dispatch_count("no-such-label") == 0
    assert batched_served("no-such-label") == 0


def test_counted_jit_preserves_jit_attributes():
    @counted_jit
    def f(x):
        return x * x

    assert f._cache_size() == 0
    f(jnp.arange(4.0))
    assert f._cache_size() == 1
    f(jnp.arange(4.0))
    assert f._cache_size() == 1       # no retrace on the same shape
    lowered = f.lower(jnp.arange(4.0))
    assert "jit" in lowered.as_text().lower() or lowered is not None


def test_counted_jit_forwards_jit_kwargs():
    @counted_jit
    def plain(x):
        return x

    f = counted_jit(lambda x, k: x * k, static_argnames=("k",))
    assert float(f(jnp.float32(2.0), k=3)) == 6.0
    g = counted_jit(lambda s: {k: v + 1 for k, v in s.items()},
                    donate_argnums=(0,))
    state = {"a": jnp.arange(3.0)}
    out = g(state)
    assert np.allclose(np.asarray(out["a"]), [1.0, 2.0, 3.0])
    with pytest.raises(RuntimeError):
        np.asarray(state["a"])        # donated: buffer deleted
    assert float(plain(jnp.float32(1.0))) == 1.0


def test_hot_path_marker_is_noop_at_runtime():
    @hot_path
    def body(x):
        return x + 1

    assert body.__hot_path__ is True
    assert body(41) == 42

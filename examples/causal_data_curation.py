"""Causal data curation — ZaliQL as a first-class training-framework
feature (the integration story from DESIGN.md §3).

Question every pretraining team asks: "does data property T *cause* better
(lower) loss, or is it just correlated through confounders?" Here the
training pipeline emits per-example telemetry and the causal engine answers
with CEM/ATE instead of a correlational dashboard.

Setup (synthetic but structurally honest):
  * examples have a data property T ("curated source") whose TRUE causal
    effect on loss is a planted -0.30;
  * a confounder (document length) affects BOTH curation probability and
    loss, making the naive correlation wildly optimistic;
  * we train a tiny LM, collect per-example loss telemetry, and compare
    naive difference-in-means vs CEM ATE against the planted truth.

Run:  PYTHONPATH=src python examples/causal_data_curation.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (CoarsenSpec, cem, difference_in_means, estimate_ate)
from repro.data.columnar import Table
from repro.launch.train import PRESETS
from repro.models import forward, init_params

TRUE_EFFECT = -0.30


def make_corpus(rng, n_docs, seq, vocab):
    """Docs with a 'length' confounder: long docs are more regular (lower
    loss) AND more likely curated. Curation itself adds extra regularity
    worth TRUE_EFFECT nats."""
    length = rng.uniform(0, 1, n_docs)                      # confounder
    curated = (rng.random(n_docs) < 0.15 + 0.7 * length).astype(np.int32)
    # regularity in [0, 1]: longer docs more regular; curation adds more
    regular = np.clip(0.25 + 0.5 * length + 0.25 * curated
                      + rng.normal(0, 0.05, n_docs), 0, 1)
    toks = rng.integers(0, vocab, (n_docs, seq), dtype=np.int64)
    period = rng.integers(2, 6, (n_docs, 1))
    pattern = (np.arange(seq)[None, :] // period) % vocab
    use = rng.random((n_docs, seq)) < regular[:, None]
    tokens = np.where(use, pattern, toks).astype(np.int32)
    return tokens, curated, length


def main():
    rng = np.random.default_rng(0)
    cfg = PRESETS["lm-tiny"]
    n_docs, seq = 4096, 64
    tokens, curated, length = make_corpus(rng, n_docs, seq, cfg.vocab_size)

    print("== scoring per-example loss with the LM (telemetry pass) ==")
    params = init_params(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def example_loss(params, toks):
        logits, _, _ = forward(params, cfg, {"tokens": toks})
        labels = jnp.roll(toks, -1, axis=1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return jnp.mean(nll[:, :-1], axis=1)

    losses = []
    bs = 256
    for i in range(0, n_docs, bs):
        losses.append(np.asarray(example_loss(
            params, jnp.asarray(tokens[i:i + bs]))))
    loss = np.concatenate(losses)
    # normalize loss scale so the planted effect is in nats as stated
    loss = (loss - loss.mean()) / max(loss.std(), 1e-9)
    # planted structural equation for the telemetry outcome:
    loss = (-1.2 * length + TRUE_EFFECT * curated
            + 0.15 * rng.normal(0, 1, n_docs) + loss * 0.05)

    table = Table.from_numpy({
        "curated": curated, "length": length.astype(np.float32),
        "loss": loss.astype(np.float32)})

    naive = float(difference_in_means(table["loss"], table["curated"],
                                      table.valid))
    res = cem(table, "curated", "loss",
              {"length": CoarsenSpec.equal_width(0, 1, 20)})
    est = estimate_ate(res.groups, table["loss"], table["curated"],
                       res.table.valid)
    print(f"naive effect of curation on loss : {naive:+.3f}  "
          "(confounded by doc length)")
    print(f"CEM ATE                          : {float(est.ate):+.3f}  "
          f"[truth {TRUE_EFFECT:+.3f}]")
    print(f"matched {int(est.n_matched_treated)} curated vs "
          f"{int(est.n_matched_control)} uncurated docs in "
          f"{int(est.n_groups)} length strata")
    assert abs(float(est.ate) - TRUE_EFFECT) < abs(naive - TRUE_EFFECT), \
        "CEM should beat the naive estimate"
    assert abs(float(est.ate) - TRUE_EFFECT) < 0.1
    print("OK — curation effect recovered causally")


if __name__ == "__main__":
    main()

"""Batched serving example: continuous slot batcher over prefill/decode.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--preset", "lm-tiny", "--requests", "10",
                "--new", "12", "--slots", "4"]
    main()

"""End-to-end LM training driver example.

Runs the full production path on this container: config -> init -> jitted
train_step (remat, microbatch accumulation, AdamW + cosine schedule) ->
deterministic data pipeline -> async checkpointing -> crash + bit-exact
resume (simulated kill halfway).

Defaults are CPU-sized (a ~3M-param LM, 60 steps). `--preset lm-100m
--steps 300` is the full-fat configuration for real hardware; identical
code path.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import PRESETS, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm-tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    ckpt = tempfile.mkdtemp(prefix="trainlm_")
    try:
        print(f"== phase 1: train to step {args.steps // 2} then 'crash' ==")
        run(cfg, args.steps // 2, args.batch, args.seq, ckpt_dir=ckpt,
            microbatches=2)
        print("\n== phase 2: resume from checkpoint, finish ==")
        state, hist = run(cfg, args.steps, args.batch, args.seq,
                          ckpt_dir=ckpt, microbatches=2, resume=True)
        print(f"\nloss: first {hist[0]:.3f} -> last {hist[-1]:.3f}")
        assert hist[-1] < hist[0], "loss should decrease"
        print("OK — trained, crashed, resumed, improved")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()

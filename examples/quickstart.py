"""Quickstart: causal inference with ZaliQL-on-JAX in ~40 lines.

Estimates the causal effect of a binary treatment under confounding, shows
why the naive correlational estimate is wrong, and prints balance
diagnostics — the paper's core loop (CEM -> overlap filter -> Eq. 4 ATE).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CoarsenSpec, awmd, cem, difference_in_means,
                        estimate_ate, raw_imbalance)
from repro.data.columnar import Table

# --- observational data with a confounder -------------------------------
rng = np.random.default_rng(0)
n = 50_000
severity = rng.normal(0, 1, n)                  # confounder (e.g. illness)
treated = (rng.random(n) < 1 / (1 + np.exp(-1.5 * severity))).astype(np.int32)
# true effect of treatment: -2.0 (helps); severity hurts (+3.0)
outcome = (-2.0 * treated + 3.0 * severity + rng.normal(0, .5, n)
           ).astype(np.float32)

table = Table.from_numpy({"severity": severity.astype(np.float32),
                          "t": treated, "y": outcome})

# --- naive (predictive) answer: wrong sign! ------------------------------
naive = float(difference_in_means(table["y"], table["t"], table.valid))
print(f"naive difference-in-means : {naive:+.3f}   (sicker people get "
      "treated, so treatment looks harmful)")

# --- ZaliQL: coarsened exact matching + ATE ------------------------------
res = cem(table, "t", "y",
          specs={"severity": CoarsenSpec.equal_width(-4, 4, 32)})
est = estimate_ate(res.groups, table["y"], table["t"], res.table.valid)
print(f"CEM ATE                   : {float(est.ate):+.3f} "
      f"(+- {float(est.variance) ** 0.5:.3f})   [truth: -2.000]")
print(f"matched: {int(est.n_matched_treated)} treated / "
      f"{int(est.n_matched_control)} control in {int(est.n_groups)} groups")

# --- balance diagnostics (paper Eq. 5) -----------------------------------
raw = raw_imbalance({"severity": table["severity"]}, table["t"], table.valid)
bal = awmd(res.groups, {"severity": table["severity"]}, table["t"],
           res.table.valid)
print(f"severity imbalance        : raw {float(raw['severity']):.3f} -> "
      f"matched {float(bal['severity']):.3f}")

assert abs(float(est.ate) + 2.0) < 0.15, "ATE recovery failed"
print("OK")

"""FLIGHTDELAY end-to-end driver — the paper's §5 experiment, full pipeline.

Pipeline (all stages real, no stubs):
  1. generate flights + weather with planted causal ground truth
     (Table 2's full NRCM: both potential outcomes are materialized, so we
     can SCORE estimates, not eyeball them);
  2. spatio-temporal FK join (paper §4.1);
  3. per-treatment CEM with CDAG-selected covariates -> ATE (Eq. 4) + AWMD
     (Eq. 5) for 5 weather treatments incl. the low-pressure trap;
  4. the §4 optimizations end-to-end: pushdown, covariate factoring
     (Alg. 1), offline preparation (Alg. 2) + online sub-population query.

Run:  PYTHONPATH=src python examples/flight_delay_analysis.py [--flights N]
"""
import argparse
import time

import jax.numpy as jnp

from repro.core import (CoarsenSpec, awmd, cem, cem_join_pushdown,
                        difference_in_means, estimate_ate, prepare,
                        raw_imbalance)
from repro.data import flightgen
from repro.data.columnar import Table
from repro.data.join import fk_join

SPEC_RANGES = {"w_precipm": (0, 3), "w_wspdm": (0, 80), "w_hum": (0, 100),
               "w_tempm": (-20, 40)}
CO_WEATHER = {
    "thunder": ["w_precipm", "w_wspdm"],
    "lowvis": ["w_precipm", "w_hum"],
    "highwind": ["w_precipm", "w_tempm"],
    "snow": ["w_tempm", "w_wspdm"],
    "lowpressure": ["w_precipm", "w_wspdm", "w_tempm"],
}


def covariate_specs(treatment):
    specs = {
        "airport": CoarsenSpec.categorical(16),
        "carrier": CoarsenSpec.categorical(16),
        "traffic": CoarsenSpec.equal_width(0, 40, 8),
        "w_season": CoarsenSpec.equal_width(0, 1, 4),
    }
    for name in CO_WEATHER[treatment]:
        lo, hi = SPEC_RANGES[name]
        specs[name] = CoarsenSpec.equal_width(lo, hi, 5)
    return specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--flights", type=int, default=300_000)
    ap.add_argument("--airports", type=int, default=8)
    args = ap.parse_args()

    print(f"== generating {args.flights:,} flights over {args.airports} "
          "airports (1 year) ==")
    t0 = time.perf_counter()
    data = flightgen.generate(n_flights=args.flights,
                              n_airports=args.airports, seed=0)
    print(f"   {time.perf_counter() - t0:.1f}s; weather rows: "
          f"{data.weather.nrows:,}")

    print("\n== spatio-temporal join (paper §4.1) ==")
    t0 = time.perf_counter()
    joined = fk_join(data.flights, data.weather,
                     on={"airport": 64, "hour": 1 << 17}, prefix="w_")
    joined["w_thunder"].block_until_ready()
    print(f"   {time.perf_counter() - t0:.2f}s; rows: {joined.nrows:,}")

    print("\n== per-treatment CEM + ATE (paper Fig. 8) ==")
    print(f"{'treatment':12s} {'naive':>8s} {'CEM ATE':>8s} {'truth':>7s} "
          f"{'|err|':>6s} {'groups':>7s} {'matchedT':>9s} {'time':>6s}")
    for tname in CO_WEATHER:
        mask = flightgen.treatment_valid_mask(data, tname)
        table = Table(dict(joined.columns),
                      joined.valid & jnp.asarray(mask))
        t0 = time.perf_counter()
        res = cem(table, tname, "dep_delay", covariate_specs(tname))
        est = estimate_ate(res.groups)
        ate = float(est.ate)
        dt = time.perf_counter() - t0
        naive = float(difference_in_means(table["dep_delay"], table[tname],
                                          table.valid))
        truth = data.true_sate[tname]
        print(f"{tname:12s} {naive:8.2f} {ate:8.2f} {truth:7.2f} "
              f"{abs(ate - truth):6.2f} {int(est.n_groups):7d} "
              f"{int(est.n_matched_treated):9d} {dt:5.2f}s")

    print("\n== balance (paper Fig. 8(b), AWMD Eq. 5) for thunder ==")
    res = cem(joined, "thunder", "dep_delay", covariate_specs("thunder"))
    covs = {c: joined[c] for c in ("traffic", "w_season", "w_precipm",
                                   "w_wspdm")}
    raw = raw_imbalance(covs, joined["thunder"], joined.valid)
    bal = awmd(res.groups, covs, joined["thunder"], res.table.valid)
    for c in covs:
        print(f"   {c:12s} raw {float(raw[c]):8.4f} -> matched "
              f"{float(bal[c]):8.4f}")

    print("\n== CEM pushdown through the join (paper §4.1, Fig. 9(c)) ==")
    dim_specs = {"season": CoarsenSpec.equal_width(0, 1, 4),
                 "precipm": CoarsenSpec.equal_width(0, 3, 5),
                 "wspdm": CoarsenSpec.equal_width(0, 80, 5)}
    t0 = time.perf_counter()
    pd = cem_join_pushdown(
        data.weather, dim_specs, data.flights,
        {"airport": CoarsenSpec.categorical(16),
         "carrier": CoarsenSpec.categorical(16),
         "traffic": CoarsenSpec.equal_width(0, 40, 8)},
        on={"airport": 64, "hour": 1 << 17}, treatment="thunder",
        outcome="dep_delay", prefix="w_")
    est_pd = estimate_ate(pd.result.groups)
    print(f"   pushdown ATE {float(est_pd.ate):.2f} in "
          f"{time.perf_counter() - t0:.2f}s; weather rows pruned "
          f"{pd.dim_rows_before:,} -> {pd.dim_rows_after:,}")

    print("\n== offline preparation + online queries (Alg. 1 + 2) ==")
    treatments = {t: sorted(covariate_specs(t)) for t in CO_WEATHER}
    all_specs = {}
    for t in CO_WEATHER:
        all_specs.update(covariate_specs(t))
    t0 = time.perf_counter()
    db = prepare(joined, treatments, all_specs, outcome="dep_delay",
                 query_dims=("airport",))
    print(f"   prepared in {time.perf_counter() - t0:.2f}s "
          f"({len(db.cuboids)} cuboids: {list(db.cuboids)})")
    t0 = time.perf_counter()
    for tname in ("thunder", "snow"):
        est = db.ate(tname)
        print(f"   online ATE({tname}) = {float(est.ate):6.2f}   "
              f"[truth {data.true_sate[tname]:.2f}]")
    est_sfo = db.ate("thunder", subpopulation={"airport": [0]})
    print(f"   online ATE(thunder | airport=0) = {float(est_sfo.ate):6.2f}")
    print(f"   3 online queries in {time.perf_counter() - t0:.3f}s "
          "(vs a full CEM pass each without preparation)")


if __name__ == "__main__":
    main()

"""FLIGHTDELAY, online: streaming causal inference over arriving batches.

The offline driver (flight_delay_analysis.py) answers causal queries by
re-running CEM over the full relation. This demo plays the paper's ONLINE
setting instead: flights arrive in batches (think: live feed from the DOT),
and an :class:`repro.core.OnlineEngine` maintains the causal estimates by
delta cuboid maintenance — per batch it touches O(batch + stat table), never
the full history.

Per batch it prints the evolving ATE per weather treatment (vs the planted
ground truth) and the ingest latency; at the end it refreshes a propensity
model from the engine's bounded streaming reservoir (no row log), then
re-runs the offline pipeline over everything ingested to show the
estimates agree and what each refresh would have cost offline.

With ``--devices D`` the stream is row-sharded over a D-device data mesh:
each device aggregates its shard of every batch and the tiny per-device
delta stat tables are all-gathered and combined (off-TPU this forces D
host-platform devices, so it demonstrates the mechanism, not a speedup).
Add ``--partitioned`` to key-range partition the MATERIALIZED views
themselves over the mesh (deltas routed to owner devices, per-device
resident state ~1/D — printed at the end).

With ``--serve`` the demo holds back the final batch and plays the
multi-tenant serving regime: a window of concurrent HETEROGENEOUS
subpopulation queries (different treatments, airports and estimands) is
answered through :class:`repro.core.serving.ServingEngine` — duplicates
collapse in flight, cache hits skip the device entirely, and the fresh
specs of a wave cost ONE batched compiled dispatch. The held-back batch
is then ingested live to show invalidation: repeating the same queries
re-dispatches against the new state instead of serving stale estimates.

Run:  PYTHONPATH=src python examples/online_flight_delay.py \
          [--flights N] [--batches K] [--devices D] [--partitioned] \
          [--serve]
"""
import argparse
import os
import time

_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=1)
_n_dev = _pre.parse_known_args()[0].devices
if _n_dev > 1:  # must precede any jax import; preserve existing flags
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count={_n_dev}").strip()

import numpy as np

from repro.core import (CoarsenSpec, OnlineEngine, PartitionedOnlineEngine,
                        cem, estimate_ate)
from repro.data import flightgen
from repro.data.columnar import Table
from repro.data.join import fk_join
from repro.launch.mesh import make_data_mesh

SPEC_RANGES = {"w_precipm": (0, 3), "w_wspdm": (0, 80), "w_tempm": (-20, 40)}
COVARIATES = {
    "thunder": ["w_precipm", "w_wspdm"],
    "snow": ["w_tempm", "w_wspdm"],
    "highwind": ["w_precipm", "w_tempm"],
}


def build_specs():
    specs = {
        "airport": CoarsenSpec.categorical(16),
        "carrier": CoarsenSpec.categorical(16),
        "traffic": CoarsenSpec.equal_width(0, 40, 8),
        "w_season": CoarsenSpec.equal_width(0, 1, 4),
    }
    for name, (lo, hi) in SPEC_RANGES.items():
        specs[name] = CoarsenSpec.equal_width(lo, hi, 5)
    return specs


def serve_demo(engine, cols, valid, held_back):
    """Multi-tenant serving against live ingest: one wave of mixed
    subpopulation queries = one batched dispatch; a live ingest then
    invalidates the estimate cache so repeats re-dispatch."""
    from repro.core.serving import QuerySpec, ServingEngine
    from repro.launch.trace import count_dispatches

    print("\n== serving: concurrent heterogeneous queries "
          "(slot-batched, one dispatch per wave) ==")
    tnames = list(COVARIATES)
    specs = [QuerySpec.make(tnames[i % len(tnames)],
                            subpopulation={"airport": [i % 4]},
                            estimand=("ate", "att")[i % 2])
             for i in range(12)]
    specs += specs[:3]              # concurrent duplicates: collapse in flight
    srv = ServingEngine(engine, n_slots=8)
    with count_dispatches(label="query") as n:
        t0 = time.perf_counter()
        served = srv.serve(specs)
        dt = time.perf_counter() - t0
    print(f"   {len(specs)} queries ({len(set(specs))} distinct) -> "
          f"{n()} compiled dispatches in {srv.n_waves} waves, "
          f"{srv.n_deduped} deduped in flight, {dt * 1e3:.1f}ms total")
    for q in served[:4]:
        s = q.spec
        print(f"   {s.estimand.upper()}({s.treatment} | "
              f"airport={s.subpopulation[0][1][0]}) = {q.value:7.2f}")

    s, e = held_back
    print(f"   -- live ingest of {e - s:,} held-back rows "
          "(bumps state version, invalidates served estimates) --")
    engine.ingest(Table.from_numpy({k: v[s:e] for k, v in cols.items()},
                                   valid[s:e]))
    with count_dispatches(label="query") as n:
        again = srv.serve(specs[:6])
    stale = sum(a.value == b.value
                for a, b in zip(again, served[:6]))
    print(f"   same 6 queries after ingest: {n()} fresh dispatch(es), "
          f"{stale}/6 unchanged estimates (cache served {srv.n_cache_served}"
          " hits total)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--flights", type=int, default=200_000)
    ap.add_argument("--airports", type=int, default=8)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard ingest over a data mesh of this many devices")
    ap.add_argument("--partitioned", action="store_true",
                    help="key-range partition the materialized views over "
                         "the mesh (state ~1/D per device)")
    ap.add_argument("--serve", action="store_true",
                    help="demo the slot-batched query server: concurrent "
                         "heterogeneous subpopulation queries against "
                         "live ingest")
    args = ap.parse_args()

    print(f"== generating {args.flights:,} flights, joining weather ==")
    data = flightgen.generate(n_flights=args.flights,
                              n_airports=args.airports, seed=0)
    joined = fk_join(data.flights, data.weather,
                     on={"airport": 64, "hour": 1 << 17}, prefix="w_")
    cols = joined.to_numpy()
    valid = cols.pop("_valid")
    n = len(valid)

    specs = build_specs()
    shared = ["airport", "carrier", "traffic", "w_season"]
    treatments = {t: shared + c for t, c in COVARIATES.items()}
    mesh = make_data_mesh(args.devices) if args.devices > 1 else None
    if mesh is not None:
        print(f"== sharding ingest over {args.devices}-device data mesh ==")
    if args.partitioned:
        print("== key-range partitioned views: each device owns "
              f"1/{max(args.devices, 1)} of every stat table ==")
        engine = PartitionedOnlineEngine(specs, treatments,
                                         outcome="dep_delay",
                                         query_dims=("airport",), mesh=mesh)
    else:
        engine = OnlineEngine(specs, treatments, outcome="dep_delay",
                              query_dims=("airport",), mesh=mesh)

    # seed with the first half, stream the rest
    seed_n = n // 2
    edges = np.linspace(seed_n, n, args.batches + 1).astype(int)
    slices = [(0, seed_n)] + list(zip(edges[:-1], edges[1:]))

    print(f"\n== streaming {len(slices)} batches "
          f"(seed {seed_n:,} rows, then ~{(n - seed_n) // args.batches:,} "
          "rows/batch) ==")
    hdr = " ".join(f"{t:>9s}" for t in COVARIATES)
    print(f"{'batch':>6s} {'rows':>9s} {'ingest':>8s} {hdr}   (truth: "
          + ", ".join(f"{t}={data.true_sate[t]:.1f}" for t in COVARIATES)
          + ")")
    held_back = None
    if args.serve:                  # keep one live batch for the serve demo
        held_back = slices.pop()
    for i, (s, e) in enumerate(slices):
        batch = Table.from_numpy({k: v[s:e] for k, v in cols.items()},
                                 valid[s:e])
        t0 = time.perf_counter()
        rep = engine.ingest(batch)
        dt = time.perf_counter() - t0
        ates = " ".join(f"{float(engine.ate(t).ate):9.2f}"
                        for t in COVARIATES)
        tag = "" if all(rep.fast_path.values()) else "  [grew]"
        print(f"{i:6d} {e - s:9,d} {dt:7.2f}s {ates}{tag}")

    print("\n== online sub-population queries (materialized, cached) ==")
    for airport in (0, 1):
        t0 = time.perf_counter()
        est = engine.ate("thunder", subpopulation={"airport": [airport]})
        dt = time.perf_counter() - t0
        print(f"   ATE(thunder | airport={airport}) = {float(est.ate):7.2f}"
              f"   [{dt * 1e3:.1f}ms]")
    t0 = time.perf_counter()
    engine.ate("thunder", subpopulation={"airport": [0]})
    print(f"   repeat query: {(time.perf_counter() - t0) * 1e6:.0f}us "
          f"(cache hits={engine.cache_hits})")

    if args.serve:
        serve_demo(engine, cols, valid, held_back)

    print("\n== streaming propensity (bounded reservoir, no row log) ==")
    t0 = time.perf_counter()
    model = engine.refresh_propensity("thunder",
                                      ["traffic", "w_precipm", "w_wspdm"])
    dt = time.perf_counter() - t0
    print(f"   fit over {int(engine.stream.n):,} streamed rows via "
          f"{engine.stream.capacity:,}-row reservoir in {dt:.2f}s "
          f"(converged={bool(model.converged)})")

    print("\n== offline recompute over everything ingested (the "
          "per-refresh cost this engine avoids) ==")
    full = Table.from_numpy(cols, valid)
    for t in COVARIATES:
        tspecs = {c: specs[c] for c in treatments[t]}
        t0 = time.perf_counter()
        offline = estimate_ate(cem(full, t, "dep_delay", tspecs).groups)
        dt = time.perf_counter() - t0
        online = engine.ate(t)
        print(f"   {t:9s} offline {float(offline.ate):7.2f} in {dt:5.2f}s"
              f" | online {float(online.ate):7.2f} from materialized state"
              f" | truth {data.true_sate[t]:6.2f}")

    sb = engine.state_bytes()
    print(f"\n== materialized state: {sb['total']:,} B total, "
          f"{sb['per_device']:,} B per device ==")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization). Everything below is ordinary.
"""Multi-pod dry-run driver.

For every (architecture x applicable input shape x mesh):
  jax.jit(step, in_shardings, out_shardings).lower(abstract...).compile()
then record memory_analysis(), cost_analysis(), and the three-term roofline
(parsed from the per-device HLO). No arrays are ever allocated.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
  python -m repro.launch.dryrun --zaliql          # the causal engine cell
"""
import argparse
import json
import math
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, applicable_shapes
from repro.configs.base import ShapeSpec
from repro.launch import sharding as shp
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_state, input_specs,
                                pick_microbatches)
from repro.models import shard_hints
from repro.optim import AdamWConfig
from repro.roofline import analyze
from repro.train import make_decode, make_prefill, make_train_step


def _mesh_label(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def _scan_fallback_trip(cfg) -> int:
    # deepest scan trip count, for while-loops whose bound the parser misses
    return max(cfg.n_layers, cfg.n_encoder_layers, 1)


def lower_cell(cfg, shape: ShapeSpec, mesh, microbatches=None):
    """Returns (lowered, in_info) for one cell."""
    features = set(shard_hints.ALL_FEATURES)
    if not getattr(cfg, "seq_parallel", True):
        features.discard("seq_par")
    shard_hints.set_hints(dp_axes(mesh), dict(mesh.shape),
                          features=features)
    try:
        return _lower_cell_inner(cfg, shape, mesh, microbatches)
    finally:
        shard_hints.clear_hints()


def _lower_cell_inner(cfg, shape: ShapeSpec, mesh, microbatches=None):
    dp_n = math.prod(mesh.shape[a] for a in dp_axes(mesh))
    batch = input_specs(cfg, shape)
    batch_specs = shp.batch_pspecs(cfg, shape.kind,
                                   {k: v.shape for k, v in batch.items()},
                                   mesh)
    pspecs = shp.params_pspecs(
        jax.eval_shape(lambda: abstract_state(cfg))["params"], mesh)

    if shape.kind == "train":
        state = abstract_state(cfg)
        ospecs = shp.opt_pspecs(state["opt"], pspecs, mesh)
        state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
        mb = microbatches or pick_microbatches(cfg, shape, dp_n)
        step = make_train_step(cfg, AdamWConfig(), microbatches=mb,
                               grad_shardings=shp.to_named(pspecs, mesh))
        jitted = jax.jit(
            step,
            in_shardings=(shp.to_named(state_specs, mesh),
                          shp.to_named(batch_specs, mesh)),
            out_shardings=(shp.to_named(state_specs, mesh),
                           NamedSharding(mesh, P())),
            donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state, batch)
        return lowered, {"microbatches": mb}

    if shape.kind == "prefill":
        prefill = make_prefill(cfg, shape.seq_len)
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_specs = shp.cache_pspecs(cache, cfg, mesh)
        params = abstract_state(cfg)["params"]
        jitted = jax.jit(
            prefill,
            in_shardings=(shp.to_named(pspecs, mesh),
                          shp.to_named(batch_specs, mesh)),
            out_shardings=(shp.to_named(cache_specs, mesh),
                           NamedSharding(mesh, P())))
        with mesh:
            lowered = jitted.lower(params, batch)
        return lowered, {}

    # decode: one token against a seq_len cache
    decode = make_decode(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_specs = shp.cache_pspecs(cache, cfg, mesh)
    params = abstract_state(cfg)["params"]
    batch = input_specs(cfg, shape)
    extras = {k: v for k, v in batch.items() if k not in ("token", "pos")}
    extras_specs = {k: batch_specs[k] for k in extras}
    args = (params, cache, batch["token"], batch["pos"])
    in_sh = (shp.to_named(pspecs, mesh), shp.to_named(cache_specs, mesh),
             NamedSharding(mesh, batch_specs["token"]),
             NamedSharding(mesh, batch_specs["pos"]))
    if extras:
        args = args + (extras,)
        in_sh = in_sh + (shp.to_named(extras_specs, mesh),)
    jitted = jax.jit(
        decode,
        in_shardings=in_sh,
        out_shardings=(NamedSharding(mesh, P()),
                       shp.to_named(cache_specs, mesh)),
        donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             collect_roofline: bool = True, microbatches=None
             ) -> Dict[str, Any]:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.shape.values())
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_label(multi_pod),
        "kind": shape.kind,
    }
    try:
        lowered, info = lower_cell(cfg, shape, mesh,
                                   microbatches=microbatches)
        rec.update(info)
        compiled = lowered.compile()
        ms = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        ca = ca or {}
        rec["ok"] = True
        rec["compile_s"] = round(time.time() - t0, 1)
        total = int(ms.argument_size_in_bytes + ms.output_size_in_bytes
                    + ms.temp_size_in_bytes - ms.alias_size_in_bytes)
        rec["memory"] = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "alias_bytes": int(ms.alias_size_in_bytes),
            "total_nonaliased": total,
            "fits_16g_hbm": total <= 16 * 2 ** 30,
        }
        rec["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        if collect_roofline:
            hlo = compiled.as_text()
            rl = analyze(arch, shape, rec["mesh"], cfg, hlo, n_dev,
                         memory_stats=ms,
                         fallback_trip=_scan_fallback_trip(cfg))
            rec["roofline"] = rl.row()
            rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # record failures; the suite asserts none remain
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def zaliql_cell(multi_pod: bool, n_rows_per_dev: int = 1 << 20,
                capacity: int = 1 << 14) -> Dict[str, Any]:
    """Dry-run for the paper's engine itself: distributed CEM + ATE over the
    production mesh (rows sharded over every axis)."""
    from repro.core.distributed import make_distributed_cem
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.shape.values())
    # flatten all axes into one logical data axis for the engine
    flat = jax.sharding.Mesh(mesh.devices.reshape(-1), ("data",))
    n = n_rows_per_dev * n_dev
    S = jax.ShapeDtypeStruct
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": "zaliql-cem", "mesh": _mesh_label(multi_pod),
                           "shape": f"rows_{n}", "kind": "analytics"}
    try:
        f = make_distributed_cem(flat, capacity=capacity)
        lowered = f.lower(S((n,), jnp.uint32), S((n,), jnp.uint32),
                          S((n,), jnp.int32), S((n,), jnp.float32),
                          S((n,), jnp.bool_))
        compiled = lowered.compile()
        ms = compiled.memory_analysis()
        rec["ok"] = True
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = {"total_nonaliased": int(
            ms.argument_size_in_bytes + ms.output_size_in_bytes
            + ms.temp_size_in_bytes - ms.alias_size_in_bytes)}
        from repro.configs import REGISTRY as R
        hlo = compiled.as_text()
        from repro.roofline.hlo_cost import HloCostModel
        from repro.roofline import hw
        cost = HloCostModel(hlo, default_group=n_dev,
                            fallback_trip=32).entry_cost()
        rec["roofline"] = {
            "flops_per_dev": cost.flops,
            "hbm_bytes_per_dev": cost.hbm_bytes,
            "coll_bytes_per_dev": cost.collective_bytes,
            "coll_breakdown": cost.collective_breakdown,
            "t_compute_s": cost.flops / hw.PEAK_BF16_FLOPS,
            "t_memory_s": cost.hbm_bytes / hw.HBM_BW,
            "t_collective_s": cost.collective_bytes / hw.ICI_LINK_BW,
        }
        tt = rec["roofline"]
        rec["roofline"]["bottleneck"] = max(
            ("compute", tt["t_compute_s"]), ("memory", tt["t_memory_s"]),
            ("collective", tt["t_collective_s"]), key=lambda kv: kv[1])[0]
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zaliql", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]
    cells = []
    if args.zaliql:
        for mp in meshes:
            cells.append(("__zaliql__", None, mp))
    elif args.all:
        for arch, cfg in sorted(REGISTRY.items()):
            for s in applicable_shapes(cfg):
                for mp in meshes:
                    cells.append((arch, s.name, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    results = []
    for arch, shape_name, mp in cells:
        if arch == "__zaliql__":
            rec = zaliql_cell(mp)
        else:
            rec = run_cell(arch, shape_name, mp,
                           collect_roofline=not args.no_roofline,
                           microbatches=args.microbatches)
        status = "OK " if rec.get("ok") else "FAIL"
        extra = ""
        if rec.get("ok") and "memory" in rec:
            extra = f" mem/dev={rec['memory']['total_nonaliased']/2**30:.2f}GiB"
            if "roofline" in rec:
                extra += f" bottleneck={rec['roofline']['bottleneck']}"
        print(f"[{status}] {rec['arch']:24s} {str(rec['shape']):12s} "
              f"{rec['mesh']:8s} compile={rec.get('compile_s', '-')}s{extra}",
              flush=True)
        if not rec.get("ok"):
            print("       ", rec.get("error"), flush=True)
        results.append(rec)
        if args.out:  # write incrementally — long runs survive interrupts
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_fail = sum(1 for r in results if not r.get("ok"))
    print(f"\n{len(results) - n_fail}/{len(results)} cells OK")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Sharding policy: parameter/optimizer/batch/cache PartitionSpecs.

Strategy (Megatron-style TP + FSDP, standard at 256+ chips):
  * big 2-D matrices: TP-shard the larger of the last two dims over
    "model"; FSDP-shard the other over "data" (so params/optimizer scale
    with the full chip count, not just TP degree);
  * expert tensors (".../moe/{gate,up,down}", rank>=3): expert dim over
    "model" (EP) + FSDP on the feature dim;
  * embeddings/heads: vocab over "model" when divisible, else hidden;
  * small params (< 2^22 elements in the trailing two dims): replicated —
    sharding them buys nothing and costs collectives;
  * int8 optimizer states (blocked (nb, 128)): block dim over every mesh
    axis that divides it;
  * batch over ("pod","data"); decode caches: heads over "model" when
    divisible else sequence over "model" (context-parallel decode), batch
    over data axes when divisible else sequence again.

Leading stacked-layer dims are never sharded (they are scan axes).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

SMALL = 1 << 22


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspec(path: str, shape: Tuple[int, ...], mesh,
                fsdp: bool = True) -> P:
    nd = len(shape)
    model_n = mesh.shape.get("model", 1)
    fs_axes = dp_axes(mesh)              # ("pod","data") on the multi-pod
    fs_n = math.prod(mesh.shape[a] for a in fs_axes)
    none = (None,) * nd

    def full(*trailing):
        return P(*((None,) * (nd - len(trailing)) + trailing))

    def fs_for(dim):
        return fs_axes if (fsdp and dim % fs_n == 0) else None

    base = path.rsplit("/", 1)[-1]
    # --- embeddings / heads ---
    if base in ("embed", "lm_head") and nd == 2:
        v, d = shape
        if v % model_n == 0:
            return P("model", fs_for(d))
        if d % model_n == 0:
            return P(None, "model")
        return P(None, None)
    # --- expert tensors: weight-gathered MoE (EXPERIMENTS.md §Perf) ---
    # EP-sharding the expert dim forces token scatter/gather across the
    # "model" axis, which GSPMD lowers to TB-scale all-reduces; instead the
    # experts are FSDP-sharded over BOTH axes and all-gathered per layer
    # (~1 GB), keeping dispatch/combine token-local.
    if "/moe/" in path and base in ("gate", "up", "down") and nd >= 3:
        e, d0, d1 = shape[-3], shape[-2], shape[-1]
        ep = "model" if e % model_n == 0 else None
        if fsdp and d0 % fs_n == 0:
            return full(ep, fs_axes, None)
        return full(ep, None, None)
    if nd < 2:
        return P(*none)
    d0, d1 = shape[-2], shape[-1]
    if d0 * d1 < SMALL:
        return P(*none)
    # --- generic matrices: TP on larger trailing dim, FSDP on the other ---
    if d1 >= d0 and d1 % model_n == 0:
        return full(fs_for(d0), "model")
    if d0 % model_n == 0:
        return full("model", fs_for(d1))
    if d1 % model_n == 0:
        return full(fs_for(d0), "model")
    return P(*none)


def params_pspecs(abstract_params, mesh, fsdp: bool = True):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = [param_pspec(_path_str(p), leaf.shape, mesh, fsdp)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(abstract_opt, param_specs, mesh):
    """Optimizer-state specs. f32 m/v mirror the param spec. Int8 states are
    shape-preserving (see optim/quantized.py): q mirrors the param spec
    exactly; its per-channel scale (last dim == 1) mirrors all but the last
    axis."""

    def _lookup(tree, path):
        node = tree
        for p in path:
            key = getattr(p, "key", getattr(p, "idx", None))
            try:
                node = node[key]
            except (KeyError, TypeError, IndexError):
                return None
        return node if isinstance(node, P) else None

    def one(branch):
        def map_fn(path, leaf):
            last = str(getattr(path[-1], "key", path[-1])) if path else ""
            if last in ("q", "scale"):
                pspec = _lookup(param_specs, path[:-1])
                if pspec is None:
                    return P(*((None,) * len(leaf.shape)))
                if last == "q":
                    return pspec
                # scale: param spec with the last axis unsharded (size 1)
                entries = list(pspec) + [None] * (len(leaf.shape)
                                                  - len(pspec))
                entries = entries[:len(leaf.shape)]
                if entries:
                    entries[-1] = None
                return P(*entries)
            pspec = _lookup(param_specs, path)
            return pspec if pspec is not None else \
                P(*((None,) * len(leaf.shape)))
        return jax.tree_util.tree_map_with_path(map_fn, branch)

    return {
        "m": one(abstract_opt["m"]),
        "v": one(abstract_opt["v"]),
        "count": P(),
    }


def batch_pspecs(cfg, shape_kind: str, batch_shapes: Dict[str, Tuple[int, ...]],
                 mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    dp_n = math.prod(mesh.shape[a] for a in dp)
    out = {}
    for name, shp in batch_shapes.items():
        if name == "positions" and len(shp) == 3:      # (3, B, S)
            out[name] = P(None, dp if shp[1] % dp_n == 0 else None, None)
        elif name in ("tokens", "labels", "loss_mask"):
            out[name] = P(dp if shp[0] % dp_n == 0 else None,
                          *((None,) * (len(shp) - 1)))
        elif name in ("frames", "inputs_embeds", "enc_out"):
            out[name] = P(dp if shp[0] % dp_n == 0 else None,
                          *((None,) * (len(shp) - 1)))
        elif name in ("token", "pos"):                  # decode scalars (B,)
            out[name] = P(dp if shp[0] % dp_n == 0 else None)
        else:
            out[name] = P(*((None,) * len(shp)))
    return out


def cache_pspec(path: str, shape: Tuple[int, ...], cfg, mesh) -> P:
    """KV/SSM cache sharding by leaf name (see module docstring)."""
    dp = dp_axes(mesh)
    dp_n = math.prod(mesh.shape[a] for a in dp)
    model_n = mesh.shape.get("model", 1)
    nd = len(shape)
    base = path.rsplit("/", 1)[-1]
    spec = [None] * nd

    def set_ax(i, ax):
        spec[i] = ax

    if base in ("k", "v"):          # (..., B, S, Hkv, Dh)
        bi, si, hi = nd - 4, nd - 3, nd - 2
        heads = shape[hi]
        if shape[bi] % dp_n == 0 and shape[bi] > 1:
            set_ax(bi, dp)
        if heads % model_n == 0:
            set_ax(hi, "model")
            if spec[bi] is None and shape[si] % dp_n == 0:
                set_ax(si, dp)
        elif shape[si] % model_n == 0:
            set_ax(si, "model")
            if spec[bi] is None and shape[si] % (dp_n * model_n) == 0:
                set_ax(si, dp + ("model",))
    elif base in ("c_kv", "k_pe"):  # (..., B, S, r)
        bi, si = nd - 3, nd - 2
        if shape[bi] % dp_n == 0 and shape[bi] > 1:
            set_ax(bi, dp)
            if shape[si] % model_n == 0:
                set_ax(si, "model")
        elif shape[si] % (dp_n * model_n) == 0:
            set_ax(si, dp + ("model",))
        elif shape[si] % model_n == 0:
            set_ax(si, "model")
    elif base == "conv":            # (..., B, di, K-1)
        bi, di = nd - 3, nd - 2
        if shape[bi] % dp_n == 0 and shape[bi] > 1:
            set_ax(bi, dp)
            if shape[di] % model_n == 0:
                set_ax(di, "model")
        elif shape[di] % (dp_n * model_n) == 0:
            set_ax(di, dp + ("model",))
        elif shape[di] % model_n == 0:
            set_ax(di, "model")
    elif base == "h":
        # mamba1 (..., B, di, st) / mamba2 (..., B, H, P, st)
        m2 = cfg.ssm_variant == "mamba2" or cfg.family == "hybrid"
        bi = nd - 4 if m2 else nd - 3
        ci = nd - 3 if m2 else nd - 2   # H or di
        if shape[bi] % dp_n == 0 and shape[bi] > 1:
            set_ax(bi, dp)
            if shape[ci] % model_n == 0:
                set_ax(ci, "model")
        elif shape[ci] % (dp_n * model_n) == 0:
            set_ax(ci, dp + ("model",))
        elif shape[ci] % model_n == 0:
            set_ax(ci, "model")
    return P(*spec)


def cache_pspecs(abstract_cache, cfg, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    specs = [cache_pspec(_path_str(p), leaf.shape, cfg, mesh)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(tree_of_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))

from repro.launch.mesh import (dp_axes, dp_size, make_production_mesh,
                               model_size)

__all__ = ["dp_axes", "dp_size", "make_production_mesh", "model_size"]

"""Production causal-analysis launcher: ZaliQL on a device mesh.

The paper-side counterpart of train.py/serve.py: loads (or generates)
observational data, coarsens + packs keys with the fused kernel wrapper,
and runs the DISTRIBUTED CEM + ATE (combine-broadcast group-by) with rows
sharded over every device, plus balance diagnostics and timings.

  python -m repro.launch.analyze --rows 2_000_000            # 1 device
  python -m repro.launch.analyze --rows 8_000_000 --devices 8
"""
import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = real devices)")
    ap.add_argument("--capacity", type=int, default=1 << 13)
    ap.add_argument("--treatment", default="thunder")
    args = ap.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np
    from repro.core import CoarsenSpec, difference_in_means
    from repro.core.cem import pack_keys
    from repro.core.distributed import make_distributed_cem
    from repro.data import flightgen
    from repro.data.columnar import compact

    n_dev = jax.device_count()
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    print(f"devices: {n_dev}; rows: {args.rows:,}")

    t0 = time.perf_counter()
    data = flightgen.generate(n_flights=args.rows, n_airports=8, seed=0)
    table = data.integrated
    print(f"generate+join: {time.perf_counter() - t0:.1f}s")

    specs = {
        "airport": CoarsenSpec.categorical(16),
        "carrier": CoarsenSpec.categorical(16),
        "traffic": CoarsenSpec.equal_width(0, 60, 8),
        "w_season": CoarsenSpec.equal_width(0, 1, 4),
        "w_precipm": CoarsenSpec.equal_width(0, 3, 5),
        "w_wspdm": CoarsenSpec.equal_width(0, 80, 5),
    }
    # pad rows to device multiple for even sharding
    pad = (-table.nrows) % n_dev
    if pad:
        table = compact(table, granule=max(n_dev, 4096))
    codec, hi, lo = pack_keys(table, specs)
    print(f"key width: {codec.total_bits} bits "
          f"({'single-word sort' if codec.total_bits <= 31 else 'lexicographic'})")

    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    put = lambda x: jax.device_put(x, sh)
    args_dev = (put(hi), put(lo), put(table[args.treatment]),
                put(table["dep_delay"]), put(table.valid))

    capacity = args.capacity
    while True:
        f = make_distributed_cem(mesh, capacity=capacity,
                                 key_bits=codec.total_bits)
        t0 = time.perf_counter()
        out = f(*args_dev)           # compile + first run
        out[0].block_until_ready()
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        ate, att, var, ng, nt, nc, matched, overflow = f(*args_dev)
        ate.block_until_ready()
        t_run = time.perf_counter() - t0
        if not bool(overflow):
            break
        # the overflow flag means the stat table truncated real groups:
        # results would be silently biased — grow and retry
        print(f"capacity {capacity} overflowed (distinct groups exceed "
              f"table); retrying with {capacity * 4}")
        capacity *= 4

    naive = float(difference_in_means(table["dep_delay"],
                                      table[args.treatment], table.valid))
    print(f"\nATE({args.treatment}) = {float(ate):+.3f} "
          f"± {float(var) ** 0.5:.3f} min  "
          f"(ATT {float(att):+.3f}; naive {naive:+.3f}; "
          f"truth {data.true_sate.get(args.treatment, float('nan')):+.3f})")
    print(f"groups: {int(ng)}; matched T/C: {int(nt)}/{int(nc)}; "
          f"overflow: {bool(overflow)}")
    print(f"first call (compile+run): {t_compile:.2f}s; steady-state pass: "
          f"{t_run * 1000:.0f} ms  ({table.nrows / max(t_run, 1e-9):,.0f} "
          "rows/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

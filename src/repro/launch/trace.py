"""Dispatch accounting for the online ingest AND query hot paths.

The single-dispatch claim of the fused pipelines ("one compiled program
per steady-state batch; one compiled program per uncached query") is
load-bearing: every extra launch is a host round-trip that serializes the
stream. jax 0.4.x executes jitted calls through a C++ fastpath that no
python-level hook observes, so the counter here instruments the call sites
we own instead: every compiled entry point of the engine hot paths is
wrapped with :func:`counted_jit`, which bumps a process-global counter on
each invocation of the compiled callable.

Scope: the counter sees every program launch issued through a
``counted_jit``-wrapped callable (all of ``repro.core.fused`` — ingest,
eviction, query and row-lookup programs — ``repro.core.online``'s planner
helpers, and the cached build/rollup programs). Launches can additionally
be LABELED (``counted_jit(fn, label="query")``) so tests can assert on one
entry-point family — e.g. "a cached ``ate()`` issues zero dispatches, an
uncached one exactly one". It does not see eager ``jnp`` operations — the
fused pipelines are written so their steady-state paths perform none
(pure-numpy host logic on fetched verdicts only), with ONE documented
exception: a batch whose row count is not already a power-of-two bucket
pays per-column eager ``jnp.pad`` copies before the ingest program
(``online.OnlineEngine._bucket_pad`` — async, no host sync, skipped
entirely for bucket-sized batches). ``tests/test_online_fused.py``
additionally asserts the jit trace cache stays cold (no retrace) across
steady-state ingests.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Callable, Optional

_state = threading.local()


def _counter() -> list:
    if not hasattr(_state, "count"):
        _state.count = [0]
    return _state.count


def _labels() -> dict:
    if not hasattr(_state, "labels"):
        _state.labels = {}
    return _state.labels


def dispatch_count(label: Optional[str] = None) -> int:
    """Total compiled-program launches observed so far (this thread).

    ``label`` restricts the count to launches issued through
    ``counted_jit(..., label=label)`` wrappers (e.g. ``"query"`` for the
    fused query / row-lookup programs)."""
    if label is None:
        return _counter()[0]
    return _labels().get(label, 0)


def record_dispatch(n: int = 1, label: Optional[str] = None) -> None:
    """Manually account ``n`` launches (for call sites that cannot wrap)."""
    _counter()[0] += n
    if label is not None:
        lab = _labels()
        lab[label] = lab.get(label, 0) + n


def dispatch_counts() -> dict:
    """Snapshot of every labeled counter (label -> launches, this
    thread). The unlabeled total is :func:`dispatch_count`."""
    return dict(_labels())


def _batches() -> dict:
    if not hasattr(_state, "batches"):
        _state.batches = {}
    return _state.batches


def record_batch(n_items: int, label: str = "query") -> None:
    """Account ``n_items`` logical requests served by ONE batched launch
    of the ``label`` family — e.g. a batched query program answering B
    heterogeneous specs in one dispatch. Lets tests and benchmarks read
    requests-per-dispatch directly instead of inferring it."""
    b = _batches()
    b[label] = b.get(label, 0) + int(n_items)


def batched_served(label: str = "query") -> int:
    """Total logical requests served through batched launches of the
    ``label`` family (this thread); pairs with ``dispatch_count(label)``
    to give the amortization ratio of the batched query path."""
    return _batches().get(label, 0)


def _syncs() -> dict:
    if not hasattr(_state, "syncs"):
        _state.syncs = {None: 0}
    return _state.syncs


def record_host_sync(n: int = 1, label: Optional[str] = None) -> None:
    """Account ``n`` host synchronizations (device->host fetches that block
    the python thread on device results). The fused ingest hot path claims
    ZERO of these between a dispatch and its commit point; the overlap
    benches and the jaxpr audit read this counter to prove it, because
    ``jax.transfer_guard("disallow")`` only intercepts IMPLICIT transfers —
    an explicit ``jax.device_get`` sails straight through the guard."""
    s = _syncs()
    s[None] += n
    if label is not None:
        s[label] = s.get(label, 0) + n


def host_sync_count(label: Optional[str] = None) -> int:
    """Total host syncs accounted so far (this thread), optionally
    restricted to one ``label`` family (e.g. ``"commit"``, ``"query"``)."""
    return _syncs().get(label, 0)


@contextlib.contextmanager
def count_host_syncs(label: Optional[str] = None):
    """Context manager yielding a zero-based live host-sync counter:

    >>> with count_host_syncs() as n:
    ...     eng.ingest(batch)          # overlap mode: dispatch only
    >>> assert n() == 0                # verdicts are checked at commit()
    """
    start = host_sync_count(label)
    yield lambda: host_sync_count(label) - start


def device_fetch(tree, label: Optional[str] = None):
    """``jax.device_get`` with host-sync accounting — the ONLY way engine
    code is allowed to pull device values to the host (contract rule
    ZQL007 treats it as a sync call like ``jax.device_get`` itself).
    Routing every fetch through here lets the audit assert "zero host
    syncs between ingest dispatch and commit" as a counted fact rather
    than an unobservable claim."""
    import jax

    record_host_sync(1, label=label)
    return jax.device_get(tree)


# Background-thread accounting. Dispatch/sync counters above are
# thread-local on purpose (each test thread sees only its own launches),
# which makes them blind to work done OFF the engine thread — e.g. the
# AsyncSaver retrying a checkpoint write in its writer thread. Events are
# the process-global, lock-protected complement for exactly those.
_events: dict = {}
_events_lock = threading.Lock()


def record_event(name: str, n: int = 1) -> None:
    """Account ``n`` occurrences of a named process-global event (safe to
    call from any thread; e.g. ``"ckpt_save_retry"`` from the AsyncSaver
    writer thread)."""
    with _events_lock:
        _events[name] = _events.get(name, 0) + n


def event_count(name: str) -> int:
    """Total process-global occurrences of ``name`` recorded so far."""
    with _events_lock:
        return _events.get(name, 0)


def event_counts() -> dict:
    """Snapshot of every process-global event counter."""
    with _events_lock:
        return dict(_events)


def record_replication(**counts: int) -> None:
    """Account replication-tier events under the ``repl.`` namespace —
    ``record_replication(ship_records=3, ship_bytes=n)`` from the shipping
    loop, ``failovers=1`` from promotion, ``stale_rejects=1`` from epoch
    fencing. Process-global (the ship/apply loops may run off-thread) and
    host-side only: accounting must never touch a device buffer."""
    for name, n in counts.items():
        record_event(f"repl.{name}", int(n))


def replication_counts() -> dict:
    """Snapshot of the ``repl.*`` counters, namespace stripped:
    ``{"ship_records": 12, "failovers": 1, ...}``."""
    with _events_lock:
        return {k[len("repl."):]: v for k, v in _events.items()
                if k.startswith("repl.")}


def hot_path(fn: Callable) -> Callable:
    """Marker for traced hot-path bodies: ``fn`` runs INSIDE a compiled
    program (a fused-pipeline body, a shard_map shard body, a Pallas
    kernel wrapper), so it must stay free of host synchronization —
    ``jax.device_get``, ``np.asarray``/``np.array``, ``.block_until_ready``,
    ``float()/int()/bool()`` on traced values would either fail under jit
    or silently serialize the stream when the body is also callable
    eagerly. A no-op at runtime; the static contract checker
    (``repro.analysis``, rule ZQL002) enforces the restriction on every
    function carrying this marker or wrapped by :func:`counted_jit`."""
    fn.__hot_path__ = True
    return fn


def counted_jit(fn: Callable = None, label: Optional[str] = None,
                **jit_kwargs) -> Callable:
    """``jax.jit`` that bumps the dispatch counter once per call.

    Drop-in replacement: ``counted_jit(f, static_argnames=...)`` or as a
    decorator. ``label`` additionally attributes the launch to a named
    entry-point family (see :func:`dispatch_count`). The wrapper preserves
    the jitted callable's AOT/trace attributes that the engines rely on
    (``_cache_size`` for the no-retrace assertion)."""
    import jax

    def wrap(f):
        jitted = jax.jit(f, **jit_kwargs)

        @functools.wraps(f)
        def call(*args, **kwargs):
            record_dispatch(1, label=label)
            return jitted(*args, **kwargs)

        call._jitted = jitted
        call._cache_size = jitted._cache_size
        call.lower = jitted.lower
        return call

    return wrap if fn is None else wrap(fn)


@contextlib.contextmanager
def count_dispatches(label: Optional[str] = None):
    """Context manager yielding a zero-based live counter:

    >>> with count_dispatches() as n:
    ...     eng.ingest(batch)
    >>> assert n() == 1

    ``label`` restricts the live counter to one entry-point family:

    >>> with count_dispatches(label="query") as n:
    ...     eng.ate("t")
    >>> assert n() == 1
    """
    start = dispatch_count(label)
    yield lambda: dispatch_count(label) - start

"""Dispatch accounting for the online ingest hot path.

The single-dispatch claim of the fused ingest pipeline ("one compiled
program per steady-state batch") is load-bearing: every extra launch is a
host round-trip that serializes the stream. jax 0.4.x executes jitted
calls through a C++ fastpath that no python-level hook observes, so the
counter here instruments the call sites we own instead: every compiled
entry point of the engine hot paths is wrapped with :func:`counted_jit`,
which bumps a process-global counter on each invocation of the compiled
callable.

Scope: the counter sees every program launch issued through a
``counted_jit``-wrapped callable (all of ``repro.core.fused``,
``repro.core.online``'s planner helpers, and the cached build/rollup
programs). It does not see eager ``jnp`` operations — the fused pipeline
is written so its steady-state path performs none (pure-numpy host logic
on fetched verdicts only), and ``tests/test_online_fused.py`` additionally
asserts the jit trace cache stays cold (no retrace) across steady-state
ingests.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Callable

_state = threading.local()


def _counter() -> list:
    if not hasattr(_state, "count"):
        _state.count = [0]
    return _state.count


def dispatch_count() -> int:
    """Total compiled-program launches observed so far (this thread)."""
    return _counter()[0]


def record_dispatch(n: int = 1) -> None:
    """Manually account ``n`` launches (for call sites that cannot wrap)."""
    _counter()[0] += n


def counted_jit(fn: Callable = None, **jit_kwargs) -> Callable:
    """``jax.jit`` that bumps the dispatch counter once per call.

    Drop-in replacement: ``counted_jit(f, static_argnames=...)`` or as a
    decorator. The wrapper preserves the jitted callable's AOT/trace
    attributes that the engines rely on (``_cache_size`` for the
    no-retrace assertion)."""
    import jax

    def wrap(f):
        jitted = jax.jit(f, **jit_kwargs)

        @functools.wraps(f)
        def call(*args, **kwargs):
            _counter()[0] += 1
            return jitted(*args, **kwargs)

        call._jitted = jitted
        call._cache_size = jitted._cache_size
        call.lower = jitted.lower
        return call

    return wrap if fn is None else wrap(fn)


@contextlib.contextmanager
def count_dispatches():
    """Context manager yielding a zero-based live counter:

    >>> with count_dispatches() as n:
    ...     eng.ingest(batch)
    >>> assert n() == 1
    """
    start = dispatch_count()
    yield lambda: dispatch_count() - start

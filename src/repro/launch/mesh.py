"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries the slow inter-pod (DCN) dimension; batch shards over
(pod, data), gradients all-reduce hierarchically.

Defined as FUNCTIONS so importing this module never touches jax device
state (dryrun.py must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """`jax.make_mesh` across jax versions: `axis_types` (and
    `jax.sharding.AxisType`) only exist in newer releases."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_data_mesh(n_devices: int = None, axis: str = "data"):
    """1-D mesh over ``n_devices`` (default: all local devices) with a
    single data axis — the shape the online engine's sharded delta
    maintenance and the distributed combine-broadcast programs expect."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh((n,), (axis,))


def partition_sharding(mesh, axis: str = "data"):
    """NamedSharding that lays a ``(n_parts, capacity)`` partitioned stat
    table out along ``axis``. With ``n_parts == k * n_devices`` each device
    receives k CONTIGUOUS rows — and because key-range partitions are
    contiguous ranges of the hash space, a device's k partitions form one
    contiguous hash range too (k-partitions-per-device: partition capacity
    is bounded independently of the mesh size). ``n_parts`` must be a
    multiple of the axis size; the partitioned online engine enforces
    that."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec(axis, None))


def parts_per_device(mesh, n_parts: int, axis: str = "data") -> int:
    """k = n_parts / axis size (validating divisibility)."""
    n_dev = int(mesh.shape[axis])
    if n_parts % n_dev != 0:
        raise ValueError(f"n_parts={n_parts} not a multiple of the "
                         f"'{axis}' axis size {n_dev}")
    return n_parts // n_dev


def shard_partitions(mesh, tree, axis: str = "data"):
    """Place every (n_parts, ...) array leaf of ``tree`` with
    :func:`partition_sharding` over ``mesh``."""
    import jax as _jax
    s = partition_sharding(mesh, axis)
    return _jax.tree.map(lambda a: _jax.device_put(a, s), tree)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)

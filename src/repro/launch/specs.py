"""ShapeDtypeStruct stand-ins for every model input (no allocation).

`input_specs(cfg, shape)` builds the abstract batch for a (arch x shape)
cell; modality frontends are stubs per the assignment: audio supplies
frame embeddings, vision supplies 3-D M-RoPE positions (patch embeddings
ride through `tokens` + positions for shape purposes).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import abstract_params, init_cache
from repro.optim import get_optimizer

S = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b = shape.global_batch
    s = shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": S((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["positions"] = S((3, b, s), jnp.int32)
        if cfg.family == "encdec":
            # stub audio frontend: precomputed frame embeddings, 1 frame/token
            batch["frames"] = S((b, s, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"token": S((b,), jnp.int32), "pos": S((b,), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_out"] = S((b, s, cfg.d_model), jnp.float32)
    return batch


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


def abstract_state(cfg: ModelConfig):
    """Abstract {params, opt, step} for the train dry-run."""
    params = abstract_params(cfg)
    opt_init, _ = get_optimizer(cfg.optimizer)
    opt = jax.eval_shape(lambda p: opt_init(p), params)
    return {"params": params, "opt": opt,
            "step": S((), jnp.int32)}


def pick_microbatches(cfg: ModelConfig, shape: ShapeSpec, dp_total: int,
                      act_budget_bytes: float = 1.2e9) -> int:
    """Split the per-device batch so scanned-layer activation stash fits.

    Per-layer stash ~= B_loc * S * d_model * 2 bytes (bf16 residual stream,
    remat recomputes the rest); budget it against ~5 GB of the 16 GB HBM.
    """
    b_loc = max(1, shape.global_batch // dp_total)
    n_scan = cfg.n_layers
    stash = b_loc * shape.seq_len * cfg.d_model * 2 * n_scan
    mb = 1
    while stash / mb > act_budget_bytes and mb < b_loc:
        mb *= 2
    return min(mb, b_loc)

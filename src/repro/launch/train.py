"""End-to-end training launcher.

Wires together: config registry -> sharded state init -> pjit train_step
(remat + microbatch + optimizer) -> synthetic/deterministic data pipeline
-> async checkpointing -> Supervisor (crash recovery) -> straggler monitor.
Runs on one CPU device (mesh="none") for the examples/tests and on the
production meshes unchanged.

  python -m repro.launch.train --arch qwen3-1.7b --steps 100 --mesh single
  python -m repro.launch.train --preset lm-tiny --steps 60 --mesh none
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.configs.base import ModelConfig
from repro.checkpoint import AsyncSaver, latest_step, restore
from repro.launch import sharding as shp
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import init_params, shard_hints
from repro.optim import AdamWConfig
from repro.runtime import StepTimeMonitor, Supervisor
from repro.train import init_state, make_train_step

# CPU-scale presets for the runnable examples (the assigned archs lower on
# the production mesh via dryrun; these TRAIN for real on this container).
PRESETS = {
    "lm-tiny": ModelConfig(
        name="lm-tiny", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=2048,
        dtype="float32", param_dtype="float32", remat=False),
    "lm-100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
        dtype="float32", param_dtype="float32", remat=True),
}


def get_any_config(name: str) -> ModelConfig:
    if name in PRESETS:
        return PRESETS[name]
    return REGISTRY[name]


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int
                    ) -> Dict[str, np.ndarray]:
    """Deterministic step-keyed data (replayable across restarts): a mixture
    of 'skill' n-gram processes so the loss actually falls."""
    rng = np.random.default_rng(1234 + step)
    v = cfg.vocab_size
    base = rng.integers(0, v, (batch, seq), dtype=np.int64)
    # plant learnable structure: next token = (token + skill) % v on a slice
    skill = rng.integers(1, 17, (batch, 1))
    ar = (np.cumsum(np.ones((batch, seq), dtype=np.int64) * skill, axis=1)
          + base[:, :1]) % v
    use_ar = rng.random((batch, 1)) < 0.7
    tokens = np.where(use_ar, ar, base).astype(np.int32)
    out = {"tokens": tokens}
    if cfg.family == "encdec":
        out["frames"] = rng.normal(0, 1, (batch, seq, cfg.d_model)
                                   ).astype(np.float32)
    return out


def run(cfg: ModelConfig, steps: int, batch: int, seq: int,
        mesh_kind: str = "none", ckpt_dir: Optional[str] = None,
        microbatches: int = 1, log_every: int = 10, seed: int = 0,
        resume: bool = True, telemetry: Optional[list] = None):
    mesh = None
    if mesh_kind != "none":
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        shard_hints.set_hints(dp_axes(mesh), dict(mesh.shape))

    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = init_state(params, cfg)
    if mesh is not None:
        pspecs = shp.params_pspecs(jax.eval_shape(lambda: params), mesh)
        ospecs = shp.opt_pspecs(state["opt"], pspecs, mesh)
        sspecs = {"params": pspecs, "opt": ospecs,
                  "step": jax.sharding.PartitionSpec()}
        step_fn = make_train_step(cfg, AdamWConfig(lr=3e-4),
                                  microbatches=microbatches,
                                  total_steps=max(steps, 1),
                                  grad_shardings=shp.to_named(pspecs, mesh))
        step_fn = jax.jit(step_fn,
                          in_shardings=(shp.to_named(sspecs, mesh), None),
                          out_shardings=(shp.to_named(sspecs, mesh), None),
                          donate_argnums=(0,))
    else:
        step_fn = make_train_step(cfg, AdamWConfig(lr=3e-4),
                                  microbatches=microbatches,
                                  total_steps=max(steps, 1))
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
        _, state = restore(ckpt_dir, template=state)
        print(f"resumed from step {int(state['step'])}")

    saver = AsyncSaver()
    monitor = StepTimeMonitor(n_hosts=jax.process_count())
    history = []
    t_last = time.perf_counter()
    while int(state["step"]) < steps:
        s = int(state["step"])
        b = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, batch, seq, s).items()}
        state, metrics = step_fn(state, b)
        if telemetry is not None:
            telemetry.append({k: float(v) for k, v in metrics.items()})
        now = time.perf_counter()
        monitor.record({jax.process_index(): now - t_last})
        t_last = now
        s = int(state["step"])
        history.append(float(metrics["loss"]))
        if s % log_every == 0 or s == steps:
            print(f"step {s:5d}  loss {float(metrics['loss']):.4f}  "
                  f"ce {float(metrics['ce']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ckpt_dir and s % 50 == 0:
            saver.save(state, s, ckpt_dir)
    saver.wait()
    if ckpt_dir:
        from repro.checkpoint import save
        save(state, int(state["step"]), ckpt_dir)
    if mesh is not None:
        shard_hints.clear_hints()
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    cfg = get_any_config(args.preset or args.arch)
    _, history = run(cfg, args.steps, args.batch, args.seq,
                     mesh_kind=args.mesh, ckpt_dir=args.ckpt_dir,
                     microbatches=args.microbatches)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()

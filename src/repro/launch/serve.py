"""Batched serving launcher: prefill + decode with a simple continuous
batcher (slot-based, like vLLM's scheduler at its smallest).

  python -m repro.launch.serve --preset lm-tiny --requests 12 --new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serving import pow2_bucket
from repro.launch.train import get_any_config
from repro.models import init_params
from repro.train import make_decode, make_prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Batcher:
    """Fixed-slot continuous batching: new requests join as slots free.

    This is the LM-serving instance of the repo's slot-batcher shape —
    :class:`repro.core.serving.ServingEngine` generalizes the same
    admission policy to causal queries (where a request completes in one
    batched dispatch instead of a prefill + decode loop). Wave prompt
    lengths are padded to pow2 buckets (``pow2_bucket``, the shared
    bucketing rule of every batched entry point) so an irregular stream
    of prompt sizes retraces the prefill program at most ~log2(max_seq)
    times instead of once per distinct length."""

    def __init__(self, cfg, params, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill = jax.jit(make_prefill(cfg, max_seq))
        self.decode = jax.jit(make_decode(cfg))
        self.slots: List[Optional[Request]] = [None] * n_slots

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        # simplest correct policy: group requests into slot-sized waves with
        # same prompt length (pad), prefill the wave, decode until done
        while queue:
            wave = queue[:self.n_slots]
            queue = queue[self.n_slots:]
            n_new = max(r.max_new for r in wave)
            raw = max(len(r.prompt) for r in wave)
            # pow2 prompt bucket, capped so the decode positions still fit
            # the cache (and never below the longest prompt of the wave)
            plen = min(pow2_bucket(raw), max(raw, self.max_seq - n_new))
            toks = np.zeros((len(wave), plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, -len(r.prompt):] = r.prompt  # left-pad
            cache, last = self.prefill(self.params,
                                       {"tokens": jnp.asarray(toks)})
            tok = jnp.argmax(last, -1).astype(jnp.int32)
            for step in range(n_new):
                for i, r in enumerate(wave):
                    if len(r.out) < r.max_new:
                        r.out.append(int(tok[i]))
                pos = jnp.full((len(wave),), plen + step, jnp.int32)
                logits, cache = self.decode(self.params, cache, tok, pos)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r in wave:
                r.done = True
                results[r.rid] = r.out
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm-tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    cfg = get_any_config(args.preset)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12,
                                        dtype=np.int32),
                    max_new=args.new)
            for i in range(args.requests)]
    b = Batcher(cfg, params, n_slots=args.slots,
                max_seq=12 + args.new + 4)
    t0 = time.perf_counter()
    results = b.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
    assert all(len(v) == args.new for v in results.values())


if __name__ == "__main__":
    main()

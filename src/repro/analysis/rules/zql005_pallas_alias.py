"""ZQL005 — Pallas read-modify-write kernels without ``input_output_aliases``.

Contract (``docs/architecture.md`` — donation and aliasing rules): a
Pallas kernel whose output is an updated version of an input table (it
initializes the output ref FROM an input ref, then accumulates into it)
is a state-mutating kernel; without ``input_output_aliases`` XLA
materializes a second table-sized buffer per call — on the ingest hot
path that doubles the state traffic the in-place story exists to avoid.

Detection: for each ``pl.pallas_call(kernel, ...)`` the kernel's body is
inspected (module-level def). Output refs are recognized by the repo's
naming idiom (``out*`` parameters). The kernel mutates state when some
``out*[...] = ...`` assignment reads another parameter AND an
``out*[...] += ...`` accumulation exists; such a call must carry
``input_output_aliases``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common


def _is_out_param(name: str) -> bool:
    return name.startswith("out") or name.startswith("o_")


def _kernel_mutates_state(kernel: ast.FunctionDef) -> bool:
    params = [a.arg for a in kernel.args.args]
    outs = {p for p in params if _is_out_param(p)}
    ins = {p for p in params if p not in outs}
    if not outs or not ins:
        return False
    init_from_input = False
    accumulates = False
    for node in ast.walk(kernel):
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
        elif isinstance(node, ast.AugAssign):
            target = node.target
        if not (isinstance(target, ast.Subscript)
                and isinstance(target.value, ast.Name)
                and target.value.id in outs):
            continue
        if isinstance(node, ast.AugAssign):
            accumulates = True
        else:
            reads = {n.id for n in ast.walk(node.value)
                     if isinstance(n, ast.Name)}
            if reads & ins:
                init_from_input = True
    return init_from_input and accumulates


class Rule:
    id = "ZQL005"
    summary = ("Pallas kernel mutates state without input_output_aliases")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        kernels: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not _common.matches(_common.call_canonical(node, aliases),
                                   "pallas_call"):
                continue
            if any(kw.arg == "input_output_aliases" for kw in node.keywords):
                continue
            kernel = None
            if node.args and isinstance(node.args[0], ast.Name):
                kernel = kernels.get(node.args[0].id)
            if kernel is not None and _kernel_mutates_state(kernel):
                yield ctx.finding(
                    node, self.id,
                    f"pallas_call of `{kernel.name}` initializes its "
                    "output from an input table and accumulates into it "
                    "(read-modify-write) but has no input_output_aliases "
                    "— XLA materializes a second table buffer per call")


RULE = Rule()

"""Shared AST helpers for the ZQL rules: import-aware name resolution and
hot-path function discovery."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local name -> canonical dotted path, from the module's imports.

    ``import jax.numpy as jnp`` -> {"jnp": "jax.numpy"};
    ``from jax import jit`` -> {"jit": "jax.jit"}; relative imports keep
    the trailing module path (``from ..launch.trace import counted_jit``
    -> {"counted_jit": "launch.trace.counted_jit"}).
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
                if a.asname:
                    out[a.asname] = a.name
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for a in node.names:
                out[a.asname or a.name] = f"{mod}.{a.name}" if mod else a.name
    return out


def canonical(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Canonical dotted name of a Name/Attribute chain, resolving the
    leading segment through the import aliases; None for anything else."""
    if isinstance(node, ast.Name):
        return aliases.get(node.id, node.id)
    if isinstance(node, ast.Attribute):
        base = canonical(node.value, aliases)
        if base is None:
            return None
        return f"{base}.{node.attr}"
    return None


def call_canonical(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return canonical(node.func, aliases)


def matches(canon: Optional[str], *tails: str) -> bool:
    """True when ``canon`` is exactly a tail or ends with ``.<tail>`` —
    robust to import style (``jax.jit`` vs ``jit`` vs re-export)."""
    if canon is None:
        return False
    return any(canon == t or canon.endswith("." + t) for t in tails)


def decorator_targets(fn: ast.FunctionDef) -> Iterator[ast.AST]:
    """Each decorator's underlying callable expression:
    ``@jax.jit`` -> jax.jit; ``@partial(jax.jit, ...)`` -> jax.jit;
    ``@counted_jit`` -> counted_jit."""
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            c = dec.func
            if isinstance(c, (ast.Name, ast.Attribute)):
                name = c.attr if isinstance(c, ast.Attribute) else c.id
                if name == "partial" and dec.args:
                    yield dec.args[0]
                    continue
            yield dec.func
        else:
            yield dec


HOT_MARKERS = ("hot_path", "counted_jit")


def hot_functions(tree: ast.Module, aliases: Dict[str, str]
                  ) -> List[ast.FunctionDef]:
    """Functions whose bodies are traced hot-path compute: decorated with
    ``@hot_path`` or ``@counted_jit`` (directly or through ``partial``),
    or passed by name to a ``counted_jit(...)`` call in this module."""
    by_name: Dict[str, ast.FunctionDef] = {}
    hot: List[ast.FunctionDef] = []
    seen: Set[int] = set()

    def mark(fn: ast.FunctionDef):
        if id(fn) not in seen:
            seen.add(id(fn))
            hot.append(fn)

    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            by_name.setdefault(node.name, node)
            for target in decorator_targets(node):
                if matches(canonical(target, aliases), *HOT_MARKERS):
                    mark(node)
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and matches(call_canonical(node, aliases), "counted_jit")):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name) and arg.id in by_name:
                    mark(by_name[arg.id])
    return hot


def jit_cached_factory(fn: ast.FunctionDef, aliases: Dict[str, str]) -> bool:
    """True when ``fn`` is an ``lru_cache``/``cache``-decorated factory —
    its parameters are cache keys (static configuration by construction),
    so closures over them are keyed, not retrace hazards."""
    return any(matches(canonical(t, aliases), "lru_cache", "cache")
               for t in decorator_targets(fn))

"""Rule registry: one module per rule ID. Each rule object exposes
``id``, ``summary`` and ``check(ctx) -> Iterable[Finding]``."""
from repro.analysis.rules import (
    zql001_raw_jit,
    zql002_host_sync,
    zql003_reductions,
    zql004_donation,
    zql005_pallas_alias,
    zql006_retrace,
    zql007_sync_before_commit,
    zql008_wal_ordering,
    zql009_ship_verify,
)

RULES = [
    zql001_raw_jit.RULE,
    zql002_host_sync.RULE,
    zql003_reductions.RULE,
    zql004_donation.RULE,
    zql005_pallas_alias.RULE,
    zql006_retrace.RULE,
    zql007_sync_before_commit.RULE,
    zql008_wal_ordering.RULE,
    zql009_ship_verify.RULE,
]

RULE_IDS = [r.id for r in RULES]

"""ZQL009 — shipped WAL record applied before its epoch/CRC verification.

Contract (docs/architecture.md — Replication & failover): a follower may
only apply records that have passed BOTH gates — CRC decoding
(``repro.core.wal.decode_records`` / a ``read``/``read_tail`` on the log,
which validate every record's header and payload CRCs) and the
epoch/contiguity check (``repro.core.replication.verify_records``).
Applying an unverified shipped record lets a torn span, a bit-flipped
payload, or a fenced zombie primary's stale-epoch history mutate engine
state — silently breaking the replica-at-seq-s bitwise-identity
guarantee the whole tier rests on.

The rule fires when an engine-owned function calls an APPLY entry point
(``_apply_records`` / ``_apply_one`` / ``apply_records`` /
``apply_record``) without a VERIFY call — ``verify_records`` /
``decode_records`` or a ``read``/``read_tail``/``read_log`` whose
receiver chain names the log — EARLIER in source order: the straight-line
receive/replay protocols this rule guards execute in source order,
exactly like ZQL008's journaling windows. Functions that ARE an apply
entry point (their own name is in the apply set) are exempt — they are
the implementation the rule protects, and their CALLERS carry the
verification obligation.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

#: record-applying entry points — the calls that mutate engine state from
#: a decoded WAL/ship record
_APPLY_CALLS = ("_apply_records", "_apply_one", "apply_records",
                "apply_record")

#: verification calls that may appear anywhere (module-level gates)
_VERIFY_CALLS = ("verify_records", "_verify_records", "verify_record",
                 "decode_records")

#: log reads that CRC-validate every record they return; the receiver
#: chain must name the log (``self.wal.read_tail`` / ``read_log(dir)``)
_VERIFIED_READS = ("read", "read_tail", "read_log")


def _receiver_names(node: ast.AST) -> Iterator[str]:
    while isinstance(node, ast.Attribute):
        yield node.attr
        node = node.value
    if isinstance(node, ast.Name):
        yield node.id


def _is_verified_read(node: ast.Call) -> bool:
    if isinstance(node.func, ast.Name):
        return node.func.id == "read_log"
    if not isinstance(node.func, ast.Attribute):
        return False
    if node.func.attr not in _VERIFIED_READS:
        return False
    return any("wal" in name.lower() or "log" in name.lower()
               for name in _receiver_names(node.func.value))


def _events(fn: ast.AST, aliases) -> List[Tuple[Tuple[int, int], str,
                                                ast.AST]]:
    events = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        pos = (node.lineno, node.col_offset)
        canon = _common.call_canonical(node, aliases)
        if _common.matches(canon, *_VERIFY_CALLS) or _is_verified_read(node):
            events.append((pos, "verify", node))
        elif _common.matches(canon, *_APPLY_CALLS):
            events.append((pos, "apply", node))
    events.sort(key=lambda e: e[0])
    return events


class Rule:
    id = "ZQL009"
    summary = "shipped WAL record applied before epoch/CRC verification"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in _APPLY_CALLS:
                continue          # the apply implementation itself
            events = _events(fn, aliases)
            first_apply = next((e for e in events if e[1] == "apply"), None)
            if first_apply is None:
                continue          # function never applies records
            first_verify = next((e for e in events if e[1] == "verify"),
                                None)
            if first_verify is None or first_apply[0] < first_verify[0]:
                where = ("no verification in scope" if first_verify is None
                         else f"verification only at line "
                              f"{first_verify[0][0]}")
                yield ctx.finding(
                    first_apply[2], self.id,
                    f"`{fn.name}` applies a shipped/journaled WAL record "
                    f"(line {first_apply[0][0]}) before verifying its "
                    f"epoch/CRC ({where}) — a torn span or a fenced "
                    "zombie's stale history could mutate engine state; "
                    "verify_records/decode first")


RULE = Rule()

"""ZQL002 — host synchronization inside hot-path (traced) bodies.

Contract: a ``@hot_path``/``counted_jit`` body runs INSIDE a compiled
program; ``jax.device_get``, ``np.asarray``/``np.array``, numpy scalar
constructors on traced values, ``.block_until_ready()``, ``.item()``,
``.tolist()`` and ``float()/int()/bool()`` on non-constants either fail
under jit or — when the body also runs eagerly — silently serialize the
stream with a device->host round trip per call
(``docs/architecture.md`` — ingest/query pipelines: ONE host sync per
batch/query, placed by the orchestration layer, never by traced bodies).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

_SYNC_CALLS = ("jax.device_get", "numpy.asarray", "numpy.array",
               "numpy.frombuffer")
_SYNC_SCALAR_CTORS = ("numpy.int32", "numpy.int64", "numpy.float32",
                      "numpy.float64", "numpy.bool_", "numpy.uint32")
_SYNC_METHODS = ("block_until_ready", "item", "tolist")
_PY_CASTS = ("float", "int", "bool")


def _non_constant(args) -> bool:
    return bool(args) and not isinstance(args[0], ast.Constant)


class Rule:
    id = "ZQL002"
    summary = "host-sync call inside a hot-path (traced) body"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        aliases = _common.import_aliases(ctx.tree)
        for fn in _common.hot_functions(ctx.tree, aliases):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                canon = _common.call_canonical(node, aliases)
                if canon in _SYNC_CALLS or canon == "jax.device_get":
                    yield ctx.finding(
                        node, self.id,
                        f"`{canon}` inside hot-path body `{fn.name}` — "
                        "host sync on the traced path")
                elif canon in _SYNC_SCALAR_CTORS and _non_constant(node.args):
                    yield ctx.finding(
                        node, self.id,
                        f"`{canon}(...)` on a non-constant inside hot-path "
                        f"body `{fn.name}` — numpy scalar construction "
                        "syncs traced values to host")
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SYNC_METHODS):
                    yield ctx.finding(
                        node, self.id,
                        f"`.{node.func.attr}()` inside hot-path body "
                        f"`{fn.name}` — host sync on the traced path")
                elif (isinstance(node.func, ast.Name)
                        and node.func.id in _PY_CASTS
                        and aliases.get(node.func.id, node.func.id)
                        == node.func.id
                        and _non_constant(node.args)):
                    yield ctx.finding(
                        node, self.id,
                        f"`{node.func.id}(...)` on a non-constant inside "
                        f"hot-path body `{fn.name}` — python casts force "
                        "a device->host sync on traced values")


RULE = Rule()

"""ZQL007 — host sync between an ingest dispatch and its commit point.

Contract (MVCC overlap, docs/architecture.md — snapshot/commit
protocol): once a fused ingest/evict program has been DISPATCHED, the
host must not synchronize on device results until the output state has
been committed — a reference swap (``_unpack_view_state`` /
``_post_state_swap``) or an explicit ``commit()``. A ``device_get`` /
``device_fetch`` / ``np.asarray`` / ``.block_until_ready()`` in that
window stalls the python thread behind the dispatch and silently
re-serializes the pipelined ingest path back into stop-the-world
interleaving (the verdict scalars must be checked LAZILY, after the
commit point). The jaxpr audit enforces the same window dynamically with
``jax.transfer_guard`` plus the host-sync counter; this rule catches it
statically in engine-owned modules.

A dispatch site is a call of a local name bound from one of the fused
program factories (``_fused_program``, ``get_fused_ingest``,
``get_fused_ingest_parts``, ``get_fused_evict``), or a direct
``factory(...)(args)`` call. The window closes at the first
commit-point call (a name ending in ``_unpack_view_state``,
``_post_state_swap`` or ``commit``).
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

#: factories whose return value is a compiled ingest/evict program —
#: calling that value is the dispatch that opens the no-sync window
_PROGRAM_FACTORIES = ("_fused_program", "get_fused_ingest",
                      "get_fused_ingest_parts", "get_fused_evict")

#: calls that close the window: the output state is committed (reference
#: swap / version bump) and lazy verdict checks become legal
_COMMIT_POINTS = ("_unpack_view_state", "_post_state_swap", "commit")

#: host-synchronizing calls (the explicit-fetch subset of ZQL002 — these
#: pass jax.transfer_guard("disallow"), which only stops IMPLICIT
#: transfers, so they need a static rule)
_SYNC_CALLS = ("jax.device_get", "numpy.asarray", "numpy.array",
               "numpy.frombuffer")
_SYNC_TAILS = ("device_fetch",)
_SYNC_METHODS = ("block_until_ready", "item", "tolist")


def _call_events(fn: ast.AST, aliases) -> List[Tuple[Tuple[int, int],
                                                     str, ast.Call]]:
    """Every relevant call in ``fn``, tagged ``dispatch`` / ``sync`` /
    ``commit`` and ordered by source position (the bodies this rule
    guards are straight-line dispatch protocols, so source order is
    execution order; the growth loop commits before it fetches)."""
    program_names = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            canon = _common.call_canonical(node.value, aliases)
            if canon and _common.matches(canon, *_PROGRAM_FACTORIES):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        program_names.add(tgt.id)
    events = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        pos = (node.lineno, node.col_offset)
        # dispatch: prog(...) with prog bound from a factory, or the
        # direct get_fused_ingest(...)(args) form
        if (isinstance(node.func, ast.Name)
                and node.func.id in program_names):
            events.append((pos, "dispatch", node))
            continue
        if isinstance(node.func, ast.Call):
            inner = _common.call_canonical(node.func, aliases)
            if inner and _common.matches(inner, *_PROGRAM_FACTORIES):
                events.append((pos, "dispatch", node))
                continue
        canon = _common.call_canonical(node, aliases)
        if canon and (canon in _SYNC_CALLS
                      or _common.matches(canon, *_SYNC_TAILS)):
            events.append((pos, "sync", node))
        elif (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS):
            events.append((pos, "sync", node))
        elif canon and _common.matches(canon, *_COMMIT_POINTS):
            events.append((pos, "commit", node))
    events.sort(key=lambda e: e[0])
    return events


class Rule:
    id = "ZQL007"
    summary = "host sync between an ingest dispatch and its commit point"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            open_dispatch = None
            for _, kind, node in _call_events(fn, aliases):
                if kind == "dispatch":
                    open_dispatch = node
                elif kind == "commit":
                    open_dispatch = None
                elif kind == "sync" and open_dispatch is not None:
                    yield ctx.finding(
                        node, self.id,
                        f"host sync in `{fn.name}` between a fused "
                        f"program dispatch (line {open_dispatch.lineno}) "
                        "and its commit point — check verdicts lazily "
                        "AFTER the state swap/commit")


RULE = Rule()

"""ZQL008 — commit acknowledged before its write-ahead-log append.

Contract (docs/architecture.md — Durability & recovery): the WAL is only
a recovery oracle if every acknowledged operation is journaled FIRST. In
any engine-owned function that touches the log, a commit action — a
``_state_version`` bump, a ``commit()`` / ``ingest()`` / ``evict()``
dispatch into the wrapped engine, or a state swap
(``_unpack_view_state`` / ``_post_state_swap``) — must come AFTER the
function's WAL append/fsync: a crash between an early commit and a late
append loses an acknowledged batch, silently breaking the
restore-then-replay bit-identity guarantee. The fault-injection harness
(``tests/fault_injection.py``) checks the same ordering dynamically by
killing the process at each boundary; this rule catches an inverted
ordering statically, before it ships.

A WAL event is a method call whose receiver chain names the log
(``self.wal.append_batch(...)``, ``log.sync()``, ``wal.append_evict``);
``rotate``/``gc``/``read`` are bookkeeping, not durability points, and
are deliberately NOT events (``checkpoint()`` legally rotates after its
commit). The rule fires when the FIRST commit action in such a function
precedes the FIRST WAL event in source order — the straight-line
journaling protocols this rule guards execute in source order, exactly
like ZQL007's dispatch windows.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

#: calls on a WAL-named receiver that constitute journaling (append or
#: make-durable). rotate/gc/read/mark/rollback are bookkeeping.
_WAL_METHODS = ("append", "append_batch", "append_evict", "sync")

#: calls that acknowledge/commit the covered operation
_COMMIT_CALLS = ("commit", "ingest", "evict", "_unpack_view_state",
                 "_post_state_swap")


def _receiver_names(node: ast.AST) -> Iterator[str]:
    """Every identifier on an attribute chain: ``self.wal.sync`` ->
    ("self", "wal", "sync")."""
    while isinstance(node, ast.Attribute):
        yield node.attr
        node = node.value
    if isinstance(node, ast.Name):
        yield node.id


def _is_wal_call(node: ast.Call) -> bool:
    if not isinstance(node.func, ast.Attribute):
        return False
    if node.func.attr not in _WAL_METHODS:
        return False
    # the receiver (everything left of the method) must name the log
    return any("wal" in name.lower() or "log" in name.lower()
               for name in _receiver_names(node.func.value))


def _version_bump_target(node: ast.AST) -> Optional[ast.AST]:
    """The ``_state_version`` store in an (Aug)Assign, if any."""
    targets = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, ast.AugAssign):
        targets = [node.target]
    for tgt in targets:
        if isinstance(tgt, ast.Attribute) and tgt.attr == "_state_version":
            return tgt
        if isinstance(tgt, ast.Name) and tgt.id == "_state_version":
            return tgt
    return None


def _events(fn: ast.AST, aliases) -> List[Tuple[Tuple[int, int], str,
                                                ast.AST]]:
    events = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            tgt = _version_bump_target(node)
            if tgt is not None:
                events.append(((node.lineno, node.col_offset),
                               "commit", node))
            continue
        if not isinstance(node, ast.Call):
            continue
        pos = (node.lineno, node.col_offset)
        if _is_wal_call(node):
            events.append((pos, "wal", node))
            continue
        canon = _common.call_canonical(node, aliases)
        if canon and _common.matches(canon, *_COMMIT_CALLS):
            events.append((pos, "commit", node))
    events.sort(key=lambda e: e[0])
    return events


class Rule:
    id = "ZQL008"
    summary = "commit acknowledged before its WAL append/fsync"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            events = _events(fn, aliases)
            first_wal = next((e for e in events if e[1] == "wal"), None)
            if first_wal is None:
                continue                    # function never journals
            first_commit = next((e for e in events if e[1] == "commit"),
                                None)
            if first_commit is not None and first_commit[0] < first_wal[0]:
                yield ctx.finding(
                    first_commit[2], self.id,
                    f"`{fn.name}` acknowledges a commit (line "
                    f"{first_commit[0][0]}) before its WAL append/fsync "
                    f"(line {first_wal[0][0]}) — a crash in between loses "
                    "an acknowledged operation; journal first")


RULE = Rule()

"""ZQL001 — raw ``jax.jit``/``pjit`` in engine-owned code.

Contract: every compiled entry point of the engine hot paths goes through
``repro.launch.trace.counted_jit`` so the single-dispatch claims stay
measurable (``docs/architecture.md`` — dispatch accounting). A raw
``jax.jit`` launch is invisible to the counter, so the 1-dispatch tests
would pass while the engine silently issues more launches.

Any *reference* to ``jax.jit``/``pjit`` in an engine-owned module is
flagged — call, decorator, ``partial(jax.jit, ...)`` or alias — because
there is no sanctioned direct use outside ``launch/trace.py`` itself.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

_JIT_NAMES = ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit")


class Rule:
    id = "ZQL001"
    summary = ("raw jax.jit/pjit in engine-owned code "
               "(use launch.trace.counted_jit)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if isinstance(node, ast.Name) and not isinstance(node.ctx,
                                                             ast.Load):
                continue
            canon = _common.canonical(node, aliases)
            if canon in _JIT_NAMES:
                yield ctx.finding(
                    node, self.id,
                    f"raw `{canon}` in engine-owned code — wrap with "
                    "repro.launch.trace.counted_jit so the launch is "
                    "dispatch-accounted")


RULE = Rule()

"""ZQL004 — donation hazards at ``counted_jit(donate_argnums=...)`` sites.

Contract (``docs/architecture.md`` — donation and aliasing rules): the
ingest/evict programs donate the state pytree for in-place XLA updates;
after the call the donated buffers are DEAD. Three statically-checkable
hazards:

- duplicate indices in ``donate_argnums`` itself;
- the same buffer (same local name) passed in two donated leaves of one
  call — XLA rejects duplicate-donated buffers at runtime;
- a donated local reused after the donating call (reads a deleted
  buffer — ``RuntimeError`` at runtime, but only on the executed path).

The engine's own donating call sites pass freshly packed state
(``self._pack_view_state()``), never a held local, so a clean tree has
no findings; the rule guards new call sites.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

#: factory name (last dotted segment) -> donated positional indices of the
#: program it returns. Mirrors repro.core.fused's counted_jit wrappers.
DONATING_FACTORIES = {
    "get_fused_ingest": (2,),
    "get_fused_ingest_parts": (2,),
    "get_fused_evict": (0,),
}


def _const_argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if not (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)):
                        return None
                    out.append(e.value)
                return tuple(out)
    return None


def _name_leaves(node: ast.AST) -> List[str]:
    """Plain-Name leaves of a literal dict/tuple/list argument."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            out.append(sub.id)
    return out


class Rule:
    id = "ZQL004"
    summary = "donated-then-reused buffer / duplicate-donated arguments"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)

        # (a) malformed donate_argnums anywhere
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                nums = _const_argnums(node)
                if nums is not None and len(set(nums)) != len(nums):
                    yield ctx.finding(
                        node, self.id,
                        f"duplicate indices in donate_argnums={nums} — "
                        "the same argument cannot be donated twice")

        # (b) per-function: donated locals reused / duplicated
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, ast.FunctionDef):
                yield from self._check_function(ctx, fn, aliases)

    def _check_function(self, ctx: ModuleContext, fn: ast.FunctionDef,
                        aliases) -> Iterator[Finding]:
        donating: Dict[str, Tuple[int, ...]] = {}
        # pass 1: locals bound to donating programs
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                continue
            call = node.value
            canon = _common.call_canonical(call, aliases) or ""
            tail = canon.split(".")[-1]
            nums = _const_argnums(call)
            if _common.matches(canon, "counted_jit", "jit") and nums:
                donating[node.targets[0].id] = nums
            elif tail in DONATING_FACTORIES:
                donating[node.targets[0].id] = DONATING_FACTORIES[tail]
        if not donating:
            return

        # pass 2: calls of donating programs
        names = [n for n in ast.walk(fn) if isinstance(n, ast.Name)]
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in donating):
                continue
            nums = donating[node.func.id]
            donated_args = [(i, node.args[i]) for i in nums
                            if i < len(node.args)]
            # duplicate-donated: same name in two donated positions
            plain = [a.id for _, a in donated_args if isinstance(a, ast.Name)]
            dupes = {n for n in plain if plain.count(n) > 1}
            for d in sorted(dupes):
                yield ctx.finding(
                    node, self.id,
                    f"`{d}` passed in two donated positions of "
                    f"`{node.func.id}` — XLA rejects duplicate-donated "
                    "buffers")
            # duplicate leaves inside one donated literal argument
            for _, a in donated_args:
                if isinstance(a, (ast.Dict, ast.Tuple, ast.List)):
                    leaves = _name_leaves(a)
                    for d in sorted({n for n in leaves
                                     if leaves.count(n) > 1}):
                        yield ctx.finding(
                            a, self.id,
                            f"buffer `{d}` appears in multiple leaves of a "
                            f"donated argument of `{node.func.id}` — "
                            "duplicate-donated buffer")
            # donated-then-reused: a plain donated Name loaded after the call
            for name in plain:
                stores_after = [n.lineno for n in names
                                if isinstance(n.ctx, ast.Store)
                                and n.id == name and n.lineno > node.lineno]
                next_store = min(stores_after, default=None)
                for n in names:
                    if (isinstance(n.ctx, ast.Load) and n.id == name
                            and n.lineno > node.lineno
                            and (next_store is None
                                 or n.lineno <= next_store)):
                        yield ctx.finding(
                            n, self.id,
                            f"`{name}` used after being donated to "
                            f"`{node.func.id}` at line {node.lineno} — "
                            "the buffer is deleted by donation")
                        break


RULE = Rule()

"""ZQL003 — order-sensitive reductions in estimator bodies.

Contract (``docs/architecture.md`` — the bit-identity contract): the
float reductions that produce an estimate must be a deterministic
function of the canonical group content alone — invariant to capacity,
partition count and mesh size. A bare ``jnp.sum`` over a
capacity-dependent axis re-associates when the capacity grows and
``jax.lax.psum`` re-associates with the device count, so estimator
bodies must route cross-group float reductions through
``kernels.segment_stats.chunked_sum`` (fixed canonical block size,
strictly sequential combine).

Scope: functions whose name contains ``estimate`` in engine-owned
modules — the canonical estimator bodies. Integer/bool count reductions
are exact in fp32/int32 and exempt (detected via ``.astype(int*)`` on
the reduced operand).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

_ORDER_SENSITIVE = ("jax.numpy.sum", "jax.numpy.nansum", "jax.lax.psum",
                    "numpy.sum")
_EXACT_DTYPES = ("int32", "int64", "uint32", "uint64", "bool_", "int8",
                 "uint8", "int16", "uint16")


def _is_exact_count(call: ast.Call, aliases) -> bool:
    """True when the reduced operand is integer-cast (exact sums)."""
    for arg in call.args[:1]:
        for sub in ast.walk(arg):
            if (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr == "astype"):
                for a in sub.args:
                    canon = _common.canonical(a, aliases) or ""
                    if canon.split(".")[-1] in _EXACT_DTYPES:
                        return True
    for kw in call.keywords:
        if kw.arg == "dtype":
            canon = _common.canonical(kw.value, aliases) or ""
            if canon.split(".")[-1] in _EXACT_DTYPES:
                return True
    return False


class Rule:
    id = "ZQL003"
    summary = ("order-sensitive reduction in an estimator body "
               "(use kernels.segment_stats.chunked_sum)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if "estimate" not in fn.name:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                canon = _common.call_canonical(node, aliases)
                if canon not in _ORDER_SENSITIVE:
                    continue
                if _is_exact_count(node, aliases):
                    continue
                yield ctx.finding(
                    node, self.id,
                    f"`{canon}` in estimator body `{fn.name}` — "
                    "order-sensitive float reduction breaks the "
                    "bit-identity contract; route through "
                    "kernels.segment_stats.chunked_sum (or inject via "
                    "sum_fn=)")


RULE = Rule()

"""ZQL006 — retrace hazards: shape-derived Python scalars captured in
traced closures.

Contract (``docs/architecture.md`` — pipeline flags / bucketing): every
size that reaches a compiled program must be BUCKETED (pow2 spec
buckets, ``BATCH_BUCKET_GRANULE`` row buckets, capacity granules) so the
trace count of an irregular load stays ~log of the max size. A Python
int derived from an un-bucketed input (``x.shape[...]``, ``len(x)``,
``table.nrows``) that a traced closure captures becomes part of the
trace constant — one fresh trace PER DISTINCT SIZE.

Exemptions: ``lru_cache``/``cache``-decorated factories (their
parameters are cache keys — static configuration by construction, e.g.
every ``repro.core.fused.get_fused_*``) and ``self``/``mesh`` parameters
(mesh geometry is static configuration).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from repro.analysis.lint import Finding, ModuleContext
from repro.analysis.rules import _common

_STATIC_PARAMS = {"self", "cls", "mesh"}
_SHAPE_ATTRS = {"shape", "nrows", "size", "ndim"}


def _taint_sources(expr: ast.AST, data_params: Set[str],
                   tainted: Set[str]) -> bool:
    """Does ``expr`` derive from a data param's shape or a tainted name?"""
    for sub in ast.walk(expr):
        if (isinstance(sub, ast.Attribute) and sub.attr in _SHAPE_ATTRS
                and isinstance(sub.value, ast.Name)
                and sub.value.id in data_params):
            return True
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "len" and sub.args
                and isinstance(sub.args[0], ast.Name)
                and sub.args[0].id in data_params):
            return True
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                and sub.id in tainted:
            return True
    return False


def _bound_names(fn: ast.FunctionDef) -> Set[str]:
    bound = {a.arg for a in list(fn.args.args) + list(fn.args.kwonlyargs)}
    if fn.args.vararg:
        bound.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        bound.add(fn.args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, ast.FunctionDef):
            bound.add(node.name)
    return bound


def _traced_inner_defs(fn: ast.FunctionDef, aliases) -> Iterator[
        ast.FunctionDef]:
    """Inner defs of ``fn`` that get jitted within ``fn``'s scope."""
    inner: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(fn)
        if isinstance(n, ast.FunctionDef) and n is not fn}
    for g in inner.values():
        if any(_common.matches(_common.canonical(t, aliases),
                               "counted_jit", "jit", "pjit", "hot_path")
               for t in _common.decorator_targets(g)):
            yield g
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and _common.matches(
                    _common.call_canonical(node, aliases),
                    "counted_jit", "jit", "pjit")):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name) and arg.id in inner:
                    yield inner[arg.id]


class Rule:
    id = "ZQL006"
    summary = ("retrace hazard: un-bucketed shape captured in a traced "
               "closure")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not ctx.engine_owned:
            return
        aliases = _common.import_aliases(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if _common.jit_cached_factory(fn, aliases):
                continue
            data_params = {a.arg for a in list(fn.args.args)
                           + list(fn.args.kwonlyargs)} - _STATIC_PARAMS
            if not data_params:
                continue
            tainted: Set[str] = set()
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and _taint_sources(node.value, data_params,
                                           tainted)):
                    tainted.add(node.targets[0].id)
            if not tainted:
                continue
            seen = set()
            for g in _traced_inner_defs(fn, aliases):
                if id(g) in seen:
                    continue
                seen.add(id(g))
                bound = _bound_names(g)
                captured = sorted(
                    {n.id for n in ast.walk(g)
                     if isinstance(n, ast.Name)
                     and isinstance(n.ctx, ast.Load)
                     and n.id in tainted and n.id not in bound})
                for name in captured:
                    yield ctx.finding(
                        g, self.id,
                        f"traced body `{g.name}` captures `{name}`, a "
                        f"Python scalar derived from an un-bucketed "
                        f"input of `{fn.name}` — one retrace per "
                        "distinct size; bucket the input or pass the "
                        "value as a traced argument")


RULE = Rule()

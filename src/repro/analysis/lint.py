"""AST-level contract lint: framework + rule driver.

Every rule inspects one module at a time through a :class:`ModuleContext`
(parsed tree, source lines, engine-owned flag, per-line suppressions) and
yields :class:`Finding` records. Rules live in ``repro.analysis.rules``
(one module per rule ID) and register themselves via ``RULES``.

Scoping: a module is ENGINE-OWNED — subject to the dispatch-accounting
and donation rules — when it declares ``__engine_owned__ = True`` at
module level, or (absent a declaration) when it lives under one of
``DEFAULT_ENGINE_DIRS`` relative to the package root. Declaring
``__engine_owned__ = False`` opts a host-side module out explicitly.

Suppressions: a finding on a line carrying ``# zql: ok[ZQL00X] reason``
is intentional and dropped (the reason is mandatory by convention — see
docs/architecture.md, Enforced contracts). ``# zql: ok[*]`` suppresses
every rule on that line. Findings can also be grandfathered through a
baseline file (JSON list of fingerprints): baselined findings don't fail
the CLI but are reported as such.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: directories (relative to the ``repro`` package root) whose modules are
#: engine-owned unless they declare ``__engine_owned__ = False``.
DEFAULT_ENGINE_DIRS = ("core", "kernels", "data")

_SUPPRESS_RE = re.compile(r"#\s*zql:\s*ok\[([A-Z0-9*,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str          # path as given to the linter (repo-relative in CI)
    line: int
    col: int
    rule: str          # "ZQL001" .. "ZQL006"
    message: str
    snippet: str = ""  # stripped source line, for the baseline fingerprint

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def fingerprint(self) -> str:
        """Stable identity for baselining: file + rule + line CONTENT
        (not line number, so unrelated edits above don't churn the
        baseline)."""
        key = f"{self.path}::{self.rule}::{self.snippet.strip()}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]


class ModuleContext:
    """Everything a rule needs about one module."""

    def __init__(self, path: Path, display_path: str, source: str,
                 package_root: Optional[Path] = None):
        self.path = path
        self.display_path = display_path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.suppressed: Dict[int, Set[str]] = self._parse_suppressions()
        self.engine_owned = self._engine_owned(package_root)

    # ------------------------------------------------------------ scoping
    def _declared_engine_owned(self) -> Optional[bool]:
        for node in self.tree.body:
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "__engine_owned__"
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, bool)):
                return node.value.value
        return None

    def _engine_owned(self, package_root: Optional[Path]) -> bool:
        declared = self._declared_engine_owned()
        if declared is not None:
            return declared
        if package_root is None:
            return False
        try:
            rel = self.path.resolve().relative_to(package_root.resolve())
        except ValueError:
            return False
        return bool(rel.parts) and rel.parts[0] in DEFAULT_ENGINE_DIRS

    # ------------------------------------------------------- suppressions
    def _parse_suppressions(self) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out[i] = rules
        return out

    def is_suppressed(self, line: int, rule: str) -> bool:
        rules = self.suppressed.get(line, set())
        return rule in rules or "*" in rules

    # ---------------------------------------------------------- utilities
    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(path=self.display_path, line=node.lineno,
                       col=node.col_offset + 1, rule=rule, message=message,
                       snippet=self.line_text(node.lineno))


def _all_rules():
    from repro.analysis.rules import RULES
    return RULES


def _iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def _find_package_root(path: Path) -> Optional[Path]:
    """The ``repro`` package directory containing ``path``, if any —
    anchors the DEFAULT_ENGINE_DIRS path scoping."""
    cur = path.resolve()
    for parent in cur.parents:
        # namespace package: no top-level __init__.py, anchor on the name
        if parent.name == "repro" and parent.is_dir():
            return parent
    return None


def run_lint(paths: Sequence, select: Optional[Sequence[str]] = None,
             ignore: Optional[Sequence[str]] = None,
             root: Optional[Path] = None) -> List[Finding]:
    """Run every (selected) rule over every ``.py`` file under ``paths``.

    ``select``/``ignore`` filter by rule ID; ``root`` (default: the
    current directory) makes reported paths repo-relative and stable for
    fingerprints.
    """
    root = Path(root) if root is not None else Path.cwd()
    rules = _all_rules()
    if select:
        rules = [r for r in rules if r.id in set(select)]
    if ignore:
        rules = [r for r in rules if r.id not in set(ignore)]
    findings: List[Finding] = []
    for f in _iter_py_files([Path(p) for p in paths]):
        try:
            display = str(f.resolve().relative_to(root.resolve()))
        except ValueError:
            display = str(f)
        try:
            source = f.read_text()
            ctx = ModuleContext(f, display, source,
                                package_root=_find_package_root(f))
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(path=display, line=1, col=1,
                                    rule="ZQL000",
                                    message=f"unparseable module: {e}"))
            continue
        for rule in rules:
            for finding in rule.check(ctx):
                if not ctx.is_suppressed(finding.line, finding.rule):
                    findings.append(finding)
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
    return findings


# ------------------------------------------------------------- baselines
def load_baseline(path) -> Set[str]:
    """Grandfathered finding fingerprints (empty set if no file)."""
    p = Path(path)
    if not p.exists():
        return set()
    data = json.loads(p.read_text())
    return {entry["fingerprint"] for entry in data}


def write_baseline(path, findings: Sequence[Finding]) -> None:
    data = [dict(path=f.path, rule=f.rule, fingerprint=f.fingerprint(),
                 snippet=f.snippet.strip())
            for f in findings]
    Path(path).write_text(json.dumps(data, indent=2) + "\n")


def split_baselined(findings: Sequence[Finding], baseline: Set[str]):
    """(new, grandfathered) partition of ``findings`` by the baseline."""
    new = [f for f in findings if f.fingerprint() not in baseline]
    old = [f for f in findings if f.fingerprint() in baseline]
    return new, old

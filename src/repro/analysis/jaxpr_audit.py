"""Layer 2 of the contract checker: compiled-program audits.

The AST lint (layer 1) proves the SOURCE follows the contracts; this
module proves the COMPILED ARTIFACTS do. It builds tiny replicated and
partitioned engines, warms their fused programs, and asserts — against
the real executables, not the prose in ``docs/architecture.md`` — that:

- **donation took effect**: every leaf of the donated state pytree
  appears in the compiled ingest executable's ``input_output_alias`` set
  (static) AND the pre-call buffers are deleted after a steady-state
  ingest / evict (runtime);
- **the hot paths are transfer-clean**: a steady-state ingest and an
  uncached query complete under ``jax.transfer_guard("disallow")`` —
  every host<->device movement on those paths is an explicit
  ``device_put``/``device_get``, never an implicit sync;
- **dispatch counts match the 1-dispatch contract**: steady-state
  ingest = 1 launch, uncached query = 1 launch (label ``"query"``),
  cached query = 0, a B-spec ``ate_batch`` = 1;
- **the MVCC overlap window is sync-free** (the dynamic twin of lint
  rule ZQL007): an ``overlap=True`` ingest performs ZERO host syncs
  between dispatch and commit (counted via ``trace.count_host_syncs`` —
  explicit ``device_get``s pass the transfer guard), the committed
  snapshot's buffers stay alive under the in-flight chain, and the
  post-commit answer is bitwise identical to the synchronous pipeline.

Each check returns an :class:`AuditResult`; ``run_audit()`` runs the
whole matrix (both engine layouts). ``tools/contract_check.py --jaxpr``
and ``tests/test_contract_check.py`` drive it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List

import numpy as np

#: rows per audit batch — one fixed size, so every ingest after the first
#: hits the same row bucket (no retrace noise in the dispatch counts).
#: POWER OF TWO on purpose: bucket-sized batches skip the documented
#: eager ``jnp.pad`` pre-step (``OnlineEngine._bucket_pad``), which is
#: the steady-state path the transfer-clean contract covers — eager pads
#: materialize fill constants via implicit host->device transfers.
_BATCH_ROWS = 256

#: alias entries in an HloModule header look like ``(12, {}, may-alias)``
#: (param_number, param_index, kind) — one per donated flat input.
_ALIAS_PARAM_RE = re.compile(r"\((\d+), \{\}")


@dataclasses.dataclass(frozen=True)
class AuditResult:
    engine: str     # "replicated" | "partitioned"
    contract: str   # short contract key, e.g. "ingest-donation-static"
    ok: bool
    detail: str

    def format(self) -> str:
        status = "ok" if self.ok else "FAIL"
        return f"[{status}] {self.engine}/{self.contract}: {self.detail}"


def _tiny_engines() -> Dict[str, Callable]:
    """Factories for the two engine layouts on a tiny config — small
    granule, two views — so the audit traces the same program families
    the production paths use, in seconds."""
    from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine

    specs = {"x0": CoarsenSpec.categorical(5),
             "x1": CoarsenSpec.categorical(4),
             "x2": CoarsenSpec.categorical(3)}
    treatments = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}
    return {
        "replicated": lambda: OnlineEngine(specs, treatments, "y",
                                           granule=256),
        "partitioned": lambda: PartitionedOnlineEngine(
            specs, treatments, "y", granule=128, n_parts=2),
    }


def _tiny_overlap_engines() -> Dict[str, Callable]:
    """Per-layout factories returning ``(overlap, sync)`` twins on the
    same tiny config — the overlap engine pipelines ingest dispatches
    against the committed snapshot; the sync twin is the bit-identity
    oracle."""
    from repro.core import CoarsenSpec, OnlineEngine, PartitionedOnlineEngine

    specs = {"x0": CoarsenSpec.categorical(5),
             "x1": CoarsenSpec.categorical(4),
             "x2": CoarsenSpec.categorical(3)}
    treatments = {"ta": ["x0", "x1"], "tb": ["x0", "x2"]}

    def _pair(cls, **kw):
        return (cls(specs, treatments, "y", overlap=True, **kw),
                cls(specs, treatments, "y", **kw))

    return {
        "replicated": lambda: _pair(OnlineEngine, granule=256),
        "partitioned": lambda: _pair(PartitionedOnlineEngine,
                                     granule=128, n_parts=2),
    }


def _batch(seed: int):
    from repro.data.columnar import Table

    rng = np.random.default_rng(seed)
    n = _BATCH_ROWS
    cols = {
        "x0": rng.integers(0, 5, n).astype(np.int32),
        "x1": rng.integers(0, 4, n).astype(np.int32),
        "x2": rng.integers(0, 3, n).astype(np.int32),
    }
    cols["ta"] = (rng.random(n) < 0.2 + 0.5 * cols["x0"] / 4).astype(np.int32)
    cols["tb"] = (rng.random(n) < 0.4).astype(np.int32)
    y = 2.0 * cols["ta"] + 1.5 * cols["x0"] + rng.normal(0, 0.5, n)
    cols["y"] = np.round(y).astype(np.float32)
    return Table.from_numpy(cols, rng.random(n) > 0.05)


def _transfer_clean(fn: Callable) -> AuditResult:
    """Run ``fn()`` under the strictest transfer guard; any implicit
    host<->device transfer on the path surfaces as the guard's error."""
    import jax

    try:
        with jax.transfer_guard("disallow"):
            fn()
    except Exception as e:                      # guard violations raise
        return AuditResult("", "", False, f"implicit transfer: {e}")
    return AuditResult("", "", True, "")


def _audit_ingest(name: str, eng, results: List[AuditResult]) -> None:
    import jax
    import jax.numpy as jnp

    from repro.launch.trace import count_dispatches

    # -- static donation: the compiled executable aliases every state leaf
    batch = eng._bucket_pad(_batch(seed=7))
    cols = {c: batch.columns[c] for c in eng._row_cols}
    state = eng._pack_view_state()
    counter = jnp.asarray(eng._ingest_count + 1, dtype=jnp.int32)
    n_batches = jnp.asarray(
        0 if eng.stream is None else eng.stream.n_batches,
        dtype=jnp.int32)
    prog = eng._fused_program(False)
    hlo = prog.lower(cols, batch.valid, state, counter,
                     n_batches).compile().as_text()
    header = hlo.split("\n", 1)[0]
    aliased = {int(m) for m in _ALIAS_PARAM_RE.findall(header)}
    n_prefix = len(jax.tree.leaves((cols, batch.valid)))
    n_leaves = len(jax.tree.leaves(state))
    expected = set(range(n_prefix, n_prefix + n_leaves))
    results.append(AuditResult(
        name, "ingest-donation-static", aliased == expected,
        f"all {n_leaves} donated state leaves aliased in the compiled "
        "executable" if aliased == expected else
        f"executable aliases params {sorted(aliased)}, expected the "
        f"{n_leaves} state leaves (params {n_prefix}.."
        f"{n_prefix + n_leaves - 1})"))

    # -- runtime: 1 dispatch, transfer-clean, donated buffers deleted
    leaves_before = jax.tree.leaves(eng._pack_view_state())
    steady = _batch(seed=8)
    with count_dispatches() as n:
        guard = _transfer_clean(lambda: eng.ingest(steady))
    results.append(AuditResult(
        name, "ingest-1-dispatch", n() == 1,
        f"steady-state ingest issued {n()} dispatch(es), contract is 1"))
    results.append(AuditResult(
        name, "ingest-transfer-clean", guard.ok,
        "steady-state ingest is transfer-clean under "
        "jax.transfer_guard('disallow')" if guard.ok else guard.detail))
    dead = [leaf.is_deleted() for leaf in leaves_before]
    results.append(AuditResult(
        name, "ingest-donation-runtime", bool(dead) and all(dead),
        f"{sum(dead)}/{len(dead)} pre-ingest state buffers deleted by "
        "donation"))


def _audit_query(name: str, eng, results: List[AuditResult]) -> None:
    from repro.launch.trace import count_dispatches

    sub = {"x1": [0, 1]}
    eng._cache.clear()
    box = {}
    with count_dispatches(label="query") as n:
        guard = _transfer_clean(
            lambda: box.update(est=eng.ate("ta", subpopulation=sub)))
    results.append(AuditResult(
        name, "query-1-dispatch", n() == 1,
        f"uncached ate() issued {n()} query dispatch(es), contract is 1"))
    results.append(AuditResult(
        name, "query-transfer-clean", guard.ok,
        "uncached ate() is transfer-clean under "
        "jax.transfer_guard('disallow')" if guard.ok else guard.detail))
    with count_dispatches() as n:
        est2 = eng.ate("ta", subpopulation=sub)
    ok = n() == 0 and guard.ok and est2.ate == box["est"].ate
    results.append(AuditResult(
        name, "query-cached-0-dispatch", ok,
        f"cached ate() issued {n()} dispatch(es), contract is 0"))


def _audit_batch_query(name: str, eng, results: List[AuditResult]) -> None:
    from repro.launch.trace import count_dispatches

    specs = [("ta", None), ("tb", None), ("ta", (("x1", (0, 1)),))]
    eng._cache.clear()
    with count_dispatches() as n:
        eng.ate_batch(specs)
    results.append(AuditResult(
        name, "batch-query-1-dispatch", n() == 1,
        f"ate_batch of {len(specs)} heterogeneous specs issued {n()} "
        "dispatch(es), contract is 1"))


def _audit_evict(name: str, eng, results: List[AuditResult]) -> None:
    import jax

    leaves_before = jax.tree.leaves(eng._pack_view_state())
    eng.evict(ttl=10_000)   # nothing old enough: pure compaction pass
    dead = [leaf.is_deleted() for leaf in leaves_before]
    results.append(AuditResult(
        name, "evict-donation-runtime", bool(dead) and all(dead),
        f"{sum(dead)}/{len(dead)} pre-evict state buffers deleted by "
        "donation"))


def _audit_overlap(name: str, make_overlap: Callable,
                   results: List[AuditResult]) -> None:
    """MVCC overlap contracts on the dispatch->commit window: a
    steady-state overlap ingest performs ZERO host syncs (counted — the
    transfer guard alone cannot see explicit ``device_get``s) while
    staying transfer-clean and one-dispatch; the committed snapshot's
    buffers stay ALIVE under the in-flight dispatch (first-hop
    ``donate=False`` — they keep serving queries); and after ``commit()``
    the answered state is bitwise identical to the synchronous
    pipeline's."""
    import jax

    from repro.launch.trace import count_dispatches, count_host_syncs

    eng, ref = make_overlap()
    batches = [_batch(seed=s) for s in range(3)]
    for b in batches:               # warm: traces + capacity settle
        eng.ingest(b)
        ref.ingest(b)
    eng.commit()
    committed = jax.tree.leaves(eng._pack_view_state())
    steady = _batch(seed=11)
    with count_host_syncs() as s, count_dispatches() as n:
        guard = _transfer_clean(lambda: eng.ingest(steady))
    ok = s() == 0 and n() == 1 and guard.ok
    results.append(AuditResult(
        name, "overlap-ingest-0-sync", ok,
        "overlap ingest: 1 dispatch, 0 host syncs, transfer-clean "
        "(verdicts deferred to commit)" if ok else
        f"overlap ingest: {n()} dispatch(es), {s()} host sync(s), "
        f"guard={'ok' if guard.ok else guard.detail}"))
    alive = [not leaf.is_deleted() for leaf in committed]
    results.append(AuditResult(
        name, "overlap-committed-buffers-live", bool(alive) and all(alive),
        f"{sum(alive)}/{len(alive)} committed snapshot buffers alive "
        "under the in-flight dispatch (first hop does not donate)"))
    eng.commit()
    ref.ingest(steady)
    a = eng.ate("ta")
    b = ref.ate("ta")
    same = (float(a.ate) == float(b.ate)
            and float(a.variance) == float(b.variance)
            and a.state_version == b.state_version)
    results.append(AuditResult(
        name, "overlap-commit-bit-identity", same,
        "post-commit query bitwise equals the synchronous pipeline at "
        "the same snapshot version" if same else
        f"overlap ({float(a.ate)!r}, v{a.state_version}) != sync "
        f"({float(b.ate)!r}, v{b.state_version})"))


def audit_engine(name: str, make_engine: Callable) -> List[AuditResult]:
    """Run every compiled-program audit against one engine layout."""
    results: List[AuditResult] = []
    eng = make_engine()
    for seed in range(3):           # warm: traces + capacity settle
        eng.ingest(_batch(seed=seed))
    _audit_ingest(name, eng, results)
    _audit_query(name, eng, results)
    _audit_batch_query(name, eng, results)
    _audit_evict(name, eng, results)
    return results


def run_audit() -> List[AuditResult]:
    """The full audit matrix: both engine layouts, sync and overlap."""
    results: List[AuditResult] = []
    for name, make in _tiny_engines().items():
        results.extend(audit_engine(name, make))
    overlap = _tiny_overlap_engines()
    for name, make in overlap.items():
        _audit_overlap(name, make, results)
    return results

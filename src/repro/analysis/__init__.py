"""Contract checker for the engine's dispatch/donation/bit-identity
invariants (see ``docs/architecture.md`` — Enforced contracts).

Two layers:

- :mod:`repro.analysis.lint` — AST rules ZQL001-ZQL006 over the source
  tree (raw ``jax.jit`` outside dispatch accounting, host syncs in hot
  paths, order-sensitive reductions in estimator bodies, donation
  hazards, Pallas in-place kernels without aliasing, retrace hazards).
- :mod:`repro.analysis.jaxpr_audit` — traces the REAL fused
  ingest/query/evict/batch programs of both engines on tiny configs and
  asserts donation took effect, the hot paths are transfer-clean under
  ``jax.transfer_guard``, and dispatch counts match the 1-dispatch
  contract.

``tools/contract_check.py`` is the CLI over both.
"""
from repro.analysis.lint import (  # noqa: F401
    Finding,
    load_baseline,
    run_lint,
    write_baseline,
)

"""Foreign-key joins for normalized schemas (paper §4.1).

The FLIGHTDELAY schema is a fact table (flights) pointing at a dimension
table (weather) via (airport, hour). TPU idiom for a many-to-one FK join:
pack join keys on both sides, sort the dimension side once, binary-search
each fact key, gather. Output shape == fact shape (static).
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core.groupby import lookup_rows_in_table
from repro.core.keys import KeyCodec
from repro.data.columnar import Table


def pack_join_keys(table: Table, on: Mapping[str, int], codec: KeyCodec = None
                   ) -> Tuple[KeyCodec, jnp.ndarray, jnp.ndarray]:
    """Pack integer join columns (name -> cardinality) into sortable keys."""
    codec = codec or KeyCodec.from_cardinalities(on)
    buckets = {n: table[n].astype(jnp.int32) for n in codec.names}
    hi, lo = codec.pack(buckets, table.valid)
    return codec, hi, lo


def fk_join(fact: Table, dim: Table, on: Mapping[str, int],
            prefix: str = "") -> Table:
    """fact LEFT-INNER join dim on shared integer key columns.

    Facts whose key is missing (or whose dim row is invalid) become invalid —
    the masked analogue of an inner join. Dim columns are appended (optionally
    prefixed); shared key columns are not duplicated.
    """
    codec, fhi, flo = pack_join_keys(fact, on)
    _, dhi, dlo = pack_join_keys(dim, on, codec)

    n_dim = dim.nrows
    iota = jnp.arange(n_dim, dtype=jnp.int32)
    shi, slo, perm = jax.lax.sort((dhi, dlo, iota), num_keys=2, is_stable=True)
    pos, found = lookup_rows_in_table(fhi, flo, shi, slo)
    src = perm[pos]

    new_cols: Dict[str, jnp.ndarray] = dict(fact.columns)
    for name in dim.names():
        if name in on:
            continue
        out_name = prefix + name
        if out_name in new_cols:
            raise ValueError(f"join column collision: {out_name}")
        new_cols[out_name] = dim.columns[name][src]
    valid = fact.valid & found & dim.valid[src]
    return Table(new_cols, valid)

"""Synthetic FLIGHTDELAY generator with planted causal ground truth.

Reproduces the paper's experimental substrate (U.S. DOT flights joined to
Weather Underground observations) as a generative model whose *true* causal
effects are known — the generator materializes the full Neyman-Rubin table
(paper Table 2) including both potential outcomes Y(0), Y(1) per treatment,
so estimators can be scored on SATE recovery, not just eyeballed.

Planted structure (matching the paper's Example 2 narrative):
  - season (summer) raises BOTH thunderstorm probability AND traffic
    (confounding path  T <- season -> traffic -> delay);
  - pressure is lowered by storms but has ZERO causal effect on delay
    (the paper's low-pressure trap: maximally correlated, causally null);
  - true effects: thunder +30, low visibility +25, high wind +15, snow +40
    minutes, additively on the uncensored delay.

Schemas follow the paper's Table 1 (weather: visim/tempm/wspdm/pressurem/
precipm/thunder/hum/dewpoint per (airport, hour); flights: carrier, origin,
hour, traffic, delay, cancelled).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.data.columnar import Table

TRUE_EFFECTS = {
    "thunder": 30.0,
    "lowvis": 25.0,
    "highwind": 15.0,
    "snow": 40.0,
    "lowpressure": 0.0,   # the trap
}


@dataclasses.dataclass
class FlightData:
    weather: Table            # dimension table, one row per (airport, hour)
    flights: Table            # fact table (holds outcome + flight covariates)
    integrated: Table         # flights |><| weather (host-side join)
    true_sate: Dict[str, float]  # per-treatment sample ATE from counterfactuals
    n_airports: int
    n_carriers: int
    n_hours: int


def _weather(rng, n_airports: int, n_hours: int):
    hours = np.arange(n_hours)
    day = hours / 24.0
    season = 0.5 - 0.5 * np.cos(2 * np.pi * (day % 365.25) / 365.25)  # 0=winter
    season = np.broadcast_to(season, (n_airports, n_hours))
    apt_temp = rng.uniform(-5, 15, size=(n_airports, 1))

    storm = np.clip(rng.beta(0.6, 4.0, size=(n_airports, n_hours))
                    * (0.5 + 1.5 * season), 0, 1)
    fog = np.clip(rng.beta(0.7, 6.0, size=(n_airports, n_hours))
                  * (1.5 - season), 0, 1)

    tempm = apt_temp + 18 * season + rng.normal(0, 4, (n_airports, n_hours))
    thunder = (rng.random((n_airports, n_hours))
               < 0.01 + 0.25 * storm * season).astype(np.int32)
    wspdm = np.clip(8 + 45 * storm + rng.normal(0, 6, (n_airports, n_hours)),
                    0, None)
    precipm = np.clip(storm * rng.gamma(1.5, 0.6, (n_airports, n_hours))
                      - 0.1, 0, None)
    visim = np.clip(10 - 8.5 * fog - 4 * storm
                    + rng.normal(0, 1.2, (n_airports, n_hours)), 0.05, 10)
    # Low pressure: *caused by* storms, causally inert for delays.
    pressurem = 1015 - 9 * storm - 3 * season + rng.normal(
        0, 2, (n_airports, n_hours))
    hum = np.clip(45 + 40 * storm + 20 * fog
                  + rng.normal(0, 8, (n_airports, n_hours)), 5, 100)
    dewpoint = tempm - (100 - hum) / 5.0
    return dict(season=season, tempm=tempm, thunder=thunder, wspdm=wspdm,
                precipm=precipm, visim=visim, pressurem=pressurem, hum=hum,
                dewpoint=dewpoint)


def generate(n_flights: int = 20000, n_airports: int = 8, n_carriers: int = 6,
             n_days: int = 365, seed: int = 0) -> FlightData:
    rng = np.random.default_rng(seed)
    n_hours = 24 * n_days
    w = _weather(rng, n_airports, n_hours)

    # ---- flights: seasonal + diurnal draw rates (summer = high season) ----
    hours = np.arange(n_hours)
    tod = hours % 24
    diurnal = np.clip(np.sin(np.pi * (tod - 5) / 18.0), 0.02, None)
    season_1d = 0.5 - 0.5 * np.cos(2 * np.pi * ((hours / 24.0) % 365.25)
                                   / 365.25)
    apt_pop = rng.uniform(0.5, 1.5, n_airports)
    rate = apt_pop[:, None] * diurnal[None, :] * (1.0 + 1.2 * season_1d)[None, :]
    p = (rate / rate.sum()).reshape(-1)
    cell = rng.choice(n_airports * n_hours, size=n_flights, p=p)
    f_apt = (cell // n_hours).astype(np.int32)
    f_hour = (cell % n_hours).astype(np.int32)
    f_carrier = rng.integers(0, n_carriers, n_flights).astype(np.int32)

    # traffic = #flights at same (airport, hour)  (paper's AirportTraffic)
    traffic_grid = np.zeros((n_airports, n_hours), np.int32)
    np.add.at(traffic_grid, (f_apt, f_hour), 1)
    f_traffic = traffic_grid[f_apt, f_hour].astype(np.float32)
    carrier_traffic = np.zeros((n_carriers, n_hours), np.int32)
    np.add.at(carrier_traffic, (f_carrier, f_hour), 1)
    f_carrier_traffic = carrier_traffic[f_carrier, f_hour].astype(np.float32)

    # ---- treatments (paper §5.1 definitions, incl. discard bands) --------
    gv = lambda name: w[name][f_apt, f_hour]
    thunder = gv("thunder").astype(np.int32)
    visim, wspdm, precipm, tempm, pressurem = (gv("visim"), gv("wspdm"),
                                               gv("precipm"), gv("tempm"),
                                               gv("pressurem"))
    lowvis = (visim < 1).astype(np.int32)
    lowvis_band = (visim >= 1) & (visim <= 5)          # discarded units
    highwind = (wspdm > 40).astype(np.int32)
    highwind_band = (wspdm >= 20) & (wspdm <= 40)
    snow = ((precipm > 0.3) & (tempm < 0)).astype(np.int32)
    lowpressure = (pressurem < 1008).astype(np.int32)

    # ---- potential outcomes (uncensored base + per-treatment effect) -----
    carrier_eff = rng.normal(0, 3, n_carriers)[f_carrier]
    apt_eff = rng.normal(0, 3, n_airports)[f_apt]
    noise = rng.normal(0, 10, n_flights)
    base = (6.0 + 0.9 * (f_traffic - f_traffic.mean())
            + 0.15 * (f_carrier_traffic - f_carrier_traffic.mean())
            + carrier_eff + apt_eff + noise)
    effects = (TRUE_EFFECTS["thunder"] * thunder
               + TRUE_EFFECTS["lowvis"] * lowvis
               + TRUE_EFFECTS["highwind"] * highwind
               + TRUE_EFFECTS["snow"] * snow)
    censor = lambda v: np.clip(v, 0, None).astype(np.float32)
    y_factual = censor(base + effects)

    treatments = dict(thunder=thunder, lowvis=lowvis, highwind=highwind,
                      snow=snow, lowpressure=lowpressure)
    true_sate = {}
    y0_cols, y1_cols = {}, {}
    for name, t in treatments.items():
        beta = TRUE_EFFECTS[name]
        y_others = base + effects - beta * t  # remove own effect
        y0 = censor(y_others)
        y1 = censor(y_others + beta)
        y0_cols[f"y0_{name}"] = y0
        y1_cols[f"y1_{name}"] = y1
        true_sate[name] = float(np.mean(y1 - y0))

    cancelled = (rng.random(n_flights)
                 < 0.004 + 0.04 * thunder + 0.05 * snow + 0.03 * lowvis
                 + 0.02 * highwind).astype(np.int32)

    weather_cols = {k: v.reshape(-1).astype(np.float32) if v.dtype != np.int32
                    else v.reshape(-1)
                    for k, v in w.items()}
    weather_cols["airport"] = np.repeat(np.arange(n_airports, dtype=np.int32),
                                        n_hours)
    weather_cols["hour"] = np.tile(np.arange(n_hours, dtype=np.int32),
                                   n_airports)
    weather = Table.from_numpy(weather_cols)

    flight_cols = dict(
        airport=f_apt, hour=f_hour, carrier=f_carrier,
        traffic=f_traffic, carrier_traffic=f_carrier_traffic,
        dep_delay=y_factual, cancelled=cancelled,
        lowvis_band=lowvis_band.astype(np.int32),
        highwind_band=highwind_band.astype(np.int32),
        **{k: v for k, v in treatments.items()},
        **y0_cols, **y1_cols,
    )
    flights = Table.from_numpy(flight_cols)

    int_cols = dict(flight_cols)
    for k, v in weather_cols.items():
        if k in ("airport", "hour"):
            continue
        int_cols[f"w_{k}"] = v.reshape(n_airports, n_hours)[f_apt, f_hour]
    integrated = Table.from_numpy(int_cols)

    return FlightData(weather=weather, flights=flights, integrated=integrated,
                      true_sate=true_sate, n_airports=n_airports,
                      n_carriers=n_carriers, n_hours=n_hours)


def treatment_valid_mask(data: FlightData, treatment: str) -> np.ndarray:
    """Paper §5.1: units inside the treatment's dead band are discarded."""
    t = data.integrated
    if treatment == "lowvis":
        return ~np.asarray(t["lowvis_band"]).astype(bool)
    if treatment == "highwind":
        return ~np.asarray(t["highwind_band"]).astype(bool)
    return np.ones(t.nrows, bool)

from repro.data.columnar import Table
from repro.data import join, flightgen

__all__ = ["Table", "join", "flightgen"]

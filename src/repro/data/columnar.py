"""Masked columnar batches — the TPU-native stand-in for a SQL row set.

A ``Table`` is a dict of equal-length device arrays plus a validity mask.
SQL's dynamic-cardinality operations (WHERE, discarding no-overlap CEM
groups, caliper misses) become mask updates: shapes never change, so
everything stays jit/pjit-compatible. Aggregates are mask-weighted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Table:
    """Fixed-shape masked columnar batch.

    columns: name -> array of shape (N,) or (N, d).
    valid:   bool (N,); False rows are "deleted".
    """

    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(columns=dict(zip(names, children[:-1])), valid=children[-1])

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_dict(cls, cols: Mapping[str, jnp.ndarray], valid=None) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in cols.items()}
        n = next(iter(cols.values())).shape[0]
        for k, v in cols.items():
            if v.shape[0] != n:
                raise ValueError(f"column {k}: length {v.shape[0]} != {n}")
        if valid is None:
            valid = jnp.ones((n,), dtype=bool)
        return cls(columns=cols, valid=jnp.asarray(valid, dtype=bool))

    @classmethod
    def from_numpy(cls, cols: Mapping[str, np.ndarray], valid=None) -> "Table":
        return cls.from_dict({k: jnp.asarray(v) for k, v in cols.items()}, valid)

    # -- basic accessors ---------------------------------------------------
    @property
    def nrows(self) -> int:
        return int(self.valid.shape[0])

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def names(self) -> Iterator[str]:
        return iter(sorted(self.columns))

    def count(self) -> jnp.ndarray:
        """Number of valid rows (dynamic)."""
        return jnp.sum(self.valid.astype(jnp.int32))

    # -- relational-ish ops ------------------------------------------------
    def filter(self, mask: jnp.ndarray) -> "Table":
        """WHERE: rows failing ``mask`` become invalid. Shape unchanged."""
        return Table(self.columns, self.valid & mask.astype(bool))

    def with_columns(self, new: Mapping[str, jnp.ndarray]) -> "Table":
        cols = dict(self.columns)
        cols.update({k: jnp.asarray(v) for k, v in new.items()})
        return Table(cols, self.valid)

    def select(self, names) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.valid)

    def drop(self, names) -> "Table":
        names = set(names)
        return Table({k: v for k, v in self.columns.items() if k not in names},
                     self.valid)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self.columns.items()},
                     self.valid)

    def masked(self, name: str, fill=0) -> jnp.ndarray:
        """Column with invalid rows replaced by ``fill``."""
        col = self.columns[name]
        mask = self.valid
        if col.ndim > 1:
            mask = mask[(...,) + (None,) * (col.ndim - 1)]
        return jnp.where(mask, col, jnp.asarray(fill, dtype=col.dtype))

    def mean(self, name: str) -> jnp.ndarray:
        """Mask-weighted mean of a column."""
        w = self.valid.astype(jnp.float32)
        x = self.columns[name].astype(jnp.float32)
        return jnp.sum(w * x) / jnp.maximum(jnp.sum(w), 1.0)

    # -- host-side utilities (not jittable) ---------------------------------
    def to_numpy(self, compact: bool = False) -> Dict[str, np.ndarray]:
        """Materialize on host. compact=True drops invalid rows."""
        out = {k: np.asarray(v) for k, v in self.columns.items()}
        v = np.asarray(self.valid)
        if compact:
            out = {k: a[v] for k, a in out.items()}
        else:
            out["_valid"] = v
        return out

    def head(self, n: int = 8) -> str:
        cols = self.to_numpy(compact=True)
        lines = ["\t".join(sorted(cols))]
        k = min(n, len(next(iter(cols.values()))) if cols else 0)
        for i in range(k):
            lines.append("\t".join(str(cols[c][i]) for c in sorted(cols)))
        return "\n".join(lines)


def _round_capacity(n: int, granule: int = 4096) -> int:
    """Round row counts up to a granule so re-jitted shapes cache well."""
    return max(granule, ((n + granule - 1) // granule) * granule)


def compact(table: Table, granule: int = 4096) -> Table:
    """Materialize only the valid rows (host-side gather), padded to a shape
    granule. This is the TPU analogue of materializing a filtered SQL view:
    masking alone never shrinks compute, compaction does. Used by the
    covariate-factoring / pushdown / prepared-database optimizations between
    pipeline stages (paper §4).
    """
    v = np.asarray(table.valid)
    idx = np.nonzero(v)[0]
    n_out = _round_capacity(len(idx), granule)
    pad = n_out - len(idx)
    cols = {}
    for name, col in table.columns.items():
        a = np.asarray(col)[idx]
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        cols[name] = np.pad(a, widths)
    valid = np.zeros(n_out, dtype=bool)
    valid[:len(idx)] = True
    return Table.from_numpy(cols, valid)


@dataclasses.dataclass(frozen=True)
class GrowableTable:
    """Append-only table for the online engine (INSERT INTO ... VALUES).

    ``table`` holds ``capacity`` slots; slots at index >= ``used`` are dead
    padding (valid=False) awaiting future appends. Appends that fit in the
    current capacity are a device-side ``dynamic_update_slice`` (shape
    unchanged, so jitted consumers don't recompile); appends that overflow
    grow the capacity geometrically past :func:`_round_capacity` on the host.
    """

    table: Table
    used: int

    @classmethod
    def from_table(cls, table: Table, granule: int = 4096) -> "GrowableTable":
        cap = _round_capacity(table.nrows, granule)
        if cap == table.nrows:
            return cls(table=table, used=table.nrows)
        pad = cap - table.nrows
        cols = {}
        for name, col in table.columns.items():
            a = np.asarray(col)
            cols[name] = np.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
        valid = np.pad(np.asarray(table.valid), (0, pad))
        return cls(table=Table.from_numpy(cols, valid), used=table.nrows)

    @property
    def capacity(self) -> int:
        return self.table.nrows

    def append(self, batch: Table, granule: int = 4096) -> "GrowableTable":
        """Append ``batch`` rows (with their validity) after slot ``used``."""
        if set(batch.columns) != set(self.table.columns):
            raise ValueError("schema mismatch in append")
        new_used = self.used + batch.nrows
        base = self.table
        if new_used > base.nrows:
            # host-side geometric growth: at least double, rounded to granule
            cap = _round_capacity(max(new_used, 2 * base.nrows), granule)
            pad = cap - base.nrows
            cols = {}
            for name, col in base.columns.items():
                a = np.asarray(col)
                cols[name] = jnp.asarray(
                    np.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)))
            valid = jnp.asarray(np.pad(np.asarray(base.valid), (0, pad)))
            base = Table(cols, valid)
        cols = {}
        for name, col in base.columns.items():
            update = batch.columns[name].astype(col.dtype)
            cols[name] = jax.lax.dynamic_update_slice_in_dim(
                col, update, self.used, axis=0)
        valid = jax.lax.dynamic_update_slice_in_dim(
            base.valid, batch.valid, self.used, axis=0)
        return GrowableTable(table=Table(cols, valid), used=new_used)


def concat(tables: list) -> Table:
    """UNION ALL of same-schema tables."""
    names = set(tables[0].columns)
    for t in tables[1:]:
        if set(t.columns) != names:
            raise ValueError("schema mismatch in concat")
    cols = {n: jnp.concatenate([t.columns[n] for t in tables]) for n in names}
    valid = jnp.concatenate([t.valid for t in tables])
    return Table(cols, valid)

"""Elastic re-meshing: continue a run on fewer (or more) hosts.

Given the current mesh layout and a survivor set, pick the largest valid
mesh shape (data axis shrinks first — model parallelism degree is a
property of the checkpointed layouts, data parallelism is free to change),
and rebuild shardings so `checkpoint.restore(..., shardings=...)` lands
arrays directly on the new topology.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_devices: int

    def describe(self) -> str:
        dims = "x".join(f"{n}({a})" for n, a in zip(self.shape, self.axes))
        return f"{dims} = {self.n_devices} devices"


def plan_elastic_mesh(n_available: int, model_parallel: int,
                      axes: Tuple[str, ...] = ("data", "model"),
                      pods: int = 1) -> MeshPlan:
    """Largest mesh with fixed model-parallel degree that fits survivors.

    data = floor(available / (model * pods)); refuses if data < 1.
    """
    per_pod = n_available // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError(
            f"cannot re-mesh: {n_available} devices < model_parallel="
            f"{model_parallel} (x pods={pods})")
    if pods > 1:
        return MeshPlan((pods, data, model_parallel),
                        ("pod",) + axes, pods * data * model_parallel)
    return MeshPlan((data, model_parallel), axes, data * model_parallel)


def build_mesh(plan: MeshPlan, devices: Optional[Sequence] = None):
    devs = list(devices if devices is not None else jax.devices())
    need = plan.n_devices
    if len(devs) < need:
        raise ValueError(f"need {need} devices, have {len(devs)}")
    arr = np.array(devs[:need]).reshape(plan.shape)
    return jax.sharding.Mesh(arr, plan.axes)


def shrink_after_failure(old_plan: MeshPlan, n_dead: int) -> MeshPlan:
    """Re-plan after losing n_dead devices' worth of hosts."""
    model = old_plan.shape[-1]
    pods = old_plan.shape[0] if len(old_plan.shape) == 3 else 1
    return plan_elastic_mesh(old_plan.n_devices - n_dead, model,
                             axes=old_plan.axes[-2:], pods=pods)

"""Straggler detection & mitigation policy.

Synchronous SPMD training runs at the speed of the slowest host. The
monitor keeps a per-host EWMA of step times; hosts persistently slower
than `threshold` x the fleet median are flagged. Mitigations emitted (in
escalating order):
  rebalance  shrink the flagged host's data shard (gradual, cheap)
  evict      treat as failed -> elastic restart without it (decisive)

The data loader consumes `shard_weights()`; the supervisor consumes
`evictions()`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5        # x median EWMA to flag
    ewma_alpha: float = 0.2
    patience: int = 5             # consecutive flags before mitigation
    rebalance_floor: float = 0.5  # min relative shard size
    evict_threshold: float = 3.0  # x median -> immediate eviction candidate


class StepTimeMonitor:
    def __init__(self, n_hosts: int, policy: StragglerPolicy = StragglerPolicy()):
        self.n = n_hosts
        self.policy = policy
        self.ewma = np.zeros(n_hosts)
        self.flags = np.zeros(n_hosts, dtype=int)
        self.seen = np.zeros(n_hosts, dtype=bool)

    def record(self, host_times: Dict[int, float]):
        a = self.policy.ewma_alpha
        for h, t in host_times.items():
            self.ewma[h] = t if not self.seen[h] else \
                (1 - a) * self.ewma[h] + a * t
            self.seen[h] = True
        med = np.median(self.ewma[self.seen])
        for h in range(self.n):
            if not self.seen[h]:
                continue
            if self.ewma[h] > self.policy.threshold * med:
                self.flags[h] += 1
            else:
                self.flags[h] = 0

    def stragglers(self) -> List[int]:
        return [h for h in range(self.n)
                if self.flags[h] >= self.policy.patience]

    def evictions(self) -> List[int]:
        med = np.median(self.ewma[self.seen]) if self.seen.any() else 0
        return [h for h in self.stragglers()
                if self.ewma[h] > self.policy.evict_threshold * max(med, 1e-9)]

    def shard_weights(self) -> np.ndarray:
        """Relative data-shard sizes per host (1.0 = fair share). Slow hosts
        get proportionally less data, floored by policy."""
        med = np.median(self.ewma[self.seen]) if self.seen.any() else 1.0
        w = np.ones(self.n)
        for h in self.stragglers():
            rel = med / max(self.ewma[h], 1e-9)
            w[h] = max(self.policy.rebalance_floor, rel)
        return w / w.mean()

"""Failure detection and recovery orchestration.

At 1000+ nodes the question is never *if* a host dies mid-run but how
cheaply the job continues. Components:

  HeartbeatMonitor  per-host liveness table with timeout-based detection
                    (clock injectable for tests)
  RecoveryPlan      what to do: restart on the survivors (elastic shrink
                    via runtime/elastic.py) or wait for replacement
  Supervisor        wraps a step function: on failure it restores the
                    latest checkpoint (integrity-checked) and replays —
                    tested for bit-exact continuation in test_runtime.py
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.checkpoint import latest_step, restore, save


@dataclasses.dataclass
class RecoveryPlan:
    # "continue" | "elastic_restart" | "failover" | "wait"
    action: str
    dead_hosts: List[int]
    survivor_hosts: List[int]
    restart_step: Optional[int] = None
    #: failover only: the most-caught-up survivor (highest beaten step —
    #: for the replication tier, its durable WAL seq), ties to the lowest
    #: host id so every observer picks the SAME candidate deterministically
    promote_to: Optional[int] = None


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self.last_seen: Dict[int, float] = {h: now for h in range(n_hosts)}
        self.last_step: Dict[int, int] = {h: -1 for h in range(n_hosts)}

    def beat(self, host: int, step: int):
        self.last_seen[host] = self.clock()
        self.last_step[host] = step

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h in range(self.n_hosts)
                if now - self.last_seen[h] > self.timeout_s]

    def plan(self, ckpt_dir: Optional[str] = None,
             min_hosts: int = 1,
             primary: Optional[int] = None) -> RecoveryPlan:
        """Liveness verdict. With ``primary`` given (the replication
        tier's write node), a dead primary with live followers yields a
        ``"failover"`` plan naming ``promote_to`` — the survivor whose
        last beaten step (durable WAL seq) is highest, ties broken toward
        the lowest host id. Follower deaths alone are ``"continue"``:
        the tier keeps serving on the remaining nodes."""
        dead = self.dead_hosts()
        alive = [h for h in range(self.n_hosts) if h not in dead]
        if primary is not None:
            if primary not in dead:
                return RecoveryPlan("continue", dead, alive)
            if not alive:
                return RecoveryPlan("wait", dead, alive)
            best = max(alive, key=lambda h: (self.last_step[h], -h))
            return RecoveryPlan("failover", dead, alive, promote_to=best)
        if not dead:
            return RecoveryPlan("continue", [], alive)
        if len(alive) < min_hosts:
            return RecoveryPlan("wait", dead, alive)
        step = latest_step(ckpt_dir) if ckpt_dir else None
        return RecoveryPlan("elastic_restart", dead, alive,
                            restart_step=step)


class Supervisor:
    """Checkpoint-restart harness around a pure step function.

    step_fn(state, batch) -> (state, metrics). Any exception triggers a
    restore of the latest checkpoint and a replay from there; data order is
    reproduced via the step index (the data iterator must be step-keyed,
    which synthetic/deterministic pipelines are).
    """

    def __init__(self, step_fn, ckpt_dir: str, ckpt_every: int = 10,
                 keep_last: int = 3, max_restarts: int = 5):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state, batch_for_step: Callable[[int], dict],
            n_steps: int, fail_at: Optional[Callable[[int], bool]] = None):
        """Train n_steps; `fail_at(step)` lets tests inject crashes."""
        step = int(state["step"])
        metrics_log = []
        while step < n_steps:
            try:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = self.step_fn(state, batch_for_step(step))
                step = int(state["step"])
                metrics_log.append(metrics)
                if step % self.ckpt_every == 0:
                    save(state, step, self.ckpt_dir,
                         keep_last=self.keep_last)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                _, state = restore(self.ckpt_dir, step=last, template=state)
                step = int(state["step"])
        return state, metrics_log

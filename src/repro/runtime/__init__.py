from repro.runtime.fault_tolerance import (HeartbeatMonitor, RecoveryPlan,
                                           Supervisor)
from repro.runtime.straggler import StepTimeMonitor, StragglerPolicy
from repro.runtime.elastic import (MeshPlan, build_mesh, plan_elastic_mesh,
                                   shrink_after_failure)

__all__ = ["HeartbeatMonitor", "RecoveryPlan", "Supervisor",
           "StepTimeMonitor", "StragglerPolicy", "MeshPlan", "build_mesh",
           "plan_elastic_mesh", "shrink_after_failure"]

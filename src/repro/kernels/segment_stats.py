"""Pallas kernel: MXU segmented partial reduction (the GROUP-BY hot loop).

After sorting by key, CEM needs per-group sums of a statistics bundle
(n_t, n_c, y_t, y_c, per-covariate arm sums...). TPUs have no fast scatter;
the MXU idiom is a one-hot matmul: within a row block, partial[i, s] =
sum_j [local_seg(j) == i] * value[j, s] — a (B, B) @ (B, S) matmul that runs
on the systolic array instead of a serial scatter loop. Cross-block segment
spill is handled by a cheap jnp combine over the (nb*B, S) partials (a
segment id crosses at most nb blocks).

local_ids (= global segment id minus the block's first segment id) are
computed outside with a cumsum; the kernel is the FLOP hot spot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, vals_ref, out_ref):
    ids = ids_ref[...]                 # (B,) int32, in [0, B)
    vals = vals_ref[...]               # (B, S) f32
    b = ids.shape[0]
    onehot = (ids[None, :] == jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
              ).astype(vals.dtype)     # (B, B): rows = local segment
    out_ref[...] = jnp.dot(onehot, vals,
                           preferred_element_type=jnp.float32)[None]


def segment_partials_pallas(values: jnp.ndarray, local_ids: jnp.ndarray,
                            block: int = 256, interpret: bool = True
                            ) -> jnp.ndarray:
    """values: (N, S) f32 (N % block == 0); local_ids: (N,) int32 in
    [0, block). Returns (nb, block, S) per-block partial sums."""
    n, s = values.shape
    nb = n // block
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block, s), jnp.float32),
        interpret=interpret,
    )(local_ids, values)


def combine_partials(partials: jnp.ndarray, block_base: jnp.ndarray,
                     num_segments: int) -> jnp.ndarray:
    """Merge per-block partials into global per-segment sums.

    partials: (nb, B, S); block_base: (nb,) int32 = global segment id of each
    block's local segment 0. Returns (num_segments, S).
    """
    nb, b, s = partials.shape
    gid = (block_base[:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
           ).reshape(-1)
    flat = partials.reshape(nb * b, s)
    gid = jnp.clip(gid, 0, num_segments - 1)
    return jax.ops.segment_sum(flat, gid, num_segments=num_segments)

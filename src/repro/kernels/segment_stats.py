"""Pallas kernel: MXU segmented partial reduction (the GROUP-BY hot loop).

After sorting by key, CEM needs per-group sums of a statistics bundle
(n_t, n_c, y_t, y_c, per-covariate arm sums...). TPUs have no fast scatter;
the MXU idiom is a one-hot matmul: within a row block, partial[i, s] =
sum_j [local_seg(j) == i] * value[j, s] — a (B, B) @ (B, S) matmul that runs
on the systolic array instead of a serial scatter loop. Cross-block segment
spill is handled by a cheap jnp combine over the (nb*B, S) partials (a
segment id crosses at most nb blocks).

local_ids (= global segment id minus the block's first segment id) are
computed outside with a cumsum; the kernel is the FLOP hot spot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, vals_ref, out_ref):
    ids = ids_ref[...]                 # (B,) int32, in [0, B)
    vals = vals_ref[...]               # (B, S) f32
    b = ids.shape[0]
    onehot = (ids[None, :] == jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
              ).astype(vals.dtype)     # (B, B): rows = local segment
    out_ref[...] = jnp.dot(onehot, vals,
                           preferred_element_type=jnp.float32)[None]


def segment_partials_pallas(values: jnp.ndarray, local_ids: jnp.ndarray,
                            block: int = 256, interpret: bool = True
                            ) -> jnp.ndarray:
    """values: (N, S) f32 (N % block == 0); local_ids: (N,) int32 in
    [0, block). Returns (nb, block, S) per-block partial sums."""
    n, s = values.shape
    nb = n // block
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block, s), jnp.float32),
        interpret=interpret,
    )(local_ids, values)


def _scatter_kernel(pos_ref, table_ref, vals_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = table_ref[...]

    pos = pos_ref[...]                 # (B,) int32, in [0, C)
    vals = vals_ref[...]               # (B, S) f32
    c = out_ref.shape[0]
    b = pos.shape[0]
    onehot = (pos[None, :] == jax.lax.broadcasted_iota(jnp.int32, (c, b), 0)
              ).astype(vals.dtype)     # (C, B): rows = destination slot
    out_ref[...] += jnp.dot(onehot, vals,
                            preferred_element_type=jnp.float32)


def scatter_merge_pallas(table: jnp.ndarray, pos: jnp.ndarray,
                         vals: jnp.ndarray, block: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """Online delta merge: out[pos[j], s] = table[pos[j], s] + vals[j, s].

    table: (C, S) materialized stat table; pos: (B,) destination rows
    (B % block == 0); vals: (B, S) delta stats. TPUs have no fast scatter;
    like the GROUP-BY hot loop this routes the scatter through a one-hot
    (C, B) @ (B, S) matmul per delta block, accumulating into the output
    ref across the sequential grid — duplicate positions sum, matching
    ``jnp.ndarray.at[].add`` semantics. ``input_output_aliases`` marks the
    read-modify-write on the table buffer, so on TPU the merge happens IN
    PLACE instead of materializing a second (C, S) table per call (same
    aliasing contract as :func:`scatter_merge_parts_pallas`; XLA inserts a
    copy only when the caller still needs the input table).
    """
    c, s = table.shape
    nb = pos.shape[0] // block
    return pl.pallas_call(
        _scatter_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((c, s), lambda i: (0, 0)),
            pl.BlockSpec((block, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, s), jnp.float32),
        input_output_aliases={1: 0},   # table (input 1) -> merged output
        interpret=interpret,
    )(pos, table, vals)


def _scatter_parts_kernel(pos_ref, table_ref, vals_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = table_ref[...]

    pos = pos_ref[0]                   # (B,) int32, in [0, C)
    vals = vals_ref[0]                 # (B, S) f32
    c = out_ref.shape[1]
    b = pos.shape[0]
    onehot = (pos[None, :] == jax.lax.broadcasted_iota(jnp.int32, (c, b), 0)
              ).astype(vals.dtype)     # (C, B): rows = destination slot
    out_ref[0] += jnp.dot(onehot, vals,
                          preferred_element_type=jnp.float32)


def scatter_merge_parts_pallas(tables: jnp.ndarray, pos: jnp.ndarray,
                               vals: jnp.ndarray, block: int = 256,
                               interpret: bool = True) -> jnp.ndarray:
    """Fused partition-local scatter merge: ONE kernel launch over a
    (n_parts, n_delta_blocks) grid instead of one :func:`scatter_merge_pallas`
    call per partition — each grid row p accumulates its partition's delta
    blocks into its own (C, S) stat table via the one-hot MXU matmul.

    tables: (P, C, S); pos: (P, B) destination slots (B % block == 0);
    vals: (P, B, S). ``input_output_aliases`` donates the table buffer, so
    on TPU the merged stats are written IN PLACE — the kernel-level analogue
    of the fused ingest program's buffer donation.
    """
    n_parts, c, s = tables.shape
    nb = pos.shape[1] // block
    return pl.pallas_call(
        _scatter_parts_kernel,
        grid=(n_parts, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, j: (p, j)),
            pl.BlockSpec((1, c, s), lambda p, j: (p, 0, 0)),
            pl.BlockSpec((1, block, s), lambda p, j: (p, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, s), lambda p, j: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_parts, c, s), jnp.float32),
        input_output_aliases={1: 0},   # table buffer updates in place
        interpret=interpret,
    )(pos, tables, vals)


# canonical chunk width of the capacity-invariant query reductions — the
# single source of truth for the device-resident query path's fixed
# reduce window (historically the online engine's host-compaction
# granule)
CANONICAL_BLOCK = 1024


def chunked_sum(x: jnp.ndarray, block: int = CANONICAL_BLOCK) -> jnp.ndarray:
    """Capacity-invariant canonical sum of a zero-tail-padded stat vector.

    The device-resident query pipeline reduces per-group statistics whose
    VALID content is a key-sorted prefix and whose tail is exact zeros —
    but whose total length depends on engine layout (view capacity,
    partition count, growth history). A plain ``jnp.sum`` associates
    differently per length, so the same groups could reduce to different
    f32 bits on different engines. This sum is bitwise INVARIANT to
    trailing zero padding: the vector is padded to a multiple of ``block``,
    each ``block``-wide chunk is reduced with a fixed-shape ``jnp.sum``
    (identical lowering for every chunk, in every program), and the chunk
    partials are combined STRICTLY SEQUENTIALLY in order — appending zero
    chunks appends exact ``+ 0.0`` steps, which cannot change the result.
    Replicated / partitioned / assembled layouts therefore all reduce to
    the same bits whenever their canonical key-sorted content matches.
    """
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    total = jnp.sum(x[:block])
    for i in range(1, x.shape[0] // block):
        total = total + jnp.sum(x[i * block:(i + 1) * block])
    return total


def _chunk_sums_kernel(vals_ref, out_ref):
    out_ref[...] = jnp.sum(vals_ref[...], axis=0, keepdims=True)


def chunk_sums_pallas(values: jnp.ndarray, block: int = CANONICAL_BLOCK,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-chunk partial sums of a (N, S) stat bundle as ONE Pallas launch
    over the chunk grid — the MXU/VPU hot path of the canonical query
    reduction for very large group tables (N % block == 0). Returns
    (nb, S) chunk partials; the caller combines them sequentially exactly
    like :func:`chunked_sum`. The pure-jnp :func:`chunked_sum` is the
    bit-exactness reference the query pipeline ships with; this kernel is
    benchmarked/parity-tested (``tests/test_kernels.py``) for accelerator
    deployments where the chunk reduce dominates."""
    n, s = values.shape
    nb = n // block
    return pl.pallas_call(
        _chunk_sums_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, s), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, s), jnp.float32),
        interpret=interpret,
    )(values)


def combine_partials(partials: jnp.ndarray, block_base: jnp.ndarray,
                     num_segments: int) -> jnp.ndarray:
    """Merge per-block partials into global per-segment sums.

    partials: (nb, B, S); block_base: (nb,) int32 = global segment id of each
    block's local segment 0. Returns (num_segments, S).
    """
    nb, b, s = partials.shape
    gid = (block_base[:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
           ).reshape(-1)
    flat = partials.reshape(nb * b, s)
    gid = jnp.clip(gid, 0, num_segments - 1)
    return jax.ops.segment_sum(flat, gid, num_segments=num_segments)

"""Pallas kernel: MXU segmented partial reduction (the GROUP-BY hot loop).

After sorting by key, CEM needs per-group sums of a statistics bundle
(n_t, n_c, y_t, y_c, per-covariate arm sums...). TPUs have no fast scatter;
the MXU idiom is a one-hot matmul: within a row block, partial[i, s] =
sum_j [local_seg(j) == i] * value[j, s] — a (B, B) @ (B, S) matmul that runs
on the systolic array instead of a serial scatter loop. Cross-block segment
spill is handled by a cheap jnp combine over the (nb*B, S) partials (a
segment id crosses at most nb blocks).

local_ids (= global segment id minus the block's first segment id) are
computed outside with a cumsum; the kernel is the FLOP hot spot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, vals_ref, out_ref):
    ids = ids_ref[...]                 # (B,) int32, in [0, B)
    vals = vals_ref[...]               # (B, S) f32
    b = ids.shape[0]
    onehot = (ids[None, :] == jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
              ).astype(vals.dtype)     # (B, B): rows = local segment
    out_ref[...] = jnp.dot(onehot, vals,
                           preferred_element_type=jnp.float32)[None]


def segment_partials_pallas(values: jnp.ndarray, local_ids: jnp.ndarray,
                            block: int = 256, interpret: bool = True
                            ) -> jnp.ndarray:
    """values: (N, S) f32 (N % block == 0); local_ids: (N,) int32 in
    [0, block). Returns (nb, block, S) per-block partial sums."""
    n, s = values.shape
    nb = n // block
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block, s), jnp.float32),
        interpret=interpret,
    )(local_ids, values)


def _scatter_kernel(pos_ref, table_ref, vals_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = table_ref[...]

    pos = pos_ref[...]                 # (B,) int32, in [0, C)
    vals = vals_ref[...]               # (B, S) f32
    c = out_ref.shape[0]
    b = pos.shape[0]
    onehot = (pos[None, :] == jax.lax.broadcasted_iota(jnp.int32, (c, b), 0)
              ).astype(vals.dtype)     # (C, B): rows = destination slot
    out_ref[...] += jnp.dot(onehot, vals,
                            preferred_element_type=jnp.float32)


def scatter_merge_pallas(table: jnp.ndarray, pos: jnp.ndarray,
                         vals: jnp.ndarray, block: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """Online delta merge: out[pos[j], s] = table[pos[j], s] + vals[j, s].

    table: (C, S) materialized stat table; pos: (B,) destination rows
    (B % block == 0); vals: (B, S) delta stats. TPUs have no fast scatter;
    like the GROUP-BY hot loop this routes the scatter through a one-hot
    (C, B) @ (B, S) matmul per delta block, accumulating into the output
    ref across the sequential grid — duplicate positions sum, matching
    ``jnp.ndarray.at[].add`` semantics.
    """
    c, s = table.shape
    nb = pos.shape[0] // block
    return pl.pallas_call(
        _scatter_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((c, s), lambda i: (0, 0)),
            pl.BlockSpec((block, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, s), jnp.float32),
        interpret=interpret,
    )(pos, table, vals)


def _scatter_parts_kernel(pos_ref, table_ref, vals_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = table_ref[...]

    pos = pos_ref[0]                   # (B,) int32, in [0, C)
    vals = vals_ref[0]                 # (B, S) f32
    c = out_ref.shape[1]
    b = pos.shape[0]
    onehot = (pos[None, :] == jax.lax.broadcasted_iota(jnp.int32, (c, b), 0)
              ).astype(vals.dtype)     # (C, B): rows = destination slot
    out_ref[0] += jnp.dot(onehot, vals,
                          preferred_element_type=jnp.float32)


def scatter_merge_parts_pallas(tables: jnp.ndarray, pos: jnp.ndarray,
                               vals: jnp.ndarray, block: int = 256,
                               interpret: bool = True) -> jnp.ndarray:
    """Fused partition-local scatter merge: ONE kernel launch over a
    (n_parts, n_delta_blocks) grid instead of one :func:`scatter_merge_pallas`
    call per partition — each grid row p accumulates its partition's delta
    blocks into its own (C, S) stat table via the one-hot MXU matmul.

    tables: (P, C, S); pos: (P, B) destination slots (B % block == 0);
    vals: (P, B, S). ``input_output_aliases`` donates the table buffer, so
    on TPU the merged stats are written IN PLACE — the kernel-level analogue
    of the fused ingest program's buffer donation.
    """
    n_parts, c, s = tables.shape
    nb = pos.shape[1] // block
    return pl.pallas_call(
        _scatter_parts_kernel,
        grid=(n_parts, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, j: (p, j)),
            pl.BlockSpec((1, c, s), lambda p, j: (p, 0, 0)),
            pl.BlockSpec((1, block, s), lambda p, j: (p, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, s), lambda p, j: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_parts, c, s), jnp.float32),
        input_output_aliases={1: 0},   # table buffer updates in place
        interpret=interpret,
    )(pos, tables, vals)


def combine_partials(partials: jnp.ndarray, block_base: jnp.ndarray,
                     num_segments: int) -> jnp.ndarray:
    """Merge per-block partials into global per-segment sums.

    partials: (nb, B, S); block_base: (nb,) int32 = global segment id of each
    block's local segment 0. Returns (num_segments, S).
    """
    nb, b, s = partials.shape
    gid = (block_base[:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
           ).reshape(-1)
    flat = partials.reshape(nb * b, s)
    gid = jnp.clip(gid, 0, num_segments - 1)
    return jax.ops.segment_sum(flat, gid, num_segments=num_segments)

"""Jit'd public wrappers around the Pallas kernels.

Responsibilities: pad inputs to block multiples, pick interpret mode (this
container is CPU-only — interpret=True executes the kernel body in Python
for correctness; on TPU backends the same calls compile to Mosaic), and
slice padding back off. `repro.core` calls these; `ref.py` holds oracles.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.keys import KeyCodec
from repro.kernels import ref
from repro.kernels.cem_keys import cem_keys_pallas
from repro.kernels.knn_topk import knn_topk_pallas
from repro.kernels.logistic_grad import logistic_newton_terms_pallas
from repro.kernels.segment_stats import (combine_partials,
                                         scatter_merge_pallas,
                                         scatter_merge_parts_pallas,
                                         segment_partials_pallas)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, block: int, fill=0):
    n = x.shape[0]
    pad = (-n) % block
    if pad == 0:
        return x, n
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill), n


def cem_keys_op(X: jnp.ndarray, specs_cutpoints: Sequence[Sequence[float]],
                widths: Sequence[int], valid: jnp.ndarray,
                block: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused coarsen+pack for continuous covariates.

    specs_cutpoints[j] = cutpoint list of covariate j (column j of X);
    widths[j] = bit width (from KeyCodec). Fields are packed MSB-first in
    column order — callers must order columns to match their codec.
    """
    n, d = X.shape
    cmax = max(1, max(len(c) for c in specs_cutpoints))
    cp = np.full((d, cmax), np.inf, np.float32)
    n_cuts = []
    for j, c in enumerate(specs_cutpoints):
        cp[j, :len(c)] = c
        n_cuts.append(len(c))
    Xp, n0 = _pad_rows(X.astype(jnp.float32), block)
    vp, _ = _pad_rows(valid.astype(jnp.int32), block)
    hi, lo = cem_keys_pallas(Xp, jnp.asarray(cp), vp, tuple(n_cuts),
                             tuple(widths), block=block,
                             interpret=_interpret())
    return hi[:n0], lo[:n0]


def segment_sums_op(values: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int, block: int = 256) -> jnp.ndarray:
    """Drop-in for jax.ops.segment_sum over SORTED seg_ids (N, S) -> (G, S),
    backed by the MXU one-hot matmul kernel."""
    n, s = values.shape
    vp, n0 = _pad_rows(values.astype(jnp.float32), block)
    # padded rows: give them a segment id one past the last (clipped later)
    pad_id = num_segments
    ip, _ = _pad_rows(seg_ids.astype(jnp.int32), block, fill=pad_id)
    nb = vp.shape[0] // block
    base = ip.reshape(nb, block)[:, 0]
    local = ip - jnp.repeat(base, block)
    local = jnp.clip(local, 0, block - 1)
    partials = segment_partials_pallas(vp, local, block=block,
                                       interpret=_interpret())
    return combine_partials(partials, base, num_segments + 1)[:num_segments]


def scatter_merge_op(table: jnp.ndarray, pos: jnp.ndarray,
                     vals: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    """Merge delta stat rows into a (C, S) stat table at known positions
    (the online engine's fast-path cuboid update). Pads the delta to a
    block multiple (padding rows contribute zeros) and, on TPU backends,
    the stat axis to the 128-lane width Mosaic tiles by — the cuboid stat
    bundle (3 + 3 * #treatments columns) is rarely lane-aligned."""
    if pos.shape[0] == 0:  # empty delta: at[].add semantics -> no-op
        return table.astype(jnp.float32)
    interp = _interpret()
    vp, _ = _pad_rows(vals.astype(jnp.float32), block)
    pp, _ = _pad_rows(pos.astype(jnp.int32), block, fill=0)  # pad adds 0s
    t = table.astype(jnp.float32)
    s = t.shape[1]
    pad_s = 0 if interp else (-s) % 128
    if pad_s:
        t = jnp.pad(t, ((0, 0), (0, pad_s)))
        vp = jnp.pad(vp, ((0, 0), (0, pad_s)))
    out = scatter_merge_pallas(t, pp, vp, block=block, interpret=interp)
    return out[:, :s] if pad_s else out


def scatter_merge_parts_op(tables: jnp.ndarray, pos: jnp.ndarray,
                           vals: jnp.ndarray, block: int = 256
                           ) -> jnp.ndarray:
    """Scatter-merge over a PARTITION-LOCAL key space: ``tables`` is
    (P, C, S) — one stat table per key-range partition — ``pos``/``vals``
    are (P, B)/(P, B, S) routed delta rows whose positions index their own
    partition's table only. ONE fused kernel launch over a (P, blocks)
    grid (``scatter_merge_parts_pallas``) with the table buffer donated
    in place, replacing the per-partition python loop of kernel calls; on
    a sharded leading axis the merge stays device-local."""
    if pos.shape[1] == 0:  # empty delta: at[].add semantics -> no-op
        return tables.astype(jnp.float32)
    interp = _interpret()
    n_parts, c, s = tables.shape
    pad_b = (-pos.shape[1]) % block
    pp = jnp.pad(pos.astype(jnp.int32), ((0, 0), (0, pad_b)))
    vp = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, pad_b), (0, 0)))
    t = tables.astype(jnp.float32)
    pad_s = 0 if interp else (-s) % 128
    if pad_s:
        t = jnp.pad(t, ((0, 0), (0, 0), (0, pad_s)))
        vp = jnp.pad(vp, ((0, 0), (0, 0), (0, pad_s)))
    out = scatter_merge_parts_pallas(t, pp, vp, block=block,
                                     interpret=interp)
    return out[:, :, :s] if pad_s else out


def knn_topk_op(Q: jnp.ndarray, C: jnp.ndarray, c_valid: jnp.ndarray,
                k: int, caliper: float = None, block_q: int = 256,
                block_c: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k-NN (squared distances) with optional caliper on the *Euclidean*
    distance; pads both sides, slices back."""
    Qp, nq = _pad_rows(Q.astype(jnp.float32), block_q)
    Cp, nc = _pad_rows(C.astype(jnp.float32), block_c)
    cvp, _ = _pad_rows(c_valid.astype(jnp.int32), block_c, fill=0)
    d2, idx = knn_topk_pallas(Qp, Cp, cvp, k, block_q=block_q,
                              block_c=block_c, interpret=_interpret())
    d2, idx = d2[:nq], idx[:nq]
    dist = jnp.sqrt(d2)
    if caliper is not None:
        dist = jnp.where(dist <= caliper, dist, ref.BIG)
    return dist, idx


def logistic_newton_terms_op(X: jnp.ndarray, t: jnp.ndarray, m: jnp.ndarray,
                             w: jnp.ndarray, block: int = 1024
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    Xp, n0 = _pad_rows(X.astype(jnp.float32), block)
    tp, _ = _pad_rows(t.astype(jnp.float32), block)
    mp, _ = _pad_rows(m.astype(jnp.float32), block, fill=0)  # pad -> weight 0
    return logistic_newton_terms_pallas(Xp, tp, mp, w.astype(jnp.float32),
                                        block=block, interpret=_interpret())


def local_seg_ids(seg_ids: jnp.ndarray, block: int) -> jnp.ndarray:
    """Helper mirrored from segment_sums_op for tests."""
    n = seg_ids.shape[0]
    nb = n // block
    base = seg_ids.reshape(nb, block)[:, 0]
    return seg_ids - jnp.repeat(base, block)

"""Pallas kernel: fused coarsen + bit-pack of CEM group keys.

The CEM front-end touches every row once: bucketize d covariates against
their cutpoint vectors and pack the bucket ids into a 63-bit (hi, lo) key.
Done naively this is d searchsorteds + d shift/or passes = 2d+ HBM trips.
The kernel fuses everything into ONE pass: a (B, d) tile of covariates
streams through VMEM, cutpoints (d, C) stay resident, and the two u32 key
words leave. Memory-bound by design — the roofline term is exactly
N*(4d + 8 + 1) bytes.

Block layout: rows B=512 (sublane multiple), covariates padded to the lane
width in ops.py. Cutpoint comparisons vectorize over the C lane dimension;
bucket id = popcount of (x >= cutpoint) over real cutpoints.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cp_ref, valid_ref, hi_ref, lo_ref, *, n_cuts, widths):
    x = x_ref[...]                       # (B, d) f32
    cps = cp_ref[...]                    # (d, C) f32, +inf padded
    valid = valid_ref[...]               # (B,) int32 (bool as i32)
    b, d = x.shape
    c = cps.shape[1]
    hi = jnp.zeros((b,), jnp.uint32)
    lo = jnp.zeros((b,), jnp.uint32)
    for j in range(d):
        if widths[j] == 0:
            continue
        cmp = (x[:, j:j + 1] >= cps[j][None, :]).astype(jnp.uint32)
        mask = (jnp.arange(c) < n_cuts[j])[None, :].astype(jnp.uint32)
        bucket = jnp.sum(cmp * mask, axis=1).astype(jnp.uint32)
        w = widths[j]
        hi = (hi << w) | (lo >> (32 - w))
        lo = (lo << w) | bucket
    inval = jnp.uint32(0xFFFFFFFF)
    ok = valid != 0
    hi_ref[...] = jnp.where(ok, hi, inval)
    lo_ref[...] = jnp.where(ok, lo, inval)


def cem_keys_pallas(X: jnp.ndarray, cutpoints: jnp.ndarray,
                    valid: jnp.ndarray, n_cuts: Sequence[int],
                    widths: Sequence[int], block: int = 512,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """X: (N, d) f32, N % block == 0; cutpoints: (d, C) f32 (+inf padded);
    valid: (N,) int32. Returns (hi, lo) u32 keys."""
    n, d = X.shape
    c = cutpoints.shape[1]
    grid = (n // block,)
    kernel = functools.partial(_kernel, n_cuts=tuple(n_cuts),
                               widths=tuple(widths))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((d, c), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=interpret,
    )(X, cutpoints, valid)

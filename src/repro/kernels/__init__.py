"""Pallas TPU kernels for the ZaliQL hot spots, validated in interpret mode.

Kernels (each <name>.py has the pl.pallas_call + BlockSpec tiling; ops.py
holds the jit'd wrappers; ref.py the pure-jnp oracles):

  cem_keys       fused coarsen + 63-bit key pack (memory-bound, 1 pass)
  segment_stats  MXU one-hot-matmul segmented reduction (GROUP BY core),
                 plus the scatter-merge of online delta stat tables
  knn_topk       tiled all-pairs distance + running top-k (NNM core)
  logistic_grad  fused Newton gradient+Hessian (propensity core)
"""
from repro.kernels.ops import (cem_keys_op, knn_topk_op,
                               logistic_newton_terms_op, scatter_merge_op,
                               scatter_merge_parts_op, segment_sums_op)

__all__ = ["cem_keys_op", "knn_topk_op", "logistic_newton_terms_op",
           "scatter_merge_op", "scatter_merge_parts_op", "segment_sums_op"]

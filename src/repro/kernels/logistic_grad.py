"""Pallas kernel: fused Newton terms for propensity logistic regression.

Per Newton iteration the engine needs g = X^T(m*(sigmoid(Xw)-t)) and
H = X^T diag(m*p*(1-p)) X. Unfused that is 3 passes over X (logits,
gradient, Hessian); the kernel computes logits, residual, gradient tile and
Hessian tile in ONE pass per (B, d) block, accumulating g (d,) and H (d, d)
in output refs across the sequential grid — X is read exactly once per
iteration, which is the roofline minimum (X never fits in VMEM at 10^8
rows; w, g, H always do).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, t_ref, m_ref, w_ref, g_ref, h_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]                      # (B, d)
    t = t_ref[...]                      # (B,)
    m = m_ref[...]                      # (B,)
    w = w_ref[...]                      # (d,)
    logits = jnp.dot(x, w[:, None],
                     preferred_element_type=jnp.float32)[:, 0]
    p = jax.nn.sigmoid(logits)
    r = m * (p - t)                     # (B,)
    s = m * p * (1.0 - p)               # (B,)
    g_ref[...] += jnp.dot(r[None, :], x,
                          preferred_element_type=jnp.float32)[0]
    h_ref[...] += jnp.dot(x.T * s[None, :], x,
                          preferred_element_type=jnp.float32)


def logistic_newton_terms_pallas(X: jnp.ndarray, t: jnp.ndarray,
                                 m: jnp.ndarray, w: jnp.ndarray,
                                 block: int = 1024, interpret: bool = True):
    """X: (N, d) with bias column (N % block == 0); t, m: (N,); w: (d,).
    Returns (g: (d,), H: (d, d))."""
    n, d = X.shape
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        ],
        interpret=interpret,
    )(X, t, m, w)

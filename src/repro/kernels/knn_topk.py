"""Pallas kernel: tiled all-pairs k-NN with running top-k (NNM hot loop).

The paper's NNM is a quadratic spatial self-join. TPU-native formulation:
queries tile over grid dim 0, controls stream over grid dim 1 (fastest-
varying, executed sequentially on TPU), distances for each (Bq, Bc) tile
come from ONE matmul (|q|^2 + |c|^2 - 2 q.c — Mahalanobis is pre-rotated
into Euclidean form by ops.py), and a running (Bq, k) top-k accumulates in
the output ref across control tiles — the same accumulator pattern as
flash attention. Selection uses k argmin-extract passes (k is small and
static), entirely vectorized over the query rows; no sort network needed.

The identical loop body is reused by the distributed ring k-NN
(`repro.core.distributed`), where control tiles arrive via `ppermute`
instead of grid iteration.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: jnp constants may not be closed over in kernels


def _kernel(q_ref, c_ref, cv_ref, od_ref, oi_ref, *, k, block_c):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, BIG, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]                     # (Bq, d)
    c = c_ref[...]                     # (Bc, d)
    cv = cv_ref[...]                   # (Bc,) int32
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    d2 = qn + cn - 2.0 * jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where((cv != 0)[None, :], d2, BIG)
    base = ci * block_c
    col = (base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1))

    run_d = od_ref[...]                # (Bq, k)
    run_i = oi_ref[...]
    cand_d = jnp.concatenate([run_d, d2], axis=1)      # (Bq, k+Bc)
    cand_i = jnp.concatenate([run_i, col], axis=1)
    for slot in range(k):
        m = jnp.min(cand_d, axis=1)                    # (Bq,)
        am = jnp.argmin(cand_d, axis=1)
        run_d = run_d.at[:, slot].set(m)
        take = jnp.take_along_axis(cand_i, am[:, None], axis=1)[:, 0]
        run_i = run_i.at[:, slot].set(take)
        cand_d = cand_d.at[jnp.arange(cand_d.shape[0]), am].set(BIG)
    od_ref[...] = run_d
    oi_ref[...] = run_i


def knn_topk_pallas(Q: jnp.ndarray, C: jnp.ndarray, c_valid: jnp.ndarray,
                    k: int, block_q: int = 256, block_c: int = 512,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Q: (Nq, d), C: (Nc, d) (both block-divisible), c_valid: (Nc,) int32.
    Returns (d2, idx): k smallest squared distances + control indices."""
    nq, d = Q.shape
    nc = C.shape[0]
    grid = (nq // block_q, nc // block_c)
    kernel = functools.partial(_kernel, k=k, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((block_c, d), lambda qi, ci: (ci, 0)),
            pl.BlockSpec((block_c,), lambda qi, ci: (ci,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, ci: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(Q, C, c_valid)

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(interpret-mode allclose over shape/dtype sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

BIG = jnp.float32(3.4e38)


def cem_keys_ref(X: jnp.ndarray, cutpoints: jnp.ndarray,
                 n_cuts: Sequence[int], widths: Sequence[int],
                 valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused coarsen + bit-pack.

    X: (N, d) f32; cutpoints: (d, C) f32 padded with +inf; n_cuts[j] = number
    of real cutpoints of field j (buckets = n_cuts[j]+1); widths[j] = bit
    width allotted to field j (MSB-first packing, same as KeyCodec).
    """
    n, d = X.shape
    hi = jnp.zeros((n,), jnp.uint32)
    lo = jnp.zeros((n,), jnp.uint32)
    for j in range(d):
        cp = cutpoints[j]
        b = jnp.sum((X[:, j:j + 1] >= cp[None, :]).astype(jnp.uint32)
                    * (jnp.arange(cp.shape[0]) < n_cuts[j])[None, :],
                    axis=1).astype(jnp.uint32)
        w = widths[j]
        hi = (hi << w) | (lo >> (32 - w))
        lo = (lo << w) | b
    hi = jnp.where(valid, hi, jnp.uint32(0xFFFFFFFF))
    lo = jnp.where(valid, lo, jnp.uint32(0xFFFFFFFF))
    return hi, lo


def segment_partials_ref(values: jnp.ndarray, local_ids: jnp.ndarray,
                         block: int) -> jnp.ndarray:
    """Per-block segmented partial sums.

    values: (N, S); local_ids: (N,) int32 in [0, block) — the row's segment
    id *relative to the first segment of its block*. Output: (nb, block, S)
    partial sums per (block, local segment).
    """
    n, s = values.shape
    nb = n // block
    v = values.reshape(nb, block, s)
    ids = local_ids.reshape(nb, block)
    onehot = (ids[:, None, :] == jnp.arange(block)[None, :, None])
    return jnp.einsum("bij,bjs->bis", onehot.astype(values.dtype), v)


def scatter_merge_ref(table: jnp.ndarray, pos: jnp.ndarray,
                      vals: jnp.ndarray) -> jnp.ndarray:
    """Delta stat-table merge: out[pos[j]] += vals[j] (duplicates sum).

    table: (C, S) materialized stats; pos: (B,) destination rows;
    vals: (B, S) delta stats.
    """
    return table.at[pos].add(vals.astype(table.dtype))


def knn_topk_ref(Q: jnp.ndarray, C: jnp.ndarray, c_valid: jnp.ndarray,
                 k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k smallest squared-Euclidean distances (and indices) per query row.
    Invalid controls -> BIG. Ties broken by lower index."""
    qn = jnp.sum(Q * Q, axis=1, keepdims=True)
    cn = jnp.sum(C * C, axis=1)[None, :]
    d2 = jnp.maximum(qn + cn - 2.0 * (Q @ C.T), 0.0)
    d2 = jnp.where(c_valid[None, :], d2, BIG)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def logistic_newton_terms_ref(X: jnp.ndarray, t: jnp.ndarray,
                              m: jnp.ndarray, w: jnp.ndarray
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One fused pass of Newton logistic terms.

    X: (N, d) standardized features WITH bias column; t: (N,) targets;
    m: (N,) row weights (validity); w: (d,) coefficients.
    Returns (g, H): g = X^T(m*(sigmoid(Xw)-t)), H = X^T diag(m*p*(1-p)) X.
    """
    logits = X @ w
    p = jax.nn.sigmoid(logits)
    r = m * (p - t)
    g = X.T @ r
    s = m * p * (1.0 - p)
    H = (X * s[:, None]).T @ X
    return g, H

"""Online incremental causal inference (paper §4.2's "online setting",
made truly incremental — and sharded over the device mesh).

The offline path re-coarsens, re-groups and re-cubes the whole relation for
every new batch of rows. This engine instead maintains causal estimates
under streaming INSERTs with work proportional to the DELTA, not the data:

  1. DELTA CUBOID MAINTENANCE — every cuboid stat is decomposable
     (count/sum), so a streamed batch reduces to a tiny stat table that is
     folded into each materialized cuboid with the same combine the
     distributed engine uses for per-chip partials
     (:func:`repro.core.cube.merge_delta`). The delta is computed ONCE at
     base granularity and propagated DOWN the cube lattice by rolling the
     delta itself up to each view's dims — never by rebuilding a cuboid
     from rows.
  2. SHARDED INGEST — on a multi-device mesh the batch is row-sharded over
     the data axis: each device coarsens/packs/locally-aggregates its
     shard, the per-device delta stat tables are ``all_gather``ed and
     combined (:func:`repro.core.distributed.make_sharded_delta_build`),
     and the replicated merged delta folds into every view exactly as on
     one chip — the offline-equivalence guarantees carry over verbatim on
     1..N devices. :class:`PartitionedOnlineEngine` goes further: the
     MATERIALIZED views themselves are key-range partitioned over the mesh
     (each device owns 1/N of every stat table), deltas are ROUTED to
     their owner device (all-to-all on key range instead of
     all-gather-everything), and merges/eviction run per partition — total
     state scales with the mesh instead of being capped by one chip.
  3. INCREMENTAL CEM OVERLAP — when a merge keeps the stat-table layout
     (fast path), the overlap filter ``max(T) != min(T)`` is re-evaluated
     only at the group ids the delta touched
     (:func:`repro.core.cem.update_overlap`): groups flip in and out of the
     matched set in O(|delta groups|).
  4. STREAMING PROPENSITY — logistic refreshes no longer need an unbounded
     row log: a :class:`repro.core.propensity.StreamStats` maintains exact
     per-feature moment accumulators (stream-wide standardization,
     retractable) plus a bounded uniform reservoir that the warm-started
     Newton refit (:func:`repro.core.propensity.warm_refit`) runs over.
  5. ESTIMATE CACHE — repeated online queries are served from a cache keyed
     by (treatment, sub-population); a delta invalidates only the entries
     whose group predicate it actually touched.
  6. ONE FUSED HOST SYNC PER INGEST — the per-merge fast/slow-path
     decisions, the retraction guard, the delta group count, and the cache
     invalidation predicate all come back from the device in a single
     ``device_get`` (:func:`_plan_ingest`), instead of one blocking
     device->host read per merge serializing dispatch every batch.
  7. ONE COMPILED DISPATCH PER INGEST (``pipeline="fused1"``, the default)
     — the whole maintenance loop of a batch (delta build, rollups,
     routing, merges incl. the re-sort grow path, overlap flips, touch
     stamps, streaming-propensity update, verdict scalars) is one donated
     device program (:mod:`repro.core.fused`): state updates in place, the
     host fetches one verdict ``device_get`` and commits by reference
     swap. Growth recompiles the program at a doubled capacity (keyed on
     the granule count) and re-dispatches; only delta-capacity overflow
     still falls back to the exact host rebuild. ``pipeline="planner"``
     keeps the PR 3 two-dispatch planner path and ``pipeline="unfused"``
     the legacy per-merge-sync loop, both measurable in
     ``benchmarks/bench_online.py``.
  8. ONE COMPILED DISPATCH PER QUERY (``query_pipeline="fused"``, the
     default) — an uncached ``ate()`` runs subpopulation filtering, keep
     masking and the sufficient-stat reductions inside one device program
     straight on the raw materialized state (per-partition/1-per-device
     on a mesh), fetches one scalar dict, and caches it host-side
     (delta-predicate invalidation, item 5): repeated dashboard queries
     are zero dispatches and zero transfers, and the partitioned
     engine's canonical-reassembly memo is keyed on a state version
     bumped per commit. ``matched_rows`` is a one-dispatch
     routed row lookup on the partitioned layout. The canonical chunked
     reduction (:func:`repro.kernels.segment_stats.chunked_sum`) makes
     every estimate a bitwise-deterministic function of the group content
     alone, so the fused path, the ``query_pipeline="assemble"``
     baseline and both engine layouts agree exactly.
  9. MVCC SNAPSHOT OVERLAP (``overlap=True``) — ingest dispatches for
     version v+1..v+k run PIPELINED while every query path serves the
     last COMMITTED snapshot v: the engine's attributes only ever hold
     committed state, in-flight dispatches chain device-side off each
     other (the first hop does NOT donate the committed buffers — the
     MVCC double-buffer rule, :func:`repro.core.fused.get_fused_ingest`),
     and the verdict scalars are checked LAZILY at :meth:`commit` — the
     steady-state ingest hot path performs ZERO host syncs
     (``device_get`` leaves the dispatch path entirely; rule ZQL007 and
     the jaxpr audit enforce it). Commit is an atomic reference swap plus
     one version bump per batch; a batch that needed growth or the exact
     fallback rolls BACK to the committed snapshot and REPLAYS all
     in-flight batches synchronously in order, so every committed version
     is bitwise identical to the synchronous pipeline's. Every
     ``ATEEstimate`` carries ``state_version`` — the snapshot it was
     computed at (:meth:`snapshot_version`).

The maintained state is EXACT: after any number of ingested batches, every
cuboid stat, CEM matched set and ATE equals the offline computation over
the concatenated table (bit-identical when outcome sums are exact, e.g.
integer-valued outcomes; to float tolerance otherwise — summation order is
the only difference). ``tests/test_online.py`` asserts this equivalence,
and ``tests/test_online_sharded.py`` asserts it per device count. Eviction
(:meth:`OnlineEngine.evict`) deliberately trades this exactness for
bounded state on unbounded key spaces.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cube as cube_mod
from repro.core import fused as fused_mod
from repro.core import groupby
from repro.core.ate import ATEEstimate
from repro.core.cem import (CEMGroups, make_codec, overlap_keep, pack_keys,
                            update_overlap)
from repro.core.coarsen import CoarsenSpec
from repro.core.propensity import (LogisticModel, StreamStats, design_matrix,
                                   fit_logistic)
from repro.data.columnar import GrowableTable, Table, _round_capacity
from repro.launch.trace import counted_jit, device_fetch, record_batch

import collections.abc as _cabc

#: contract-lint scoping (tools/contract_check.py): this module is
#: engine-owned — dispatch/donation rules ZQL001-ZQL006 apply.
__engine_owned__ = True

BASE_VIEW = fused_mod.BASE_VIEW

# The query reductions run at a fixed canonical chunk width
# (repro.kernels.segment_stats.CANONICAL_BLOCK, via chunked_sum): the
# key-sorted group stats reduce in fixed 1024-wide chunks combined
# strictly sequentially, so estimates are a function of the canonical
# group CONTENT alone — never of an engine's capacity, growth history or
# partition count — and the same state yields bit-identical results from
# every engine layout and query pipeline on any device count.

# Streamed batches are padded to power-of-two row buckets (floor below)
# before they reach the compiled ingest pipeline: the fused program traces
# per row-count, so bucketing caps the trace count of an irregular stream
# at ~log2(max batch) instead of one trace per distinct size. Padding rows
# are invalid (masked everywhere, including the streaming-propensity
# update, which sees the same padded draw shape in every engine — that is
# what keeps reservoir states bit-identical across engines and pipelines).
BATCH_BUCKET_GRANULE = 64


def _bucket_rows(n: int) -> int:
    """Power-of-two row bucket (>= BATCH_BUCKET_GRANULE) a batch pads to."""
    b = BATCH_BUCKET_GRANULE
    while b < n:
        b <<= 1
    return b


def _bucket_specs(n: int) -> int:
    """Power-of-two SPEC bucket a query batch pads to. Same idea as
    :func:`_bucket_rows` (the batched query program traces per padded
    batch size, so bucketing caps retraces at ~log2(max B)) but floored
    at 1: single queries through the batched path should not pay a
    64-wide estimate."""
    b = 1
    while b < n:
        b <<= 1
    return b


SubPop = Optional[Mapping[str, Sequence[int]]]


class PoisonBatchError(ValueError):
    """A streamed batch failed host-side validation BEFORE any dispatch,
    WAL append or state mutation: the engine's committed state, snapshot
    version, estimate cache and in-flight MVCC chain are all untouched
    (exception safety asserted by ``tests/test_online_recovery.py``)."""


def _freeze_subpop(subpopulation: SubPop):
    """Canonical hashable form of a subpopulation predicate: ``((dim,
    (bucket, ...)), ...)`` sorted, or None. Idempotent — accepts either
    the mapping form or an already-frozen tuple (``QuerySpec`` stores the
    frozen form)."""
    if not subpopulation:
        return None
    items = (subpopulation if isinstance(subpopulation, tuple)
             else subpopulation.items())
    return tuple(sorted((d, tuple(sorted(int(b) for b in bs)))
                        for d, bs in items))


@dataclasses.dataclass
class DeltaReport:
    """What one :meth:`OnlineEngine.ingest` call did."""

    n_rows: int                   # batch rows (valid or not)
    n_delta_groups: int           # distinct base-granularity groups touched
    fast_path: Dict[str, bool]    # view -> scatter-merge (True) / re-sort
    invalidated: Tuple            # estimate-cache keys dropped


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-uncommitted MVCC ingest hop: the program's
    output state pytree (the NEXT hop's input), its device-resident
    verdict scalars (fetched lazily at commit), the bucket-padded batch
    (the replay input on rollback) and the caller's original batch (row
    accounting)."""

    state: dict
    verdicts: dict
    batch: "Table"
    orig: "Table"
    pending: "PendingIngest"


class PendingIngest:
    """Lazy :class:`DeltaReport` of one overlap-mode ingest.

    The dispatch already happened; the verdict scalars stay on device
    until :meth:`OnlineEngine.commit` fetches them all in ONE
    ``device_get``. ``n_rows`` is known immediately; touching any
    verdict-derived field (``n_delta_groups``, ``fast_path``,
    ``invalidated``) forces the commit — so code written against the
    synchronous ``DeltaReport`` keeps working, it just pays the sync it
    asks for."""

    def __init__(self, engine: "OnlineEngine", n_rows: int):
        self._engine = engine
        self.n_rows = n_rows
        self.report: Optional[DeltaReport] = None

    @property
    def committed(self) -> bool:
        return self.report is not None

    def _force(self) -> DeltaReport:
        if self.report is None:
            self._engine.commit()
        return self.report

    @property
    def n_delta_groups(self) -> int:
        return self._force().n_delta_groups

    @property
    def fast_path(self) -> Dict[str, bool]:
        return self._force().fast_path

    @property
    def invalidated(self) -> Tuple:
        return self._force().invalidated


class EvictReport(_cabc.Mapping):
    """Lazy ``{view: groups evicted}`` mapping returned by
    :meth:`OnlineEngine.evict`.

    The eviction program's count/occupancy scalars stay on device (their
    host copy is started async) so ``evict()`` never blocks the python
    thread behind an in-flight ingest dispatch; the engine resolves them
    — ONE ``device_get``, then the scoped cache invalidation and the
    capacity-shrink pass — at its next sync point
    (:meth:`OnlineEngine._resolve_evictions`) or on first access here,
    whichever comes first. Compares equal to the plain dict it resolves
    to."""

    def __init__(self, engine: "OnlineEngine"):
        self._engine = engine
        self._counts: Optional[Dict[str, int]] = None

    def _resolve(self) -> Dict[str, int]:
        if self._counts is None:
            self._engine._resolve_evictions()
        return self._counts

    def __getitem__(self, key: str) -> int:
        return self._resolve()[key]

    def __iter__(self):
        return iter(self._resolve())

    def __len__(self) -> int:
        return len(self._resolve())

    def __eq__(self, other):
        if isinstance(other, EvictReport):
            other = dict(other._resolve())
        if not isinstance(other, dict):
            return NotImplemented
        return dict(self._resolve()) == other

    def __repr__(self) -> str:
        if self._counts is None:
            return "EvictReport(<unresolved>)"
        return f"EvictReport({self._counts!r})"


@dataclasses.dataclass
class _View:
    """One materialized cuboid + incrementally maintained overlap mask."""

    treatment: str
    dims: Tuple[str, ...]
    cuboid: cube_mod.Cuboid
    keep: jnp.ndarray

    @property
    def table(self):
        """Uniform accessor over replicated/partitioned view state."""
        return self.cuboid

    def set_table(self, tab) -> None:
        self.cuboid = tab


@dataclasses.dataclass
class _PartView:
    """One key-range partitioned cuboid + per-partition overlap mask."""

    treatment: str
    dims: Tuple[str, ...]
    pcub: cube_mod.PartitionedCuboid
    keep: jnp.ndarray            # (P, C)

    @property
    def table(self):
        return self.pcub

    def set_table(self, tab) -> None:
        self.pcub = tab


def _run_fused_query(tab, keep: jnp.ndarray, treatment: str,
                     subpopulation: SubPop, *, mesh=None,
                     mesh_axis: str = "data",
                     partitioned: bool = False) -> ATEEstimate:
    """THE one construction of a fused query call: resolve the cached
    program for (codec, treatment, frozen subpopulation, mesh layout),
    select the stat columns the estimator consumes, dispatch once.
    ``tab`` is any stat table with the cuboid field names — a replicated
    ``Cuboid``, a ``(P, C)`` ``PartitionedCuboid``, or an assembled
    canonical view — so every query pipeline and both engine layouts
    share this single entry point."""
    prog = fused_mod.get_fused_query(tab.codec, treatment,
                                     _freeze_subpop(subpopulation),
                                     mesh, mesh_axis, partitioned)
    stats = {k: tab.stats[k]
             for k in fused_mod.query_stat_names(treatment)}
    return ATEEstimate(**prog(tab.key_hi, tab.key_lo, stats,
                              tab.group_valid, keep))


def _estimate_view(cub: cube_mod.Cuboid, keep: jnp.ndarray, treatment: str,
                   subpopulation: SubPop) -> ATEEstimate:
    """Causal estimate over one materialized view's stat table — ONE
    compiled dispatch, no host round trip anywhere on the path.

    The subpopulation filter, the keep mask and the estimate reductions
    all run inside the same device program
    (:func:`repro.core.fused.estimate_view_body`): the surviving groups
    are re-sorted into canonical key order in-program and reduced with the
    capacity-invariant canonical sum, so the float reductions are
    deterministic functions of the maintained group stats alone —
    replicated and partitioned engines (any partition count, any
    capacity-growth history) return bit-identical ATE, ATT and Neyman
    variance for identical state. The former host-side
    ``compact_cuboid`` + blocking ``np.asarray(keep)`` transfer are gone
    from the query path entirely; this shared body is also the
    ``query_pipeline="assemble"`` baseline and the differential oracle's
    estimator."""
    return _run_fused_query(cub, keep, treatment, subpopulation)


# Touch-stamp helpers: the pure bodies live in ``repro.core.fused`` (the
# single-dispatch program traces them inline); these counted-jit wrappers
# are the standalone dispatches the planner/unfused paths still issue, so
# the dispatch counter (repro.launch.trace) accounts for them.
_stamp_touch = counted_jit(fused_mod.stamp_touch)
_remap_touch_arrays = counted_jit(fused_mod.remap_touch)
_stamp_touch_parts = counted_jit(
    jax.vmap(fused_mod.stamp_touch, in_axes=(0, 0, 0, None)))
_remap_touch_parts_arrays = counted_jit(jax.vmap(fused_mod.remap_touch))


def _remap_touch(old_cub: cube_mod.Cuboid, new_cub: cube_mod.Cuboid,
                 touch: jnp.ndarray) -> jnp.ndarray:
    """Carry last-touch stamps across a layout-changing (re-sort) merge."""
    return _remap_touch_arrays(old_cub.key_hi, old_cub.key_lo,
                               old_cub.group_valid, new_cub.key_hi,
                               new_cub.key_lo, touch)


def _remap_touch_parts(old: cube_mod.PartitionedCuboid,
                       new: cube_mod.PartitionedCuboid,
                       touch: jnp.ndarray) -> jnp.ndarray:
    """Carry (P, C) last-touch stamps across a per-partition re-sort merge
    or compaction. Keys never change partition (the owner is a pure
    function of the key), so the remap is partition-local."""
    return _remap_touch_parts_arrays(old.key_hi, old.key_lo, old.group_valid,
                                     new.key_hi, new.key_lo, touch)


@functools.partial(
    counted_jit,
    static_argnames=("codec", "tnames", "vdims", "retract", "use_pallas",
                     "dcap"))
def _plan_ingest(d_hi, d_lo, d_stats, d_gv, base_hi, base_lo, base_stats,
                 view_hi, view_lo, view_stats, view_gv, view_keep, *,
                 codec, tnames, vdims, retract, use_pallas, dcap):
    """Everything one ingest must know, computed in ONE device program.

    Produces, without any host round-trip: the per-view rolled-up deltas,
    the fast/slow-path verdicts (is every delta key already materialized?),
    the fast-path merge candidates with their updated overlap masks, the
    retraction-negativity probe, and the cache-invalidation predicate
    inputs. The engine then issues a single fused ``device_get`` for the
    scalars/small vectors it needs to branch on — replacing the one-sync-
    per-merge pattern that serialized device dispatch on every batch.
    """
    d_hi, d_lo, d_gv = d_hi[:dcap], d_lo[:dcap], d_gv[:dcap]
    d_stats = {k: v[:dcap] for k, v in d_stats.items()}
    if retract:
        d_stats = {k: -v for k, v in d_stats.items()}
    pos_b, found_b = groupby.lookup_rows_in_table(d_hi, d_lo,
                                                  base_hi, base_lo)
    ok_b = jnp.all(found_b | ~d_gv)
    merged_b = cube_mod.scatter_merge_stats(base_stats, pos_b, d_stats,
                                            use_pallas=use_pallas)
    count_cols = [merged_b["one"]] + [merged_b[f"t_{t}"] for t in tnames]
    neg_min = jnp.min(jnp.stack(count_cols))
    views = {}
    for t, dims in zip(tnames, vdims):
        roll = cube_mod._rollup_fn(codec, dims)
        v_hi, v_lo, v_stats, v_gv = roll(d_hi, d_lo, d_gv, d_stats)
        pos_v, found_v = groupby.lookup_rows_in_table(
            v_hi, v_lo, view_hi[t], view_lo[t])
        ok_v = jnp.all(found_v | ~v_gv)
        m_stats = cube_mod.scatter_merge_stats(view_stats[t], pos_v, v_stats,
                                               use_pallas=use_pallas)
        nt = m_stats[f"t_{t}"]
        nc = m_stats["one"] - nt
        new_keep = update_overlap(view_keep[t], view_gv[t], nt, nc, pos_v)
        views[t] = dict(delta=(v_hi, v_lo, v_stats, v_gv), pos=pos_v,
                        ok=ok_v, stats=m_stats, keep=new_keep)
    buckets = {d: codec.extract(d_hi, d_lo, d) for d in codec.names}
    return dict(d_stats=d_stats, d_keys=(d_hi, d_lo), pos_b=pos_b,
                ok_b=ok_b, merged_b=merged_b,
                neg_min=neg_min, views=views, buckets=buckets,
                gv=d_gv, n_delta=jnp.sum(d_gv.astype(jnp.int32)))


@functools.partial(
    counted_jit, static_argnames=("codec", "tnames", "retract", "use_pallas"))
def _plan_ingest_parts(deltas, base_hi, base_lo, base_stats, view_hi,
                       view_lo, view_stats, view_gv, view_keep, *,
                       codec, tnames, retract, use_pallas):
    """Partitioned analogue of :func:`_plan_ingest`: every per-view,
    per-partition decision of one ingest in ONE device program.

    ``deltas`` holds the ROUTED delta stat tables — (P, Cd) per view, each
    partition's rows already delivered to its owner — so lookups, scatter
    merges and overlap re-evaluation are partition-local vmaps with no
    cross-partition traffic; on a mesh the leading axis is sharded and the
    whole plan runs 1/N-per-device. The engine fetches one fused
    ``device_get`` of the verdict scalars, exactly like the replicated
    fused path."""
    out_pos, out_ok, out_merged, out_keep = {}, {}, {}, {}
    neg_min = jnp.float32(0.0)
    n_delta = jnp.int32(0)
    buckets = {}
    for name in (BASE_VIEW,) + tnames:
        d_hi, d_lo, d_stats, d_gv = deltas[name]
        if retract:
            d_stats = {k: -v for k, v in d_stats.items()}
        if name == BASE_VIEW:
            t_hi, t_lo, t_stats = base_hi, base_lo, base_stats
        else:
            t_hi, t_lo, t_stats = view_hi[name], view_lo[name], \
                view_stats[name]
        pos, found = jax.vmap(groupby.lookup_rows_in_table)(
            d_hi, d_lo, t_hi, t_lo)
        out_ok[name] = jnp.all(found | ~d_gv)
        merged = cube_mod.scatter_merge_stats_parts(
            t_stats, pos, d_stats, use_pallas=use_pallas)
        out_pos[name], out_merged[name] = pos, merged
        if name == BASE_VIEW:
            count_cols = [merged["one"]] + [merged[f"t_{t}"]
                                            for t in tnames]
            neg_min = jnp.min(jnp.stack(count_cols))
            n_delta = jnp.sum(d_gv.astype(jnp.int32))
            buckets = {d: codec.extract(d_hi, d_lo, d)
                       for d in codec.names}
        else:
            nt = merged[f"t_{name}"]
            nc = merged["one"] - nt
            out_keep[name] = jax.vmap(update_overlap)(
                view_keep[name], view_gv[name], nt, nc, pos)
    return dict(pos=out_pos, ok=out_ok, merged=out_merged, keep=out_keep,
                neg_min=neg_min, buckets=buckets, n_delta=n_delta)


class OnlineEngine:
    """Streaming causal-inference engine over a fixed coarsening schema.

    specs:       covariate -> CoarsenSpec (the coarsening is part of the
                 schema: delta maintenance needs stable group keys).
    treatments:  treatment name -> its covariate names (the CDAG choice).
    query_dims:  extra dims kept in every view so sub-population queries
                 (e.g. airport=SFO) stay answerable from materialized state.
    keep_rows:   also log raw rows (append-only, geometric growth) — needed
                 only for row-level diagnostics; propensity refreshes now
                 run off the bounded streaming reservoir instead.
    reservoir_size: rows of streaming-propensity reservoir state kept per
                 engine. Default-on so ``refresh_propensity`` works out of
                 the box without a row log; it costs one jitted top-k
                 merge per ingest (no host sync) — pass 0 to disable if
                 propensity refreshes are never needed.
    mesh:        a jax Mesh with a ``mesh_axis`` data axis: streamed batches
                 are row-sharded across it and per-device delta stat tables
                 combined via all-gather. None = single-device build.
    use_pallas:  route fast-path merges through the MXU scatter kernel.
    pipeline:    "fused1" (default) runs the WHOLE ingest as one donated
                 compiled dispatch (delta build + merges + overlap + touch
                 + reservoir in one program, state updated in place — see
                 :mod:`repro.core.fused`); "planner" keeps the two-dispatch
                 on-device planner; "unfused" the legacy
                 one-blocking-read-per-merge loop. All three maintain
                 bit-identical state; the non-default modes exist as
                 measurable baselines (``benchmarks/bench_online.py``).
    query_pipeline: "fused" (default) answers ``ate()`` /
                 ``matched_rows()`` with ONE compiled dispatch straight on
                 the raw materialized state (filter + keep + canonical
                 reduce in-program; routed row lookup on partitioned
                 views); "assemble" keeps the planner-era baseline that
                 first reassembles the canonical view. Both return
                 bit-identical results (the shared canonical estimator);
                 "assemble" exists as the measurable baseline.
    fused_host_sync: legacy alias — ``False`` selects
                 ``pipeline="unfused"``; ignored when ``pipeline`` is
                 passed explicitly.

    Which pipeline am I on?  (full table: docs/architecture.md)

    ==================  ===================  =========================
    flag                value                dispatches / role
    ==================  ===================  =========================
    ``pipeline=``       ``"fused1"``         1 donated (production)
    (ingest)            ``"planner"``        2 (PR 3 baseline)
                        ``"unfused"``        O(#views) (legacy)
    ``query_pipeline=`` ``"fused"``          1, 0 cached (production)
                        ``"assemble"``       reassembly baseline
    (no flag)           :meth:`ate_batch`    1 per B-spec wave
    ==================  ===================  =========================

    Many heterogeneous queries batch into ONE dispatch via
    :meth:`ate_batch` (specs are encoded as device-resident data, so
    changing WHAT a batch asks never retraces);
    :class:`repro.core.serving.ServingEngine` wraps it in a slot-based
    continuous batcher for the multi-tenant serving regime. Both share
    ``ate()``'s estimate cache and invalidation.
    """

    def __init__(self, specs: Mapping[str, CoarsenSpec],
                 treatments: Mapping[str, Sequence[str]], outcome: str,
                 query_dims: Sequence[str] = (), granule: int = 1024,
                 delta_granule: int = 256, keep_rows: bool = False,
                 row_granule: int = 4096, use_pallas: bool = False,
                 reservoir_size: int = 8192, mesh=None,
                 mesh_axis: str = "data", seed: int = 0,
                 fused_host_sync: bool = True, pipeline: str = None,
                 query_pipeline: str = "fused", overlap: bool = False,
                 max_inflight: int = 8):
        if pipeline is None:
            pipeline = "fused1" if fused_host_sync else "unfused"
        if pipeline not in ("fused1", "planner", "unfused"):
            raise ValueError(f"unknown pipeline {pipeline!r}")
        if query_pipeline not in ("fused", "assemble"):
            raise ValueError(f"unknown query_pipeline {query_pipeline!r}")
        if overlap and pipeline != "fused1":
            raise ValueError("overlap=True requires pipeline='fused1' "
                             "(the MVCC chain is a fused-dispatch protocol)")
        self.pipeline = pipeline
        self.query_pipeline = query_pipeline
        self.overlap = bool(overlap)
        self.max_inflight = int(max_inflight)
        self._inflight: List[_InFlight] = []
        self._pending_evict: Optional[Tuple] = None
        self._state_version = 0
        self.fused_host_sync = pipeline != "unfused"
        self.seed = seed
        self.treatments = {t: tuple(sorted(c)) for t, c in treatments.items()}
        self.outcome = outcome
        self.query_dims = tuple(query_dims)
        base_dims = sorted(set(self.query_dims).union(
            *[set(c) for c in self.treatments.values()]))
        missing = [d for d in base_dims if d not in specs]
        if missing:
            raise ValueError(f"no CoarsenSpec for dims {missing}")
        self.specs = {d: specs[d] for d in base_dims}
        self.codec = make_codec(self.specs)
        self.granule = granule
        self.delta_granule = delta_granule
        self.use_pallas = use_pallas
        self.row_granule = row_granule
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._mesh_ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])
        self._delta_cap = delta_granule
        self._sharded_builds: Dict[int, Callable] = {}
        tnames = sorted(self.treatments)
        self._row_cols = (*base_dims, *tnames, outcome)
        self._init_state()
        self._ingest_count = 0
        self.rows: Optional[GrowableTable] = (
            None if not keep_rows else GrowableTable.from_table(
                Table.from_numpy(
                    {c: np.zeros((0,), np.float32) for c in self._row_cols},
                    np.zeros((0,), bool)),
                granule=row_granule))
        self.stream: Optional[StreamStats] = (
            StreamStats.empty(self._row_cols, capacity=reservoir_size,
                              seed=seed) if reservoir_size > 0 else None)
        self.n_rows_ingested = 0
        self._cache: Dict[Tuple, ATEEstimate] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.batch_deduped = 0
        self.models: Dict[str, LogisticModel] = {}

    def _view_schema(self):
        """(treatment, dims, codec) of every materialized view — shared by
        the replicated and partitioned state layouts."""
        for t in sorted(self.treatments):
            dims = tuple(sorted(set(self.treatments[t])
                                | set(self.query_dims)))
            yield t, dims, make_codec({d: self.specs[d] for d in dims})

    def _init_state(self) -> None:
        """Allocate the empty materialized views (replicated layout);
        :class:`PartitionedOnlineEngine` overrides this with per-partition
        tables, so no replicated state is ever allocated there."""
        tnames = tuple(sorted(self.treatments))
        self.base = cube_mod.empty_cuboid(self.codec, tnames,
                                          capacity=self.granule)
        self.views: Dict[str, _View] = {}
        for t, dims, vcodec in self._view_schema():
            self.views[t] = _View(
                treatment=t, dims=dims,
                cuboid=cube_mod.empty_cuboid(vcodec, tnames,
                                             capacity=self.granule),
                keep=jnp.zeros((self.granule,), bool))
        self._touch: Dict[str, jnp.ndarray] = {
            name: jnp.zeros((self.granule,), jnp.int32)
            for name in (BASE_VIEW, *tnames)}

    @classmethod
    def from_table(cls, table: Table, specs: Mapping[str, CoarsenSpec],
                   treatments: Mapping[str, Sequence[str]], outcome: str,
                   **kwargs) -> "OnlineEngine":
        """Seed the engine with an initial offline table, then stream."""
        eng = cls(specs, treatments, outcome, **kwargs)
        eng.ingest(table)
        return eng

    # ------------------------------------------------------- delta build
    def _get_sharded_build(self, capacity: int) -> Callable:
        if capacity not in self._sharded_builds:
            from repro.core.distributed import make_sharded_delta_build
            self._sharded_builds[capacity] = make_sharded_delta_build(
                self.mesh, self.specs, sorted(self.treatments),
                self.outcome, capacity, axis=self.mesh_axis)
        return self._sharded_builds[capacity]

    def _build_delta(self, batch: Table):
        """Raw (uncompacted) delta stat table of one batch, sharded over
        the mesh when one is attached. Returns device arrays only —
        (hi, lo, stats, group_valid, n_groups, overflow) — where overflow
        means the table is INCOMPLETE (a local shard overflowed its
        capacity) and the caller must rebuild exactly on the host.
        """
        cols = {c: batch.columns[c] for c in self._row_cols}
        valid = batch.valid
        if self.mesh is not None and self._mesh_ndev > 1:
            cols, valid = fused_mod.pad_tail(
                cols, valid, (-batch.nrows) % self._mesh_ndev)
            fn = self._get_sharded_build(self._delta_cap)
            return fn(cols, valid)
        fn = cube_mod._build_fn(self.codec,
                                tuple(sorted(self.specs.items())),
                                tuple(sorted(self.treatments)), self.outcome)
        hi, lo, stats, gv = fn(cols, valid)
        n_full = jnp.sum(gv.astype(jnp.int32))
        return hi, lo, stats, gv, n_full, jnp.asarray(False)

    # ------------------------------------------------------------- ingest
    def ingest(self, batch: Table, retract: bool = False) -> DeltaReport:
        """Fold one streamed batch into every materialized view.

        Work is O(batch/device + |delta groups| * #views) on the fast path;
        a full re-sort of a view's (tiny) stat table only happens when the
        delta introduces group keys that view has never seen.

        ``retract=True`` REMOVES previously ingested rows: every maintained
        stat is a count/sum, so retraction is exact sign-flipped delta
        maintenance — groups can lose overlap and flip back out of the
        matched set. Retracting rows that were never ingested would drive
        group counts negative and silently corrupt overlap masks, so it is
        detected (new keys, or any post-merge count below zero) and raises
        ``ValueError`` BEFORE any state is committed.

        The batch is padded to a power-of-two row bucket before it reaches
        any compiled pipeline (invalid padding rows contribute nothing),
        capping the fused program's retrace count for irregular streams at
        ~log2(max batch). Row accounting (``DeltaReport.n_rows``,
        ``n_rows_ingested``, the optional row log) stays on the original
        batch.

        With ``overlap=True`` (MVCC) this call only DISPATCHES: the fused
        program chains off the previous in-flight state while every query
        keeps serving the committed snapshot, the returned report is a
        lazy :class:`PendingIngest`, and the verdicts are checked at
        :meth:`commit` — zero host syncs on this path. Retraction flushes
        the pipeline first (its guard must validate eagerly against
        committed state).
        """
        self.validate_batch(batch, retract=retract)
        self._resolve_evictions()
        self._guard_retract_rows(retract)
        if self.overlap and retract:
            self.commit()
        self._maybe_renorm_touch()
        padded = self._bucket_pad(batch)
        if self.pipeline == "fused1":
            if self.overlap and not retract:
                return self._ingest_overlap(padded, orig=batch)
            return self._ingest_fused1(padded, retract, orig=batch)
        hi, lo, stats, gv, n_full, overflow = self._build_delta(padded)
        if self.pipeline == "planner":
            return self._ingest_fused(padded, hi, lo, stats, gv, n_full,
                                      overflow, retract, orig=batch)
        return self._ingest_unfused(padded, hi, lo, stats, gv, n_full,
                                    overflow, retract, orig=batch)

    def validate_batch(self, batch: Table, retract: bool = False) -> None:
        """Poison-batch quarantine: host-side schema/content validation of
        one streamed batch, run as the FIRST step of :meth:`ingest` —
        before any device dispatch, WAL append or state mutation — so a
        rejected batch provably leaves the committed state, the snapshot
        version, the estimate cache and any in-flight MVCC chain
        untouched, and never reaches a durable-engine journal.

        Rejected (raises :class:`PoisonBatchError`): missing or
        wrong-length columns, non-numeric dtypes, NaN/±inf outcomes,
        non-0/1 treatment indicators, non-finite covariates, and
        categorical codes outside ``[0, n_buckets)``.  Checks apply to
        VALID rows only — padding rows are masked everywhere downstream.
        The column pulls are explicit host reads of the caller's batch
        (never of in-flight engine state), so the overlap ingest path
        stays clean under ``jax.transfer_guard("disallow")`` and the
        host-sync counter."""
        del retract                      # same validation both directions
        cols = batch.columns
        missing = [c for c in self._row_cols if c not in cols]
        if missing:
            raise PoisonBatchError(f"batch is missing columns {missing}")
        n = batch.nrows
        valid = np.asarray(batch.valid)
        if valid.shape != (n,):
            raise PoisonBatchError(
                f"valid mask has shape {valid.shape}, want ({n},)")
        host = {}
        for c in self._row_cols:
            a = np.asarray(cols[c])
            if a.ndim != 1 or a.shape[0] != n:
                raise PoisonBatchError(
                    f"column {c!r} has shape {a.shape}, want ({n},)")
            if not (np.issubdtype(a.dtype, np.number)
                    or a.dtype == np.bool_):
                raise PoisonBatchError(
                    f"column {c!r} has non-numeric dtype {a.dtype}")
            host[c] = a
        v = valid.astype(bool)
        if not v.any():
            return
        y = host[self.outcome][v].astype(np.float64)
        if not np.isfinite(y).all():
            raise PoisonBatchError(
                f"non-finite outcome values in column {self.outcome!r}")
        for t in sorted(self.treatments):
            tv = host[t][v].astype(np.float64)
            if not (np.isfinite(tv).all()
                    and np.isin(tv, (0.0, 1.0)).all()):
                raise PoisonBatchError(
                    f"treatment column {t!r} must be a 0/1 indicator")
        for d, spec in self.specs.items():
            b = host[d][v].astype(np.float64)
            if not np.isfinite(b).all():
                raise PoisonBatchError(
                    f"non-finite values in covariate {d!r}")
            if spec.kind == "categorical" and (
                    (b < 0).any() or (b >= spec.n_buckets).any()):
                raise PoisonBatchError(
                    f"covariate {d!r} codes out of range "
                    f"[0, {spec.n_buckets})")

    @staticmethod
    def _bucket_pad(batch: Table) -> Table:
        """Pad a streamed batch to its power-of-two row bucket with
        invalid rows. Every engine and pipeline pads identically (the
        bucket is a pure function of the row count), so the streaming-
        propensity reservoir — whose uniform priorities depend on the
        padded draw SHAPE — stays bit-identical across engines, pipelines
        and mesh sizes; power-of-two buckets also absorb the mesh
        divisibility padding.

        Cost note: a non-bucket-sized batch pays one eager ``jnp.pad``
        per column here, OUTSIDE the fused program (the pads are async
        copies, no host sync, and invisible to the dispatch counter) —
        streams that deliver bucket-sized batches skip them entirely and
        keep the pure one-launch ingest."""
        pad = _bucket_rows(batch.nrows) - batch.nrows
        if pad == 0:
            return batch
        cols, valid = fused_mod.pad_tail(batch.columns, batch.valid, pad)
        return Table(columns=cols, valid=valid)

    # ------------------------------------------- single-dispatch pipeline
    def _view_table(self, name: str):
        """The stat table backing ``name`` (base or a view), in whichever
        layout (replicated Cuboid / PartitionedCuboid) the engine runs."""
        return self.base if name == BASE_VIEW else self.views[name].table

    def _pack_view_state(self):
        """The fused program's DONATED state pytree, built by reference
        from the engine's materialized views (zero copies)."""
        views = {}
        for name in (BASE_VIEW, *sorted(self.treatments)):
            tab = self._view_table(name)
            st = dict(hi=tab.key_hi, lo=tab.key_lo, stats=dict(tab.stats),
                      gv=tab.group_valid, touch=self._touch[name])
            if name != BASE_VIEW:
                st["keep"] = self.views[name].keep
            views[name] = st
        state = dict(views=views)
        if self.stream is not None:
            s = self.stream
            state["stream"] = dict(res=dict(s.columns), pri=s.priority,
                                   n=s.n, sums=dict(s.sums),
                                   sumsqs=dict(s.sumsqs))
        return state

    def _unpack_view_state(self, state) -> None:
        """Install a fused program's output state by reference swap. MUST
        run for every return (donation invalidated the old buffers, even
        when the program left the values unchanged)."""
        for name, st in state["views"].items():
            tab = dataclasses.replace(
                self._view_table(name), key_hi=st["hi"], key_lo=st["lo"],
                stats=st["stats"], group_valid=st["gv"])
            if name == BASE_VIEW:
                self.base = tab
            else:
                view = self.views[name]
                view.set_table(tab)
                view.keep = st["keep"]
            self._touch[name] = st["touch"]
        if "stream" in state:
            s = state["stream"]
            self.stream = dataclasses.replace(
                self.stream, columns=s["res"], priority=s["pri"], n=s["n"],
                sums=s["sums"], sumsqs=s["sumsqs"])
        self._post_state_swap()

    def _post_state_swap(self) -> None:
        """Invalidate layout-derived memos after ANY state mutation: the
        state version keys the partitioned canonical-reassembly memo
        (``_view_state``). The estimate cache is NOT version-checked —
        its validity is delta-predicate-based (:meth:`_invalidate` drops
        exactly the entries a committed delta touched, eviction clears
        it), so untouched subpopulation entries deliberately survive
        commits and keep serving with zero dispatches."""
        self._state_version += 1

    def _fused_caps(self) -> Tuple:
        return tuple(sorted(
            (name, self._view_table(name).capacity)
            for name in (BASE_VIEW, *self.treatments)))

    def _fused_view_dims(self) -> Tuple:
        return ((BASE_VIEW, tuple(self.codec.names)),
                *((t, self.views[t].dims) for t in sorted(self.treatments)))

    def _stream_names(self) -> Tuple[str, ...]:
        return self._row_cols if self.stream is not None else ()

    def _fused_program(self, retract: bool, donate: bool = True):
        mesh = self.mesh if self._mesh_ndev > 1 else None
        return fused_mod.get_fused_ingest(
            self.codec, tuple(sorted(self.specs.items())),
            tuple(sorted(self.treatments)), self._fused_view_dims(),
            self.outcome, self._fused_caps(), self._delta_cap, mesh,
            self.mesh_axis, self.use_pallas, retract, self._stream_names(),
            self.seed, donate)

    def _fallback_overflow(self, batch: Table, retract: bool,
                           orig: Table) -> DeltaReport:
        """Delta-capacity overflow: the in-program delta table missed
        groups. ``_delta_cap`` has already been grown; rebuild the delta
        (now at the larger capacity) and take the exact legacy path."""
        hi, lo, stats, gv, n_full, overflow = self._build_delta(batch)
        return self._ingest_unfused(batch, hi, lo, stats, gv, n_full,
                                    overflow, retract, orig=orig)

    def _grow_views(self, n_merged: Dict[str, int],
                    grew: Dict[str, bool]) -> None:
        """Capacity-doubling growth between fused dispatches: pad every
        overflowing view (invalid-key padding keeps tables sorted and
        binary-searchable) so the re-dispatched program — recompiled at the
        new granule count — fits the merged table."""
        for name, g in grew.items():
            if not g:
                continue
            tab = self._view_table(name)
            new_cap = _round_capacity(max(n_merged[name], 2 * tab.capacity),
                                      self.granule)
            padded = cube_mod._pad_cuboid(tab, new_cap)
            pad = new_cap - tab.capacity
            if name == BASE_VIEW:
                self.base = padded
            else:
                view = self.views[name]
                view.set_table(padded)
                view.keep = jnp.pad(view.keep, (0, pad))
            self._touch[name] = jnp.pad(self._touch[name], (0, pad))

    def _ingest_fused1(self, batch: Table, retract: bool,
                       orig: Table = None) -> DeltaReport:
        """ONE compiled dispatch per steady-state batch: run the fused
        program (state donated), fetch the verdict scalars once, commit by
        reference swap. Growth re-dispatches at a doubled capacity; only
        delta overflow leaves the device-resident path. ``batch`` is the
        bucket-padded table the program consumes; ``orig`` the caller's
        batch, which row accounting reports."""
        orig = batch if orig is None else orig
        cols = {c: batch.columns[c] for c in self._row_cols}
        valid = batch.valid
        # explicit device_put of the host scalars: the steady-state ingest
        # must stay clean under jax.transfer_guard("disallow"), and the
        # guard treats jnp.asarray/implicit jit-arg transfers as implicit
        counter = jax.device_put(np.int32(self._ingest_count + 1))
        for _ in range(32):
            prog = self._fused_program(retract)
            n_batches = jax.device_put(
                np.int32(0 if self.stream is None
                         else self.stream.n_batches))
            new_state, verdicts = prog(cols, valid, self._pack_view_state(),
                                       counter, n_batches)
            self._unpack_view_state(new_state)
            f = device_fetch(verdicts, label="ingest-verdict")
            if bool(f["overflow"]):
                self._delta_cap = _round_capacity(
                    max(int(f["n_full"]), 2 * self._delta_cap),
                    self.delta_granule)
                return self._fallback_overflow(batch, retract, orig)
            if retract and (not all(map(bool, f["ok"].values()))
                            or f["neg_min"] < -0.5):
                self._raise_bad_retraction()
            if not any(map(bool, f["grew"].values())):
                break
            self._grow_views({k: int(v) for k, v in f["n_merged"].items()},
                             {k: bool(v) for k, v in f["grew"].items()})
        else:
            raise RuntimeError("fused ingest: capacity growth diverged")
        # committed on device; mirror the host-side bookkeeping
        if self.rows is not None:
            self.rows = self.rows.append(
                orig.select(list(self.rows.table.columns)),
                granule=self.row_granule)
        if self.stream is not None:
            self.stream = dataclasses.replace(
                self.stream, n_batches=self.stream.n_batches + 1)
        self.n_rows_ingested += -orig.nrows if retract else orig.nrows
        self._ingest_count += 1
        invalidated = self._invalidate(
            np.asarray(f["gv"]).reshape(-1),
            lambda d: np.asarray(f["buckets"][d]).reshape(-1))
        return DeltaReport(n_rows=orig.nrows,
                           n_delta_groups=int(f["n_delta"]),
                           fast_path={k: bool(v) for k, v in f["ok"].items()},
                           invalidated=invalidated)

    # ------------------------------------------- MVCC overlap (pipelined)
    @staticmethod
    def _start_async_fetch(tree) -> None:
        """Kick off device->host copies without blocking (the commit-time
        ``device_get`` then finds them already in flight)."""
        for leaf in jax.tree_util.tree_leaves(tree):
            start = getattr(leaf, "copy_to_host_async", None)
            if start is not None:
                start()

    def _ingest_overlap(self, batch: Table, orig: Table) -> PendingIngest:
        """Dispatch one MVCC ingest hop WITHOUT any host sync.

        The program's input is the tail of the in-flight chain (or the
        committed snapshot when the chain is empty — that first hop
        compiles with ``donate=False`` so the committed buffers stay
        alive for serving and rollback); its output becomes the new tail.
        Verdicts stay on device (async host copy started) until
        :meth:`commit`. Device-side gating makes the chain safe to run
        blind: a hop that overflowed or needed growth passes its input
        state through unchanged, so later hops always compute on a
        correct base and commit-time rollback simply replays every
        in-flight batch in order."""
        if len(self._inflight) >= self.max_inflight:
            self.commit()   # bounded pipeline depth: documented sync point
        depth = len(self._inflight)
        cols = {c: batch.columns[c] for c in self._row_cols}
        valid = batch.valid
        counter = jax.device_put(
            np.int32(self._ingest_count + depth + 1))
        n_batches = jax.device_put(np.int32(
            0 if self.stream is None else self.stream.n_batches + depth))
        src = (self._inflight[-1].state if depth
               else self._pack_view_state())
        prog = self._fused_program(False, donate=depth > 0)
        new_state, verdicts = prog(cols, valid, src, counter, n_batches)
        self._start_async_fetch(verdicts)
        pending = PendingIngest(self, orig.nrows)
        self._inflight.append(_InFlight(state=new_state, verdicts=verdicts,
                                        batch=batch, orig=orig,
                                        pending=pending))
        return pending

    def commit(self) -> List[DeltaReport]:
        """MVCC commit point: check every in-flight verdict with ONE
        ``device_get`` and atomically advance the committed snapshot.

        Clean chain (no delta overflow, no capacity growth): install the
        LAST in-flight state by reference swap — the intermediate states
        were consumed device-side by donation — bump the version once per
        batch, and run each batch's host bookkeeping and delta-predicate
        cache invalidation in order. Any failed hop instead ROLLS BACK to
        the committed snapshot (its buffers were never donated) and
        REPLAYS all in-flight batches synchronously in original order,
        which preserves the float merge order — every committed version
        is bitwise identical to the synchronous pipeline's. Returns the
        per-batch reports (also filled into each :class:`PendingIngest`).
        No-op when nothing is in flight."""
        entries = self._inflight
        if not entries:
            return []
        self._inflight = []
        fetched = device_fetch([e.verdicts for e in entries],
                               label="commit")
        n_good = 0
        for f in fetched:
            if bool(f["overflow"]) or any(map(bool, f["grew"].values())):
                break
            n_good += 1
        if n_good < len(entries):
            # rollback-and-replay: the committed buffers are alive (first
            # hop never donates), every in-flight output is discarded
            reports = []
            for e in entries:
                rep = self._ingest_fused1(e.batch, False, orig=e.orig)
                e.pending.report = rep
                reports.append(rep)
            return reports
        self._unpack_view_state(entries[-1].state)   # bumps version by 1
        self._state_version += len(entries) - 1      # ... one per batch
        if self.stream is not None:
            self.stream = dataclasses.replace(
                self.stream, n_batches=self.stream.n_batches + len(entries))
        reports = []
        for e, f in zip(entries, fetched):
            if self.rows is not None:
                self.rows = self.rows.append(
                    e.orig.select(list(self.rows.table.columns)),
                    granule=self.row_granule)
            self.n_rows_ingested += e.orig.nrows
            self._ingest_count += 1
            invalidated = self._invalidate(
                np.asarray(f["gv"]).reshape(-1),
                lambda d, f=f: np.asarray(f["buckets"][d]).reshape(-1))
            rep = DeltaReport(
                n_rows=e.orig.nrows, n_delta_groups=int(f["n_delta"]),
                fast_path={k: bool(v) for k, v in f["ok"].items()},
                invalidated=invalidated)
            e.pending.report = rep
            reports.append(rep)
        return reports

    def snapshot_version(self) -> int:
        """The committed MVCC snapshot version queries serve RIGHT NOW.

        Settles any lazily pending eviction first (its deferred shrink
        pass is a commit), so two reads with no intervening commit are
        guaranteed equal — the serving layer's one-version-per-wave
        invariant reads this, never ``_state_version`` directly.
        In-flight overlap ingests do NOT move it; :meth:`commit` does."""
        self._resolve_evictions()
        return self._state_version

    # -------------------------------------------------- touch-stamp renorm
    def _maybe_renorm_touch(self) -> None:
        """int32 wraparound guard for the eviction stamps: when the ingest
        counter nears 2^31, shift every live stamp (and the counter) down.
        Eviction compares differences only, so TTL semantics are unchanged
        — exactly for ``ttl < TOUCH_CLAMP_AGE`` (~2^30 ingests), and
        conservatively (groups kept, never spuriously evicted) beyond.
        The threshold compare is host-integer only (sync-free); when it
        fires in overlap mode the pipeline is flushed first — the renorm
        rewrites the committed touch stamps."""
        if (self._ingest_count + len(self._inflight)
                < fused_mod.TOUCH_RENORM_LIMIT):
            return
        self.commit()
        self._renorm_touch()

    def _renorm_touch(self) -> None:
        touch = {k: np.asarray(v) for k, v in self._touch.items()}
        gvs = {name: np.asarray(self._view_table(name).group_valid)
               for name in touch}
        mins = [int(t[gvs[n]].min()) for n, t in touch.items()
                if gvs[n].any()]
        # shift by the min live stamp (exact), but at least down to
        # TOUCH_CLAMP_AGE: a cold group stamped ages ago must not pin the
        # shift at ~0 and turn renormalization into a per-ingest full
        # host sync. Stamps older than the clamp window collapse to 0 =
        # "at least TOUCH_CLAMP_AGE ingests old".
        m = min(mins + [self._ingest_count])
        m = max(m, self._ingest_count - fused_mod.TOUCH_CLAMP_AGE)
        if m <= 0:
            return
        self._touch = {
            n: self._place(jnp.asarray(
                np.where(gvs[n], np.maximum(t - m, 0), 0).astype(np.int32)))
            for n, t in touch.items()}
        self._ingest_count -= m

    def _place(self, tree):
        """State placement hook — identity for the replicated layout; the
        partitioned engine shards (P, ...) leaves over the mesh."""
        return tree

    def _commit_rows(self, batch: Table, retract: bool,
                     orig: Table = None) -> None:
        """Row log / streaming-propensity / counter updates shared by both
        ingest paths. Called only after the retraction guard has passed.
        ``batch`` is the bucket-padded table (the streaming-propensity
        update MUST see the padded draw shape — same as the fused1
        in-program update); row accounting uses ``orig``."""
        orig = batch if orig is None else orig
        if self.rows is not None:
            self.rows = self.rows.append(
                orig.select(list(self.rows.table.columns)),
                granule=self.row_granule)
        if self.stream is not None:
            self.stream = self.stream.update(
                {c: batch.columns[c] for c in self._row_cols},
                batch.valid, retract=retract)
        self.n_rows_ingested += -orig.nrows if retract else orig.nrows
        self._ingest_count += 1

    def _guard_retract_rows(self, retract: bool) -> None:
        if retract and self.rows is not None:
            raise ValueError("retract=True is not supported with "
                             "keep_rows=True (the row log is append-only)")

    def _raise_bad_retraction(self) -> None:
        raise ValueError(
            "retraction of rows that were never ingested: the delta "
            "contains unknown group keys or would drive a group count "
            "negative; engine state is unchanged")

    def _ingest_fused(self, batch: Table, hi, lo, stats, gv, n_full,
                      overflow, retract: bool,
                      orig: Table = None) -> DeltaReport:
        orig = batch if orig is None else orig
        dcap = self._delta_cap
        tnames = tuple(sorted(self.treatments))
        plan = _plan_ingest(
            hi, lo, stats, gv,
            self.base.key_hi, self.base.key_lo, self.base.stats,
            {t: self.views[t].cuboid.key_hi for t in tnames},
            {t: self.views[t].cuboid.key_lo for t in tnames},
            {t: self.views[t].cuboid.stats for t in tnames},
            {t: self.views[t].cuboid.group_valid for t in tnames},
            {t: self.views[t].keep for t in tnames},
            codec=self.codec, tnames=tnames,
            vdims=tuple(self.views[t].dims for t in tnames),
            retract=retract, use_pallas=self.use_pallas, dcap=dcap)
        # THE one host sync of a fast-path ingest: every decision at once
        fetched = device_fetch(dict(
            overflow=overflow, n_full=n_full, ok_b=plan["ok_b"],
            ok_v={t: plan["views"][t]["ok"] for t in tnames},
            neg_min=plan["neg_min"], n_delta=plan["n_delta"],
            gv=plan["gv"], buckets=plan["buckets"]))
        fetched["overflow"] = bool(fetched["overflow"]) or (
            int(fetched["n_full"]) > dcap)
        d_hi, d_lo = plan["d_keys"]
        d_gv = plan["gv"]
        if fetched["overflow"]:
            # the sliced delta missed groups: fall back to the exact
            # host-compacted path and grow the delta capacity geometrically
            self._delta_cap = _round_capacity(
                max(int(n_full), 2 * self._delta_cap), self.delta_granule)
            return self._ingest_unfused(batch, hi, lo, stats, gv, n_full,
                                        overflow, retract, orig=orig)
        all_fast = bool(fetched["ok_b"]) and all(
            bool(v) for v in fetched["ok_v"].values())
        if retract and (not all_fast or fetched["neg_min"] < -0.5):
            self._raise_bad_retraction()
        counter = self._ingest_count + 1
        fast: Dict[str, bool] = {}
        d_base = cube_mod.Cuboid(
            codec=self.codec, key_hi=d_hi, key_lo=d_lo,
            stats=plan["d_stats"], group_valid=d_gv, treatments=tnames)
        if fetched["ok_b"]:
            old = self.base
            self.base = dataclasses.replace(old, stats=plan["merged_b"])
            self._touch[BASE_VIEW] = _stamp_touch(
                self._touch[BASE_VIEW], plan["pos_b"], d_gv, counter)
        else:
            old = self.base
            self.base, pos_b, _ = cube_mod.merge_delta(
                old, d_base, granule=self.granule,
                use_pallas=self.use_pallas, fast=False)
            self._touch[BASE_VIEW] = _stamp_touch(
                _remap_touch(old, self.base, self._touch[BASE_VIEW]),
                pos_b, d_gv, counter)
        fast[BASE_VIEW] = bool(fetched["ok_b"])
        for t in tnames:
            view = self.views[t]
            vplan = plan["views"][t]
            v_gv = vplan["delta"][3]
            if fetched["ok_v"][t]:
                view.cuboid = dataclasses.replace(view.cuboid,
                                                  stats=vplan["stats"])
                view.keep = vplan["keep"]
                self._touch[t] = _stamp_touch(self._touch[t], vplan["pos"],
                                              v_gv, counter)
            else:
                v_hi, v_lo, v_stats, _ = vplan["delta"]
                d_view = cube_mod.Cuboid(
                    codec=view.cuboid.codec, key_hi=v_hi, key_lo=v_lo,
                    stats=v_stats, group_valid=v_gv, treatments=tnames)
                old_v = view.cuboid
                merged, pos_v, _ = cube_mod.merge_delta(
                    old_v, d_view, granule=self.granule,
                    use_pallas=self.use_pallas, fast=False)
                nt = merged.stats[f"t_{t}"]
                view.keep = overlap_keep(merged.group_valid, nt,
                                         merged.stats["one"] - nt)
                view.cuboid = merged
                self._touch[t] = _stamp_touch(
                    _remap_touch(old_v, merged, self._touch[t]),
                    pos_v, v_gv, counter)
            fast[t] = bool(fetched["ok_v"][t])
        self._commit_rows(batch, retract, orig=orig)
        self._post_state_swap()
        invalidated = self._invalidate(
            fetched["gv"], lambda d: fetched["buckets"][d])
        return DeltaReport(n_rows=orig.nrows,
                           n_delta_groups=int(fetched["n_delta"]),
                           fast_path=fast, invalidated=invalidated)

    def _ingest_unfused(self, batch: Table, hi, lo, stats, gv, n_full,
                        overflow, retract: bool,
                        orig: Table = None) -> DeltaReport:
        """Legacy merge loop: one blocking device->host read per merge (the
        fast/slow decision), plus host-side delta compaction. Kept as the
        exact fallback for delta-capacity overflow and as the measurable
        baseline for the fused path (``bench_online.py``)."""
        orig = batch if orig is None else orig
        tnames = tuple(sorted(self.treatments))
        if bool(overflow):
            # a local shard overflowed: the gathered table is incomplete,
            # so rebuild the delta exactly on one device
            d_base = cube_mod.delta_cuboid(batch, self.specs, tnames,
                                           self.outcome,
                                           granule=self.delta_granule)
        else:
            d_base = cube_mod.compact_cuboid(
                cube_mod.Cuboid(codec=self.codec, key_hi=hi, key_lo=lo,
                                stats=stats, group_valid=gv,
                                treatments=tnames),
                granule=self.delta_granule)
        if retract:
            d_base = dataclasses.replace(
                d_base, stats={k: -v for k, v in d_base.stats.items()})
        fast: Dict[str, bool] = {}
        merged_base, pos_b, fast_b = cube_mod.merge_delta(
            self.base, d_base, granule=self.granule,
            use_pallas=self.use_pallas)
        if retract:
            counts = np.stack(
                [np.asarray(merged_base.stats["one"])]
                + [np.asarray(merged_base.stats[f"t_{t}"]) for t in tnames])
            if not fast_b or counts.min() < -0.5:
                self._raise_bad_retraction()
        counter = self._ingest_count + 1
        old_base = self.base
        self.base, fast[BASE_VIEW] = merged_base, fast_b
        touch_b = (self._touch[BASE_VIEW] if fast_b else
                   _remap_touch(old_base, merged_base,
                                self._touch[BASE_VIEW]))
        self._touch[BASE_VIEW] = _stamp_touch(touch_b, pos_b,
                                              d_base.group_valid, counter)
        # lattice propagation: the delta itself rolls up to each view's dims
        for t, view in self.views.items():
            d_view = cube_mod.compact_cuboid(
                cube_mod.rollup(d_base, view.dims),
                granule=self.delta_granule)
            old_v = view.cuboid
            merged, pos, was_fast = cube_mod.merge_delta(
                old_v, d_view, granule=self.granule,
                use_pallas=self.use_pallas)
            nt = merged.stats[f"t_{t}"]
            nc = merged.stats["one"] - nt
            if was_fast:
                # O(|delta groups|): flip only the touched groups
                view.keep = update_overlap(view.keep, merged.group_valid,
                                           nt, nc, pos)
            else:
                view.keep = overlap_keep(merged.group_valid, nt, nc)
            view.cuboid = merged
            touch_v = (self._touch[t] if was_fast else
                       _remap_touch(old_v, merged, self._touch[t]))
            self._touch[t] = _stamp_touch(touch_v, pos,
                                          d_view.group_valid, counter)
            fast[t] = was_fast
        self._commit_rows(batch, retract, orig=orig)
        self._post_state_swap()
        gv_host = np.asarray(d_base.group_valid)
        buckets: Dict[str, np.ndarray] = {}

        def dim_buckets(dim: str) -> np.ndarray:
            if dim not in buckets:
                buckets[dim] = np.asarray(self.codec.extract(
                    d_base.key_hi, d_base.key_lo, dim))
            return buckets[dim]

        invalidated = self._invalidate(gv_host, dim_buckets)
        return DeltaReport(n_rows=orig.nrows,
                           n_delta_groups=int(np.sum(gv_host)),
                           fast_path=fast, invalidated=invalidated)

    def _invalidate(self, gv: np.ndarray,
                    dim_buckets: Callable[[str], np.ndarray]) -> Tuple:
        """Drop exactly the cache entries whose group predicate the delta
        touched: an unrestricted estimate is touched by any delta; a
        sub-population estimate only if some delta group satisfies its
        (conjunctive) bucket predicate. Operates on host arrays the caller
        already fetched — no extra device sync."""
        if not gv.any():
            return ()
        dropped: List[Tuple] = []
        for key in list(self._cache):
            _, subpop = key
            if subpop is None:
                touched = True
            else:
                sat = gv.copy()
                for dim, allowed in subpop:
                    sat &= np.isin(dim_buckets(dim), list(allowed))
                touched = bool(sat.any())
            if touched:
                dropped.append(key)
                del self._cache[key]
        return tuple(dropped)

    # ----------------------------------------------------------- eviction
    def _evict_n_parts(self) -> int:
        """Partition count handed to the fused eviction program: 0 marks
        the replicated (C,) layout, >0 the (P, C) partitioned one."""
        return 0

    def evict(self, ttl: int) -> EvictReport:
        """Drop every group whose last delta touch is more than ``ttl``
        ingests old — the bounded-state escape hatch for streams whose key
        space grows without bound. Estimates afterwards cover only the
        surviving (recently active) groups, so this deliberately trades
        the offline-equivalence guarantee for bounded memory; re-ingesting
        an evicted key later resurrects it as a fresh group.

        Runs as ONE donated device program over every view (per-partition
        compaction kernels on the partitioned layout — no host round trip
        per view; the compaction is an exact re-sort GATHER at the current
        capacity, so surviving stats are bit-identical). When the live
        occupancy of a view falls below 1/4 of its (grown) capacity, a
        shrink pass slices the compacted tables down to a halved-or-
        smaller capacity and the next ingest recompiles at the smaller
        granule count — long-lived streams whose live set collapses
        reclaim device memory (``state_bytes()`` decreases).

        Returns a LAZY :class:`EvictReport` ({view name: groups evicted}):
        the count scalars are fetched — and the estimate-cache
        invalidation is applied, scoped to the views with NONZERO evicted
        counts — at the engine's next sync point or on first access,
        whichever comes first, so this call never stalls behind an
        in-flight ingest dispatch. In overlap mode the pipeline is
        committed first (eviction rewrites the committed snapshot)."""
        self.commit()
        self._resolve_evictions()
        mesh = self.mesh if self._mesh_ndev > 1 else None
        prog = fused_mod.get_fused_evict(
            tuple(sorted(self.treatments)), self._fused_caps(),
            self._evict_n_parts(), mesh, self.mesh_axis,
            self.stream is not None)
        new_state, counts, live = prog(
            self._pack_view_state(),
            jax.device_put(np.int32(self._ingest_count - ttl)))
        self._unpack_view_state(new_state)
        self._start_async_fetch((counts, live))
        report = EvictReport(self)
        self._pending_evict = (counts, live, report)
        return report

    def _resolve_evictions(self) -> None:
        """Settle a lazily pending :meth:`evict`: ONE ``device_get`` for
        the count/occupancy scalars, then the cache invalidation scoped
        to views that actually lost groups (untouched-view entries keep
        serving at zero dispatches — evicting only the base view never
        drops a treatment-view estimate) and the deferred capacity-shrink
        pass. Every cache probe, ingest, commit and state accessor calls
        this first, so no stale entry is ever served and the next
        dispatch compiles against settled shapes. Idempotent no-op when
        nothing is pending."""
        if self._pending_evict is None:
            return
        counts, live, report = self._pending_evict
        self._pending_evict = None
        fetched = device_fetch(dict(counts=counts, live=live),
                               label="evict")
        evicted = {k: int(v) for k, v in fetched["counts"].items()}
        report._counts = evicted
        touched = {name for name, n in evicted.items() if n}
        if touched:
            for key in list(self._cache):
                if key[0] in touched:
                    del self._cache[key]
        self._maybe_shrink({k: int(v) for k, v in fetched["live"].items()})

    # ------------------------------------------------ capacity shrink pass
    def _shrink_granule(self) -> int:
        """Capacity floor of the shrink pass (per partition when the
        layout is partitioned)."""
        return self.granule

    def _shrink_view(self, name: str, new_cap: int) -> None:
        """Slice one view's compacted tables (valid groups are a sorted
        prefix after eviction, so slicing is lossless) down to
        ``new_cap`` slots."""
        tab = self._view_table(name)
        sliced = cube_mod.slice_cuboid(tab, new_cap)
        if name == BASE_VIEW:
            self.base = sliced
        else:
            view = self.views[name]
            view.set_table(sliced)
            view.keep = view.keep[:new_cap]
        self._touch[name] = self._touch[name][:new_cap]

    def _maybe_shrink(self, live_max: Dict[str, int]) -> None:
        """Reclaim capacity after eviction: when a view's live occupancy
        (max per partition on the (P, C) layout) fell below 1/4 of its
        grown capacity, compact into a halved-or-smaller capacity (floor:
        the allocation granule, headroom: 2x live rounded up) so the next
        fused dispatch recompiles at the smaller shape and device memory
        is actually returned."""
        shrunk = False
        for name, live in live_max.items():
            cap = self._view_table(name).capacity
            gran = self._shrink_granule()
            if cap <= gran or 4 * live > cap:
                continue
            new_cap = max(gran, _round_capacity(max(2 * live, 1), gran))
            if new_cap >= cap:
                continue
            self._shrink_view(name, new_cap)
            shrunk = True
        if shrunk:
            self._post_state_swap()

    # ------------------------------------------------------------ queries
    def _view_state(self, treatment: str
                    ) -> Tuple[cube_mod.Cuboid, jnp.ndarray]:
        """(stat table, overlap mask) an ``assemble``-path query runs on —
        the replicated view directly; the partitioned engine overrides
        this with the canonical cross-partition reassembly (one compiled
        dispatch, memoized per state version)."""
        view = self.views[treatment]
        return view.cuboid, view.keep

    def _fused_estimate(self, treatment: str,
                        subpopulation: SubPop) -> ATEEstimate:
        """One-dispatch fused query over the RAW materialized state. The
        replicated layout feeds the (C,) view arrays straight in; the
        partitioned engine overrides this with the (P, C) state
        (shard_map body on a mesh)."""
        view = self.views[treatment]
        return _run_fused_query(view.cuboid, view.keep, treatment,
                                subpopulation)

    def _estimate(self, treatment: str, subpopulation: SubPop,
                  pipeline: str = None) -> ATEEstimate:
        """Uncached estimate through the chosen query pipeline (device
        scalars). Both pipelines share the canonical estimator body, so
        they return bit-identical results — the differential harness
        cross-checks them against the oracle on every stream."""
        pipeline = pipeline or self.query_pipeline
        if pipeline == "fused":
            return self._fused_estimate(treatment, subpopulation)
        cub, keep = self._view_state(treatment)
        return _estimate_view(cub, keep, treatment, subpopulation)

    def ate(self, treatment: str, subpopulation: SubPop = None
            ) -> ATEEstimate:
        """Online causal query from materialized state: ONE compiled
        dispatch + one scalar-sized ``device_get`` (the fused query
        program — subpopulation filter, keep mask and canonical reduction
        all in-program, per-partition/1-per-device work on a mesh), or the
        ``assemble`` baseline when selected. Repeated queries hit the
        host-resident cache with ZERO dispatches and zero transfers;
        validity is delta-predicate-based (a committed batch drops
        exactly the entries whose subpopulation it touched, eviction
        clears the cache — see :meth:`_invalidate`).
        Includes the Neyman within-group variance, carried by the cuboid's
        second-moment (``yy``) stat columns. Estimates are a deterministic
        function of the canonical (key-sorted) group content alone, so
        identical maintained stats give bit-identical results regardless
        of engine layout, query pipeline or mesh size (see
        :func:`_estimate_view`). For a WINDOW of heterogeneous queries
        use :meth:`ate_batch` (one dispatch for all of them, same cache,
        bitwise-identical answers)."""
        self._resolve_evictions()
        key = (treatment, _freeze_subpop(subpopulation))
        if key in self._cache:
            self.cache_hits += 1
            return self._cache[key]
        self.cache_misses += 1
        est = self._estimate(treatment, subpopulation)
        # THE one host sync of an uncached query: every scalar at once.
        # state_version tags the committed MVCC snapshot this estimate
        # was computed at (a cache hit keeps the version it was COMPUTED
        # at — the entry surviving later commits means the delta
        # predicate proved those commits did not touch it).
        est = ATEEstimate(**device_fetch(dict(
            ate=est.ate, att=est.att,
            n_matched_treated=est.n_matched_treated,
            n_matched_control=est.n_matched_control,
            n_groups=est.n_groups, variance=est.variance),
            label="query"), state_version=self._state_version)
        self._cache[key] = est
        return est

    def cached_estimate(self, treatment: str, subpopulation: SubPop = None
                        ) -> Optional[ATEEstimate]:
        """Cache-only probe: the host-resident estimate for this query if
        one is live, else None — NEVER dispatches (a lazily pending
        eviction is settled first, so a stale entry for an evicted view
        can never be served). The serving layer uses this so cache hits
        are answered without occupying a batch slot."""
        self._resolve_evictions()
        return self._cache.get((treatment, _freeze_subpop(subpopulation)))

    # ------------------------------------------------- batched query path
    def _spec_cards(self) -> Tuple:
        """The engine's base-dim ``(dim, cardinality)`` schema — the
        static word layout every encoded query spec of this engine shares
        (:func:`repro.core.fused.spec_word_layout`)."""
        return tuple((d, self.specs[d].n_buckets) for d in sorted(self.specs))

    def _batch_view_schema(self) -> Tuple:
        """Views in view-id order as ``(treatment, codec)`` — the static
        half of the batched query program's cache key."""
        return tuple((t, self.views[t].table.codec)
                     for t in sorted(self.treatments))

    def _view_query_args(self, treatment: str) -> Tuple:
        """One view's raw state in the batched program's layout: keys,
        ROLE-ordered stat columns, group validity, overlap keep."""
        view = self.views[treatment]
        tab = view.table
        stats = tuple(tab.stats[k]
                      for k in fused_mod.query_stat_names(treatment))
        return (tab.key_hi, tab.key_lo, stats, tab.group_valid, view.keep)

    def _batch_query_flags(self) -> Tuple:
        """(mesh, mesh_axis, partitioned) the batched program compiles
        under — replicated views never shard the query."""
        return None, self.mesh_axis, False

    def _normalize_spec(self, spec) -> Tuple[str, Tuple, int]:
        """Accept a ``QuerySpec``-shaped object (``treatment``,
        ``subpopulation``, optional ``estimand`` attributes) or a plain
        ``(treatment, subpopulation)`` pair; returns (treatment, frozen
        subpop, estimand id) and validates against the schema."""
        if isinstance(spec, tuple):
            treatment, sub = spec
            estimand = "ate"
        else:
            treatment = spec.treatment
            sub = spec.subpopulation
            estimand = getattr(spec, "estimand", "ate")
        if treatment not in self.treatments:
            raise KeyError(f"unknown treatment {treatment!r}")
        if estimand not in fused_mod.ESTIMAND_IDS:
            raise ValueError(f"unknown estimand {estimand!r}")
        frozen = _freeze_subpop(sub)
        if frozen:
            vdims = set(self.views[treatment].dims)
            bad = [d for d, _ in frozen if d not in vdims]
            if bad:
                raise ValueError(
                    f"subpopulation dims {bad} not materialized in view "
                    f"{treatment!r} (dims {sorted(vdims)}); add them to "
                    f"query_dims")
        return treatment, frozen, fused_mod.ESTIMAND_IDS[estimand]

    def _batched_estimate(self, keys: Sequence[Tuple[str, Tuple, int]]
                          ) -> List[ATEEstimate]:
        """Uncached batched estimate: encode the specs into the device
        spec table, pad to the pow2 spec bucket, run ONE compiled batched
        query dispatch, fetch the ``(B,)`` scalar vectors with one
        ``device_get``. Bitwise identical per spec to the B=1 fused
        path (shared canonical estimator body + padding-invariant
        canonical reduce)."""
        cards = self._spec_cards()
        view_ids = {t: i for i, t in enumerate(sorted(self.treatments))}
        rows = [fused_mod.encode_query_spec(cards, view_ids[t], est, sub)
                for t, sub, est in keys]
        bucket = _bucket_specs(len(rows))
        width = rows[0].shape[0]
        table = np.zeros((bucket, width), np.uint32)
        table[:len(rows)] = np.stack(rows)
        mesh, mesh_axis, partitioned = self._batch_query_flags()
        prog = fused_mod.get_fused_query_batch(
            self._batch_view_schema(), cards, bucket, mesh, mesh_axis,
            partitioned)
        states = tuple(self._view_query_args(t)
                       for t in sorted(self.treatments))
        out = device_fetch(prog(states, jnp.asarray(table)), label="query")
        record_batch(len(rows), label="query")
        return [ATEEstimate(
            ate=out["ate"][i], att=out["att"][i],
            n_matched_treated=out["n_matched_treated"][i],
            n_matched_control=out["n_matched_control"][i],
            n_groups=out["n_groups"][i], variance=out["variance"][i],
            state_version=self._state_version)
            for i in range(len(rows))]

    def ate_batch(self, specs: Sequence) -> List[ATEEstimate]:
        """Answer MANY heterogeneous causal queries with at most ONE
        compiled dispatch. ``specs`` mixes treatments (view choice),
        subpopulation predicates and estimands freely — each is encoded
        into a fixed-width device-resident spec row
        (:func:`repro.core.fused.encode_query_spec`) and the whole batch
        runs through the batched query program
        (:func:`repro.core.fused.get_fused_query_batch`), padded to a
        pow2 spec bucket so batch-size jitter never retraces.

        Cache integration mirrors :meth:`ate`: specs whose
        ``(treatment, subpopulation)`` estimate is cached are answered
        host-side with zero dispatches; identical in-flight specs in one
        batch window are DEDUPED to a single slot (``batch_deduped``
        counts the collapsed duplicates — e.g. many dashboards asking the
        same question); every computed estimate lands in the same cache,
        with the same delta-predicate invalidation on later ingests.
        Results are bitwise identical to B sequential uncached
        :meth:`ate` calls, in input order. Each element of ``specs`` is a
        ``QuerySpec``-shaped object or a ``(treatment, subpopulation)``
        pair."""
        self._resolve_evictions()
        resolved = [self._normalize_spec(s) for s in specs]
        out: List[Optional[ATEEstimate]] = [None] * len(resolved)
        miss_keys: List[Tuple[str, Tuple, int]] = []
        slot_of: Dict[Tuple, int] = {}
        slot_idx: List[Tuple[int, int]] = []   # (spec index, slot)
        for i, (t, sub, est) in enumerate(resolved):
            cache_key = (t, sub)
            hit = self._cache.get(cache_key)
            if hit is not None:
                self.cache_hits += 1
                out[i] = hit
                continue
            slot = slot_of.get(cache_key)
            if slot is None:
                slot = len(miss_keys)
                slot_of[cache_key] = slot
                miss_keys.append((t, sub, est))
                self.cache_misses += 1
            else:
                self.batch_deduped += 1
            slot_idx.append((i, slot))
        if miss_keys:
            results = self._batched_estimate(miss_keys)
            for (t, sub, _), est in zip(miss_keys, results):
                self._cache[(t, sub)] = est
            for i, slot in slot_idx:
                out[i] = results[slot]
        return out

    def cem_groups(self, treatment: str) -> CEMGroups:
        """Current CEM group stats with the incrementally maintained
        overlap mask (same shape the offline path produces)."""
        self._resolve_evictions()
        cub, keep = self._view_state(treatment)
        nt = cub.stats[f"t_{treatment}"]
        nc = cub.stats["one"] - nt
        yt = cub.stats[f"yt_{treatment}"]
        dummy = groupby.Grouping(
            perm=jnp.zeros((cub.capacity,), jnp.int32),
            inv_perm=jnp.zeros((cub.capacity,), jnp.int32),
            seg_ids=jnp.zeros((cub.capacity,), jnp.int32),
            group_hi=cub.key_hi, group_lo=cub.key_lo,
            group_valid=cub.group_valid, n_groups=cub.n_groups())
        return CEMGroups(grouping=dummy, keep=keep, n_treated=nt,
                         n_control=nc, sum_y_t=yt,
                         sum_y_c=cub.stats["y"] - yt)

    def _rowlookup_query(self, treatment: str):
        """(program, state args) of the one-dispatch row lookup over the
        RAW materialized state (replicated layout: broadcast binary
        search; partitioned override: per-partition probe, routed over the
        mesh)."""
        view = self.views[treatment]
        tab = view.table
        vspecs = tuple(sorted((d, self.specs[d]) for d in view.dims))
        prog = fused_mod.get_fused_rowlookup(tab.codec, vspecs, 0, None,
                                             self.mesh_axis)
        return prog, (tab.key_hi, tab.key_lo, view.keep)

    def matched_rows(self, treatment: str, table: Table,
                     pipeline: str = None) -> jnp.ndarray:
        """Row-level matched mask for ``table`` against current state.

        The fused pipeline (default) runs coarsen + pack + lookup + keep
        mask as ONE compiled dispatch straight on the materialized state;
        on the partitioned layout each probe row hashes to its owning
        partition and binary-searches only that partition's table — on a
        mesh via the ROUTED lookup (one all-to-all out, local search, one
        all-to-all back), so no device ever reassembles the view. The
        ``assemble`` baseline keeps the broadcast-table search of the
        planner era. Both return identical masks (exact boolean
        semantics)."""
        self._resolve_evictions()
        pipeline = pipeline or self.query_pipeline
        if pipeline == "assemble":
            cub, keep = self._view_state(treatment)
            vspecs = {d: self.specs[d] for d in self.views[treatment].dims}
            _, hi, lo = pack_keys(table, vspecs, codec=cub.codec)
            pos, found = groupby.lookup_rows_in_table(
                hi, lo, cub.key_hi, cub.key_lo)
            return table.valid & found & keep[pos]
        prog, state_args = self._rowlookup_query(treatment)
        cols = {d: table.columns[d] for d in self.views[treatment].dims}
        return prog(cols, table.valid, *state_args)

    # --------------------------------------------------------- propensity
    def refresh_propensity(self, treatment: str, features: Sequence[str],
                           step_budget: int = 4, cold_iters: int = 32,
                           ridge: float = 1e-4) -> LogisticModel:
        """(Re)fit the propensity model: a cold Newton fit the first time,
        afterwards warm-started from the previous coefficients with
        ``step_budget`` iterations. With ``keep_rows=True`` the fit runs
        over the full row log; otherwise it runs over the engine's
        streaming sufficient statistics — the bounded uniform reservoir
        for rows, standardized by the exact stream-wide moment
        accumulators — so no unbounded row log is ever needed."""
        prev = self.models.get(treatment)
        n_iter = step_budget if prev is not None else cold_iters
        if self.rows is not None:
            tbl = self.rows.table
            X = design_matrix(tbl, features)
            model = fit_logistic(X, tbl[treatment], tbl.valid,
                                 n_iter=n_iter, ridge=ridge, init=prev)
        elif self.stream is not None:
            cols, rvalid = self.stream.reservoir()
            X = jnp.stack([cols[f] for f in features], axis=-1)
            # stream-exact moments standardize the COLD fit; warm refits
            # keep the previous model's frozen standardization so the
            # coefficients stay in one basis across refreshes
            moments = (self.stream.moments(features) if prev is None
                       else None)
            model = fit_logistic(X, cols[treatment], rvalid,
                                 n_iter=n_iter, ridge=ridge, init=prev,
                                 moments=moments)
        else:
            raise ValueError("refresh_propensity needs keep_rows=True or "
                             "reservoir_size > 0")
        self.models[treatment] = model
        return model

    # ------------------------------------------- durability (canonical)
    def schema_fingerprint(self) -> str:
        """Stable description of the engine's coarsening schema — a
        checkpoint taken under one fingerprint only restores into engines
        with the SAME fingerprint (layout/partition count/mesh are free to
        differ; the schema is not)."""
        return repr((tuple(sorted(self.specs.items())),
                     tuple(sorted(self.treatments.items())), self.outcome,
                     tuple(sorted(self.query_dims)), self.seed,
                     0 if self.stream is None else self.stream.capacity))

    def export_canonical(self) -> dict:
        """Layout-free snapshot of the committed engine state, on host.

        Every view is exported as its CANONICAL content: the valid groups
        (including exactly-retracted zero-count groups — they are live
        groups and dropping them would change later fast/slow merge
        decisions), globally key-sorted, with their stat columns, overlap
        keep, and touch stamps; plus the streaming-propensity reservoir,
        the optional row log, the estimate cache and the version/counter
        scalars.  Because estimates are functions of canonical group
        content alone, this snapshot restores into ANY engine layout —
        replicated or partitioned at any ``n_parts``/device count — with
        bit-identical queries (:meth:`install_canonical`).

        Commits the in-flight MVCC chain first (a checkpoint is a commit
        barrier) and fetches the committed buffers with ONE labeled
        ``device_fetch`` — the sync lives HERE, never on the ingest path.
        """
        self.commit()
        self._resolve_evictions()
        tnames = tuple(sorted(self.treatments))
        fetch = {}
        for name in (BASE_VIEW, *tnames):
            tab = self._view_table(name)
            entry = dict(hi=tab.key_hi, lo=tab.key_lo,
                         stats=dict(tab.stats), gv=tab.group_valid,
                         touch=self._touch[name])
            if name != BASE_VIEW:
                entry["keep"] = self.views[name].keep
            fetch[name] = entry
        if self.stream is not None:
            s = self.stream
            fetch["__stream__"] = dict(res=dict(s.columns), pri=s.priority,
                                       n=s.n, sums=dict(s.sums),
                                       sumsqs=dict(s.sumsqs))
        if self.rows is not None:
            fetch["__rows__"] = dict(cols=dict(self.rows.table.columns),
                                     valid=self.rows.table.valid)
        host = device_fetch(fetch, label="checkpoint")
        views = {}
        for name in (BASE_VIEW, *tnames):
            h = host[name]
            gv = np.asarray(h["gv"]).reshape(-1).astype(bool)
            hi = np.asarray(h["hi"]).reshape(-1)[gv]
            lo = np.asarray(h["lo"]).reshape(-1)[gv]
            order = np.lexsort((lo, hi))
            view = dict(
                hi=np.ascontiguousarray(hi[order]),
                lo=np.ascontiguousarray(lo[order]),
                touch=np.ascontiguousarray(
                    np.asarray(h["touch"]).reshape(-1)[gv][order]),
                stats={k: np.ascontiguousarray(
                    np.asarray(c).reshape(-1)[gv][order])
                    for k, c in h["stats"].items()})
            if name != BASE_VIEW:
                view["keep"] = np.ascontiguousarray(
                    np.asarray(h["keep"]).reshape(-1)[gv][order])
            views[name] = view
        snap = dict(views=views, scalars=dict(
            state_version=int(self._state_version),
            ingest_count=int(self._ingest_count),
            n_rows_ingested=int(self.n_rows_ingested),
            delta_cap=int(self._delta_cap)))
        if self.stream is not None:
            hs = host["__stream__"]
            snap["stream"] = dict(
                res={k: np.asarray(a) for k, a in hs["res"].items()},
                pri=np.asarray(hs["pri"]), n=np.asarray(hs["n"]),
                sums={k: np.asarray(a) for k, a in hs["sums"].items()},
                sumsqs={k: np.asarray(a)
                        for k, a in hs["sumsqs"].items()},
                n_batches=int(self.stream.n_batches),
                capacity=int(self.stream.capacity))
        if self.rows is not None:
            hr = host["__rows__"]
            used = self.rows.used
            snap["rows"] = dict(
                cols={k: np.asarray(a)[:used]
                      for k, a in hr["cols"].items()},
                valid=np.asarray(hr["valid"])[:used])
        snap["cache"] = tuple(
            (t, sub, dict(ate=e.ate, att=e.att,
                          n_matched_treated=e.n_matched_treated,
                          n_matched_control=e.n_matched_control,
                          n_groups=e.n_groups, variance=e.variance,
                          state_version=int(e.state_version)))
            for (t, sub), e in sorted(self._cache.items(),
                                      key=lambda kv: repr(kv[0])))
        snap["fingerprint"] = self.schema_fingerprint()
        return snap

    def install_canonical(self, snap: dict) -> None:
        """Install an :meth:`export_canonical` snapshot into THIS engine.

        The engine must be freshly constructed (nothing ingested) with
        the same schema fingerprint; its layout is free to differ from
        the exporter's — the per-view install hook (:meth:`_install_view`)
        re-materializes the canonical content under the local layout
        (replicated: one padded sorted table; partitioned: scattered to
        owner partitions by key hash, sorted per partition), and the
        bit-identity contract makes every query agree with the exporting
        engine bitwise."""
        if snap.get("fingerprint") != self.schema_fingerprint():
            raise ValueError(
                "checkpoint schema mismatch: snapshot fingerprint "
                f"{snap.get('fingerprint')!r} != engine "
                f"{self.schema_fingerprint()!r}")
        if self._ingest_count or self.n_rows_ingested or self._inflight:
            raise ValueError(
                "install_canonical requires a freshly constructed engine")
        tnames = tuple(sorted(self.treatments))
        for name in (BASE_VIEW, *tnames):
            self._install_view(name, snap["views"][name])
        stream = snap.get("stream")
        if (stream is None) != (self.stream is None):
            raise ValueError("snapshot/engine reservoir config mismatch "
                             "(reservoir_size)")
        if stream is not None:
            self.stream = dataclasses.replace(
                self.stream,
                columns={k: jnp.asarray(a)
                         for k, a in stream["res"].items()},
                priority=jnp.asarray(stream["pri"]),
                n=jnp.asarray(stream["n"]),
                sums={k: jnp.asarray(a)
                      for k, a in stream["sums"].items()},
                sumsqs={k: jnp.asarray(a)
                        for k, a in stream["sumsqs"].items()},
                n_batches=int(stream["n_batches"]))
        rows = snap.get("rows")
        if (rows is None) != (self.rows is None):
            raise ValueError("snapshot/engine row-log config mismatch "
                             "(keep_rows)")
        if rows is not None:
            self.rows = GrowableTable.from_table(
                Table.from_numpy(dict(rows["cols"]),
                                 np.asarray(rows["valid"])),
                granule=self.row_granule)
        self._cache = {}
        for t, sub, est in snap.get("cache", ()):
            key = (t, _freeze_subpop(sub) if sub else None)
            self._cache[key] = ATEEstimate(**est)
        sc = snap["scalars"]
        self._ingest_count = int(sc["ingest_count"])
        self.n_rows_ingested = int(sc["n_rows_ingested"])
        self._delta_cap = int(sc["delta_cap"])
        self._state_version = int(sc["state_version"])

    def _install_view(self, name: str, v: dict) -> None:
        """Re-materialize one canonical view under the replicated layout:
        valid groups as a sorted prefix, invalid-key padding to the
        granule-rounded capacity (the same convention empty/merged tables
        use, so the next ingest merges against it transparently)."""
        from repro.core.keys import INVALID_HI, INVALID_LO
        tab = self._view_table(name)
        n = int(np.asarray(v["hi"]).shape[0])
        cap = _round_capacity(max(n, 1), self.granule)
        hi = np.full((cap,), INVALID_HI, np.uint32)
        lo = np.full((cap,), INVALID_LO, np.uint32)
        gv = np.zeros((cap,), bool)
        hi[:n], lo[:n], gv[:n] = v["hi"], v["lo"], True
        stats = {}
        for k, col in v["stats"].items():
            a = np.zeros((cap,), np.asarray(col).dtype)
            a[:n] = col
            stats[k] = jnp.asarray(a)
        cub = dataclasses.replace(
            tab, key_hi=jnp.asarray(hi), key_lo=jnp.asarray(lo),
            stats=stats, group_valid=jnp.asarray(gv))
        touch = np.zeros((cap,), np.int32)
        touch[:n] = v["touch"]
        if name == BASE_VIEW:
            self.base = cub
        else:
            view = self.views[name]
            view.set_table(cub)
            keep = np.zeros((cap,), bool)
            keep[:n] = v["keep"]
            view.keep = jnp.asarray(keep)
        self._touch[name] = jnp.asarray(touch)

    # -------------------------------------------------------------- state
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Materialized-state summary (for benchmarks and demos)."""
        self._resolve_evictions()
        out = {BASE_VIEW: {"capacity": self.base.capacity,
                           "n_groups": int(self.base.n_groups())}}
        for t, view in self.views.items():
            out[t] = {"capacity": view.cuboid.capacity,
                      "n_groups": int(view.cuboid.n_groups()),
                      "n_matched_groups": int(jnp.sum(
                          view.keep.astype(jnp.int32)))}
        return out

    def _state_arrays(self) -> List[jnp.ndarray]:
        """Every array of the materialized views — `self.base` and
        `view.table` have the same field names in both the replicated and
        the partitioned layouts, so one walk serves both engines."""
        arrs = [self.base.key_hi, self.base.key_lo, self.base.group_valid,
                *self.base.stats.values()]
        for view in self.views.values():
            tab = view.table
            arrs += [tab.key_hi, tab.key_lo, tab.group_valid,
                     *tab.stats.values(), view.keep]
        arrs += list(self._touch.values())
        return arrs

    @staticmethod
    def _per_device_bytes(a) -> int:
        shards = getattr(a, "addressable_shards", None)
        if shards:
            return max(int(s.data.nbytes) for s in shards)
        return int(a.nbytes)

    def state_bytes(self) -> Dict[str, int]:
        """Resident bytes of the materialized views (keys + stats + masks
        + touch stamps): ``total`` across the job and ``per_device`` (the
        largest per-device share — equal to ``total`` when views are
        replicated, ~``total / n_parts`` when partitioned over a mesh)."""
        self._resolve_evictions()
        arrs = self._state_arrays()
        return {"total": sum(int(a.nbytes) for a in arrs),
                "per_device": sum(self._per_device_bytes(a) for a in arrs)}


class PartitionedOnlineEngine(OnlineEngine):
    """Online engine whose MATERIALIZED views are key-range partitioned.

    The replicated :class:`OnlineEngine` shards the per-batch delta BUILD
    over a mesh but keeps every merged stat table fully replicated, so
    total materialized state is capped by one chip's memory. Here the
    tables themselves are split into contiguous ranges of a hashed key
    space (:func:`repro.core.cube.partition_ids`): every view is a
    ``(n_parts, capacity)`` :class:`repro.core.cube.PartitionedCuboid`
    whose leading axis is sharded over the mesh's data axis, deltas are
    ROUTED to owner devices (one all-to-all on key range,
    :func:`repro.core.distributed.make_routed_delta_build`, instead of
    all-gather-everything), and merges, overlap maintenance, eviction and
    compaction run per partition. Per-device resident state is ~1/N of the
    total (``state_bytes()``).

    Queries run straight on the partitioned state
    (``query_pipeline="fused"``, the default): ``ate()`` is one compiled
    dispatch whose per-partition masking is device-local and whose
    canonical reduction is capacity/partition-count invariant, and
    ``matched_rows()`` is a routed row lookup (hash probes to owner
    partitions, all-to-all, partition-local binary search) — no full
    reassembly anywhere, and every result bit-identical to the replicated
    engine's on any device count. ``query_pipeline="assemble"`` keeps the
    planner-era reassembly baseline
    (:func:`repro.core.cube.unpartition_view`, memoized per state
    version), which ``cem_groups()`` also serves from. Batched queries
    (:meth:`OnlineEngine.ate_batch`) shard the same way: the one batched
    dispatch all-gathers each view's raw tables once (state-sized
    traffic, independent of the batch size) and runs the replicated
    batched estimator, bit-identical to the replicated engine's batch.

    n_parts: number of key-range partitions. With a mesh attached it must
    be a MULTIPLE of the data-axis size: each device owns
    ``k = n_parts / N`` contiguous hash ranges (k-partitions-per-device),
    so per-partition capacity — and with it the unit of growth and
    compaction — is bounded independently of the mesh size. Without a
    mesh, any ``n_parts >= 1`` runs the same layout on a single device
    (the differential test harness exercises this). All other arguments
    match :class:`OnlineEngine`; ``fused_host_sync=False`` /
    ``pipeline="unfused"`` are not supported (the partitioned path is
    fused-only, with the exact host fallback on delta overflow).
    """

    def __init__(self, specs: Mapping[str, CoarsenSpec],
                 treatments: Mapping[str, Sequence[str]], outcome: str,
                 n_parts: int = None, **kwargs):
        # consumed by _init_state, which super().__init__ invokes once the
        # mesh attributes exist — so only partitioned tables are ever
        # allocated, never a throwaway replicated layout
        self._requested_n_parts = n_parts
        super().__init__(specs, treatments, outcome, **kwargs)
        if not self.fused_host_sync:
            raise ValueError("PartitionedOnlineEngine is fused-only")

    def _init_state(self) -> None:
        n_parts = self._requested_n_parts
        if self.mesh is not None and self._mesh_ndev > 1:
            if n_parts is None:
                n_parts = self._mesh_ndev
            if n_parts % self._mesh_ndev != 0:
                raise ValueError(
                    f"n_parts={n_parts} must be a multiple of the mesh "
                    f"data-axis size {self._mesh_ndev} (k contiguous "
                    f"partitions per device)")
        self.n_parts = 1 if n_parts is None else int(n_parts)
        if self.n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {self.n_parts}")
        # per-partition capacity granule: hashing balances groups across
        # partitions, so each holds ~1/n_parts of the keys — capacities
        # (hence per-device bytes) shrink with the partition count
        self._part_granule = max(64, -(-self.granule // self.n_parts))
        tnames = tuple(sorted(self.treatments))
        self.base = self._place(cube_mod.stack_partitions(
            [cube_mod.empty_cuboid(self.codec, tnames,
                                   capacity=self._part_granule)
             for _ in range(self.n_parts)]))
        self.views: Dict[str, _PartView] = {}
        for t, dims, vcodec in self._view_schema():
            self.views[t] = _PartView(
                treatment=t, dims=dims,
                pcub=self._place(cube_mod.stack_partitions(
                    [cube_mod.empty_cuboid(vcodec, tnames,
                                           capacity=self._part_granule)
                     for _ in range(self.n_parts)])),
                keep=self._place(
                    jnp.zeros((self.n_parts, self._part_granule), bool)))
        self._touch = {name: self._place(
            jnp.zeros((self.n_parts, self._part_granule), jnp.int32))
            for name in (BASE_VIEW, *tnames)}
        self._routed_builds: Dict[int, Callable] = {}
        # treatment -> (state version, canonical cuboid, keep): the
        # assemble-path / cem_groups reassembly memo
        self._assembled: Dict[str, Tuple] = {}

    # ----------------------------------------------------- state placement
    def _place(self, tree):
        """Shard (P, ...) state over the mesh's data axis (one partition
        per device); identity on a single device."""
        if self.mesh is None or self._mesh_ndev == 1:
            return tree
        from repro.launch.mesh import shard_partitions
        return shard_partitions(self.mesh, tree, axis=self.mesh_axis)

    # ------------------------------------------------------- delta build
    def _get_routed_build(self, capacity: int) -> Callable:
        if capacity not in self._routed_builds:
            from repro.core.distributed import make_routed_delta_build
            view_dims = {BASE_VIEW: tuple(self.codec.names)}
            for t in sorted(self.treatments):
                view_dims[t] = self.views[t].dims
            self._routed_builds[capacity] = make_routed_delta_build(
                self.mesh, self.specs, sorted(self.treatments),
                self.outcome, capacity, view_dims, axis=self.mesh_axis,
                n_parts=self.n_parts)
        return self._routed_builds[capacity]

    def _route_from_base(self, hi, lo, stats, gv):
        """Single-device routing: regroup a base-granularity delta stat
        table into per-partition tables for every view (each view routes
        by ITS OWN key hash — rollup changes the key, hence the owner)."""
        deltas = {BASE_VIEW: cube_mod.route_delta(hi, lo, stats, gv,
                                                  self.n_parts)}
        for t in sorted(self.treatments):
            roll = cube_mod._rollup_fn(self.codec, self.views[t].dims)
            vhi, vlo, vstats, vgv = roll(hi, lo, gv, stats)
            deltas[t] = cube_mod.route_delta(vhi, vlo, vstats, vgv,
                                             self.n_parts)
        return deltas

    def _build_delta_parts(self, batch: Table):
        """Routed delta stat tables of one batch: (deltas, n_full,
        overflow) where deltas[name] is (hi, lo, stats, gv) with leading
        (n_parts, delta_capacity) axes."""
        cols = {c: batch.columns[c] for c in self._row_cols}
        valid = batch.valid
        if self.mesh is not None and self._mesh_ndev > 1:
            cols, valid = fused_mod.pad_tail(
                cols, valid, (-batch.nrows) % self._mesh_ndev)
            fn = self._get_routed_build(self._delta_cap)
            return fn(cols, valid)
        fn = cube_mod._build_fn(self.codec,
                                tuple(sorted(self.specs.items())),
                                tuple(sorted(self.treatments)), self.outcome)
        hi, lo, stats, gv = fn(cols, valid)
        n_full = jnp.sum(gv.astype(jnp.int32))
        dcap = self._delta_cap
        deltas = self._route_from_base(hi[:dcap], lo[:dcap],
                                       {k: v[:dcap] for k, v in
                                        stats.items()}, gv[:dcap])
        return deltas, n_full, n_full > dcap

    # ------------------------------------------------------------- ingest
    def ingest(self, batch: Table, retract: bool = False) -> DeltaReport:
        """Fold one streamed batch into every partitioned view: route the
        delta to owner partitions, merge/flip/stamp per partition, fetch
        ONE fused verdict. ``pipeline="fused1"`` (default) does ALL of it —
        routing included — in one donated compiled dispatch; "planner"
        keeps the PR 3 two-dispatch path. Semantics (including the
        retraction guard and the delta-overflow exact fallback) match
        :meth:`OnlineEngine.ingest` bit for bit — including the
        ``overlap=True`` MVCC protocol (dispatch-only, lazy verdicts,
        commit-time rollback-and-replay)."""
        self.validate_batch(batch, retract=retract)
        self._resolve_evictions()
        self._guard_retract_rows(retract)
        if self.overlap and retract:
            self.commit()
        self._maybe_renorm_touch()
        padded = self._bucket_pad(batch)
        if self.pipeline == "fused1":
            if self.overlap and not retract:
                return self._ingest_overlap(padded, orig=batch)
            return self._ingest_fused1(padded, retract, orig=batch)
        deltas, n_full, overflow = self._build_delta_parts(padded)
        return self._ingest_parts(padded, deltas, n_full, overflow, retract,
                                  orig=batch)

    # --------------------------------------- single-dispatch (fused1) hooks
    def _fused_program(self, retract: bool, donate: bool = True):
        mesh = self.mesh if self._mesh_ndev > 1 else None
        return fused_mod.get_fused_ingest_parts(
            self.codec, tuple(sorted(self.specs.items())),
            tuple(sorted(self.treatments)), self._fused_view_dims(),
            self.outcome, self._fused_caps(), self._delta_cap,
            self.n_parts, mesh, self.mesh_axis, self.use_pallas, retract,
            self._stream_names(), self.seed, donate)

    def _fallback_overflow(self, batch: Table, retract: bool,
                           orig: Table) -> DeltaReport:
        """Exact host fallback on delta overflow: rebuild the delta at the
        (already grown) capacity, re-route, run the planner commit path."""
        tnames = tuple(sorted(self.treatments))
        d = cube_mod.delta_cuboid(batch, self.specs, tnames, self.outcome,
                                  granule=self._delta_cap)
        deltas = self._route_from_base(d.key_hi, d.key_lo, dict(d.stats),
                                       d.group_valid)
        return self._ingest_parts(batch, deltas, jnp.asarray(0),
                                  jnp.asarray(False), retract, orig=orig)

    def _grow_views(self, n_merged: Dict[str, int],
                    grew: Dict[str, bool]) -> None:
        """Per-partition capacity doubling: pad every (P, C) array of an
        overflowing view along the slot axis (keys stay sorted — invalid
        padding is the largest key) and let the next dispatch recompile at
        the new per-partition granule count."""
        for name, g in grew.items():
            if not g:
                continue
            tab = self._view_table(name)
            new_cap = _round_capacity(max(n_merged[name], 2 * tab.capacity),
                                      self._part_granule)
            padded = self._place(cube_mod.pad_partitioned(tab, new_cap))
            pad = new_cap - tab.capacity
            if name == BASE_VIEW:
                self.base = padded
            else:
                view = self.views[name]
                view.set_table(padded)
                view.keep = self._place(
                    jnp.pad(view.keep, ((0, 0), (0, pad))))
            self._touch[name] = self._place(
                jnp.pad(self._touch[name], ((0, 0), (0, pad))))

    def _evict_n_parts(self) -> int:
        return self.n_parts

    def _ingest_parts(self, batch: Table, deltas, n_full, overflow,
                      retract: bool, orig: Table = None) -> DeltaReport:
        orig = batch if orig is None else orig
        tnames = tuple(sorted(self.treatments))
        plan = _plan_ingest_parts(
            deltas, self.base.key_hi, self.base.key_lo, self.base.stats,
            {t: self.views[t].pcub.key_hi for t in tnames},
            {t: self.views[t].pcub.key_lo for t in tnames},
            {t: self.views[t].pcub.stats for t in tnames},
            {t: self.views[t].pcub.group_valid for t in tnames},
            {t: self.views[t].keep for t in tnames},
            codec=self.codec, tnames=tnames, retract=retract,
            use_pallas=self.use_pallas)
        # THE one host sync of a fast-path ingest
        fetched = device_fetch(dict(
            overflow=overflow, ok=plan["ok"], neg_min=plan["neg_min"],
            n_delta=plan["n_delta"], gv=deltas[BASE_VIEW][3],
            buckets=plan["buckets"]))
        if fetched["overflow"]:
            # a routed table was truncated: rebuild the delta exactly on
            # the host, grow the capacity geometrically, and re-route
            self._delta_cap = _round_capacity(
                max(int(n_full), 2 * self._delta_cap), self.delta_granule)
            d = cube_mod.delta_cuboid(batch, self.specs, tnames,
                                      self.outcome,
                                      granule=self._delta_cap)
            deltas = self._route_from_base(d.key_hi, d.key_lo,
                                           dict(d.stats), d.group_valid)
            return self._ingest_parts(batch, deltas, n_full,
                                      jnp.asarray(False), retract,
                                      orig=orig)
        all_fast = all(bool(v) for v in fetched["ok"].values())
        if retract and (not all_fast or fetched["neg_min"] < -0.5):
            self._raise_bad_retraction()
        counter = self._ingest_count + 1
        fast: Dict[str, bool] = {}
        for name in (BASE_VIEW, *tnames):
            ok = bool(fetched["ok"][name])
            d_hi, d_lo, d_stats, d_gv = deltas[name]
            pcub = (self.base if name == BASE_VIEW
                    else self.views[name].pcub)
            if ok:
                merged = dataclasses.replace(pcub,
                                             stats=plan["merged"][name])
                self._touch[name] = _stamp_touch_parts(
                    self._touch[name], plan["pos"][name], d_gv, counter)
            else:
                merged, pos = cube_mod.merge_delta_parts(
                    pcub, d_hi, d_lo, d_stats, d_gv,
                    granule=self._part_granule)
                merged = self._place(merged)
                self._touch[name] = _stamp_touch_parts(
                    self._place(_remap_touch_parts(pcub, merged,
                                                   self._touch[name])),
                    pos, d_gv, counter)
            if name == BASE_VIEW:
                self.base = merged
            else:
                view = self.views[name]
                if ok:
                    view.keep = plan["keep"][name]
                else:
                    nt = merged.stats[f"t_{name}"]
                    view.keep = overlap_keep(merged.group_valid, nt,
                                             merged.stats["one"] - nt)
                view.pcub = merged
            fast[name] = ok
        self._commit_rows(batch, retract, orig=orig)
        self._post_state_swap()
        invalidated = self._invalidate(
            fetched["gv"].reshape(-1),
            lambda d: fetched["buckets"][d].reshape(-1))
        return DeltaReport(n_rows=orig.nrows,
                           n_delta_groups=int(fetched["n_delta"]),
                           fast_path=fast, invalidated=invalidated)

    # ------------------------------------------- durability (canonical)
    def _install_view(self, name: str, v: dict) -> None:
        """Re-materialize one canonical view under the partitioned layout:
        scatter the globally key-sorted groups to their owner partitions
        (the owner is the same pure key-hash function deltas route by, so
        a replicated checkpoint restores into ANY ``n_parts``), keep each
        partition's slice sorted (global key order restricted to one
        partition stays sorted — partition ids are monotone in the key),
        and pad every partition to one shared granule-rounded capacity."""
        from repro.core.keys import INVALID_HI, INVALID_LO
        tab = self._view_table(name)
        hi_c = np.asarray(v["hi"], np.uint32)
        lo_c = np.asarray(v["lo"], np.uint32)
        n = int(hi_c.shape[0])
        P = self.n_parts
        if n:
            pid = np.asarray(cube_mod.partition_ids(hi_c, lo_c, P))
            counts = np.bincount(pid, minlength=P)
        else:
            pid = np.zeros((0,), np.int64)
            counts = np.zeros((P,), np.int64)
        cap = _round_capacity(max(int(counts.max()), 1),
                              self._part_granule)
        hi = np.full((P, cap), INVALID_HI, np.uint32)
        lo = np.full((P, cap), INVALID_LO, np.uint32)
        gv = np.zeros((P, cap), bool)
        touch = np.zeros((P, cap), np.int32)
        keep = np.zeros((P, cap), bool)
        stats = {k: np.zeros((P, cap), np.asarray(c).dtype)
                 for k, c in v["stats"].items()}
        for p in range(P):
            idx = np.nonzero(pid == p)[0]
            k = len(idx)
            if not k:
                continue
            hi[p, :k], lo[p, :k], gv[p, :k] = hi_c[idx], lo_c[idx], True
            touch[p, :k] = np.asarray(v["touch"])[idx]
            for sk, c in v["stats"].items():
                stats[sk][p, :k] = np.asarray(c)[idx]
            if name != BASE_VIEW:
                keep[p, :k] = np.asarray(v["keep"])[idx]
        pcub = self._place(dataclasses.replace(
            tab, key_hi=jnp.asarray(hi), key_lo=jnp.asarray(lo),
            stats={k: jnp.asarray(a) for k, a in stats.items()},
            group_valid=jnp.asarray(gv)))
        if name == BASE_VIEW:
            self.base = pcub
        else:
            view = self.views[name]
            view.set_table(pcub)
            view.keep = self._place(jnp.asarray(keep))
        self._touch[name] = self._place(jnp.asarray(touch))

    # ------------------------------------------------ capacity shrink pass
    def _shrink_granule(self) -> int:
        return self._part_granule

    def _shrink_view(self, name: str, new_cap: int) -> None:
        tab = self._view_table(name)
        sliced = self._place(cube_mod.slice_partitioned(tab, new_cap))
        if name == BASE_VIEW:
            self.base = sliced
        else:
            view = self.views[name]
            view.set_table(sliced)
            view.keep = self._place(view.keep[:, :new_cap])
        self._touch[name] = self._place(self._touch[name][:, :new_cap])

    # ------------------------------------------------------------ queries
    def _view_state(self, treatment: str
                    ) -> Tuple[cube_mod.Cuboid, jnp.ndarray]:
        """Canonical reassembly of a partitioned view in ONE compiled
        dispatch (:func:`repro.core.cube.unpartition_view`): flatten the
        (tiny) per-partition stat vectors, re-sort by key, recompute
        overlap from the (exact) stats. Memoized per STATE VERSION — the
        memo survives until the next committed mutation, so dashboards
        repeating ``cem_groups``/assemble-path queries pay zero extra
        dispatches."""
        entry = self._assembled.get(treatment)
        if entry is None or entry[0] != self._state_version:
            pv = self.views[treatment]
            cub, keep = cube_mod.unpartition_view(pv.pcub, treatment)
            entry = (self._state_version, cub, keep)
            self._assembled[treatment] = entry
        return entry[1], entry[2]

    def _fused_estimate(self, treatment: str,
                        subpopulation: SubPop) -> ATEEstimate:
        """Fused one-dispatch query straight on the (P, C) partitioned
        state: per-partition masking (sharded over the mesh when one is
        attached — per-device work 1/N), canonical reduce in-program."""
        pv = self.views[treatment]
        mesh = self.mesh if self._mesh_ndev > 1 else None
        return _run_fused_query(pv.pcub, pv.keep, treatment, subpopulation,
                                mesh=mesh, mesh_axis=self.mesh_axis,
                                partitioned=True)

    def _batch_query_flags(self) -> Tuple:
        """Batched queries run straight on the (P, C) partitioned state:
        on a mesh the batched program all_gathers each view's raw
        partition tables once inside its shard_map body and reduces
        replicated (bit-identical to the replicated engine)."""
        mesh = self.mesh if self._mesh_ndev > 1 else None
        return mesh, self.mesh_axis, True

    def _rowlookup_query(self, treatment: str):
        """Partitioned row lookup: hash each probe row to its owning
        partition, binary-search only that partition's table — ROUTED over
        the mesh (all-to-all out and back) when one is attached."""
        view = self.views[treatment]
        tab = view.pcub
        mesh = self.mesh if self._mesh_ndev > 1 else None
        vspecs = tuple(sorted((d, self.specs[d]) for d in view.dims))
        prog = fused_mod.get_fused_rowlookup(tab.codec, vspecs,
                                             self.n_parts, mesh,
                                             self.mesh_axis)
        return prog, (tab.key_hi, tab.key_lo, view.keep)

    # -------------------------------------------------------------- state
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Materialized-state summary; capacities are PER PARTITION."""
        self._resolve_evictions()
        out = {BASE_VIEW: {"capacity": self.base.capacity,
                           "n_parts": self.n_parts,
                           "n_groups": int(self.base.n_groups())}}
        for t, view in self.views.items():
            out[t] = {"capacity": view.pcub.capacity,
                      "n_parts": self.n_parts,
                      "n_groups": int(view.pcub.n_groups()),
                      "n_matched_groups": int(jnp.sum(
                          view.keep.astype(jnp.int32)))}
        return out

"""Online incremental causal inference (paper §4.2's "online setting",
made truly incremental).

The offline path re-coarsens, re-groups and re-cubes the whole relation for
every new batch of rows. This engine instead maintains causal estimates
under streaming INSERTs with work proportional to the DELTA, not the data:

  1. DELTA CUBOID MAINTENANCE — every cuboid stat is decomposable
     (count/sum), so a streamed batch reduces to a tiny stat table
     (:func:`repro.core.cube.delta_cuboid`) that is folded into each
     materialized cuboid with the same combine the distributed engine uses
     for per-chip partials (:func:`repro.core.cube.merge_delta`). The delta
     is computed ONCE at base granularity and propagated DOWN the cube
     lattice by rolling the delta itself up to each view's dims — never by
     rebuilding a cuboid from rows.
  2. INCREMENTAL CEM OVERLAP — when a merge keeps the stat-table layout
     (fast path), the overlap filter ``max(T) != min(T)`` is re-evaluated
     only at the group ids the delta touched
     (:func:`repro.core.cem.update_overlap`): groups flip in and out of the
     matched set in O(|delta groups|).
  3. WARM-STARTED PROPENSITY — logistic refreshes resume Newton from the
     previous coefficients under a configurable step budget with frozen
     standardization (:func:`repro.core.propensity.warm_refit`).
  4. ESTIMATE CACHE — repeated online queries are served from a cache keyed
     by (treatment, sub-population); a delta invalidates only the entries
     whose group predicate it actually touched.

The maintained state is EXACT: after any number of ingested batches, every
cuboid stat, CEM matched set and ATE equals the offline computation over
the concatenated table (bit-identical when outcome sums are exact, e.g.
integer-valued outcomes; to float tolerance otherwise — summation order is
the only difference). ``tests/test_online.py`` asserts this equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cube as cube_mod
from repro.core import groupby
from repro.core.ate import ATEEstimate, estimate_ate_from_stats
from repro.core.cem import (CEMGroups, make_codec, overlap_keep, pack_keys,
                            update_overlap)
from repro.core.coarsen import CoarsenSpec
from repro.core.propensity import (LogisticModel, design_matrix,
                                   fit_logistic)
from repro.data.columnar import GrowableTable, Table

BASE_VIEW = "__base__"

SubPop = Optional[Mapping[str, Sequence[int]]]


def _freeze_subpop(subpopulation: SubPop):
    if not subpopulation:
        return None
    return tuple(sorted((d, tuple(sorted(int(b) for b in bs)))
                        for d, bs in subpopulation.items()))


@dataclasses.dataclass
class DeltaReport:
    """What one :meth:`OnlineEngine.ingest` call did."""

    n_rows: int                   # batch rows (valid or not)
    n_delta_groups: int           # distinct base-granularity groups touched
    fast_path: Dict[str, bool]    # view -> scatter-merge (True) / re-sort
    invalidated: Tuple            # estimate-cache keys dropped


@dataclasses.dataclass
class _View:
    """One materialized cuboid + incrementally maintained overlap mask."""

    treatment: str
    dims: Tuple[str, ...]
    cuboid: cube_mod.Cuboid
    keep: jnp.ndarray


class OnlineEngine:
    """Streaming causal-inference engine over a fixed coarsening schema.

    specs:       covariate -> CoarsenSpec (the coarsening is part of the
                 schema: delta maintenance needs stable group keys).
    treatments:  treatment name -> its covariate names (the CDAG choice).
    query_dims:  extra dims kept in every view so sub-population queries
                 (e.g. airport=SFO) stay answerable from materialized state.
    keep_rows:   also log raw rows (append-only, geometric growth) — needed
                 only for propensity refreshes and row-level diagnostics.
    use_pallas:  route fast-path merges through the MXU scatter kernel.
    """

    def __init__(self, specs: Mapping[str, CoarsenSpec],
                 treatments: Mapping[str, Sequence[str]], outcome: str,
                 query_dims: Sequence[str] = (), granule: int = 1024,
                 delta_granule: int = 256, keep_rows: bool = False,
                 row_granule: int = 4096, use_pallas: bool = False):
        self.treatments = {t: tuple(sorted(c)) for t, c in treatments.items()}
        self.outcome = outcome
        self.query_dims = tuple(query_dims)
        base_dims = sorted(set(self.query_dims).union(
            *[set(c) for c in self.treatments.values()]))
        missing = [d for d in base_dims if d not in specs]
        if missing:
            raise ValueError(f"no CoarsenSpec for dims {missing}")
        self.specs = {d: specs[d] for d in base_dims}
        self.codec = make_codec(self.specs)
        self.granule = granule
        self.delta_granule = delta_granule
        self.use_pallas = use_pallas
        self.row_granule = row_granule
        tnames = sorted(self.treatments)
        self.base = cube_mod.empty_cuboid(self.codec, tnames,
                                          capacity=granule)
        self.views: Dict[str, _View] = {}
        for t in tnames:
            dims = tuple(sorted(set(self.treatments[t])
                                | set(self.query_dims)))
            vcodec = make_codec({d: self.specs[d] for d in dims})
            self.views[t] = _View(
                treatment=t, dims=dims,
                cuboid=cube_mod.empty_cuboid(vcodec, tnames,
                                             capacity=granule),
                keep=jnp.zeros((granule,), bool))
        self.rows: Optional[GrowableTable] = (
            None if not keep_rows else GrowableTable.from_table(
                Table.from_numpy(
                    {c: np.zeros((0,), np.float32)
                     for c in (*base_dims, *tnames, outcome)},
                    np.zeros((0,), bool)),
                granule=row_granule))
        self.n_rows_ingested = 0
        self._cache: Dict[Tuple, ATEEstimate] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.models: Dict[str, LogisticModel] = {}

    @classmethod
    def from_table(cls, table: Table, specs: Mapping[str, CoarsenSpec],
                   treatments: Mapping[str, Sequence[str]], outcome: str,
                   **kwargs) -> "OnlineEngine":
        """Seed the engine with an initial offline table, then stream."""
        eng = cls(specs, treatments, outcome, **kwargs)
        eng.ingest(table)
        return eng

    # ------------------------------------------------------------- ingest
    def ingest(self, batch: Table, retract: bool = False) -> DeltaReport:
        """Fold one streamed batch into every materialized view.

        Work is O(batch + |delta groups| * #views) on the fast path; a full
        re-sort of a view's (tiny) stat table only happens when the delta
        introduces group keys that view has never seen.

        ``retract=True`` REMOVES previously ingested rows: every maintained
        stat is a count/sum, so retraction is exact sign-flipped delta
        maintenance — groups can lose overlap and flip back out of the
        matched set. Retracting rows that were never ingested corrupts the
        state (counts go negative), as in any incremental view.
        """
        if retract and self.rows is not None:
            raise ValueError("retract=True is not supported with "
                             "keep_rows=True (the row log is append-only)")
        if self.rows is not None:
            self.rows = self.rows.append(
                batch.select(list(self.rows.table.columns)),
                granule=self.row_granule)
        self.n_rows_ingested += -batch.nrows if retract else batch.nrows
        tnames = sorted(self.treatments)
        d_base = cube_mod.delta_cuboid(batch, self.specs, tnames,
                                       self.outcome,
                                       granule=self.delta_granule)
        if retract:
            d_base = dataclasses.replace(
                d_base, stats={k: -v for k, v in d_base.stats.items()})
        fast: Dict[str, bool] = {}
        self.base, _, fast[BASE_VIEW] = cube_mod.merge_delta(
            self.base, d_base, granule=self.granule,
            use_pallas=self.use_pallas)
        # lattice propagation: the delta itself rolls up to each view's dims
        for t, view in self.views.items():
            d_view = cube_mod.compact_cuboid(
                cube_mod.rollup(d_base, view.dims),
                granule=self.delta_granule)
            merged, pos, was_fast = cube_mod.merge_delta(
                view.cuboid, d_view, granule=self.granule,
                use_pallas=self.use_pallas)
            nt = merged.stats[f"t_{t}"]
            nc = merged.stats["one"] - nt
            if was_fast:
                # O(|delta groups|): flip only the touched groups
                view.keep = update_overlap(view.keep, merged.group_valid,
                                           nt, nc, pos)
            else:
                view.keep = overlap_keep(merged.group_valid, nt, nc)
            view.cuboid = merged
            fast[t] = was_fast
        invalidated = self._invalidate(d_base)
        return DeltaReport(n_rows=batch.nrows,
                           n_delta_groups=int(d_base.n_groups()),
                           fast_path=fast, invalidated=invalidated)

    def _invalidate(self, d_base: cube_mod.Cuboid) -> Tuple:
        """Drop exactly the cache entries whose group predicate the delta
        touched: an unrestricted estimate is touched by any delta; a
        sub-population estimate only if some delta group satisfies its
        (conjunctive) bucket predicate."""
        gv = np.asarray(d_base.group_valid)
        if not gv.any():
            return ()
        buckets: Dict[str, np.ndarray] = {}

        def dim_buckets(dim: str) -> np.ndarray:
            if dim not in buckets:
                buckets[dim] = np.asarray(self.codec.extract(
                    d_base.key_hi, d_base.key_lo, dim))
            return buckets[dim]

        dropped: List[Tuple] = []
        for key in list(self._cache):
            _, subpop = key
            if subpop is None:
                touched = True
            else:
                sat = gv.copy()
                for dim, allowed in subpop:
                    sat &= np.isin(dim_buckets(dim), list(allowed))
                touched = bool(sat.any())
            if touched:
                dropped.append(key)
                del self._cache[key]
        return tuple(dropped)

    # ------------------------------------------------------------ queries
    def ate(self, treatment: str, subpopulation: SubPop = None
            ) -> ATEEstimate:
        """Online causal query from materialized state: O(view capacity),
        independent of rows ingested. Repeated queries hit the cache."""
        key = (treatment, _freeze_subpop(subpopulation))
        if key in self._cache:
            self.cache_hits += 1
            return self._cache[key]
        self.cache_misses += 1
        view = self.views[treatment]
        cub, keep = view.cuboid, view.keep
        if subpopulation:
            for dim, allowed in subpopulation.items():
                cub = cube_mod.filter_cuboid(cub, dim, allowed)
            # population restriction leaves per-group stats (hence overlap)
            # of surviving groups unchanged
            keep = keep & cub.group_valid
        nt = cub.stats[f"t_{treatment}"]
        nc = cub.stats["one"] - nt
        yt = cub.stats[f"yt_{treatment}"]
        yc = cub.stats["y"] - yt
        est = estimate_ate_from_stats(keep, nt, nc, yt, yc)
        self._cache[key] = est
        return est

    def cem_groups(self, treatment: str) -> CEMGroups:
        """Current CEM group stats with the incrementally maintained
        overlap mask (same shape the offline path produces)."""
        view = self.views[treatment]
        cub = view.cuboid
        nt = cub.stats[f"t_{treatment}"]
        nc = cub.stats["one"] - nt
        yt = cub.stats[f"yt_{treatment}"]
        dummy = groupby.Grouping(
            perm=jnp.zeros((cub.capacity,), jnp.int32),
            inv_perm=jnp.zeros((cub.capacity,), jnp.int32),
            seg_ids=jnp.zeros((cub.capacity,), jnp.int32),
            group_hi=cub.key_hi, group_lo=cub.key_lo,
            group_valid=cub.group_valid, n_groups=cub.n_groups())
        return CEMGroups(grouping=dummy, keep=view.keep, n_treated=nt,
                         n_control=nc, sum_y_t=yt,
                         sum_y_c=cub.stats["y"] - yt)

    def matched_rows(self, treatment: str, table: Table) -> jnp.ndarray:
        """Row-level matched mask for ``table`` against current state
        (binary-search lookup into the broadcast stat table, exactly like
        the distributed engine's row mask)."""
        view = self.views[treatment]
        vspecs = {d: self.specs[d] for d in view.dims}
        _, hi, lo = pack_keys(table, vspecs, codec=view.cuboid.codec)
        pos, found = groupby.lookup_rows_in_table(
            hi, lo, view.cuboid.key_hi, view.cuboid.key_lo)
        return table.valid & found & view.keep[pos]

    # --------------------------------------------------------- propensity
    def refresh_propensity(self, treatment: str, features: Sequence[str],
                           step_budget: int = 4, cold_iters: int = 32,
                           ridge: float = 1e-4) -> LogisticModel:
        """(Re)fit the propensity model over all ingested rows: a cold
        Newton fit the first time, afterwards warm-started from the
        previous coefficients with ``step_budget`` iterations."""
        if self.rows is None:
            raise ValueError("refresh_propensity needs keep_rows=True")
        tbl = self.rows.table
        X = design_matrix(tbl, features)
        prev = self.models.get(treatment)
        model = fit_logistic(
            X, tbl[treatment], tbl.valid,
            n_iter=step_budget if prev is not None else cold_iters,
            ridge=ridge, init=prev)
        self.models[treatment] = model
        return model

    # -------------------------------------------------------------- state
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Materialized-state summary (for benchmarks and demos)."""
        out = {BASE_VIEW: {"capacity": self.base.capacity,
                           "n_groups": int(self.base.n_groups())}}
        for t, view in self.views.items():
            out[t] = {"capacity": view.cuboid.capacity,
                      "n_groups": int(view.cuboid.n_groups()),
                      "n_matched_groups": int(jnp.sum(
                          view.keep.astype(jnp.int32)))}
        return out

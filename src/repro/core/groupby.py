"""Sort-based GROUP BY with segmented aggregation.

TPUs have no hash tables; the idiom for SQL's GROUP BY is:
sort rows by packed key (lexicographic over two u32 words), mark segment
boundaries, and run segmented reductions. All outputs are padded to N
(static shape); ``n_groups`` is dynamic.

This module is the pure-jnp engine; ``repro.kernels.segment_stats`` provides
the fused Pallas hot path for the CEM statistics bundle, and
``repro.core.distributed`` layers the multi-chip combine-broadcast on top.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core.keys import INVALID_HI, INVALID_LO


@dataclasses.dataclass(frozen=True)
class Grouping:
    """Result of grouping N rows by key.

    All arrays have length N (padded). Row-order fields are in *sorted* row
    order; ``perm`` maps sorted position -> original row index and
    ``inv_perm`` the other way.
    """

    perm: jnp.ndarray        # (N,) int32: sorted pos -> original row
    inv_perm: jnp.ndarray    # (N,) int32: original row -> sorted pos
    seg_ids: jnp.ndarray     # (N,) int32: sorted pos -> group id (invalid rows
                             #   share the trailing group)
    group_hi: jnp.ndarray    # (N,) u32: group id -> key hi (padded w/ invalid)
    group_lo: jnp.ndarray    # (N,) u32
    group_valid: jnp.ndarray  # (N,) bool: group id -> is a real (valid-key) group
    n_groups: jnp.ndarray    # () int32 (dynamic), count of valid groups

    @property
    def nrows(self) -> int:
        return int(self.perm.shape[0])

    def row_group(self) -> jnp.ndarray:
        """(N,) int32: original row -> group id."""
        return self.seg_ids[self.inv_perm]


def group_by_key(hi: jnp.ndarray, lo: jnp.ndarray,
                 single_word: bool = False) -> Grouping:
    """Group rows by (hi, lo) key. Invalid rows carry the all-ones marker and
    sort to the end, landing in a trailing pseudo-group flagged invalid.

    single_word=True (keys known to fit 31 bits, hi == 0 for valid rows and
    the invalid marker still sorts last within lo alone) sorts ONE u32 word
    instead of the lexicographic pair — ~1/3 less sort traffic; the common
    CEM case (§Perf hillclimb on the zaliql cell)."""
    n = hi.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    if single_word:
        slo, perm = jax.lax.sort((lo, iota), num_keys=1, is_stable=True)
        marker = slo == jnp.uint32(0xFFFFFFFF)
        shi = jnp.where(marker, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    else:
        shi, slo, perm = jax.lax.sort((hi, lo, iota), num_keys=2,
                                      is_stable=True)
    inv_perm = jnp.zeros((n,), jnp.int32).at[perm].set(iota)

    prev_hi = jnp.concatenate([jnp.array([~shi[0]], dtype=shi.dtype), shi[:-1]])
    prev_lo = jnp.concatenate([jnp.array([~slo[0]], dtype=slo.dtype), slo[:-1]])
    new_seg = (shi != prev_hi) | (slo != prev_lo)
    seg_ids = jnp.cumsum(new_seg.astype(jnp.int32)) - 1

    # Group-id -> representative key (first sorted row of each segment).
    group_hi = jnp.full((n,), INVALID_HI, dtype=hi.dtype)
    group_lo = jnp.full((n,), INVALID_LO, dtype=lo.dtype)
    group_hi = group_hi.at[seg_ids].set(shi)  # last-wins, same key per segment
    group_lo = group_lo.at[seg_ids].set(slo)
    group_valid = ~((group_hi == INVALID_HI) & (group_lo == INVALID_LO))
    n_groups = jnp.sum(group_valid.astype(jnp.int32))
    return Grouping(perm=perm, inv_perm=inv_perm, seg_ids=seg_ids,
                    group_hi=group_hi, group_lo=group_lo,
                    group_valid=group_valid, n_groups=n_groups)


def segment_sums(g: Grouping, columns: Mapping[str, jnp.ndarray]
                 ) -> Dict[str, jnp.ndarray]:
    """Per-group sums of each column (rows gathered into sorted order first).

    Caller is responsible for pre-masking columns (multiply by validity /
    arm indicators); this keeps one sort amortized over many aggregates.
    """
    out = {}
    for name, col in columns.items():
        sortd = col.astype(jnp.float32)[g.perm]
        out[name] = jax.ops.segment_sum(sortd, g.seg_ids,
                                        num_segments=g.nrows)
    return out


def group_minmax(g: Grouping, col: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-group (min, max) — the paper's ``min(T) OVER w / max(T) OVER w``."""
    sortd = col[g.perm]
    mn = jax.ops.segment_min(sortd, g.seg_ids, num_segments=g.nrows)
    mx = jax.ops.segment_max(sortd, g.seg_ids, num_segments=g.nrows)
    return mn, mx


def broadcast_to_rows(g: Grouping, group_vals: jnp.ndarray) -> jnp.ndarray:
    """Group-level values -> per-row values (original row order).

    The SQL analogue is selecting a window aggregate alongside each row.
    """
    return group_vals[g.seg_ids][g.inv_perm]


def combine_stat_tables(hi: jnp.ndarray, lo: jnp.ndarray,
                        stats: Mapping[str, jnp.ndarray], capacity: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    """Merge (possibly duplicated-key) stat tables into one table of
    ``capacity`` rows: sort by key, segment-sum the stats. Used by the
    distributed combine-broadcast aggregation to merge per-chip partials.

    Returns (group_hi, group_lo, summed stats, overflow flag). Overflow is
    reported when distinct keys exceed ``capacity`` (results then invalid).
    """
    g = group_by_key(hi, lo)
    summed = segment_sums(g, stats)
    ghi = g.group_hi[:capacity]
    glo = g.group_lo[:capacity]
    out = {k: v[:capacity] for k, v in summed.items()}
    overflow = g.n_groups > capacity
    return ghi, glo, out, overflow


def scatter_add_stats(stats: Mapping[str, jnp.ndarray], pos: jnp.ndarray,
                      delta: Mapping[str, jnp.ndarray]
                      ) -> Dict[str, jnp.ndarray]:
    """Merge a delta stat table into ``stats`` at known row positions —
    the O(|delta|) fast path of online cuboid maintenance (positions come
    from :func:`lookup_rows_in_table`). Pure-jnp reference; the MXU one-hot
    path is ``repro.kernels.scatter_merge_op``."""
    return {k: v.at[pos].add(delta[k].astype(v.dtype))
            for k, v in stats.items()}


from repro.launch.trace import counted_jit  # noqa: E402


@counted_jit
def lookup_rows_in_table(hi: jnp.ndarray, lo: jnp.ndarray,
                         table_hi: jnp.ndarray, table_lo: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For each row key, find its position in a *sorted* key table.

    Returns (pos, found). Rows whose key is absent get found=False.
    Table must be sorted lexicographically by (hi, lo) — group tables from
    :func:`group_by_key` already are. Jitted: the eager vmap-of-scan search
    costs ~100ms/call, which would dominate online delta maintenance;
    shapes are stable across a stream, so the trace amortizes to one.
    """
    # Vectorized binary search over the composite (hi, lo) key.
    n_table = table_hi.shape[0]
    def composite_less(i, key_hi, key_lo):
        thi = table_hi[i]
        tlo = table_lo[i]
        return (thi < key_hi) | ((thi == key_hi) & (tlo < key_lo))
    def body(state, _):
        lo_b, hi_b, key_hi, key_lo = state
        mid = (lo_b + hi_b) // 2
        less = composite_less(mid, key_hi, key_lo)
        lo_b = jnp.where(less, mid + 1, lo_b)
        hi_b = jnp.where(less, hi_b, mid)
        return (lo_b, hi_b, key_hi, key_lo), None
    n_iter = max(1, math.ceil(math.log2(max(2, n_table))) + 1)
    def search_one(key_hi, key_lo):
        state = (jnp.int32(0), jnp.int32(n_table), key_hi, key_lo)
        (lo_b, _, _, _), _ = jax.lax.scan(body, state, None, length=n_iter)
        return lo_b
    pos = jax.vmap(search_one)(hi, lo)
    pos = jnp.clip(pos, 0, n_table - 1)
    found = (table_hi[pos] == hi) & (table_lo[pos] == lo)
    return pos, found


def lookup_rows_in_parts(hi: jnp.ndarray, lo: jnp.ndarray, pid: jnp.ndarray,
                         table_hi: jnp.ndarray, table_lo: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row lookup against a STACK of sorted key tables: row i is searched
    in partition ``pid[i]`` of the ``(P, C)`` tables. This is the
    partition-local probe of the routed row lookup — each row's owning
    partition is a pure function of its key (``cube.partition_ids``), so
    one binary search in the right partition replaces a search over the
    reassembled view. Plain traceable function (no jit wrapper): it runs
    inline inside the fused query/row-lookup programs.

    Returns (pos, found) like :func:`lookup_rows_in_table`, with ``pos``
    indexing into partition ``pid[i]``'s slot axis."""
    n_table = table_hi.shape[1]

    def search_one(key_hi, key_lo, p):
        def body(state, _):
            lo_b, hi_b = state
            mid = (lo_b + hi_b) // 2
            thi = table_hi[p, mid]
            tlo = table_lo[p, mid]
            less = (thi < key_hi) | ((thi == key_hi) & (tlo < key_lo))
            lo_b = jnp.where(less, mid + 1, lo_b)
            hi_b = jnp.where(less, hi_b, mid)
            return (lo_b, hi_b), None

        n_iter = max(1, math.ceil(math.log2(max(2, n_table))) + 1)
        (lo_b, _), _ = jax.lax.scan(body, (jnp.int32(0), jnp.int32(n_table)),
                                    None, length=n_iter)
        return lo_b

    pos = jax.vmap(search_one)(hi, lo, pid)
    pos = jnp.clip(pos, 0, n_table - 1)
    found = (table_hi[pid, pos] == hi) & (table_lo[pid, pos] == lo)
    return pos, found

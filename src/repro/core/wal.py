"""Write-ahead batch log for the online engines.

Every state-mutating stream operation (``ingest``, ``retract``, ``evict``)
is journaled to an append-only segment file BEFORE its commit barrier
acknowledges, so a crashed engine can be rebuilt bitwise: restore the last
good checkpoint, then replay the WAL tail in order through the normal
ingest path.  Because estimates are deterministic functions of canonical
group content alone (the bit-identity contract), restore-then-replay is
bitwise equal to the never-crashed twin.

Record format (little-endian), one record per operation::

    magic   u32   0x5A51_574C ("ZQWL")
    kind    u8    1 = INGEST, 2 = RETRACT, 3 = EVICT
    epoch   u32   primary term that created the record (fencing token)
    seq     u64   monotonically increasing, never reused
    len     u32   payload byte length
    crc     u32   crc32 of payload
    hcrc    u32   crc32 of the 25 header bytes above
    payload len bytes

Batch payloads are a JSON column header (names, dtypes, row count, valid
bitmap dtype) followed by the raw column bytes in header order — built from
HOST numpy data, so appending a record never touches a device buffer (the
ingest hot path stays transfer-clean).  Evict payloads are a small JSON
object (``{"ttl": n}``).

Durability rule: ``append_*`` writes and flushes; :meth:`BatchLog.sync`
fsyncs.  The durable engine fsyncs before the commit barrier acknowledges
(per-record in synchronous mode, once per commit barrier in MVCC overlap
mode — either way no commit is acknowledged before its records are on
disk; lint rule ZQL008 checks the ordering statically).

Epoch fencing: every record carries the primary *epoch* (term) that
created it.  Failover bumps the cluster epoch and :meth:`BatchLog.fence`s
the old primary's log, after which any append from the stale writer raises
:class:`StaleEpochError` — a zombie primary that wakes up after promotion
cannot extend a log that replication has already moved past.  Epochs are
non-decreasing within a log; a decrease is treated as corruption.

Segments are named ``wal-<startseq>.log``; :meth:`BatchLog.rotate` starts
a new segment (called at checkpoint publish) and :meth:`BatchLog.gc`
deletes segments made redundant by a DURABLE checkpoint.  The reader
tolerates a torn tail (a truncated or CRC-bad final record is discarded);
corruption in the middle of the log — a bad record with a valid record
after it — raises :class:`WalCorruption`, because silently skipping a
record would break replay bit-identity.

Tail reads: :meth:`BatchLog.read` re-parses the whole log; replication
shipping and degraded replay instead keep a :class:`TailCursor` (segment
start, byte offset, last seq) and call :meth:`BatchLog.read_tail`, which
scans only bytes appended since the previous call — O(new bytes), not
O(log).  ``BatchLog.bytes_scanned`` counts bytes parsed by either path so
tests can pin that property.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

MAGIC = 0x5A51574C
KIND_INGEST = 1
KIND_RETRACT = 2
KIND_EVICT = 3

_HEADER = struct.Struct("<IBIQII")      # magic, kind, epoch, seq, len, crc
_HCRC = struct.Struct("<I")             # crc32 of the header bytes
_HEADER_SIZE = _HEADER.size + _HCRC.size


class WalCorruption(IOError):
    """A WAL record failed validation with valid records after it."""


class StaleEpochError(IOError):
    """A write carried an epoch below the log's fence — the writer was
    deposed by a promotion and must not extend this log."""


def _encode_batch(columns: Dict[str, np.ndarray],
                  valid: np.ndarray) -> bytes:
    cols = {name: np.ascontiguousarray(a) for name, a in columns.items()}
    v = np.ascontiguousarray(np.asarray(valid))
    header = {
        "nrows": int(v.shape[0]),
        "valid_dtype": str(v.dtype),
        "columns": [[name, str(a.dtype)] for name, a in cols.items()],
    }
    hb = json.dumps(header, sort_keys=True).encode()
    parts = [struct.pack("<I", len(hb)), hb, v.tobytes()]
    parts += [cols[name].tobytes() for name, _ in header["columns"]]
    return b"".join(parts)


def _decode_batch(payload: bytes) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    (hlen,) = struct.unpack_from("<I", payload, 0)
    header = json.loads(payload[4:4 + hlen].decode())
    n = header["nrows"]
    off = 4 + hlen
    valid = np.frombuffer(payload, dtype=header["valid_dtype"],
                          count=n, offset=off).copy()
    off += valid.itemsize * n
    columns = {}
    for name, dtype in header["columns"]:
        a = np.frombuffer(payload, dtype=dtype, count=n, offset=off)
        columns[name] = a.copy()
        off += a.itemsize * n
    return columns, valid


class Record:
    """One decoded WAL record."""

    __slots__ = ("kind", "seq", "payload", "epoch")

    def __init__(self, kind: int, seq: int, payload: bytes, epoch: int = 1):
        self.kind = kind
        self.seq = seq
        self.payload = payload
        self.epoch = epoch

    def batch(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        return _decode_batch(self.payload)

    def evict_ttl(self) -> int:
        return int(json.loads(self.payload.decode())["ttl"])


@dataclasses.dataclass
class TailCursor:
    """Resumable position in a WAL: scan only bytes after (seg_start,
    offset), deduplicating by ``last_seq``. A fresh cursor reads the whole
    log; thereafter each :meth:`BatchLog.read_tail` call advances it past
    everything cleanly parsed, so repeated tail reads cost O(new bytes)."""

    seg_start: int = 0
    offset: int = 0
    last_seq: int = 0


def encode_record(rec: Record) -> bytes:
    """Wire/segment encoding of one record — exactly the bytes a segment
    file stores, so shipped spans and local segments are interchangeable."""
    head = _HEADER.pack(MAGIC, rec.kind, rec.epoch, rec.seq,
                        len(rec.payload), zlib.crc32(rec.payload))
    return head + _HCRC.pack(zlib.crc32(head)) + rec.payload


def encode_records(records: Iterable[Record]) -> bytes:
    return b"".join(encode_record(r) for r in records)


def decode_records(data: bytes, offset: int = 0,
                   max_records: Optional[int] = None,
                   ) -> Tuple[List[Record], int, bool]:
    """Incrementally parse records out of ``data`` starting at ``offset``.

    Returns ``(records, end, clean)`` where ``end`` is the byte offset
    just past the last cleanly decoded record.  ``clean=False`` means
    parsing stopped at ``end`` on an incomplete or CRC-bad record (a torn
    tail if nothing valid follows — callers that must distinguish mid-log
    damage run :func:`_scan_rest` over the remainder).  Never raises: this
    is the shared parser for segment files AND shipped byte spans, and a
    truncated ship is routine, not fatal.
    """
    records: List[Record] = []
    off = offset
    while off < len(data):
        if max_records is not None and len(records) >= max_records:
            break
        if off + _HEADER_SIZE > len(data):
            return records, off, False                  # torn header
        magic, kind, epoch, seq, length, crc = _HEADER.unpack_from(data, off)
        (hcrc,) = _HCRC.unpack_from(data, off + _HEADER.size)
        header_ok = (magic == MAGIC
                     and zlib.crc32(data[off:off + _HEADER.size]) == hcrc)
        if not header_ok:
            return records, off, False
        start = off + _HEADER_SIZE
        end = start + length
        if end > len(data):
            return records, off, False                  # torn payload
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            return records, off, False
        records.append(Record(kind, seq, payload, epoch))
        off = end
    return records, off, True


def _segment_files(directory: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    segs = []
    for f in os.listdir(directory):
        if f.startswith("wal-") and f.endswith(".log"):
            try:
                segs.append((int(f[4:-4]), f))
            except ValueError:
                continue
    return sorted(segs)


def _read_segment(path: str) -> Tuple[List[Record], bool]:
    """Decode one segment. Returns (records, clean); clean=False means a
    torn tail was discarded. Raises WalCorruption for mid-log damage."""
    with open(path, "rb") as f:
        data = f.read()
    records, end, clean = decode_records(data)
    if not clean:
        _scan_rest(path, data, end)                     # raises if mid-log
    return records, clean


def _scan_rest(path: str, data: bytes, off: int) -> None:
    """A record at ``off`` failed validation. If any VALID record exists
    after it the damage is mid-log, not a torn tail: refuse to replay."""
    magic_bytes = struct.pack("<I", MAGIC)
    pos = data.find(magic_bytes, off + 1)
    while pos != -1:
        if pos + _HEADER_SIZE <= len(data):
            (hcrc,) = _HCRC.unpack_from(data, pos + _HEADER.size)
            if zlib.crc32(data[pos:pos + _HEADER.size]) == hcrc:
                raise WalCorruption(
                    f"corrupt WAL record mid-log in {path} at byte {off} "
                    f"(valid record follows at byte {pos}); refusing to "
                    f"replay out of order")
        pos = data.find(magic_bytes, pos + 1)


class BatchLog:
    """Append-only, fsync'd, CRC-protected, epoch-fenced operation
    journal."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        segs = _segment_files(directory)
        self.last_seq = 0
        self.last_epoch = 0             # epoch of the last record on disk
        self.bytes_scanned = 0          # bytes parsed by read()/read_tail()
        self._fence_epoch = 0
        for _, fname in segs:
            recs, _ = _read_segment(os.path.join(directory, fname))
            if recs:
                self.last_seq = max(self.last_seq, recs[-1].seq)
                self.last_epoch = max(self.last_epoch, recs[-1].epoch)
        self.epoch = max(1, self.last_epoch)    # writer epoch for appends
        self._fh = None
        self._dirty = False

    # -- epochs -----------------------------------------------------------
    def set_epoch(self, epoch: int) -> None:
        """Adopt a new (promotion) epoch for records appended from now on.
        Epochs only move forward."""
        if epoch < self.epoch:
            raise ValueError(f"epoch moves forward only: "
                             f"{self.epoch} -> {epoch}")
        self.epoch = int(epoch)

    def fence(self, min_epoch: int) -> None:
        """Revoke write access for any writer below ``min_epoch``.  Called
        on the deposed primary's log at promotion: its in-memory handle
        keeps the old epoch, so every later append raises
        :class:`StaleEpochError` — the zombie cannot diverge the history
        replication already shipped."""
        self._fence_epoch = max(self._fence_epoch, int(min_epoch))

    def set_base(self, seq: int, epoch: int = 0) -> None:
        """Declare that history up to ``seq`` lives in a bootstrap
        snapshot rather than in this log (replica bootstrap): the next
        append continues the PRIMARY's numbering at ``seq + 1``. Only
        legal on an empty log — an existing record already fixes the
        numbering."""
        if self.last_seq != 0 or _segment_files(self.directory):
            raise ValueError(f"set_base on non-empty log {self.directory}")
        self.last_seq = int(seq)
        self.last_epoch = int(epoch)
        if epoch:
            self.epoch = max(self.epoch, int(epoch))

    def _check_fence(self, epoch: int) -> None:
        if epoch < self._fence_epoch:
            raise StaleEpochError(
                f"append at epoch {epoch} rejected: log fenced at epoch "
                f">= {self._fence_epoch} ({self.directory})")

    # -- writing ----------------------------------------------------------
    def _file(self):
        if self._fh is None:
            start = self.last_seq + 1
            path = os.path.join(self.directory, f"wal-{start:012d}.log")
            self._fh = open(path, "ab")
        return self._fh

    def _append(self, kind: int, payload: bytes, sync: bool) -> int:
        self._check_fence(self.epoch)
        rec = Record(kind, self.last_seq + 1, payload, self.epoch)
        fh = self._file()
        fh.write(encode_record(rec))
        fh.flush()
        self._dirty = True
        if sync:
            self.sync()
        self.last_seq = rec.seq
        self.last_epoch = rec.epoch
        return rec.seq

    def append_batch(self, kind: int, columns: Dict[str, np.ndarray],
                     valid: np.ndarray, sync: bool = True) -> int:
        """Journal an ingest/retract batch from HOST numpy columns."""
        if kind not in (KIND_INGEST, KIND_RETRACT):
            raise ValueError(f"append_batch kind must be ingest/retract, "
                             f"got {kind}")
        return self._append(kind, _encode_batch(columns, valid), sync)

    def append_evict(self, ttl: int, sync: bool = True) -> int:
        return self._append(KIND_EVICT, json.dumps({"ttl": int(ttl)}).encode(),
                            sync)

    def append_record(self, rec: Record, sync: bool = True) -> int:
        """Append an already-sequenced record verbatim — the replica-side
        durability step for shipped records, which must keep the PRIMARY's
        seq and epoch so the follower log stays a byte-exact suffix copy.
        Enforces seq contiguity and epoch monotonicity; a fenced log
        rejects records below the fence."""
        self._check_fence(rec.epoch)
        if rec.seq != self.last_seq + 1:
            raise WalCorruption(
                f"shipped record seq {rec.seq} does not extend local log "
                f"at seq {self.last_seq} ({self.directory})")
        if rec.epoch < self.last_epoch:
            raise StaleEpochError(
                f"shipped record epoch {rec.epoch} below log epoch "
                f"{self.last_epoch} ({self.directory})")
        fh = self._file()
        fh.write(encode_record(rec))
        fh.flush()
        self._dirty = True
        if sync:
            self.sync()
        self.last_seq = rec.seq
        self.last_epoch = rec.epoch
        return rec.seq

    def sync(self) -> None:
        """fsync the open segment — the durability point for every record
        appended since the last sync. Must complete before the commit
        barrier covering those records acknowledges (ZQL008)."""
        if self._fh is not None and self._dirty:
            os.fsync(self._fh.fileno())
            self._dirty = False

    def mark(self) -> Tuple[int, bool, int]:
        """Position token for :meth:`rollback` — taken BEFORE an append
        whose covered operation might still be rejected by the engine."""
        size = 0
        if self._fh is not None:
            self._fh.flush()
            size = self._fh.tell()
        return (self.last_seq, self._fh is not None, size)

    def rollback(self, mark: Tuple[int, bool, int]) -> None:
        """Truncate records appended after ``mark``. Used when the
        operation covered by the append FAILED before its commit barrier
        could acknowledge (e.g. a rejected retraction): the record must
        not survive, or replay would re-raise the same failure — the log
        always equals the applied-operation sequence."""
        seq, was_open, size = mark
        if self.last_seq == seq or self._fh is None:
            return
        if not was_open:
            # the rolled-back record opened this segment: drop the file
            path = self._fh.name
            self._fh.close()
            self._fh = None
            os.remove(path)
        else:
            self._fh.truncate(size)
            self._fh.seek(size)
            os.fsync(self._fh.fileno())
        self._dirty = False
        self.last_seq = seq

    def rotate(self) -> None:
        """Close the current segment; the next append opens a new one.
        Called at checkpoint publish so gc() can drop whole segments."""
        self.sync()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def gc(self, upto_seq: int) -> None:
        """Delete segments whose every record is <= ``upto_seq`` (i.e. is
        covered by a checkpoint that is already DURABLE on disk)."""
        segs = _segment_files(self.directory)
        for i, (start, fname) in enumerate(segs):
            nxt = segs[i + 1][0] if i + 1 < len(segs) else None
            covered = (nxt is not None and nxt - 1 <= upto_seq)
            if covered:
                os.remove(os.path.join(self.directory, fname))

    def close(self) -> None:
        self.rotate()

    # -- reading ----------------------------------------------------------
    def read(self, after_seq: int = 0) -> List[Record]:
        """All records with seq > ``after_seq``, in order. Tolerates a torn
        tail in the LAST segment only; raises WalCorruption otherwise."""
        segs = _segment_files(self.directory)
        out: List[Record] = []
        for i, (_, fname) in enumerate(segs):
            path = os.path.join(self.directory, fname)
            self.bytes_scanned += os.path.getsize(path)
            recs, clean = _read_segment(path)
            if not clean and i + 1 < len(segs):
                raise WalCorruption(
                    f"torn/corrupt records in non-final WAL segment {path}")
            out.extend(recs)
        prev_seq = prev_epoch = None
        for r in out:
            if prev_seq is not None and r.seq <= prev_seq:
                raise WalCorruption(
                    f"non-monotonic WAL sequence {prev_seq} -> {r.seq} in "
                    f"{self.directory}")
            if prev_epoch is not None and r.epoch < prev_epoch:
                raise WalCorruption(
                    f"decreasing WAL epoch {prev_epoch} -> {r.epoch} in "
                    f"{self.directory}")
            prev_seq, prev_epoch = r.seq, r.epoch
        return [r for r in out if r.seq > after_seq]

    def read_tail(self, cursor: TailCursor,
                  max_records: Optional[int] = None,
                  ) -> Tuple[List[Record], TailCursor]:
        """Records appended since ``cursor``, plus the advanced cursor.

        Scans only bytes past the cursor position: the shipping loop and
        degraded replay call this once per tick, so tail reads must cost
        O(new bytes), not O(log).  A torn final record leaves the cursor
        BEFORE the tear — a later call re-reads it once the remaining
        bytes arrive (an in-flight append mid-flush looks exactly like a
        torn tail).  Mid-log corruption raises :class:`WalCorruption`.
        """
        if self._fh is not None:
            self._fh.flush()
        segs = _segment_files(self.directory)
        out: List[Record] = []
        cur = cursor
        for i, (start, fname) in enumerate(segs):
            if start < cur.seg_start:
                continue                        # fully consumed earlier
            if max_records is not None and len(out) >= max_records:
                break
            path = os.path.join(self.directory, fname)
            offset = cur.offset if start == cur.seg_start else 0
            with open(path, "rb") as f:
                f.seek(offset)
                data = f.read()
            self.bytes_scanned += len(data)
            budget = None if max_records is None else max_records - len(out)
            recs, end, clean = decode_records(data, 0, budget)
            if not clean:
                if i + 1 < len(segs):
                    raise WalCorruption(
                        f"torn/corrupt records in non-final WAL segment "
                        f"{path}")
                _scan_rest(path, data, end)     # raises if mid-log damage
            for r in recs:
                if r.seq <= cur.last_seq:
                    continue                    # re-shipped duplicate
                if out and r.seq != out[-1].seq + 1:
                    raise WalCorruption(
                        f"non-contiguous WAL sequence {out[-1].seq} -> "
                        f"{r.seq} in {path}")
                out.append(r)
            last = out[-1].seq if out else cur.last_seq
            cur = TailCursor(start, offset + end, last)
        return out, cur


def read_log(directory: str, after_seq: int = 0) -> List[Record]:
    """Read records from a WAL directory without opening it for append."""
    log = BatchLog.__new__(BatchLog)
    log.directory = directory
    log._fh = None
    log._dirty = False
    log.last_seq = 0
    log.last_epoch = 0
    log.bytes_scanned = 0
    log._fence_epoch = 0
    return BatchLog.read(log, after_seq)

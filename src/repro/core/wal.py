"""Write-ahead batch log for the online engines.

Every state-mutating stream operation (``ingest``, ``retract``, ``evict``)
is journaled to an append-only segment file BEFORE its commit barrier
acknowledges, so a crashed engine can be rebuilt bitwise: restore the last
good checkpoint, then replay the WAL tail in order through the normal
ingest path.  Because estimates are deterministic functions of canonical
group content alone (the bit-identity contract), restore-then-replay is
bitwise equal to the never-crashed twin.

Record format (little-endian), one record per operation::

    magic   u32   0x5A51_574C ("ZQWL")
    kind    u8    1 = INGEST, 2 = RETRACT, 3 = EVICT
    seq     u64   monotonically increasing, never reused
    len     u32   payload byte length
    crc     u32   crc32 of payload
    hcrc    u32   crc32 of the 21 header bytes above
    payload len bytes

Batch payloads are a JSON column header (names, dtypes, row count, valid
bitmap dtype) followed by the raw column bytes in header order — built from
HOST numpy data, so appending a record never touches a device buffer (the
ingest hot path stays transfer-clean).  Evict payloads are a small JSON
object (``{"ttl": n}``).

Durability rule: ``append_*`` writes and flushes; :meth:`BatchLog.sync`
fsyncs.  The durable engine fsyncs before the commit barrier acknowledges
(per-record in synchronous mode, once per commit barrier in MVCC overlap
mode — either way no commit is acknowledged before its records are on
disk; lint rule ZQL008 checks the ordering statically).

Segments are named ``wal-<startseq>.log``; :meth:`BatchLog.rotate` starts
a new segment (called at checkpoint publish) and :meth:`BatchLog.gc`
deletes segments made redundant by a DURABLE checkpoint.  The reader
tolerates a torn tail (a truncated or CRC-bad final record is discarded);
corruption in the middle of the log — a bad record with a valid record
after it — raises :class:`WalCorruption`, because silently skipping a
record would break replay bit-identity.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, List, Tuple

import numpy as np

MAGIC = 0x5A51574C
KIND_INGEST = 1
KIND_RETRACT = 2
KIND_EVICT = 3

_HEADER = struct.Struct("<IBQII")       # magic, kind, seq, len, crc
_HCRC = struct.Struct("<I")             # crc32 of the header bytes
_HEADER_SIZE = _HEADER.size + _HCRC.size


class WalCorruption(IOError):
    """A WAL record failed validation with valid records after it."""


def _encode_batch(columns: Dict[str, np.ndarray],
                  valid: np.ndarray) -> bytes:
    cols = {name: np.ascontiguousarray(a) for name, a in columns.items()}
    v = np.ascontiguousarray(np.asarray(valid))
    header = {
        "nrows": int(v.shape[0]),
        "valid_dtype": str(v.dtype),
        "columns": [[name, str(a.dtype)] for name, a in cols.items()],
    }
    hb = json.dumps(header, sort_keys=True).encode()
    parts = [struct.pack("<I", len(hb)), hb, v.tobytes()]
    parts += [cols[name].tobytes() for name, _ in header["columns"]]
    return b"".join(parts)


def _decode_batch(payload: bytes) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    (hlen,) = struct.unpack_from("<I", payload, 0)
    header = json.loads(payload[4:4 + hlen].decode())
    n = header["nrows"]
    off = 4 + hlen
    valid = np.frombuffer(payload, dtype=header["valid_dtype"],
                          count=n, offset=off).copy()
    off += valid.itemsize * n
    columns = {}
    for name, dtype in header["columns"]:
        a = np.frombuffer(payload, dtype=dtype, count=n, offset=off)
        columns[name] = a.copy()
        off += a.itemsize * n
    return columns, valid


class Record:
    """One decoded WAL record."""

    __slots__ = ("kind", "seq", "payload")

    def __init__(self, kind: int, seq: int, payload: bytes):
        self.kind = kind
        self.seq = seq
        self.payload = payload

    def batch(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        return _decode_batch(self.payload)

    def evict_ttl(self) -> int:
        return int(json.loads(self.payload.decode())["ttl"])


def _segment_files(directory: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    segs = []
    for f in os.listdir(directory):
        if f.startswith("wal-") and f.endswith(".log"):
            try:
                segs.append((int(f[4:-4]), f))
            except ValueError:
                continue
    return sorted(segs)


def _read_segment(path: str) -> Tuple[List[Record], bool]:
    """Decode one segment. Returns (records, clean); clean=False means a
    torn tail was discarded. Raises WalCorruption for mid-log damage."""
    with open(path, "rb") as f:
        data = f.read()
    records: List[Record] = []
    off = 0
    while off < len(data):
        if off + _HEADER_SIZE > len(data):
            return records, False                       # torn header
        magic, kind, seq, length, crc = _HEADER.unpack_from(data, off)
        (hcrc,) = _HCRC.unpack_from(data, off + _HEADER.size)
        header_ok = (magic == MAGIC
                     and zlib.crc32(data[off:off + _HEADER.size]) == hcrc)
        if not header_ok:
            _scan_rest(path, data, off)                 # raises if mid-log
            return records, False
        start = off + _HEADER_SIZE
        end = start + length
        if end > len(data):
            return records, False                       # torn payload
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            _scan_rest(path, data, end)                 # raises if mid-log
            return records, False
        records.append(Record(kind, seq, payload))
        off = end
    return records, True


def _scan_rest(path: str, data: bytes, off: int) -> None:
    """A record at ``off`` failed validation. If any VALID record exists
    after it the damage is mid-log, not a torn tail: refuse to replay."""
    magic_bytes = struct.pack("<I", MAGIC)
    pos = data.find(magic_bytes, off + 1)
    while pos != -1:
        if pos + _HEADER_SIZE <= len(data):
            (hcrc,) = _HCRC.unpack_from(data, pos + _HEADER.size)
            if zlib.crc32(data[pos:pos + _HEADER.size]) == hcrc:
                raise WalCorruption(
                    f"corrupt WAL record mid-log in {path} at byte {off} "
                    f"(valid record follows at byte {pos}); refusing to "
                    f"replay out of order")
        pos = data.find(magic_bytes, pos + 1)


class BatchLog:
    """Append-only, fsync'd, CRC-protected operation journal."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        segs = _segment_files(directory)
        self.last_seq = 0
        for _, fname in segs:
            recs, _ = _read_segment(os.path.join(directory, fname))
            if recs:
                self.last_seq = max(self.last_seq, recs[-1].seq)
        self._fh = None
        self._dirty = False

    # -- writing ----------------------------------------------------------
    def _file(self):
        if self._fh is None:
            start = self.last_seq + 1
            path = os.path.join(self.directory, f"wal-{start:012d}.log")
            self._fh = open(path, "ab")
        return self._fh

    def _append(self, kind: int, payload: bytes, sync: bool) -> int:
        seq = self.last_seq + 1
        head = _HEADER.pack(MAGIC, kind, seq, len(payload),
                            zlib.crc32(payload))
        fh = self._file()
        fh.write(head + _HCRC.pack(zlib.crc32(head)) + payload)
        fh.flush()
        self._dirty = True
        if sync:
            self.sync()
        self.last_seq = seq
        return seq

    def append_batch(self, kind: int, columns: Dict[str, np.ndarray],
                     valid: np.ndarray, sync: bool = True) -> int:
        """Journal an ingest/retract batch from HOST numpy columns."""
        if kind not in (KIND_INGEST, KIND_RETRACT):
            raise ValueError(f"append_batch kind must be ingest/retract, "
                             f"got {kind}")
        return self._append(kind, _encode_batch(columns, valid), sync)

    def append_evict(self, ttl: int, sync: bool = True) -> int:
        return self._append(KIND_EVICT, json.dumps({"ttl": int(ttl)}).encode(),
                            sync)

    def sync(self) -> None:
        """fsync the open segment — the durability point for every record
        appended since the last sync. Must complete before the commit
        barrier covering those records acknowledges (ZQL008)."""
        if self._fh is not None and self._dirty:
            os.fsync(self._fh.fileno())
            self._dirty = False

    def mark(self) -> Tuple[int, bool, int]:
        """Position token for :meth:`rollback` — taken BEFORE an append
        whose covered operation might still be rejected by the engine."""
        size = 0
        if self._fh is not None:
            self._fh.flush()
            size = self._fh.tell()
        return (self.last_seq, self._fh is not None, size)

    def rollback(self, mark: Tuple[int, bool, int]) -> None:
        """Truncate records appended after ``mark``. Used when the
        operation covered by the append FAILED before its commit barrier
        could acknowledge (e.g. a rejected retraction): the record must
        not survive, or replay would re-raise the same failure — the log
        always equals the applied-operation sequence."""
        seq, was_open, size = mark
        if self.last_seq == seq or self._fh is None:
            return
        if not was_open:
            # the rolled-back record opened this segment: drop the file
            path = self._fh.name
            self._fh.close()
            self._fh = None
            os.remove(path)
        else:
            self._fh.truncate(size)
            self._fh.seek(size)
            os.fsync(self._fh.fileno())
        self._dirty = False
        self.last_seq = seq

    def rotate(self) -> None:
        """Close the current segment; the next append opens a new one.
        Called at checkpoint publish so gc() can drop whole segments."""
        self.sync()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def gc(self, upto_seq: int) -> None:
        """Delete segments whose every record is <= ``upto_seq`` (i.e. is
        covered by a checkpoint that is already DURABLE on disk)."""
        segs = _segment_files(self.directory)
        for i, (start, fname) in enumerate(segs):
            nxt = segs[i + 1][0] if i + 1 < len(segs) else None
            covered = (nxt is not None and nxt - 1 <= upto_seq)
            if covered:
                os.remove(os.path.join(self.directory, fname))

    def close(self) -> None:
        self.rotate()

    # -- reading ----------------------------------------------------------
    def read(self, after_seq: int = 0) -> List[Record]:
        """All records with seq > ``after_seq``, in order. Tolerates a torn
        tail in the LAST segment only; raises WalCorruption otherwise."""
        segs = _segment_files(self.directory)
        out: List[Record] = []
        for i, (_, fname) in enumerate(segs):
            path = os.path.join(self.directory, fname)
            recs, clean = _read_segment(path)
            if not clean and i + 1 < len(segs):
                raise WalCorruption(
                    f"torn/corrupt records in non-final WAL segment {path}")
            out.extend(recs)
        prev = None
        for r in out:
            if prev is not None and r.seq <= prev:
                raise WalCorruption(
                    f"non-monotonic WAL sequence {prev} -> {r.seq} in "
                    f"{self.directory}")
            prev = r.seq
        return [r for r in out if r.seq > after_seq]


def read_log(directory: str, after_seq: int = 0) -> List[Record]:
    """Read records from a WAL directory without opening it for append."""
    log = BatchLog.__new__(BatchLog)
    log.directory = directory
    log._fh = None
    log._dirty = False
    log.last_seq = 0
    return BatchLog.read(log, after_seq)

"""Durable engine wrapper: WAL + async canonical checkpoints + recovery.

:class:`DurableEngine` wraps either online engine
(:class:`repro.core.online.OnlineEngine` /
:class:`~repro.core.online.PartitionedOnlineEngine`) and makes its state
survive process death with BITWISE-exact recovery semantics:

* every ``ingest``/``retract``/``evict`` is journaled to the write-ahead
  batch log (:mod:`repro.core.wal`) from HOST numpy data before it is
  dispatched — the fsync lands before the operation's commit barrier
  acknowledges (per record in synchronous mode; once per barrier in MVCC
  overlap mode), and the journaling itself never touches a device buffer,
  so the overlap ingest hot path stays 1 dispatch / 0 host syncs;
* :meth:`DurableEngine.checkpoint` snapshots the CANONICAL committed
  state (``OnlineEngine.export_canonical`` — layout-free, key-sorted,
  zero-count groups preserved) and hands the host tree to the
  :class:`repro.checkpoint.ckpt.AsyncSaver`: the disk write overlaps
  subsequent ingests, and the one labeled fetch lives here, never on the
  ingest path;
* :meth:`DurableEngine.recover` restores the newest restorable
  checkpoint (CRC-corrupt steps fall back to older ones, then to an
  empty engine + full-log replay) into a FRESH engine of ANY layout —
  replicated checkpoints restore into partitioned engines at different
  ``n_parts``/device counts via the canonical compaction contract — and
  replays the WAL tail in order through the normal ingest path, so the
  recovered engine's queries are bitwise equal to the never-crashed
  twin's;
* during a staged replay (``degraded_replay=True``) the wrapper reports
  ``degraded=True``: :class:`repro.core.serving.ServingEngine` keeps
  answering from the restored snapshot with results tagged degraded
  until :meth:`replay_step` drains the queue.

Fault-injection hooks (:meth:`_point`) let ``tests/fault_injection.py``
crash the wrapper deterministically at every interesting boundary.
"""
from __future__ import annotations

import json
import os
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import wal as wal_mod
from repro.core.wal import KIND_EVICT, KIND_INGEST, KIND_RETRACT
from repro.data.columnar import Table

#: contract-lint scoping: WAL-ordering rule ZQL008 applies here.
__engine_owned__ = True


def _pack_snapshot(snap: dict, wal_seq: int) -> dict:
    """Flatten an ``export_canonical`` snapshot into a checkpointable
    pytree of numpy arrays plus a JSON meta blob (stored as a uint8
    array so it rides the same CRC-validated shard)."""
    tree: Dict[str, Any] = {}
    names = list(snap["views"])
    for i, name in enumerate(names):
        v = snap["views"][name]
        ent = {"hi": v["hi"], "lo": v["lo"], "touch": v["touch"],
               "stats": dict(v["stats"])}
        if "keep" in v:
            ent["keep"] = v["keep"]
        tree[f"v{i}"] = ent
    meta = {"wal_seq": int(wal_seq), "view_names": names,
            "fingerprint": snap["fingerprint"],
            "scalars": {k: int(x) for k, x in snap["scalars"].items()},
            "cache": [[t, None if sub is None else
                       [[d, list(bs)] for d, bs in sub]]
                      for t, sub, _ in snap.get("cache", ())],
            "stream": None, "rows": "rows" in snap}
    if "stream" in snap:
        s = snap["stream"]
        meta["stream"] = {"n_batches": int(s["n_batches"]),
                          "capacity": int(s["capacity"])}
        tree["stream"] = {"res": dict(s["res"]), "pri": s["pri"],
                          "n": s["n"], "sums": dict(s["sums"]),
                          "sumsqs": dict(s["sumsqs"])}
    if "rows" in snap:
        tree["rows"] = {"cols": dict(snap["rows"]["cols"]),
                        "valid": snap["rows"]["valid"]}
    for i, (_, _, est) in enumerate(snap.get("cache", ())):
        tree[f"cache{i}"] = {k: np.asarray(x) for k, x in est.items()}
    tree["meta"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8).copy()
    _assert_clean_keys(tree)
    return tree


def _assert_clean_keys(tree, path: str = "") -> None:
    """The npz shard writer folds "/" into "__" and back; a stat/column
    name containing "__" would corrupt that round trip, so refuse it."""
    if not isinstance(tree, dict):
        return
    for k, v in tree.items():
        if "__" in k or "/" in k:
            raise ValueError(
                f"snapshot key {path + k!r} contains '__' or '/' — "
                f"unsupported by the checkpoint shard layout")
        _assert_clean_keys(v, path + k + ".")


def _flatten_tree(tree) -> Dict[str, np.ndarray]:
    """Flatten a packed snapshot to the flat ``a/b/c``-keyed array dict a
    checkpoint restore yields, so in-memory bootstrap hand-off and
    on-disk restore share one :func:`_unpack_snapshot` path."""
    return {key: np.asarray(leaf)
            for key, leaf in ckpt_mod._tree_paths(tree)}


def _unflatten(arrays: Dict[str, np.ndarray], prefix: str) -> dict:
    """Nested dict of every flat-key array under ``prefix/``."""
    out: dict = {}
    pre = prefix + "/"
    for key, a in arrays.items():
        if not key.startswith(pre):
            continue
        parts = key[len(pre):].split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = a
    return out


def _unpack_snapshot(arrays: Dict[str, np.ndarray]) -> Tuple[dict, int]:
    """Inverse of :func:`_pack_snapshot`: (canonical snapshot, wal_seq)."""
    meta = json.loads(bytes(arrays["meta"]).decode())
    views = {}
    for i, name in enumerate(meta["view_names"]):
        ent = _unflatten(arrays, f"v{i}")
        views[name] = ent
    snap = dict(views=views, scalars=meta["scalars"],
                fingerprint=meta["fingerprint"])
    if meta["stream"] is not None:
        s = _unflatten(arrays, "stream")
        s.update(meta["stream"])
        snap["stream"] = s
    if meta["rows"]:
        snap["rows"] = _unflatten(arrays, "rows")
    cache = []
    for i, (t, sub) in enumerate(meta["cache"]):
        frozen = (None if sub is None else
                  tuple((d, tuple(int(b) for b in bs)) for d, bs in sub))
        est = {k: a[()] if getattr(a, "ndim", 0) == 0 else a
               for k, a in _unflatten(arrays, f"cache{i}").items()}
        est["state_version"] = int(est["state_version"])
        cache.append((t, frozen, est))
    snap["cache"] = tuple(cache)
    return snap, int(meta["wal_seq"])


class DurableEngine:
    """WAL + checkpoint/restore wrapper around one online engine.

    Queries and attribute access proxy to the wrapped engine, so a
    ``ServingEngine(DurableEngine(engine, dir))`` serves exactly like
    ``ServingEngine(engine)`` — plus durability and degraded-mode tags.

    directory: holds ``wal/`` (segment files) and ``ckpt/`` (steps).
    saver:     an :class:`~repro.checkpoint.ckpt.AsyncSaver` (own retry
               policy) — a fresh default one if None.
    injector:  optional fault injector with a ``fire(point)`` method
               (``tests/fault_injection.py``); production passes None.
    """

    def __init__(self, engine, directory: str, saver=None, injector=None,
                 keep_last: int = 3, epoch: Optional[int] = None):
        self.engine = engine
        self.directory = directory
        self.wal = wal_mod.BatchLog(os.path.join(directory, "wal"))
        if epoch is not None:
            self.wal.set_epoch(epoch)
        self.ckpt_dir = os.path.join(directory, "ckpt")
        self.saver = saver if saver is not None else ckpt_mod.AsyncSaver()
        self.injector = injector
        self.keep_last = int(keep_last)
        self._ckpt_step = ckpt_mod.latest_step(self.ckpt_dir) or 0
        self._pending_ckpt: Optional[Tuple[int, int]] = None
        self._durable_seq = 0
        self._replay_cursor = wal_mod.TailCursor(last_seq=self.wal.last_seq)
        self.degraded = False

    @property
    def epoch(self) -> int:
        """The primary term stamped into appended WAL records."""
        return self.wal.epoch

    # ------------------------------------------------------ fault points
    def _point(self, name: str) -> None:
        if self.injector is not None:
            self.injector.fire(name)

    def _guard_degraded(self) -> None:
        if self.degraded:
            raise RuntimeError(
                "engine is replaying its WAL (degraded mode): drain "
                "replay_step() before ingesting new batches")

    # ---------------------------------------------------------- mutation
    def ingest(self, batch: Table, retract: bool = False):
        """Validate, journal (WAL append; fsync per record in synchronous
        mode), then dispatch through the wrapped engine. The journal is
        written from host numpy column data BEFORE any device work, so
        overlap-mode steady state stays 1 dispatch / 0 host syncs."""
        self._guard_degraded()
        self.engine.validate_batch(batch, retract=retract)
        cols = {c: np.asarray(batch.columns[c])
                for c in self.engine._row_cols}
        valid = np.asarray(batch.valid)
        overlap = bool(getattr(self.engine, "overlap", False))
        mark = self.wal.mark()
        self._point("wal.pre-append")
        self.wal.append_batch(KIND_RETRACT if retract else KIND_INGEST,
                              cols, valid, sync=not overlap)
        self._point("wal.post-append")
        if overlap and (retract or len(self.engine._inflight)
                        >= self.engine.max_inflight):
            # the engine will hit an internal commit barrier inside this
            # ingest: everything journaled so far must be on disk first
            self.wal.sync()
        try:
            rep = self.engine.ingest(batch, retract=retract)
        except ValueError:
            # the engine rejected the operation eagerly (config guard,
            # bad retraction) — its record must not survive, or replay
            # would re-raise the same failure mid-recovery
            self.wal.rollback(mark)
            raise
        self._point("ingest.post-dispatch")
        return rep

    def evict(self, ttl: int):
        self._guard_degraded()
        self._point("wal.pre-append")
        self.wal.append_evict(ttl, sync=True)   # evict is a commit barrier
        self._point("wal.post-append")
        return self.engine.evict(ttl)

    def commit(self):
        """MVCC commit barrier: fsync the journal FIRST, then commit —
        no batch is ever acknowledged before its WAL record is durable
        (lint rule ZQL008 checks this ordering statically)."""
        self.wal.sync()
        self._point("commit.pre")
        out = self.engine.commit()
        self._point("commit.post")
        return out

    # -------------------------------------------------------- checkpoint
    def checkpoint(self, wait: bool = False) -> int:
        """Snapshot the committed canonical state asynchronously.

        Synchronous part: fsync + commit (a checkpoint is a commit
        barrier), ONE labeled host fetch of the committed buffers
        (``export_canonical``), segment rotation. The disk write runs on
        the saver's background thread and overlaps subsequent ingests;
        WAL segments covered by the snapshot are garbage-collected only
        once the NEXT checkpoint call observes the save published (a
        checkpoint that never hit disk keeps its log tail replayable).
        Returns the checkpoint step id."""
        self._guard_degraded()
        self.wal.sync()
        self._finish_pending_ckpt()
        snap = self.engine.export_canonical()    # commits in-flight chain
        wal_seq = self.wal.last_seq
        self.wal.rotate()
        self._ckpt_step += 1
        tree = _pack_snapshot(snap, wal_seq)
        self._point("ckpt.pre-save")
        self.saver.save(tree, self._ckpt_step, self.ckpt_dir,
                        keep_last=self.keep_last)
        self._pending_ckpt = (self._ckpt_step, wal_seq)
        if wait:
            self._finish_pending_ckpt()
        return self._ckpt_step

    def _finish_pending_ckpt(self) -> None:
        if self._pending_ckpt is None:
            return
        step, seq = self._pending_ckpt
        self._pending_ckpt = None
        self.saver.wait()                        # re-raises a failed save
        self._durable_seq = seq
        self.wal.gc(self._durable_seq)

    def export_bootstrap(self) -> Dict[str, np.ndarray]:
        """Replica bootstrap snapshot: the committed canonical state plus
        the WAL seq it covers, flattened to the flat-key array dict a
        checkpoint restore yields.  A follower installs it through the
        identical ``_unpack_snapshot`` / ``install_canonical`` path used
        by crash recovery, so bootstrap inherits the cross-layout bitwise
        restore guarantee; shipping then resumes from the covered seq."""
        self._guard_degraded()
        self.wal.sync()
        snap = self.engine.export_canonical()    # commits in-flight chain
        return _flatten_tree(_pack_snapshot(snap, self.wal.last_seq))

    def close(self) -> None:
        if self._pending_ckpt is not None:
            self._finish_pending_ckpt()
        self.wal.close()

    # ---------------------------------------------------------- recovery
    @classmethod
    def recover(cls, engine, directory: str, degraded_replay: bool = False,
                **kw) -> "DurableEngine":
        """Rebuild from disk into ``engine`` (freshly constructed, ANY
        layout with the same schema fingerprint).

        Restores the newest checkpoint whose CRC validates — corrupt
        steps fall back to older ones, and with no restorable checkpoint
        the whole WAL replays into the empty engine — then replays every
        WAL record with seq > the snapshot's ``wal_seq`` in order through
        the normal ingest path. With ``degraded_replay=True`` the tail is
        queued instead: the wrapper serves from the restored snapshot
        with ``degraded=True`` until :meth:`replay_step` drains it."""
        d = cls(engine, directory, **kw)
        after_seq = 0
        step = ckpt_mod.latest_step(d.ckpt_dir)
        while step is not None:
            try:
                _, arrays = ckpt_mod.restore(d.ckpt_dir, step=step)
                snap, after_seq = _unpack_snapshot(arrays)
                engine.install_canonical(snap)
                break
            except ValueError as e:
                if "schema mismatch" in str(e):
                    raise        # wrong engine config, not disk damage
                older = [s for s in _all_steps(d.ckpt_dir) if s < step]
                step = max(older) if older else None
                after_seq = 0
            except (IOError, OSError, KeyError, zipfile.BadZipFile):
                # CRC-corrupt or torn step: fall back to an older one
                # (a flipped byte inside an npz surfaces as BadZipFile
                # before our own CRC validation even runs)
                older = [s for s in _all_steps(d.ckpt_dir) if s < step]
                step = max(older) if older else None
                after_seq = 0
        if degraded_replay and d.wal.last_seq > after_seq:
            # stage the tail behind a cursor: replay_step() pulls records
            # incrementally (O(new bytes) per pull), serving stays up
            d._replay_cursor = wal_mod.TailCursor(last_seq=after_seq)
            d.degraded = True
        else:
            records = d.wal.read(after_seq=after_seq)
            d._apply_records(records)
            d.engine.commit()
            d._replay_cursor = wal_mod.TailCursor(last_seq=d.wal.last_seq)
        return d

    def _apply_records(self, records) -> None:
        for rec in records:
            self._apply_one(rec)

    def _apply_one(self, rec: wal_mod.Record) -> None:
        if rec.kind == KIND_EVICT:
            self.engine.evict(rec.evict_ttl())
            return
        cols, valid = rec.batch()
        self.engine.ingest(Table.from_numpy(cols, valid),
                           retract=rec.kind == KIND_RETRACT)

    def replay_step(self, n: int = 1) -> int:
        """Apply up to ``n`` staged WAL records (degraded-mode replay);
        returns how many remain. Leaves degraded mode — and commits —
        when the tail drains. Records are pulled through the persistent
        tail cursor, so each step scans only the bytes it consumes."""
        if self.degraded:
            records, self._replay_cursor = self.wal.read_tail(
                self._replay_cursor, max_records=n)
            self._apply_records(records)
            if self._replay_cursor.last_seq >= self.wal.last_seq:
                self.engine.commit()
                self.degraded = False
        return max(0, self.wal.last_seq - self._replay_cursor.last_seq)

    # ----------------------------------------------------------- queries
    # explicit proxies for the serving/query surface (ServingEngine and
    # the tests talk to the wrapper exactly like to a bare engine) ...
    def ate(self, *a, **kw):
        return self.engine.ate(*a, **kw)

    def ate_batch(self, specs):
        return self.engine.ate_batch(specs)

    def cached_estimate(self, *a, **kw):
        return self.engine.cached_estimate(*a, **kw)

    def matched_rows(self, *a, **kw):
        return self.engine.matched_rows(*a, **kw)

    def snapshot_version(self) -> int:
        return self.engine.snapshot_version()

    # ... and a fallback for everything else (treatments, specs, stats()).
    def __getattr__(self, name: str):
        return getattr(self.engine, name)


def _all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = ckpt_mod._STEP_RE.match(d)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)

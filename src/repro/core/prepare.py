"""Offline database preparation (paper §4.2, Alg. 2).

Amortize matching cost across many online causal queries:
  1. Alg. 1 partitions the treatments into correlated groups with shared
     covariates.
  2. Per group: covariate factoring prunes the base data once (P_S), then
     the survivors are **compacted** (the TPU analogue of materializing the
     view).
  3. Per group: a cuboid over the union of the group's covariates (+ any
     sub-population query dims, e.g. airport/year) is materialized.
  4. Online: ATE for any (treatment, sub-population) = filter + rollup +
     group-stat CEM on the (tiny) cuboid — no pass over the base data.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Mapping, Optional, Sequence, Set, Tuple


from repro.core import cube as cube_mod
from repro.core.ate import ATEEstimate, estimate_ate
from repro.core.coarsen import CoarsenSpec
from repro.core.factoring import (covariate_factoring, partition_treatments,
                                  phi_matrix)
from repro.data.columnar import Table, compact


@dataclasses.dataclass
class PreparedDatabase:
    cuboids: Dict[str, cube_mod.Cuboid]        # group name -> cuboid
    treatment_group: Dict[str, str]            # treatment -> group name
    covsets: Dict[str, Tuple[str, ...]]        # treatment -> its covariates
    query_dims: Tuple[str, ...]
    prep_seconds: float

    def ate(self, treatment: str,
            subpopulation: Optional[Mapping[str, Sequence[int]]] = None
            ) -> ATEEstimate:
        """Online causal query: ATE of ``treatment``, optionally restricted
        to a sub-population given as {dim: [allowed bucket ids]}."""
        cub = self.cuboids[self.treatment_group[treatment]]
        if subpopulation:
            for dim, buckets in subpopulation.items():
                cub = cube_mod.filter_cuboid(cub, dim, buckets)
        dims = set(self.covsets[treatment]) | set(self.query_dims)
        dims = [d for d in cub.dims if d in dims]
        rolled = cube_mod.rollup(cub, dims)
        groups = cube_mod.cem_groups_from_cuboid(rolled, treatment)
        return estimate_ate(groups)


def prepare(table: Table, treatments: Mapping[str, Sequence[str]],
            specs: Mapping[str, CoarsenSpec], outcome: str,
            query_dims: Sequence[str] = (), max_group: int = 4
            ) -> PreparedDatabase:
    """Alg. 2. ``treatments`` maps treatment name -> its covariate names."""
    t0 = time.perf_counter()
    covsets: Dict[str, Set[str]] = {t: set(c) for t, c in treatments.items()}
    names, M = phi_matrix({t: table[t] for t in treatments}, table.valid)
    groups = partition_treatments(names, M, covsets, max_group=max_group)

    cuboids: Dict[str, cube_mod.Cuboid] = {}
    treatment_group: Dict[str, str] = {}
    for group in groups:
        gname = "+".join(group)
        shared = sorted(set.intersection(*(covsets[t] for t in group)))
        union = sorted(set.union(*(covsets[t] for t in group))
                       | set(query_dims))
        if shared:
            view = covariate_factoring(table, group,
                                       {n: specs[n] for n in union
                                        if n in specs}, shared)
            base = compact(view.table)
        else:
            base = table
        cub = cube_mod.build_cuboid(base, {n: specs[n] for n in union},
                                    group, outcome)
        cuboids[gname] = cube_mod.compact_cuboid(cub)
        for t in group:
            treatment_group[t] = gname
    return PreparedDatabase(
        cuboids=cuboids, treatment_group=treatment_group,
        covsets={t: tuple(sorted(c)) for t, c in covsets.items()},
        query_dims=tuple(query_dims),
        prep_seconds=time.perf_counter() - t0)

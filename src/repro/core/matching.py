"""Nearest-neighbour matching (paper §3.1, Figs. 2-3).

NNMWR (with replacement): for each treated unit, its k nearest control
units within the caliper — the paper's window-function view. Two engines:

* ``knn_quadratic``: tiled all-pairs distance + running top-k. This is the
  paper's "by necessity quadratic" general path; the inner tile is the
  Pallas kernel (`repro.kernels.knn_topk`), here a pure-jnp block loop.
* ``knn_sorted_1d``: beyond-paper fast path for 1-D distances (the dominant
  propensity-score case): sort controls, searchsorted each treated unit,
  scan a +/-k candidate window — O(N log N), not quadratic.

NNMNR (without replacement): the paper's greedy half-approximation (its
Fig. 3): sort candidate edges by distance, sweep keeping the 1:k invariant.
Inherently sequential (Prop. 1 shows the exact problem is NLOGSPACE-hard),
expressed as a `lax.scan` over the globally distance-sorted edge list.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.trace import counted_jit

BIG = jnp.float32(3.4e38)


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """k matches per row. Arrays are (N, k) aligned to the *original* row
    order; rows that are not valid treated units have all-invalid matches."""

    idx: jnp.ndarray       # (N, k) int32 control row indices
    dist: jnp.ndarray      # (N, k) f32
    ok: jnp.ndarray        # (N, k) bool — match exists & within caliper
    treated_mask: jnp.ndarray  # (N,) bool — rows that sought matches

    def n_matched_treated(self):
        return jnp.sum((jnp.any(self.ok, axis=1) & self.treated_mask
                        ).astype(jnp.int32))


def _topk_merge(run_d, run_i, new_d, new_i, k):
    d = jnp.concatenate([run_d, new_d], axis=1)
    i = jnp.concatenate([run_i, new_i], axis=1)
    neg = -d
    vals, pos = jax.lax.top_k(neg, k)
    return -vals, jnp.take_along_axis(i, pos, axis=1)


@partial(counted_jit, static_argnames=("k", "block"))
def knn_quadratic(U_treated: jnp.ndarray, U_control: jnp.ndarray,
                  control_valid: jnp.ndarray, k: int, caliper: float,
                  block: int = 1024) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-pairs k-NN: (Nt, d) vs (Nc, d) -> (Nt, k) (dist, idx).

    Control blocks stream through a running top-k (the same loop the Pallas
    kernel and the distributed ring k-NN use). Invalid controls get +BIG.
    """
    nt, d = U_treated.shape
    nc = U_control.shape[0]
    pad = (-nc) % block
    Uc = jnp.pad(U_control, ((0, pad), (0, 0)))
    cv = jnp.pad(control_valid, (0, pad))
    nb = (nc + pad) // block
    Ucb = Uc.reshape(nb, block, d)
    cvb = cv.reshape(nb, block)

    tn = jnp.sum(U_treated * U_treated, axis=1, keepdims=True)

    def body(carry, blk):
        run_d, run_i = carry
        Ub, vb, base = blk
        cn = jnp.sum(Ub * Ub, axis=1)[None, :]
        dist = jnp.maximum(tn + cn - 2.0 * (U_treated @ Ub.T), 0.0)
        dist = jnp.where(vb[None, :], dist, BIG)
        idx = (base + jnp.arange(block, dtype=jnp.int32))[None, :]
        idx = jnp.broadcast_to(idx, dist.shape)
        bk = min(k, block)
        nd, np_ = jax.lax.top_k(-dist, bk)
        ni = jnp.take_along_axis(idx, np_, axis=1)
        return _topk_merge(run_d, run_i, -nd, ni, k), None

    run_d = jnp.full((nt, k), BIG, jnp.float32)
    run_i = jnp.full((nt, k), -1, jnp.int32)
    bases = jnp.arange(nb, dtype=jnp.int32) * block
    (run_d, run_i), _ = jax.lax.scan(body, (run_d, run_i), (Ucb, cvb, bases))
    run_d = jnp.sqrt(run_d)  # report Euclidean (sq kept internally)
    run_d = jnp.where(run_d <= caliper, run_d, BIG)
    return run_d, run_i


@partial(counted_jit, static_argnames=("k", "window"))
def knn_sorted_1d(x_treated: jnp.ndarray, x_control: jnp.ndarray,
                  control_valid: jnp.ndarray, k: int, caliper: float,
                  window: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """1-D k-NN fast path (propensity distance). O(N log N).

    window defaults to k (candidates = k left + k right of the insertion
    point, which always contains the true k nearest in 1-D).
    """
    w = window or k
    nc = x_control.shape[0]
    xc = jnp.where(control_valid, x_control.astype(jnp.float32), BIG)
    iota = jnp.arange(nc, dtype=jnp.int32)
    xs, perm = jax.lax.sort((xc, iota), num_keys=1, is_stable=True)
    pos = jnp.searchsorted(xs, x_treated.astype(jnp.float32))
    offs = jnp.arange(-w, w, dtype=jnp.int32)  # 2w candidates
    cand = pos[:, None] + offs[None, :]
    inb = (cand >= 0) & (cand < nc)
    cand = jnp.clip(cand, 0, nc - 1)
    cd = jnp.abs(xs[cand] - x_treated[:, None].astype(jnp.float32))
    cd = jnp.where(inb & (xs[cand] < BIG), cd, BIG)
    nd, np_ = jax.lax.top_k(-cd, k)
    idx = jnp.take_along_axis(perm[cand], np_, axis=1)
    dist = -nd
    dist = jnp.where(dist <= caliper, dist, BIG)
    return dist, idx


def nnmwr(U: jnp.ndarray, treatment: jnp.ndarray, valid: jnp.ndarray,
          k: int, caliper: float, engine: str = "auto",
          block: int = 1024) -> MatchResult:
    """k:1 NNM with replacement over feature matrix U (N, d).

    All N rows are passed as "treated" queries for shape stability; rows with
    treatment==0 or invalid are masked out of the result.
    """
    t = treatment.astype(bool) & valid
    c = (~treatment.astype(bool)) & valid
    if engine == "auto":
        engine = "sorted1d" if U.shape[1] == 1 else "quadratic"
    if engine == "sorted1d":
        dist, idx = knn_sorted_1d(U[:, 0], U[:, 0], c, k, caliper)
    else:
        dist, idx = knn_quadratic(U, U, c, k, caliper, block=block)
    ok = (dist < BIG) & t[:, None]
    return MatchResult(idx=idx, dist=dist, ok=ok, treated_mask=t)


def nnmwr_att(y: jnp.ndarray, result: MatchResult) -> jnp.ndarray:
    """ATT from a with-replacement match: mean over matched treated units of
    (y_i - mean(y of matched controls))."""
    yf = y.astype(jnp.float32)
    okf = result.ok.astype(jnp.float32)
    n_ok = jnp.sum(okf, axis=1)
    ym = jnp.sum(jnp.where(result.ok, yf[jnp.clip(result.idx, 0, None)], 0.0),
                 axis=1) / jnp.maximum(n_ok, 1e-9)
    has = n_ok > 0
    diff = jnp.where(has, yf - ym, 0.0)
    return jnp.sum(diff) / jnp.maximum(jnp.sum(has.astype(jnp.float32)), 1e-9)


@partial(counted_jit, static_argnames=("n_rows", "k"))
def greedy_nnmnr(cand_dist: jnp.ndarray, cand_idx: jnp.ndarray,
                 treated_rows: jnp.ndarray, n_rows: int, k: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy without-replacement sweep (paper Fig. 3).

    cand_dist/cand_idx: (Nt, m) candidate matches per treated row (from a
    with-replacement k'-NN with k' = m >= k). Edges are globally sorted by
    distance and swept with a `lax.scan`; a control is taken at most once, a
    treated row takes at most k controls.

    Returns (take: (Nt, m) bool over candidate slots, order broken by global
    distance rank) — the 1/2-approximation of optimal matching.
    """
    nt, m = cand_dist.shape
    flat_d = cand_dist.reshape(-1)
    flat_c = cand_idx.reshape(-1)
    flat_t = jnp.repeat(treated_rows, m)
    order = jnp.argsort(flat_d)  # stable ascending

    def body(state, e):
        used_c, cnt_t = state
        d, cidx, tidx = e
        cidx_c = jnp.clip(cidx, 0, n_rows - 1)
        tidx_c = jnp.clip(tidx, 0, n_rows - 1)
        ok = (d < BIG) & (~used_c[cidx_c]) & (cnt_t[tidx_c] < k)
        used_c = used_c.at[cidx_c].set(used_c[cidx_c] | ok)
        cnt_t = cnt_t.at[tidx_c].add(ok.astype(jnp.int32))
        return (used_c, cnt_t), ok

    used_c = jnp.zeros((n_rows,), bool)
    cnt_t = jnp.zeros((n_rows,), jnp.int32)
    _, taken = jax.lax.scan(
        body, (used_c, cnt_t),
        (flat_d[order], flat_c[order], flat_t[order]))
    take_flat = jnp.zeros((nt * m,), bool).at[order].set(taken)
    return take_flat.reshape(nt, m), order


def nnmnr(U: jnp.ndarray, treatment: jnp.ndarray, valid: jnp.ndarray,
          k: int, caliper: float, m_candidates: Optional[int] = None,
          engine: str = "auto") -> MatchResult:
    """k:1 NNM without replacement = with-replacement candidates (m >= k per
    treated unit) + greedy global sweep."""
    m = m_candidates or max(4 * k, 8)
    wr = nnmwr(U, treatment, valid, k=m, caliper=caliper, engine=engine)
    treated_rows = jnp.arange(U.shape[0], dtype=jnp.int32)
    take, _ = greedy_nnmnr(jnp.where(wr.ok, wr.dist, BIG), wr.idx,
                           treated_rows, U.shape[0], k)
    ok = wr.ok & take
    return MatchResult(idx=wr.idx, dist=wr.dist, ok=ok,
                       treated_mask=wr.treated_mask)

"""Propensity-score subclassification (paper §3.2, Fig. 4).

SQL: ``ntile(n) OVER (ORDER BY ps)`` then drop subclasses failing overlap.
TPU: global sort of ps (invalid rows pushed to +inf), rank = sorted position
among valid rows, bucket = floor(rank * n / n_valid); then the same overlap
machinery as CEM over the bucket key.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cem import CEMGroups, cem_from_keys
from repro.core.keys import KeyCodec
from repro.data.columnar import Table


def ntile(ps: jnp.ndarray, valid: jnp.ndarray, n: int) -> jnp.ndarray:
    """Equal-count buckets of ps over valid rows; invalid rows get bucket n."""
    big = jnp.where(valid, ps.astype(jnp.float32), jnp.inf)
    nrows = ps.shape[0]
    iota = jnp.arange(nrows, dtype=jnp.int32)
    _, perm = jax.lax.sort((big, iota), num_keys=1, is_stable=True)
    inv = jnp.zeros((nrows,), jnp.int32).at[perm].set(iota)
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.int32)), 1)
    bucket = jnp.minimum((inv * n) // n_valid, n - 1).astype(jnp.int32)
    return jnp.where(valid, bucket, n)


@dataclasses.dataclass(frozen=True)
class SubclassResult:
    table: Table
    groups: CEMGroups
    ps: jnp.ndarray


def subclassify(table: Table, treatment: str, outcome: str,
                ps: jnp.ndarray, n_subclasses: int = 5,
                trim: Optional[Tuple[float, float]] = (0.1, 0.9)
                ) -> SubclassResult:
    """Subclassification on a given propensity score.

    ``trim`` discards units with ps outside [lo, hi] (the paper's §5.2
    "common practice" of dropping ps < 0.1 or > 0.9).
    """
    valid = table.valid
    if trim is not None:
        valid = valid & (ps >= trim[0]) & (ps <= trim[1])
    bucket = ntile(ps, valid, n_subclasses)
    codec = KeyCodec.from_cardinalities({"subclass": n_subclasses + 1})
    hi, lo = codec.pack({"subclass": bucket}, valid)
    matched_valid, row_subclass, groups = cem_from_keys(
        hi, lo, table[treatment], table[outcome], valid)
    out = Table(dict(table.columns), matched_valid).with_columns(
        {"subclass": row_subclass, "ps": ps})
    return SubclassResult(table=out, groups=groups, ps=ps)

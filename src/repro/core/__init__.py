# ZaliQL's contribution as a composable JAX module: matching and
# subclassification methods for causal inference (NRCM), re-expressed as
# static-shape masked dataflow for TPU, plus the paper's optimization suite
# (pushdown, covariate factoring, data-cube, offline preparation).
from repro.core.coarsen import CoarsenSpec, coarsen, coarsen_columns
from repro.core.keys import KeyCodec
from repro.core import groupby
from repro.core.cem import (CEMGroups, CEMResult, cem, cem_from_keys,
                            exact_matching, make_codec, pack_keys)
from repro.core.ate import (ATEEstimate, cem_weights, difference_in_means,
                            estimate_ate, estimate_ate_from_stats)
from repro.core.balance import awmd, raw_imbalance
from repro.core.propensity import (LogisticModel, fit_logistic, predict_ps,
                                   propensity_scores)
from repro.core.subclassification import SubclassResult, ntile, subclassify
from repro.core.matching import (MatchResult, greedy_nnmnr, knn_quadratic,
                                 knn_sorted_1d, nnmnr, nnmwr, nnmwr_att)
from repro.core.distance import (features, mahalanobis_transform,
                                 masked_covariance, pairwise_sqdist,
                                 ps_distance_features)
from repro.core.factoring import (FactoredView, covariate_factoring, mcem,
                                  partition_treatments, phi_coefficient,
                                  phi_matrix)
from repro.core import cube
from repro.core.pushdown import (PushdownResult, cem_join_pushdown,
                                 cem_overlap_filter)
from repro.core.prepare import PreparedDatabase, prepare
from repro.core.online import (DeltaReport, OnlineEngine,
                               PartitionedOnlineEngine, PoisonBatchError)
from repro.core.wal import (BatchLog, StaleEpochError, TailCursor,
                            WalCorruption)
from repro.core.durability import DurableEngine
from repro.core.replication import (ReplicatedEngine, Replica,
                                    ReplicationRouter, SplitBrainError)

__all__ = [
    "CoarsenSpec", "coarsen", "coarsen_columns", "KeyCodec", "groupby",
    "CEMGroups", "CEMResult", "cem", "cem_from_keys", "exact_matching",
    "make_codec", "pack_keys", "ATEEstimate", "cem_weights",
    "difference_in_means", "estimate_ate", "estimate_ate_from_stats",
    "awmd", "raw_imbalance",
    "LogisticModel", "fit_logistic", "predict_ps", "propensity_scores",
    "SubclassResult", "ntile", "subclassify", "MatchResult", "greedy_nnmnr",
    "knn_quadratic", "knn_sorted_1d", "nnmnr", "nnmwr", "nnmwr_att",
    "features", "mahalanobis_transform", "masked_covariance",
    "pairwise_sqdist", "ps_distance_features", "DeltaReport", "OnlineEngine",
    "PartitionedOnlineEngine", "PoisonBatchError", "BatchLog",
    "WalCorruption", "StaleEpochError", "TailCursor", "DurableEngine",
    "ReplicatedEngine", "Replica", "ReplicationRouter", "SplitBrainError",
]

"""CEM pushdown through foreign-key joins (paper §4.1, Prop. 2).

CEM(R1 |><| R2) = CEM(CEM(R1) |><| R2) when R1 holds the treatment: a group
discarded on R1's covariates has all-same T among its R1 rows, hence all-same
T among every refinement after the join — so it can never regain overlap.
Filtering (and compacting) R1 *before* the join shrinks both the join and
the final CEM.

In FLIGHTDELAY the treatment table is weather (dimension) and the fact table
is flights; the pushdown prunes weather rows in no-overlap weather-covariate
groups before any flight row is touched.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax.numpy as jnp

from repro.core.cem import CEMResult, cem, cem_from_keys, pack_keys
from repro.core.coarsen import CoarsenSpec
from repro.data.columnar import Table, compact
from repro.data.join import fk_join


def cem_overlap_filter(table: Table, treatment: str,
                       specs: Mapping[str, CoarsenSpec]) -> Table:
    """Stage-1 CEM: group by this relation's covariates, drop no-overlap
    groups. The outcome is irrelevant to the filter, so zeros are used."""
    codec, hi, lo = pack_keys(table, specs)
    zeros = jnp.zeros((table.nrows,), jnp.float32)
    matched_valid, _, _ = cem_from_keys(hi, lo, table[treatment], zeros,
                                        table.valid)
    return Table(dict(table.columns), matched_valid)


@dataclasses.dataclass(frozen=True)
class PushdownResult:
    result: CEMResult
    dim_rows_before: int
    dim_rows_after: int


def cem_join_pushdown(dim: Table, dim_specs: Mapping[str, CoarsenSpec],
                      fact: Table, fact_specs: Mapping[str, CoarsenSpec],
                      on: Mapping[str, int], treatment: str, outcome: str,
                      prefix: str = "", do_compact: bool = True
                      ) -> PushdownResult:
    """CEM(CEM(dim) |><| fact) — Prop. 2 specialized to a 2-relation FK
    schema with the treatment on the dimension side.

    The final CEM groups by dim covariates (prefixed) + fact covariates,
    exactly like CEM over the integrated relation.
    """
    filtered = cem_overlap_filter(dim, treatment, dim_specs)
    before = int(dim.count())
    if do_compact:
        filtered = compact(filtered)
    after = int(filtered.count())
    joined = fk_join(fact, filtered, on=on, prefix=prefix)
    all_specs = dict(fact_specs)
    for name, spec in dim_specs.items():
        all_specs[prefix + name] = spec
    res = cem(joined, prefix + treatment if prefix + treatment in joined.columns
              else treatment, outcome, all_specs)
    return PushdownResult(result=res, dim_rows_before=before,
                          dim_rows_after=after)

"""Single-dispatch device-resident ingest: the whole delta pipeline of the
online engines as ONE compiled program per batch.

The PR 3 hot path still issued a Python loop of XLA calls per ingest — a
delta-build dispatch, a planner dispatch, per-view touch stamps — and fell
back to the HOST for growth merges and eviction compaction. ZaliQL's core
argument (PAPER.md §optimizations) is that the maintenance loop must live
inside the engine so no per-operation round trip leaves the data plane;
this module is that move for the jax port. One compiled program — a plain
jit on one device, a single ``shard_map`` over the data axis on a mesh —
takes the raw batch plus every view's state and internally does

  coarsen -> pack -> group (delta stat table)
  -> rollup per view -> route to owner partitions (all-to-all on a mesh)
  -> per-view merge:  lax.cond( every delta key already materialized,
         scatter-merge fast path,
         concat + re-sort grow path at the current capacity )
  -> incremental overlap flip -> touch stamp -> streaming-moments update
  -> verdict scalars (ok / grew / overflow / neg_min / cache predicate)

with BUFFER DONATION on every cuboid / keep / touch / reservoir array, so
state updates in place instead of copy-merge-copy. The host fetches one
fused ``device_get`` of the verdicts and commits by reference swap — the
steady-state ingest is exactly one compiled dispatch
(``repro.launch.trace`` counts them; ``tests/test_online_fused.py``
asserts the invariant). On a mesh, EVERYTHING (including the merges) runs
inside the one shard_map body: the only cross-device traffic is the
routing all-to-all / gathering all-gather of the tiny delta tables plus
scalar verdict reductions — the merge compute itself is per-device local
code, never GSPMD-partitioned small ops.

Growth is device-resident too: the re-sort branch merges at the CURRENT
capacity and reports ``grew`` when the merged group count would not fit;
the engine then pads the (pass-through, unmodified) state and re-dispatches
the same program compiled at the doubled capacity — a recompile keyed on
``(granule count, n_parts)``, so a stream that stops growing stops
recompiling. Only the delta-capacity overflow (more distinct groups in one
batch than the delta table holds) still falls back to the exact host
rebuild, exactly as before.

Programs are cached at module level (``functools.lru_cache``) keyed on the
full schema + capacity signature, so every engine with the same shapes
shares one compilation.

QUERIES get the same treatment (PR 5): :func:`get_fused_query` answers an
uncached ``ate()`` with ONE compiled dispatch straight on the raw
(replicated or partitioned) view state — subpopulation filter + keep mask
per partition, in-program canonical key-sort, capacity-invariant chunked
reductions — and :func:`get_fused_rowlookup` answers ``matched_rows``
with one dispatch (routed all-to-all probe on a partitioned mesh). Query
programs take state BY REFERENCE (never donated) and return only scalars
or a per-row mask; the host fetches once and caches.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cube as cube_mod
from repro.core import groupby
from repro.core.ate import estimate_ate_from_stats
from repro.core.cem import overlap_keep, update_overlap
from repro.core.keys import INVALID_HI, INVALID_LO
from repro.core.propensity import _stream_retract, _stream_update
from repro.kernels.segment_stats import chunked_sum
from repro.launch.trace import counted_jit, hot_path

#: contract-lint scoping (tools/contract_check.py): this module is
#: engine-owned — dispatch/donation rules ZQL001-ZQL006 apply.
__engine_owned__ = True

BASE_VIEW = "__base__"


def query_stat_names(treatment: str) -> Tuple[str, ...]:
    """The stat columns one treatment's causal query consumes."""
    return ("one", "y", "yy", f"t_{treatment}", f"yt_{treatment}",
            f"yyt_{treatment}")

# renormalize int32 last-touch stamps when the ingest counter approaches
# the int32 ceiling (see OnlineEngine._renorm_touch). The shift is at
# least (counter - TOUCH_CLAMP_AGE): stamps older than that clamp to 0
# ("at least this old" — exact for every ttl < TOUCH_CLAMP_AGE,
# conservative beyond), which guarantees each renormalization buys
# ~TOUCH_CLAMP_AGE further ingests even when a cold live group pins the
# minimum stamp.
TOUCH_RENORM_LIMIT = (1 << 31) - (1 << 16)
TOUCH_CLAMP_AGE = 1 << 30


# ------------------------------------------------------------ touch stamps
@hot_path
def stamp_touch(touch: jnp.ndarray, pos: jnp.ndarray, dvalid: jnp.ndarray,
                counter) -> jnp.ndarray:
    """Record the current ingest counter at the touched group slots.
    Invalid delta rows are routed out of bounds and dropped, so a clipped
    lookup position can never stamp an unrelated live group."""
    upd = jnp.where(dvalid, pos, touch.shape[0])
    return touch.at[upd].set(jnp.int32(counter), mode="drop")


@hot_path
def remap_touch(old_hi, old_lo, old_gv, new_hi, new_lo,
                touch: jnp.ndarray) -> jnp.ndarray:
    """Carry last-touch stamps across a layout-changing (re-sort) merge."""
    pos, found = groupby.lookup_rows_in_table(old_hi, old_lo, new_hi, new_lo)
    upd = jnp.where(old_gv & found, pos, new_hi.shape[0])
    return jnp.zeros((new_hi.shape[0],), touch.dtype).at[upd].set(
        touch, mode="drop")


# ----------------------------------------------------------- merge kernels
@hot_path
def _merge_one_view(tname, st, d_hi, d_lo, d_stats, d_gv, counter,
                    use_pallas: bool):
    """One view's merge as a device-side branch: scatter fast path when
    every delta key is already materialized, concat + re-sort grow path at
    the CURRENT capacity otherwise (``grew`` reports a would-not-fit).

    ``st`` is the view's state dict; ``tname`` is None for the base view
    (which carries no overlap mask). Returns (new_st, verdicts)."""
    cap = st["hi"].shape[0]
    pos, found = groupby.lookup_rows_in_table(d_hi, d_lo, st["hi"], st["lo"])
    ok = jnp.all(found | ~d_gv)
    has_keep = st.get("keep") is not None

    def fast(_):
        mstats = cube_mod.scatter_merge_stats(st["stats"], pos, d_stats,
                                              use_pallas=use_pallas)
        if has_keep:
            nt = mstats[f"t_{tname}"]
            keep = update_overlap(st["keep"], st["gv"], nt,
                                  mstats["one"] - nt, pos)
        else:
            keep = None
        touch = stamp_touch(st["touch"], pos, d_gv, counter)
        return (st["hi"], st["lo"], mstats, st["gv"], keep, touch, pos,
                jnp.int32(0))

    def slow(_):
        cat_hi = jnp.concatenate([st["hi"], d_hi])
        cat_lo = jnp.concatenate([st["lo"], d_lo])
        g = groupby.group_by_key(cat_hi, cat_lo)
        sums = groupby.segment_sums(
            g, {k: jnp.concatenate([st["stats"][k], d_stats[k]])
                for k in st["stats"]})
        n_merged = g.n_groups
        nhi, nlo, ngv = g.group_hi[:cap], g.group_lo[:cap], g.group_valid[:cap]
        nstats = {k: v[:cap] for k, v in sums.items()}
        pos2, _ = groupby.lookup_rows_in_table(d_hi, d_lo, nhi, nlo)
        if has_keep:
            nt = nstats[f"t_{tname}"]
            keep = overlap_keep(ngv, nt, nstats["one"] - nt)
        else:
            keep = None
        touch = stamp_touch(
            remap_touch(st["hi"], st["lo"], st["gv"], nhi, nlo, st["touch"]),
            pos2, d_gv, counter)
        return nhi, nlo, nstats, ngv, keep, touch, pos2, n_merged

    hi, lo, stats, gv, keep, touch, pos_out, n_merged = jax.lax.cond(
        ok, fast, slow, None)
    new_st = dict(hi=hi, lo=lo, stats=stats, gv=gv, touch=touch)
    if has_keep:
        new_st["keep"] = keep
    return new_st, dict(ok=ok, grew=n_merged > cap, n_merged=n_merged,
                        pos=pos_out, merged_stats=stats)


@hot_path
def _merge_one_view_parts(tname, st, d_hi, d_lo, d_stats, d_gv, counter,
                          use_pallas: bool, axis=None):
    """Partitioned analogue of :func:`_merge_one_view`: state is (P, C)
    (the LOCAL (k, C) slice inside a shard_map body), routed deltas
    (P, B); the fast/slow decision is GLOBAL per view — one scalar over
    all partitions on all devices (``axis`` names the mesh axis for the
    cross-device reduction), matching the PR 3 planner verdicts — so the
    cond lifts outside the per-partition vmap and the untaken branch never
    executes."""
    cap = st["hi"].shape[1]
    pos, found = jax.vmap(groupby.lookup_rows_in_table)(
        d_hi, d_lo, st["hi"], st["lo"])
    ok = jnp.all(found | ~d_gv)
    if axis is not None:
        ok = jax.lax.pmin(ok.astype(jnp.int32), axis) > 0
    has_keep = st.get("keep") is not None

    def fast(_):
        mstats = cube_mod.scatter_merge_stats_parts(
            st["stats"], pos, d_stats, use_pallas=use_pallas)
        if has_keep:
            nt = mstats[f"t_{tname}"]
            keep = jax.vmap(update_overlap)(st["keep"], st["gv"], nt,
                                            mstats["one"] - nt, pos)
        else:
            keep = None
        touch = jax.vmap(stamp_touch, in_axes=(0, 0, 0, None))(
            st["touch"], pos, d_gv, counter)
        return (st["hi"], st["lo"], mstats, st["gv"], keep, touch, pos,
                jnp.int32(0))

    def slow(_):
        def one(thi, tlo, tstats, tgv, dhi, dlo, dstats, dgv, tch):
            cat_hi = jnp.concatenate([thi, dhi])
            cat_lo = jnp.concatenate([tlo, dlo])
            g = groupby.group_by_key(cat_hi, cat_lo)
            sums = groupby.segment_sums(
                g, {k: jnp.concatenate([tstats[k], dstats[k]])
                    for k in tstats})
            nhi, nlo = g.group_hi[:cap], g.group_lo[:cap]
            nstats = {k: v[:cap] for k, v in sums.items()}
            p2, _ = groupby.lookup_rows_in_table(dhi, dlo, nhi, nlo)
            tch2 = stamp_touch(remap_touch(thi, tlo, tgv, nhi, nlo, tch),
                               p2, dgv, counter)
            return (nhi, nlo, nstats, g.group_valid[:cap], tch2, p2,
                    g.n_groups)

        nhi, nlo, nstats, ngv, touch, pos2, nm = jax.vmap(one)(
            st["hi"], st["lo"], st["stats"], st["gv"], d_hi, d_lo, d_stats,
            d_gv, st["touch"])
        if has_keep:
            nt = nstats[f"t_{tname}"]
            keep = jax.vmap(overlap_keep)(ngv, nt, nstats["one"] - nt)
        else:
            keep = None
        return nhi, nlo, nstats, ngv, keep, touch, pos2, jnp.max(nm)

    hi, lo, stats, gv, keep, touch, pos_out, n_merged = jax.lax.cond(
        ok, fast, slow, None)
    if axis is not None:
        # cond branches hold no collectives; globalize the verdicts after
        n_merged = jax.lax.pmax(n_merged, axis)
    new_st = dict(hi=hi, lo=lo, stats=stats, gv=gv, touch=touch)
    if has_keep:
        new_st["keep"] = keep
    return new_st, dict(ok=ok, grew=n_merged > cap, n_merged=n_merged,
                        pos=pos_out, merged_stats=stats)


@hot_path
def _neg_min(stats: Dict[str, jnp.ndarray], tnames, axis=None):
    """Minimum over every count column — the retraction-negativity probe."""
    cols = [stats["one"]] + [stats[f"t_{t}"] for t in tnames]
    m = jnp.min(jnp.stack([jnp.min(c) for c in cols]))
    return m if axis is None else jax.lax.pmin(m, axis)


@hot_path
def _gate(commit, new_tree, old_tree):
    """Select committed-vs-pass-through state leaf-wise. XLA still aliases
    the donated input buffers; the untaken value only costs the select."""
    return jax.tree.map(lambda n, o: jnp.where(commit, n, o),
                        new_tree, old_tree)


@hot_path
def _stream_step(stream, stream_names, columns, valid, retract, seed,
                 n_batches):
    """Streaming-propensity update (moments + reservoir) inside the fused
    program — the last separate dispatch of the PR 3 ingest path.

    Always runs over the FULL, UNPADDED batch (in the mesh programs it
    therefore sits OUTSIDE the shard_map body, gated by the replicated
    commit scalar): the stream state is replicated, and the reservoir's
    uniform priorities depend on the draw SHAPE, so only the original
    batch length reproduces the host path bit for bit."""
    cols = {c: columns[c] for c in stream_names}
    if retract:
        res, pri, n, sums, sumsqs = _stream_retract(
            stream_names, stream["res"], stream["pri"], stream["n"],
            stream["sums"], stream["sumsqs"], cols, valid)
    else:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), n_batches)
        res, pri, n, sums, sumsqs = _stream_update(
            stream_names, stream["res"], stream["pri"], stream["n"],
            stream["sums"], stream["sumsqs"], cols, valid, key)
    return dict(res=res, pri=pri, n=n, sums=sums, sumsqs=sumsqs)


def pad_tail(columns, valid, pad: int):
    """Append ``pad`` invalid rows to a columnar batch — THE one
    definition of row padding (mesh divisibility, power-of-two batch
    buckets) shared by the engines and the fused program bodies, so
    padding semantics can never diverge between call sites."""
    if pad:
        columns = {k: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
                   for k, v in columns.items()}
        valid = jnp.pad(valid, (0, pad))
    return columns, valid


def _pad_batch(columns, valid, ndev: int):
    return pad_tail(columns, valid, (-valid.shape[0]) % ndev)


# ===================== replicated single-dispatch ingest ====================
@functools.lru_cache(maxsize=128)
def get_fused_ingest(codec, specs_items, tnames: Tuple[str, ...],
                     view_dims: Tuple, outcome: str, caps: Tuple,
                     delta_cap: int, mesh, mesh_axis: str, use_pallas: bool,
                     retract: bool, stream_names: Tuple[str, ...],
                     seed: int, donate: bool = True):
    """One-dispatch ingest program for the REPLICATED engine.

    view_dims: ((name, dims), ...) with the base view first; caps:
    ((name, capacity), ...) — part of the cache key, so capacity growth
    recompiles and a stable stream reuses one executable. stream_names=()
    disables the reservoir section. The state argument is DONATED unless
    ``donate=False`` — the MVCC double-buffer rule: the synchronous path
    and chained in-flight hops consume their input in place, but the FIRST
    hop off a committed snapshot must leave the committed buffers alive
    (they keep serving queries and anchor rollback-and-replay on a failed
    commit; see ``OnlineEngine.commit``). On a mesh the whole pipeline —
    sharded build AND merges — is one shard_map body (merges replicated
    per-device local code; no GSPMD-sharded small ops)."""
    del caps  # cache key only: capacities are read off the state shapes
    specs = dict(specs_items)
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])
    rollups = {name: dims for name, dims in view_dims if name != BASE_VIEW}

    def local_build(columns, valid):
        hi, lo, sums, gv, n_groups = cube_mod.delta_build_body(
            columns, valid, codec=codec, specs=specs, treatments=tnames,
            outcome=outcome)
        return hi, lo, sums, gv, n_groups, jnp.asarray(False)

    def merge_and_gate(delta, views, counter):
        """Everything after the delta build except the stream update —
        pure per-device local compute, shared verbatim by the 1-device and
        shard_map paths."""
        hi, lo, stats, gv, n_full, overflow = delta
        dcap = delta_cap
        d_hi, d_lo, d_gv = hi[:dcap], lo[:dcap], gv[:dcap]
        d_stats = {k: v[:dcap] for k, v in stats.items()}
        overflow = overflow | (n_full > dcap)
        if retract:
            d_stats = {k: -v for k, v in d_stats.items()}
        new_views, verdicts = {}, {}
        for name in (BASE_VIEW,) + tnames:
            if name == BASE_VIEW:
                v_hi, v_lo, v_stats, v_gv = d_hi, d_lo, d_stats, d_gv
            else:
                roll = cube_mod._rollup_fn(codec, rollups[name])
                v_hi, v_lo, v_stats, v_gv = roll(d_hi, d_lo, d_gv, d_stats)
            tname = None if name == BASE_VIEW else name
            new_views[name], verdicts[name] = _merge_one_view(
                tname, views[name], v_hi, v_lo, v_stats, v_gv,
                counter, use_pallas)
        all_ok = functools.reduce(
            jnp.logical_and, [v["ok"] for v in verdicts.values()])
        any_grew = functools.reduce(
            jnp.logical_or, [v["grew"] for v in verdicts.values()])
        neg = _neg_min(verdicts[BASE_VIEW]["merged_stats"], tnames)
        commit = ~overflow & ~any_grew
        if retract:
            commit = commit & all_ok & (neg >= -0.5)
        out = dict(
            overflow=overflow, n_full=n_full, commit=commit, neg_min=neg,
            ok={k: v["ok"] for k, v in verdicts.items()},
            grew={k: v["grew"] for k, v in verdicts.items()},
            n_merged={k: v["n_merged"] for k, v in verdicts.items()},
            n_delta=jnp.sum(d_gv.astype(jnp.int32)),
            gv=d_gv,
            buckets={d: codec.extract(d_hi, d_lo, d) for d in codec.names})
        return _gate(commit, new_views, views), out

    def finish(new_views, out, state, columns, valid, n_batches):
        """Attach the stream update (full UNPADDED batch — reservoir
        priorities depend on the draw shape) gated by the commit scalar."""
        new_state = dict(views=new_views)
        if stream_names:
            upd = _stream_step(state["stream"], stream_names, columns,
                               valid, retract, seed, n_batches)
            new_state["stream"] = _gate(out["commit"], upd,
                                        state["stream"])
        return new_state, out

    if ndev > 1:
        from jax.experimental.shard_map import shard_map

        from repro.core.distributed import _sharded_delta_body
        build = functools.partial(_sharded_delta_body, codec=codec,
                                  specs=specs, treatments=tnames,
                                  outcome=outcome, capacity=delta_cap,
                                  axis=mesh_axis)

        def body(columns, valid, views, counter):
            return merge_and_gate(build(columns, valid), views, counter)

        def program(columns, valid, state, counter, n_batches):
            pcols, pvalid = _pad_batch(columns, valid, ndev)
            new_views, out = shard_map(
                body, mesh=mesh,
                in_specs=(P(mesh_axis), P(mesh_axis), P(), P()),
                out_specs=(P(), P()),
                check_rep=False)(pcols, pvalid, state["views"], counter)
            return finish(new_views, out, state, columns, valid, n_batches)
    else:
        def program(columns, valid, state, counter, n_batches):
            new_views, out = merge_and_gate(local_build(columns, valid),
                                            state["views"], counter)
            return finish(new_views, out, state, columns, valid, n_batches)

    return counted_jit(program,
                       donate_argnums=(2,) if donate else ())


# ===================== partitioned single-dispatch ingest ===================
@functools.lru_cache(maxsize=128)
def get_fused_ingest_parts(codec, specs_items, tnames: Tuple[str, ...],
                           view_dims: Tuple, outcome: str, caps: Tuple,
                           delta_cap: int, n_parts: int, mesh,
                           mesh_axis: str, use_pallas: bool, retract: bool,
                           stream_names: Tuple[str, ...], seed: int,
                           donate: bool = True):
    """One-dispatch ingest program for the PARTITIONED engine: routed
    delta build (all-to-all on a mesh, in-program regroup off one) composed
    with the per-partition merges, overlap flips, touch stamps and verdict
    scalars — the whole maintenance loop of one batch in one executable,
    with the (P, C) state donated in place (``donate=False`` keeps the
    input alive — the MVCC first-hop rule, see :func:`get_fused_ingest`). ``n_parts`` may be any multiple
    of the mesh data-axis size: each device owns ``k = n_parts / N``
    contiguous key ranges (k-partitions-per-device). On a mesh the whole
    pipeline is ONE shard_map body: state enters as the local (k, C)
    slice, merges are partition-local, and only the delta routing
    (all-to-all) plus scalar verdict reductions cross devices."""
    del caps  # cache key only
    specs = dict(specs_items)
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])
    view_items = tuple(view_dims)

    def merge_and_gate(deltas, n_full, overflow, views, counter, axis):
        new_views, verdicts = {}, {}
        for name, _ in view_items:
            d_hi, d_lo, d_stats, d_gv = deltas[name]
            if retract:
                d_stats = {k: -v for k, v in d_stats.items()}
                deltas[name] = (d_hi, d_lo, d_stats, d_gv)
            tname = None if name == BASE_VIEW else name
            new_views[name], verdicts[name] = _merge_one_view_parts(
                tname, views[name], d_hi, d_lo, d_stats, d_gv,
                counter, use_pallas, axis=axis)
        all_ok = functools.reduce(
            jnp.logical_and, [v["ok"] for v in verdicts.values()])
        any_grew = functools.reduce(
            jnp.logical_or, [v["grew"] for v in verdicts.values()])
        neg = _neg_min(verdicts[BASE_VIEW]["merged_stats"], tnames,
                       axis=axis)
        commit = ~overflow & ~any_grew
        if retract:
            commit = commit & all_ok & (neg >= -0.5)
        b_gv = deltas[BASE_VIEW][3]
        n_delta = jnp.sum(b_gv.astype(jnp.int32))
        if axis is not None:
            n_delta = jax.lax.psum(n_delta, axis)
        out = dict(
            overflow=overflow, n_full=n_full, commit=commit, neg_min=neg,
            ok={k: v["ok"] for k, v in verdicts.items()},
            grew={k: v["grew"] for k, v in verdicts.items()},
            n_merged={k: v["n_merged"] for k, v in verdicts.items()},
            n_delta=n_delta,
            gv=b_gv,
            buckets={d: codec.extract(deltas[BASE_VIEW][0],
                                      deltas[BASE_VIEW][1], d)
                     for d in codec.names})
        return _gate(commit, new_views, views), out

    def finish(new_views, out, state, columns, valid, n_batches):
        new_state = dict(views=new_views)
        if stream_names:
            upd = _stream_step(state["stream"], stream_names, columns,
                               valid, retract, seed, n_batches)
            new_state["stream"] = _gate(out["commit"], upd,
                                        state["stream"])
        return new_state, out

    if ndev > 1:
        from jax.experimental.shard_map import shard_map

        from repro.core.distributed import _routed_delta_body
        build = functools.partial(
            _routed_delta_body, codec=codec, specs=specs,
            treatments=tnames, outcome=outcome, capacity=delta_cap,
            view_items=view_items, n_parts=n_parts, n_dev=ndev,
            axis=mesh_axis)

        def body(columns, valid, views, counter):
            deltas, n_full, overflow = build(columns, valid)
            return merge_and_gate(deltas, n_full, overflow, views, counter,
                                  mesh_axis)

        part = P(mesh_axis, None)
        out_spec = dict(overflow=P(), n_full=P(), commit=P(), neg_min=P(),
                        ok=P(), grew=P(), n_merged=P(), n_delta=P(),
                        gv=part, buckets=part)

        def program(columns, valid, state, counter, n_batches):
            pcols, pvalid = _pad_batch(columns, valid, ndev)
            new_views, out = shard_map(
                body, mesh=mesh,
                in_specs=(P(mesh_axis), P(mesh_axis), part, P()),
                out_specs=(part, out_spec),
                check_rep=False)(pcols, pvalid, state["views"], counter)
            return finish(new_views, out, state, columns, valid, n_batches)
    else:
        def single_build(columns, valid):
            hi, lo, sums, gv, n_groups = cube_mod.delta_build_body(
                columns, valid, codec=codec, specs=specs,
                treatments=tnames, outcome=outcome)
            dcap = delta_cap
            b_hi, b_lo, b_gv = hi[:dcap], lo[:dcap], gv[:dcap]
            b_stats = {k: v[:dcap] for k, v in sums.items()}
            deltas = {}
            for name, dims in view_items:
                if name == BASE_VIEW:
                    v = (b_hi, b_lo, b_stats, b_gv)
                else:
                    roll = cube_mod._rollup_fn(codec, dims)
                    v = roll(b_hi, b_lo, b_gv, b_stats)
                deltas[name] = cube_mod.route_delta(*v, n_parts)
            return deltas, n_groups, n_groups > dcap

        def program(columns, valid, state, counter, n_batches):
            deltas, n_full, overflow = single_build(columns, valid)
            new_views, out = merge_and_gate(deltas, n_full, overflow,
                                            state["views"], counter, None)
            return finish(new_views, out, state, columns, valid, n_batches)

    return counted_jit(program,
                       donate_argnums=(2,) if donate else ())


# ===================== device-resident query pipeline =======================
@hot_path
def _query_mask(hi, lo, gv, keep, codec, subpop):
    """Subpopulation filter + overlap keep as ONE elementwise mask — the
    per-partition (per-device-local, 1/N) stage of a query. ``subpop`` is
    the frozen ((dim, (bucket, ...)), ...) predicate, static per program."""
    m = gv & keep
    if subpop:
        for dim, allowed in subpop:
            vals = codec.extract(hi, lo, dim)
            ok = jnp.zeros_like(m)
            for b in allowed:
                ok = ok | (vals == b)
            m = m & ok
    return m


# role-named stat columns the canonical estimator body consumes, in the
# order :func:`query_stat_names` yields the treatment-specific names
QUERY_ROLES = ("one", "y", "yy", "t", "yt", "yyt")


@hot_path
def _estimate_from_roles(hi, lo, stats, m):
    """Canonical estimate over the masked groups: re-sort the surviving
    keys into the canonical (globally key-sorted, valid-prefix) order —
    keys are unique across partitions, so the segment sums are exact
    gathers — then reduce with the capacity-invariant canonical sum
    (:func:`repro.kernels.segment_stats.chunked_sum`). The result is a
    bitwise-deterministic function of the surviving group stats alone:
    identical for replicated/partitioned layouts, any partition count, any
    capacity history, and identical to the ``assemble`` baseline path.
    ``stats`` carries the ROLE-named columns (:data:`QUERY_ROLES`); this
    one body is shared verbatim by the single-spec and batched query
    programs, which is what makes their answers bit-identical."""
    hi = hi.reshape(-1)
    lo = lo.reshape(-1)
    m = m.reshape(-1)
    chi = jnp.where(m, hi, INVALID_HI)
    clo = jnp.where(m, lo, INVALID_LO)
    g = groupby.group_by_key(chi, clo)
    sums = groupby.segment_sums(
        g, {k: jnp.where(m, v.reshape(-1), 0.0) for k, v in stats.items()})
    keep = g.group_valid
    nt = sums["t"]
    nc = sums["one"] - nt
    yt = sums["yt"]
    yc = sums["y"] - yt
    yyt = sums["yyt"]
    yyc = sums["yy"] - yyt
    est = estimate_ate_from_stats(keep, nt, nc, yt, yc, sum_yy_t=yyt,
                                  sum_yy_c=yyc, sum_fn=chunked_sum)
    return dict(ate=est.ate, att=est.att,
                n_matched_treated=est.n_matched_treated,
                n_matched_control=est.n_matched_control,
                n_groups=est.n_groups, variance=est.variance)


@hot_path
def _estimate_from_masked(hi, lo, stats, m, treatment):
    """Treatment-named front of :func:`_estimate_from_roles`: map the
    view's stat columns onto the estimator roles and estimate."""
    roles = dict(zip(QUERY_ROLES,
                     (stats[k] for k in query_stat_names(treatment))))
    return _estimate_from_roles(hi, lo, roles, m)


@hot_path
def estimate_view_body(hi, lo, stats, gv, keep, *, codec, treatment,
                       subpop):
    """Whole causal query as pure traced compute: mask then canonical
    estimate. Shared verbatim by the fused one-dispatch query program and
    the ``assemble`` baseline (which feeds it the reassembled view) — one
    definition of the estimator across every query pipeline."""
    m = _query_mask(hi, lo, gv, keep, codec, subpop)
    return _estimate_from_masked(hi, lo, stats, m, treatment)


@functools.lru_cache(maxsize=512)
def get_fused_query(codec, treatment: str, subpop, mesh, mesh_axis: str,
                    partitioned: bool):
    """One-dispatch causal query program: ``f(hi, lo, stats, gv, keep) ->
    {ate, att, n_matched_*, n_groups, variance}`` over a view's raw
    materialized state — replicated ``(C,)`` or partitioned ``(P, C)`` —
    with NO host-side reassembly or compaction anywhere on the path. The
    engine fetches the scalar dict with one ``device_get`` and caches it;
    steady state is exactly one compiled dispatch per uncached query.

    On a mesh with partitioned state the program is a single ``shard_map``
    body: subpopulation filtering and keep masking run PER PARTITION on
    the owning device (per-device work/state ~1/N), then only the tiny
    masked key+stat vectors cross the interconnect (one ``all_gather``)
    and every device runs the identical canonical reduce. The final
    reduce is deliberately replicated rather than ``psum``-composed:
    a psum's float association would depend on the partition count, while
    the canonical chunked reduction is what keeps the estimate bit-
    identical across 1/2/4-device meshes, any ``n_parts``, and the
    replicated engine. ``subpop`` is the frozen subpopulation predicate
    (part of the program cache key, like every shape/schema input)."""
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])

    if partitioned and ndev > 1:
        from jax.experimental.shard_map import shard_map

        def body(hi, lo, stats, gv, keep):
            # local (k, C) slices: mask per partition, gather the masked
            # tables, estimate replicated (same bits on every device)
            m = _query_mask(hi, lo, gv, keep, codec, subpop)
            chi = jnp.where(m, hi, INVALID_HI)
            clo = jnp.where(m, lo, INVALID_LO)
            cstats = {k: jnp.where(m, v, 0.0) for k, v in stats.items()}
            ghi = jax.lax.all_gather(chi, mesh_axis, tiled=True)
            glo = jax.lax.all_gather(clo, mesh_axis, tiled=True)
            gstats = {k: jax.lax.all_gather(v, mesh_axis, tiled=True)
                      for k, v in cstats.items()}
            gm = ~((ghi == INVALID_HI) & (glo == INVALID_LO))
            return _estimate_from_masked(ghi, glo, gstats, gm, treatment)

        part = P(mesh_axis, None)

        def program(hi, lo, stats, gv, keep):
            return shard_map(body, mesh=mesh,
                             in_specs=(part, part, part, part, part),
                             out_specs=P(),
                             check_rep=False)(hi, lo, stats, gv, keep)
    else:
        def program(hi, lo, stats, gv, keep):
            return estimate_view_body(hi, lo, stats, gv, keep, codec=codec,
                                      treatment=treatment, subpop=subpop)

    return counted_jit(program, label="query")


# ===================== batched query: the spec table is DATA ================
#
# A single-spec query program bakes the subpopulation predicate into the
# trace (it is part of get_fused_query's cache key), so B heterogeneous
# queries cost B dispatches. The batched variant moves the WHOLE query spec
# — view choice, estimand, subpopulation predicate — into a fixed-width
# device-resident uint32 row per query:
#
#   word 0            view id (index into the engine's sorted treatments)
#   word 1            estimand selector (0 = ATE, 1 = ATT)
#   words 2..2+W-1    per-dim allowed-bucket BITMASKS in the engine's
#                     base-dim layout: dim d with cardinality c owns
#                     ceil(c/32) words; bit b set <=> bucket b passes.
#                     An unrestricted dim is all-ones.
#
# The per-group predicate test becomes one gather + bit-test per dim —
# exactly the same boolean mask _query_mask builds by unrolled equality,
# so the downstream canonical estimate (shared `_estimate_from_roles`
# body, capacity-invariant chunked_sum reduce) returns bit-identical
# answers, while the program itself is cached on SHAPES ONLY (view
# schema, word layout, pow2 spec-count bucket) — any B specs with any
# predicates run through ONE compiled dispatch with no retrace.

SPEC_META_WORDS = 2    # [view id, estimand] prefix of an encoded spec row
ESTIMAND_IDS = {"ate": 0, "att": 1}


def spec_word_layout(cards: Tuple[Tuple[str, int], ...]
                     ) -> Tuple[Dict[str, int], int]:
    """Word layout of the predicate part of a spec row. ``cards`` is the
    engine's base-dim schema as sorted ``(dim, cardinality)`` pairs —
    cardinalities are static (``CoarsenSpec.n_buckets``), so every spec of
    an engine encodes at the same fixed width. Returns (word offset per
    dim, total predicate words W)."""
    offs, pos = {}, 0
    for dim, card in cards:
        offs[dim] = pos
        pos += (int(card) + 31) // 32
    return offs, pos


def encode_query_spec(cards: Tuple[Tuple[str, int], ...], view_id: int,
                      estimand_id: int, subpop) -> np.ndarray:
    """Host-side encoding of ONE query spec into its fixed-width uint32
    row. ``subpop`` is the frozen ``((dim, (bucket, ...)), ...)`` predicate
    (or None). Raises on buckets outside a dim's cardinality — the same
    queries the static path would answer with an empty match."""
    offs, n_words = spec_word_layout(cards)
    row = np.zeros((SPEC_META_WORDS + n_words,), np.uint32)
    row[0] = np.uint32(view_id)
    row[1] = np.uint32(estimand_id)
    by_dim = dict(subpop or ())
    unknown = set(by_dim) - set(offs)
    if unknown:
        raise ValueError(f"subpopulation dims {sorted(unknown)} not in the "
                         f"engine schema {sorted(offs)}")
    for dim, card in cards:
        base = SPEC_META_WORDS + offs[dim]
        nw = (int(card) + 31) // 32
        if dim in by_dim:
            for b in by_dim[dim]:
                b = int(b)
                if not 0 <= b < card:
                    raise ValueError(f"bucket {b} out of range for dim "
                                     f"{dim!r} (cardinality {card})")
                row[base + (b >> 5)] |= np.uint32(1) << np.uint32(b & 31)
        else:
            row[base:base + nw] = np.uint32(0xFFFFFFFF)
    return row


@hot_path
def _words_mask(hi, lo, base_m, codec, words, cards, offsets):
    """Data-driven :func:`_query_mask`: evaluate one encoded predicate
    (the ``(W,)`` uint32 bitmask slice of a spec row) over one view's
    keys. Bit-for-bit the same boolean mask the static path builds: each
    dim extracts its bucket id and tests membership in the allowed-bucket
    bitmask (unrestricted dims are all-ones, a no-op AND). Dims absent
    from this view's codec are skipped — the engine validates host-side
    that a spec only restricts dims its view materializes."""
    m = base_m
    names = set(codec.names)
    for dim, _card in cards:
        if dim not in names:
            continue
        vals = codec.extract(hi, lo, dim)          # int32, < card for valid
        idx = jnp.clip(offsets[dim] + (vals >> 5), 0, words.shape[0] - 1)
        bit = (words[idx] >> (vals & 31).astype(jnp.uint32)) & jnp.uint32(1)
        m = m & (bit == jnp.uint32(1))
    return m


@hot_path
def _batched_query_body(view_schema, cards, offsets, view_states,
                        spec_rows):
    """B heterogeneous query specs over V materialized views as pure
    traced compute. Every view's state is flattened and zero/invalid-
    padded to one common length L, so per-spec state selection is a plain
    gather by view id; padding cannot perturb the answer because the
    canonical reduce is bitwise invariant to trailing invalid/zero tail
    (the same contract that makes capacity growth and partition count
    invisible — see ``chunked_sum``). Estimates run once per SPEC (not
    per spec x view): masks are evaluated per view (each view's codec is
    static), then each spec gathers its own view's mask row."""
    sizes = [int(np.prod(st[0].shape))  # zql: ok[ZQL002] static shapes
             for st in view_states]
    length = max(sizes)

    def padded(x, fill):
        x = x.reshape(-1)
        pad = length - x.shape[0]
        return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

    words = spec_rows[:, SPEC_META_WORDS:]
    phi, plo, pstats, masks = [], [], [], []
    for (_, codec), (hi, lo, stats, gv, keep) in zip(view_schema,
                                                     view_states):
        bhi = padded(hi, INVALID_HI)
        blo = padded(lo, INVALID_LO)
        base_m = padded(gv & keep, False)
        pstats.append(tuple(padded(s, 0.0) for s in stats))
        masks.append(jax.vmap(
            lambda w, h=bhi, l=blo, bm=base_m, c=codec:
            _words_mask(h, l, bm, c, w, cards, offsets))(words))
        phi.append(bhi)
        plo.append(blo)
    phi = jnp.stack(phi)                       # (V, L)
    plo = jnp.stack(plo)
    pst = tuple(jnp.stack([pstats[v][r] for v in range(len(view_schema))])
                for r in range(len(QUERY_ROLES)))
    m_all = jnp.stack(masks)                   # (V, B, L)
    view_ids = spec_rows[:, 0].astype(jnp.int32)
    estimands = spec_rows[:, 1].astype(jnp.int32)
    m_sel = m_all[view_ids, jnp.arange(spec_rows.shape[0])]

    def one(vid, est_sel, m):
        stats = dict(zip(QUERY_ROLES, (s[vid] for s in pst)))
        out = _estimate_from_roles(phi[vid], plo[vid], stats, m)
        out["value"] = jnp.where(est_sel == 0, out["ate"], out["att"])
        return out

    return jax.vmap(one)(view_ids, estimands, m_sel)


@functools.lru_cache(maxsize=64)
def get_fused_query_batch(view_schema, cards, b_bucket: int, mesh,
                          mesh_axis: str, partitioned: bool):
    """ONE-dispatch batched causal query program:
    ``f(view_states, spec_rows) -> {ate, att, value, n_matched_*,
    n_groups, variance}`` with every output a ``(B,)`` array.

    ``view_schema`` is the engine's views as ``(treatment, codec)`` in
    view-id order; ``view_states`` a matching tuple of ``(hi, lo,
    role-ordered stats, group_valid, keep)``; ``spec_rows`` the ``(B,
    SPEC_META_WORDS + W)`` encoded spec table (:func:`encode_query_spec`).
    The cache key is shapes/schema ONLY — predicates arrive as data, so B
    heterogeneous specs (mixed views, estimands, subpopulations) share one
    compilation, and any batch inside the same pow2 ``b_bucket`` reuses
    the trace.

    On a mesh with partitioned ``(P, C)`` state the program is one
    ``shard_map`` body that all_gathers each view's raw partition tables
    ONCE (state-sized traffic, not B masked copies) and then runs the
    identical replicated batched estimate — the final reduce stays the
    canonical chunked reduction, never a psum, so answers are
    bit-identical to the B=1 fused path on 1/2/4-device meshes."""
    offsets, _ = spec_word_layout(cards)
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])

    if partitioned and ndev > 1:
        from jax.experimental.shard_map import shard_map

        def sm_body(view_states, spec_rows):
            def g(x):
                return jax.lax.all_gather(x, mesh_axis, tiled=True)
            gathered = tuple(
                (g(hi), g(lo), tuple(g(s) for s in stats), g(gv), g(keep))
                for hi, lo, stats, gv, keep in view_states)
            return _batched_query_body(view_schema, cards, offsets,
                                       gathered, spec_rows)

        part = P(mesh_axis, None)
        state_spec = tuple(
            (part, part, (part,) * len(QUERY_ROLES), part, part)
            for _ in view_schema)

        def program(view_states, spec_rows):
            return shard_map(sm_body, mesh=mesh,
                             in_specs=(state_spec, P()), out_specs=P(),
                             check_rep=False)(view_states, spec_rows)
    else:
        def program(view_states, spec_rows):
            return _batched_query_body(view_schema, cards, offsets,
                                       view_states, spec_rows)

    return counted_jit(program, label="query")


@functools.lru_cache(maxsize=256)
def get_fused_rowlookup(codec, specs_items: Tuple, n_parts: int, mesh,
                        mesh_axis: str):
    """One-dispatch ``matched_rows`` program: ``f(columns, valid, t_hi,
    t_lo, keep) -> matched`` — coarsen + pack the probe rows, look each
    key up in the materialized view, and apply the overlap mask, all in
    one compiled program. ``n_parts == 0`` marks the replicated ``(C,)``
    layout (plain binary search in the broadcast table); ``n_parts > 0``
    the partitioned ``(P, C)`` one, where each probe row hashes to its
    owning partition and binary-searches ONLY that partition's table. On
    a mesh the partitioned variant is the ROUTED lookup
    (:func:`repro.core.distributed._routed_lookup_body`): probe keys hash
    to owner devices, cross with one all-to-all, answer with a local
    search, and route back — no device ever reassembles the view."""
    specs = dict(specs_items)
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])

    if n_parts > 0 and ndev > 1:
        from jax.experimental.shard_map import shard_map

        from repro.core.distributed import _routed_lookup_body
        body = functools.partial(_routed_lookup_body, codec=codec,
                                 specs=specs, n_parts=n_parts, n_dev=ndev,
                                 axis=mesh_axis)
        part = P(mesh_axis, None)

        def program(columns, valid, t_hi, t_lo, keep):
            n = valid.shape[0]
            pcols, pvalid = _pad_batch(columns, valid, ndev)
            matched = shard_map(
                body, mesh=mesh,
                in_specs=(P(mesh_axis), P(mesh_axis), part, part, part),
                out_specs=P(mesh_axis),
                check_rep=False)(pcols, pvalid, t_hi, t_lo, keep)
            return matched[:n]
    else:
        from repro.core.coarsen import coarsen_columns

        def program(columns, valid, t_hi, t_lo, keep):
            buckets = coarsen_columns(columns, specs)
            hi, lo = codec.pack(buckets, valid)
            if n_parts == 0:
                pos, found = groupby.lookup_rows_in_table(hi, lo, t_hi,
                                                          t_lo)
                return valid & found & keep[pos]
            pid = cube_mod.partition_ids(hi, lo, n_parts)
            pos, found = groupby.lookup_rows_in_parts(hi, lo, pid, t_hi,
                                                      t_lo)
            return valid & found & keep[pid, pos]

    return counted_jit(program, label="query")


# ===================== device-resident eviction compaction ==================
@hot_path
def _compact_one(hi, lo, stats, gv, touch, keep_mask):
    """Capacity-preserving device compaction of one sorted stat table:
    dropped groups take the invalid-key marker, a stable re-sort pushes
    them to the tail, and stats/touch are carried by exact GATHER (keys are
    unique, so no float re-summation — surviving groups are bit-identical,
    in the same canonical key order the host compaction produced)."""
    new_gv = gv & keep_mask
    chi = jnp.where(new_gv, hi, INVALID_HI)
    clo = jnp.where(new_gv, lo, INVALID_LO)
    g = groupby.group_by_key(chi, clo)
    out_stats = {k: jnp.where(new_gv, v, 0.0)[g.perm]
                 for k, v in stats.items()}
    out_touch = jnp.where(g.group_valid, touch[g.perm], 0)
    return g.group_hi, g.group_lo, out_stats, g.group_valid, out_touch


@functools.lru_cache(maxsize=128)
def get_fused_evict(tnames: Tuple[str, ...], caps: Tuple, n_parts: int,
                    mesh, mesh_axis: str, has_stream: bool):
    """One-dispatch TTL eviction for every view at once: keep-mask from the
    touch stamps, per-partition device compaction (n_parts == 0 marks the
    replicated (C,) layout), overlap recompute, per-view evicted counts
    AND post-compaction live occupancy (max per partition — the input of
    the capacity-shrink pass) as the only fetched scalars. State is
    DONATED — eviction, like ingest, updates in place. On a mesh, runs as
    one shard_map body over the local partition slices (replicated state:
    local full copy). Closes ROADMAP open item "eviction compaction runs
    on the host per partition"."""
    del caps  # part of the cache key only (shapes differ per capacity)
    ndev = 1 if mesh is None else int(mesh.shape[mesh_axis])
    on_mesh = ndev > 1

    def body(state, cutoff):
        new_views, counts, live_max = {}, {}, {}
        for name, st in state["views"].items():
            keep_mask = st["touch"] >= cutoff
            n_evict = jnp.sum((st["gv"] & ~keep_mask).astype(jnp.int32))
            if on_mesh and n_parts:
                n_evict = jax.lax.psum(n_evict, mesh_axis)
            counts[name] = n_evict
            fn = _compact_one if n_parts == 0 else jax.vmap(_compact_one)
            hi, lo, stats, gv, touch = fn(st["hi"], st["lo"], st["stats"],
                                          st["gv"], st["touch"], keep_mask)
            # live occupancy after compaction — per partition on the
            # (P, C) layout, whose MAX bounds the shrink-pass capacity
            if n_parts == 0:
                n_live = jnp.sum(gv.astype(jnp.int32))
            else:
                n_live = jnp.max(jnp.sum(gv.astype(jnp.int32), axis=1))
                if on_mesh:
                    n_live = jax.lax.pmax(n_live, mesh_axis)
            live_max[name] = n_live
            new_st = dict(hi=hi, lo=lo, stats=stats, gv=gv, touch=touch)
            if st.get("keep") is not None:
                nt = stats[f"t_{name}"]
                ov = (overlap_keep if n_parts == 0
                      else jax.vmap(overlap_keep))
                new_st["keep"] = ov(gv, nt, stats["one"] - nt)
            new_views[name] = new_st
        new_state = dict(state)
        new_state["views"] = new_views
        return new_state, counts, live_max

    if on_mesh:
        from jax.experimental.shard_map import shard_map
        view_spec = P(mesh_axis, None) if n_parts else P()
        state_spec = dict(views=view_spec)
        if has_stream:
            state_spec["stream"] = P()

        def program(state, cutoff):
            return shard_map(body, mesh=mesh,
                             in_specs=(state_spec, P()),
                             out_specs=(state_spec, P(), P()),
                             check_rep=False)(state, cutoff)
    else:
        program = body

    # keep_unused: the overlap keep mask is RECOMPUTED (not read) by the
    # body; without it jit would prune the donated input params and their
    # buffers could never alias the fresh keep outputs (donation must be
    # total — the jaxpr audit asserts every state leaf is consumed)
    return counted_jit(program, donate_argnums=(0,), keep_unused=True)

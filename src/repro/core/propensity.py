"""Propensity-score estimation: E(x) = Pr(T=1 | X=x)  (Rosenbaum-Rubin).

The paper learns E with logistic regression (MADlib inside Postgres). Here:
masked, batch-shardable Newton-Raphson with ridge damping. The gradient
X^T(sigma(Xw) - t) is the compute hot spot at scale — `repro.kernels.
logistic_grad` provides the fused Pallas path; this module is the engine
and pure-jnp reference.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.data.columnar import Table


@dataclasses.dataclass(frozen=True)
class LogisticModel:
    w: jnp.ndarray          # (d+1,) last entry = intercept
    mean: jnp.ndarray       # (d,) standardization
    std: jnp.ndarray        # (d,)
    converged: jnp.ndarray  # bool (grad-norm based)


def design_matrix(table: Table, features: Sequence[str]) -> jnp.ndarray:
    cols = [table[f].astype(jnp.float32) for f in features]
    return jnp.stack(cols, axis=-1)


def _standardize(X: jnp.ndarray, valid: jnp.ndarray):
    w = valid.astype(jnp.float32)[:, None]
    n = jnp.maximum(jnp.sum(w), 1.0)
    mean = jnp.sum(X * w, axis=0) / n
    var = jnp.sum(w * (X - mean) ** 2, axis=0) / n
    std = jnp.sqrt(jnp.maximum(var, 1e-12))
    return (X - mean) / std, mean, std


def fit_logistic(X: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
                 n_iter: int = 32, ridge: float = 1e-4,
                 init: Optional[LogisticModel] = None,
                 moments: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                 ) -> LogisticModel:
    """Newton-Raphson logistic regression on valid rows.

    X: (N, d) raw features; t: (N,) binary treatment; valid: (N,) mask.
    ``init`` warm-starts from a previous model: its coefficients seed the
    iteration and its standardization is FROZEN (so coefficients stay
    comparable across online refreshes); ``n_iter`` is then the step budget
    of the refresh, typically far below a cold fit's. ``moments`` overrides
    the standardization with an externally maintained (mean, std) — the
    online engine passes its exact streaming moments so a reservoir refit
    standardizes over the WHOLE stream, not just the sampled rows.
    """
    if moments is not None:
        mean, std = moments
        Xs = (X - mean) / std
    elif init is not None:
        mean, std = init.mean, init.std
        Xs = (X - mean) / std
    else:
        Xs, mean, std = _standardize(X, valid)
    n, d = Xs.shape
    Xb = jnp.concatenate([Xs, jnp.ones((n, 1), jnp.float32)], axis=1)
    m = valid.astype(jnp.float32)
    tf = t.astype(jnp.float32)

    def grad(w):
        p = jax.nn.sigmoid(Xb @ w)
        return Xb.T @ (m * (p - tf)) + ridge * w, p

    def step(w, _):
        g, p = grad(w)
        s = m * p * (1.0 - p) + 1e-6
        H = (Xb * s[:, None]).T @ Xb + ridge * jnp.eye(d + 1)
        dw = jnp.linalg.solve(H, g)
        return w - dw, None

    w0 = (init.w if init is not None
          else jnp.zeros((d + 1,), jnp.float32))
    w, _ = jax.lax.scan(step, w0, None, length=n_iter)
    # Convergence must be judged at the RETURNED w: the last scanned gradient
    # norm predates the final Newton step, so warm refits (small n_iter)
    # would mis-report by one step.
    g_final, _ = grad(w)
    converged = (jnp.linalg.norm(g_final)
                 < 1e-3 * (1 + jnp.sum(m)) ** 0.5)
    return LogisticModel(w=w, mean=mean, std=std, converged=converged)


def _signed_moments(names, n, sums, sumsqs, batch_cols, valid, sign):
    """Fold one batch into the per-column moment accumulators: plain
    signed sums, so retraction (sign=-1) reverses them exactly."""
    w = valid.astype(jnp.float32) * sign
    new_n = n + jnp.sum(w)
    new_sums, new_sumsqs = {}, {}
    for c in names:
        x = batch_cols[c].astype(jnp.float32)
        new_sums[c] = sums[c] + jnp.sum(w * x)
        new_sumsqs[c] = sumsqs[c] + jnp.sum(w * x * x)
    return new_n, new_sums, new_sumsqs


from repro.launch.trace import counted_jit  # noqa: E402


@functools.partial(counted_jit, static_argnames=("names",))
def _stream_update(names: Tuple[str, ...], res_cols, priority, n, sums,
                   sumsqs, batch_cols, valid, key):
    """One streamed batch into (moments, reservoir). Fully on device: no
    host round-trip rides on the ingest hot path.

    Moments are plain signed sums (exact, retractable). The reservoir is
    priority-based uniform sampling: every valid row draws an iid U(0,1)
    priority and the R largest priorities across the whole stream are kept —
    a top-k merge of the current reservoir with the batch, which is exactly
    Algorithm R's distribution without sequential per-row state.
    """
    new_n, new_sums, new_sumsqs = _signed_moments(
        names, n, sums, sumsqs, batch_cols, valid, jnp.float32(1.0))
    cap = priority.shape[0]
    u = jax.random.uniform(key, valid.shape)
    pri = jnp.where(valid, u, -jnp.inf)
    cat_pri = jnp.concatenate([priority, pri])
    new_pri, idx = jax.lax.top_k(cat_pri, cap)
    new_res = {}
    for c in names:
        cat = jnp.concatenate([res_cols[c],
                               batch_cols[c].astype(jnp.float32)])
        new_res[c] = cat[idx]
    return new_res, new_pri, new_n, new_sums, new_sumsqs


def _row_tags(names: Tuple[str, ...], cols, alive) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    """Key tags of rows: two independent u32 content hashes over the f32
    bit patterns of every column. Tags are pure functions of row CONTENT,
    so a retracted row presented by value re-derives the tag of its
    sampled copy. The top bit of the first word is cleared so a live tag
    can never equal the all-ones invalid-key marker; rows with
    ``alive=False`` get exactly that marker."""
    shape = alive.shape
    h1 = jnp.full(shape, 0x811C9DC5, jnp.uint32)
    h2 = jnp.full(shape, 0x01000193, jnp.uint32)
    for c in names:
        x = jax.lax.bitcast_convert_type(cols[c].astype(jnp.float32),
                                         jnp.uint32)
        h1 = (h1 ^ x) * jnp.uint32(0x9E3779B1)
        h1 = h1 ^ (h1 >> 15)
        h2 = (h2 ^ (x * jnp.uint32(0x85EBCA6B))) * jnp.uint32(0xC2B2AE35)
        h2 = h2 ^ (h2 >> 13)
    h1 = h1 & jnp.uint32(0x7FFFFFFF)
    from repro.core.keys import INVALID_HI, INVALID_LO
    return (jnp.where(alive, h1, INVALID_HI),
            jnp.where(alive, h2, INVALID_LO))


@functools.partial(counted_jit, static_argnames=("names",))
def _stream_retract(names: Tuple[str, ...], res_cols, priority, n, sums,
                    sumsqs, batch_cols, valid):
    """Exact retraction: reverse the moments AND delete the exact sampled
    copies of the retracted rows from the reservoir (key-tagged deletion).

    Each reservoir slot and each retracted row carries a content-hash tag
    (:func:`_row_tags`). Deletion is multiplicity-aware: if the stream
    held a row value twice and one copy is retracted, exactly one slot is
    removed — slot s dies iff its occurrence rank among same-tag live
    slots is below the retracted count of that tag. Removed slots are
    zeroed and the reservoir re-sorts by priority, so the surviving state
    is IDENTICAL to a stream that never held the removed rows (the
    regression contract: retract-then-refit == never-ingested-then-fit;
    bit-exact when rows are content-unique — with duplicated row values
    the surviving VALUE multiset is still exact, but which copy's sampling
    priority dies is unspecified). A retracted row whose sampled copy was
    already displaced by the bounded top-k simply removes nothing.
    """
    from repro.core import groupby
    new_n, new_sums, new_sumsqs = _signed_moments(
        names, n, sums, sumsqs, batch_cols, valid, jnp.float32(-1.0))
    cap = priority.shape[0]
    alive = priority > -jnp.inf
    s1, s2 = _row_tags(names, res_cols, alive)
    r1, r2 = _row_tags(names, batch_cols, valid)
    # per-tag retracted counts, looked up per slot (sorted group table)
    g = groupby.group_by_key(r1, r2)
    cnt = groupby.segment_sums(g, {"c": valid.astype(jnp.float32)})["c"]
    pos, found = groupby.lookup_rows_in_table(s1, s2, g.group_hi,
                                              g.group_lo)
    c = jnp.where(found, cnt[pos], 0.0)
    # occurrence rank of each live slot among equal-tag slots (slot order)
    iota = jnp.arange(cap, dtype=jnp.int32)
    o1, o2, perm = jax.lax.sort((s1, s2, iota), num_keys=2, is_stable=True)
    head = jnp.concatenate([jnp.ones((1,), bool),
                            (o1[1:] != o1[:-1]) | (o2[1:] != o2[:-1])])
    rank_sorted = iota - jax.lax.cummax(jnp.where(head, iota, 0))
    rank = jnp.zeros((cap,), jnp.int32).at[perm].set(rank_sorted)
    removed = alive & found & (rank.astype(jnp.float32) < c)
    # zero + drop removed slots, re-sort by priority: the layout equals a
    # stream that never sampled those rows
    pri = jnp.where(removed, -jnp.inf, priority)
    new_pri, idx = jax.lax.top_k(pri, cap)
    new_res = {}
    for col in names:
        zeroed = jnp.where(removed, 0.0, res_cols[col])
        new_res[col] = zeroed[idx]
    return new_res, new_pri, new_n, new_sums, new_sumsqs


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """Sufficient statistics for streaming propensity refreshes: exact
    per-column moment accumulators plus a bounded uniform reservoir of rows.

    This is what lets :meth:`OnlineEngine.refresh_propensity` work without
    ``keep_rows=True``'s unbounded row log: the moments standardize features
    over the WHOLE stream (and support exact retraction), while the Newton
    refit runs over the reservoir sample. Retraction is exact end to end:
    the moments reverse as signed sums, and the KEY-TAGGED reservoir
    (content-hash tags, :func:`_stream_retract`) deletes the exact sampled
    copy of every retracted row — multiplicity-aware, with the surviving
    layout identical to a stream that never held those rows. Only rows a
    full reservoir had already displaced are beyond recovery (they were
    not part of the sample to begin with).
    """

    names: Tuple[str, ...]
    columns: Dict[str, jnp.ndarray]   # (R,) reservoir slots per column
    priority: jnp.ndarray             # (R,) f32; -inf marks an empty slot
    n: jnp.ndarray                    # () f32 valid rows accumulated
    sums: Dict[str, jnp.ndarray]      # () f32 per column
    sumsqs: Dict[str, jnp.ndarray]    # () f32 per column
    seed: int = 0
    n_batches: int = 0                # host counter folded into the PRNG

    @classmethod
    def empty(cls, names: Sequence[str], capacity: int = 8192,
              seed: int = 0) -> "StreamStats":
        names = tuple(names)

        # distinct zero buffers per accumulator: the fused ingest DONATES
        # the whole state tree, and XLA rejects donating one buffer twice
        def zero():
            return jnp.zeros((), jnp.float32)

        return cls(
            names=names,
            columns={c: jnp.zeros((capacity,), jnp.float32) for c in names},
            priority=jnp.full((capacity,), -jnp.inf, jnp.float32),
            n=zero(), sums={c: zero() for c in names},
            sumsqs={c: zero() for c in names}, seed=seed)

    @property
    def capacity(self) -> int:
        return int(self.priority.shape[0])

    def update(self, batch_cols: Mapping[str, jnp.ndarray],
               valid: jnp.ndarray, retract: bool = False) -> "StreamStats":
        cols = {c: batch_cols[c] for c in self.names}
        if retract:
            res, pri, n, sums, sumsqs = _stream_retract(
                self.names, self.columns, self.priority, self.n,
                self.sums, self.sumsqs, cols, valid)
        else:
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                     self.n_batches)
            res, pri, n, sums, sumsqs = _stream_update(
                self.names, self.columns, self.priority, self.n,
                self.sums, self.sumsqs, cols, valid, key)
        return dataclasses.replace(self, columns=res, priority=pri, n=n,
                                   sums=sums, sumsqs=sumsqs,
                                   n_batches=self.n_batches + 1)

    def moments(self, features: Sequence[str]
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Exact stream-wide (mean, std) per feature, from the accumulators
        — same formula as :func:`_standardize` over the full row set."""
        n = jnp.maximum(self.n, 1.0)
        mean = jnp.stack([self.sums[f] for f in features]) / n
        ex2 = jnp.stack([self.sumsqs[f] for f in features]) / n
        std = jnp.sqrt(jnp.maximum(ex2 - mean ** 2, 1e-12))
        return mean, std

    def reservoir(self) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
        """(columns, valid-mask) of the sampled rows, fit-ready."""
        return self.columns, self.priority > -jnp.inf


def warm_refit(model: LogisticModel, X: jnp.ndarray, t: jnp.ndarray,
               valid: jnp.ndarray, n_iter: int = 4, ridge: float = 1e-4
               ) -> LogisticModel:
    """Online propensity refresh: resume Newton from ``model`` with a small
    step budget (Newton contracts quadratically near the optimum, so a
    handful of steps re-converges after a small data delta)."""
    return fit_logistic(X, t, valid, n_iter=n_iter, ridge=ridge, init=model)


def predict_ps(model: LogisticModel, X: jnp.ndarray) -> jnp.ndarray:
    Xs = (X - model.mean) / model.std
    logits = Xs @ model.w[:-1] + model.w[-1]
    return jax.nn.sigmoid(logits)


def propensity_scores(table: Table, treatment: str,
                      features: Sequence[str], n_iter: int = 32,
                      ridge: float = 1e-4) -> Tuple[jnp.ndarray, LogisticModel]:
    """Fit on the table's valid rows, predict for all rows."""
    X = design_matrix(table, features)
    model = fit_logistic(X, table[treatment], table.valid, n_iter=n_iter,
                         ridge=ridge)
    return predict_ps(model, X), model

"""Propensity-score estimation: E(x) = Pr(T=1 | X=x)  (Rosenbaum-Rubin).

The paper learns E with logistic regression (MADlib inside Postgres). Here:
masked, batch-shardable Newton-Raphson with ridge damping. The gradient
X^T(sigma(Xw) - t) is the compute hot spot at scale — `repro.kernels.
logistic_grad` provides the fused Pallas path; this module is the engine
and pure-jnp reference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.data.columnar import Table


@dataclasses.dataclass(frozen=True)
class LogisticModel:
    w: jnp.ndarray          # (d+1,) last entry = intercept
    mean: jnp.ndarray       # (d,) standardization
    std: jnp.ndarray        # (d,)
    converged: jnp.ndarray  # bool (grad-norm based)


def design_matrix(table: Table, features: Sequence[str]) -> jnp.ndarray:
    cols = [table[f].astype(jnp.float32) for f in features]
    return jnp.stack(cols, axis=-1)


def _standardize(X: jnp.ndarray, valid: jnp.ndarray):
    w = valid.astype(jnp.float32)[:, None]
    n = jnp.maximum(jnp.sum(w), 1.0)
    mean = jnp.sum(X * w, axis=0) / n
    var = jnp.sum(w * (X - mean) ** 2, axis=0) / n
    std = jnp.sqrt(jnp.maximum(var, 1e-12))
    return (X - mean) / std, mean, std


def fit_logistic(X: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
                 n_iter: int = 32, ridge: float = 1e-4,
                 init: Optional[LogisticModel] = None,
                 ) -> LogisticModel:
    """Newton-Raphson logistic regression on valid rows.

    X: (N, d) raw features; t: (N,) binary treatment; valid: (N,) mask.
    ``init`` warm-starts from a previous model: its coefficients seed the
    iteration and its standardization is FROZEN (so coefficients stay
    comparable across online refreshes); ``n_iter`` is then the step budget
    of the refresh, typically far below a cold fit's.
    """
    if init is not None:
        mean, std = init.mean, init.std
        Xs = (X - mean) / std
    else:
        Xs, mean, std = _standardize(X, valid)
    n, d = Xs.shape
    Xb = jnp.concatenate([Xs, jnp.ones((n, 1), jnp.float32)], axis=1)
    m = valid.astype(jnp.float32)
    tf = t.astype(jnp.float32)

    def step(w, _):
        logits = Xb @ w
        p = jax.nn.sigmoid(logits)
        g = Xb.T @ (m * (p - tf)) + ridge * w
        s = m * p * (1.0 - p) + 1e-6
        H = (Xb * s[:, None]).T @ Xb + ridge * jnp.eye(d + 1)
        dw = jnp.linalg.solve(H, g)
        return w - dw, jnp.linalg.norm(g)

    w0 = (init.w if init is not None
          else jnp.zeros((d + 1,), jnp.float32))
    w, gnorms = jax.lax.scan(step, w0, None, length=n_iter)
    return LogisticModel(w=w, mean=mean, std=std,
                         converged=gnorms[-1] < 1e-3 * (1 + jnp.sum(m)) ** 0.5)


def warm_refit(model: LogisticModel, X: jnp.ndarray, t: jnp.ndarray,
               valid: jnp.ndarray, n_iter: int = 4, ridge: float = 1e-4
               ) -> LogisticModel:
    """Online propensity refresh: resume Newton from ``model`` with a small
    step budget (Newton contracts quadratically near the optimum, so a
    handful of steps re-converges after a small data delta)."""
    return fit_logistic(X, t, valid, n_iter=n_iter, ridge=ridge, init=model)


def predict_ps(model: LogisticModel, X: jnp.ndarray) -> jnp.ndarray:
    Xs = (X - model.mean) / model.std
    logits = Xs @ model.w[:-1] + model.w[-1]
    return jax.nn.sigmoid(logits)


def propensity_scores(table: Table, treatment: str,
                      features: Sequence[str], n_iter: int = 32,
                      ridge: float = 1e-4) -> Tuple[jnp.ndarray, LogisticModel]:
    """Fit on the table's valid rows, predict for all rows."""
    X = design_matrix(table, features)
    model = fit_logistic(X, table[treatment], table.valid, n_iter=n_iter,
                         ridge=ridge)
    return predict_ps(model, X), model

"""Pure-numpy reference implementations — the "R package" proxy.

The paper's Table 3 compares ZaliQL against R's MatchIt/CEM packages. We
have no R offline, so these hash-map/loop implementations play that role:
they are written in the most obvious way possible (dict group-by, O(n^2)
scans), independently of the JAX engine, and double as oracles for unit,
property and kernel tests.
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import numpy as np


def coarsen_oracle(x: np.ndarray, cutpoints: Sequence[float]) -> np.ndarray:
    return np.searchsorted(np.asarray(cutpoints), x, side="right").astype(
        np.int32)


def cem_oracle(buckets: Mapping[str, np.ndarray], t: np.ndarray,
               valid: np.ndarray) -> Tuple[np.ndarray, Dict]:
    """Dict-based CEM: returns (matched mask, {group key -> row idx list})."""
    names = sorted(buckets)
    n = len(t)
    groups: Dict[tuple, list] = {}
    for i in range(n):
        if not valid[i]:
            continue
        key = tuple(int(buckets[m][i]) for m in names)
        groups.setdefault(key, []).append(i)
    matched = np.zeros(n, dtype=bool)
    kept = {}
    for key, rows in groups.items():
        ts = [int(t[i]) for i in rows]
        if 0 in ts and 1 in ts:
            kept[key] = rows
            for i in rows:
                matched[i] = True
    return matched, kept


def ate_oracle(groups: Dict, t: np.ndarray, y: np.ndarray) -> float:
    """Eq. 4 with group-probability weights over the matched subset."""
    n_tot = sum(len(rows) for rows in groups.values())
    acc = 0.0
    for rows in groups.values():
        rt = [i for i in rows if t[i] == 1]
        rc = [i for i in rows if t[i] == 0]
        diff = np.mean(y[rt]) - np.mean(y[rc])
        acc += len(rows) / n_tot * diff
    return float(acc)


def att_oracle(groups: Dict, t: np.ndarray, y: np.ndarray) -> float:
    n_t = sum(sum(1 for i in rows if t[i] == 1) for rows in groups.values())
    acc = 0.0
    for rows in groups.values():
        rt = [i for i in rows if t[i] == 1]
        rc = [i for i in rows if t[i] == 0]
        diff = np.mean(y[rt]) - np.mean(y[rc])
        acc += len(rt) / n_t * diff
    return float(acc)


def awmd_oracle(groups: Dict, t: np.ndarray, x: np.ndarray) -> float:
    """Eq. 5 for one covariate."""
    n_tot = sum(len(rows) for rows in groups.values())
    acc = 0.0
    for rows in groups.values():
        rt = [i for i in rows if t[i] == 1]
        rc = [i for i in rows if t[i] == 0]
        acc += len(rows) / n_tot * abs(np.mean(x[rt]) - np.mean(x[rc]))
    return float(acc)


def knn_oracle(U_treated: np.ndarray, U_control: np.ndarray,
               control_valid: np.ndarray, k: int, caliper: float
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Brute-force k-NN with caliper; ties broken by (distance, index)."""
    nt = len(U_treated)
    dist = np.full((nt, k), np.inf, dtype=np.float64)
    idx = np.full((nt, k), -1, dtype=np.int64)
    for i in range(nt):
        d = np.linalg.norm(U_control - U_treated[i], axis=1)
        d = np.where(control_valid, d, np.inf)
        order = np.lexsort((np.arange(len(d)), d))[:k]
        m = min(k, len(order))
        dist[i, :m] = d[order]
        idx[i, :m] = order
    dist = np.where(dist <= caliper, dist, np.inf)
    return dist, idx


def ntile_oracle(ps: np.ndarray, valid: np.ndarray, n: int) -> np.ndarray:
    nv = int(valid.sum())
    order = np.lexsort((np.arange(len(ps)), np.where(valid, ps, np.inf)))
    bucket = np.full(len(ps), n, dtype=np.int32)
    for rank, row in enumerate(order[:nv]):
        bucket[row] = min(rank * n // nv, n - 1)
    return bucket


def greedy_match_oracle(edges, n_rows: int, k: int):
    """edges: list of (dist, control, treated) — greedy sweep by distance."""
    edges = sorted(edges, key=lambda e: (e[0], e[1], e[2]))
    used_c = np.zeros(n_rows, bool)
    cnt_t = np.zeros(n_rows, np.int64)
    taken = []
    for d, c, t in edges:
        if not np.isfinite(d):
            continue
        if used_c[c] or cnt_t[t] >= k:
            continue
        used_c[c] = True
        cnt_t[t] += 1
        taken.append((d, c, t))
    return taken


def logistic_oracle(X: np.ndarray, t: np.ndarray, valid: np.ndarray,
                    n_iter: int = 64, ridge: float = 1e-4) -> np.ndarray:
    """Standardized Newton logistic regression; returns propensity scores."""
    v = valid.astype(np.float64)
    n = max(v.sum(), 1.0)
    mean = (X * v[:, None]).sum(0) / n
    var = (v[:, None] * (X - mean) ** 2).sum(0) / n
    std = np.sqrt(np.maximum(var, 1e-12))
    Xs = (X - mean) / std
    Xb = np.concatenate([Xs, np.ones((len(X), 1))], axis=1)
    w = np.zeros(Xb.shape[1])
    for _ in range(n_iter):
        p = 1 / (1 + np.exp(-Xb @ w))
        g = Xb.T @ (v * (p - t)) + ridge * w
        s = v * p * (1 - p) + 1e-6
        H = (Xb * s[:, None]).T @ Xb + ridge * np.eye(Xb.shape[1])
        w -= np.linalg.solve(H, g)
    return 1 / (1 + np.exp(-(Xb @ w)))

"""Covariate factoring for multiple treatments (paper §4.2, Prop. 3, Alg. 1).

Many treatments share covariates (all weather treatments condition on
season/traffic/airport). Factoring pre-filters the data ONCE per treatment
group on the *shared* covariates X' = intersection of the group's covariate
sets, keeping only super-subclasses where at least one treatment has overlap
(the paper's P_S view). Per-treatment CEM then runs on the (compacted)
survivor set — Prop. 3 guarantees the result is identical to running CEM
from scratch.

Alg. 1 chooses the grouping: treatments that are highly correlated (phi
coefficient) prune together, so greedy agglomeration maximizes the summed
|phi| within groups subject to a nonempty shared-covariate constraint.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Mapping, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import groupby
from repro.core.cem import cem_from_keys, pack_keys
from repro.core.coarsen import CoarsenSpec
from repro.data.columnar import Table


def phi_coefficient(t1: jnp.ndarray, t2: jnp.ndarray, valid: jnp.ndarray
                    ) -> jnp.ndarray:
    """Phi (Matthews) coefficient between two binary treatments."""
    w = valid.astype(jnp.float32)
    a = t1.astype(jnp.float32)
    b = t2.astype(jnp.float32)
    n11 = jnp.sum(w * a * b)
    n10 = jnp.sum(w * a * (1 - b))
    n01 = jnp.sum(w * (1 - a) * b)
    n00 = jnp.sum(w * (1 - a) * (1 - b))
    n1_, n0_ = n11 + n10, n01 + n00
    n_1, n_0 = n11 + n01, n10 + n00
    denom = jnp.sqrt(jnp.maximum(n1_ * n0_ * n_1 * n_0, 1e-9))
    return (n11 * n00 - n10 * n01) / denom


def phi_matrix(treatments: Mapping[str, jnp.ndarray], valid: jnp.ndarray
               ) -> Tuple[List[str], np.ndarray]:
    names = sorted(treatments)
    k = len(names)
    M = np.zeros((k, k))
    for i, j in itertools.combinations(range(k), 2):
        M[i, j] = M[j, i] = float(phi_coefficient(
            treatments[names[i]], treatments[names[j]], valid))
    return names, M


def partition_treatments(names: Sequence[str], M: np.ndarray,
                         covsets: Mapping[str, Set[str]],
                         max_group: int = 4) -> List[List[str]]:
    """Alg. 1: greedy agglomerative grouping maximizing summed |phi| within
    groups, subject to a nonempty shared-covariate intersection."""
    idx = {n: i for i, n in enumerate(names)}
    groups: List[List[str]] = [[n] for n in names]

    def shared(g1, g2):
        return set.intersection(*(covsets[n] for n in g1 + g2))

    def gain(g1, g2):
        return sum(abs(M[idx[a], idx[b]]) for a in g1 for b in g2)

    while True:
        best = None
        for i, j in itertools.combinations(range(len(groups)), 2):
            g1, g2 = groups[i], groups[j]
            if len(g1) + len(g2) > max_group or not shared(g1, g2):
                continue
            g = gain(g1, g2)
            if g > 1e-9 and (best is None or g > best[0]):
                best = (g, i, j)
        if best is None:
            return groups
        _, i, j = best
        groups[i] = groups[i] + groups[j]
        del groups[j]


@dataclasses.dataclass(frozen=True)
class FactoredView:
    """The paper's P_S view: rows surviving the shared-covariate prefilter,
    with their super-subclass id."""

    table: Table             # valid mask narrowed to surviving rows
    supersubclass: jnp.ndarray  # (N,) int32 group id over shared covariates
    shared: Tuple[str, ...]


def covariate_factoring(table: Table, treatments: Sequence[str],
                        specs: Mapping[str, CoarsenSpec],
                        shared: Sequence[str]) -> FactoredView:
    """Build P_S: group by shared covariates; keep groups where at least one
    treatment in S has overlap (Fig. 6(a))."""
    shared_specs = {n: specs[n] for n in shared}
    codec, hi, lo = pack_keys(table, shared_specs)
    g = groupby.group_by_key(hi, lo)
    w = table.valid.astype(jnp.float32)
    cols = {}
    for tname in treatments:
        t = table[tname].astype(jnp.float32) * w
        cols[f"nt_{tname}"] = t
        cols[f"nc_{tname}"] = w - t
    sums = groupby.segment_sums(g, cols)
    any_overlap = jnp.zeros_like(g.group_valid)
    for tname in treatments:
        any_overlap = any_overlap | ((sums[f"nt_{tname}"] > 0)
                                     & (sums[f"nc_{tname}"] > 0))
    keep = g.group_valid & any_overlap
    row_keep = groupby.broadcast_to_rows(g, keep)
    out = Table(dict(table.columns), table.valid & row_keep)
    return FactoredView(table=out, supersubclass=g.row_group(),
                        shared=tuple(shared))


def mcem(view: FactoredView, treatment: str, outcome: str,
         specs: Mapping[str, CoarsenSpec]):
    """Modified CEM over P_S (Fig. 6(b)).

    Grouping by (supersubclass, X_T \\ X') partitions rows identically to
    grouping by X_T (the shared fields determine the supersubclass), so we
    group directly on X_T restricted to the surviving rows — Prop. 3 says
    the result equals CEM(R_T).
    """
    table = view.table
    codec, hi, lo = pack_keys(table, specs)
    matched_valid, row_subclass, groups = cem_from_keys(
        hi, lo, table[treatment], table[outcome], table.valid)
    out = Table(dict(table.columns), matched_valid).with_columns(
        {"subclass": row_subclass, "supersubclass": view.supersubclass})
    from repro.core.cem import CEMResult  # local import to avoid cycle
    return CEMResult(table=out, groups=groups, codec=codec, key_hi=hi,
                     key_lo=lo)

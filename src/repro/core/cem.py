"""Coarsened Exact Matching (paper §3.2, Fig. 5).

CEM = coarsen covariates -> GROUP BY coarsened vector -> keep only groups
containing at least one treated and one control unit (the overlap filter
``max(T) != min(T)``). The matched "subclass" id is the group id.

The jit-friendly core is :func:`cem_from_keys`, which consumes pre-packed
keys — that is what the distributed engine, the cube planner, and the
factoring optimizer reuse. :func:`cem` is the user-facing Table API.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import groupby
from repro.core.coarsen import CoarsenSpec, coarsen_columns
from repro.core.keys import KeyCodec
from repro.data.columnar import Table


@dataclasses.dataclass(frozen=True)
class CEMGroups:
    """Per-group CEM statistics (arrays padded to N rows).

    Group g is *retained* iff keep[g]: it is a real key group satisfying
    overlap (>=1 treated and >=1 control valid unit).
    """

    grouping: groupby.Grouping
    keep: jnp.ndarray        # (N,) bool per group id
    n_treated: jnp.ndarray   # (N,) f32 per group
    n_control: jnp.ndarray   # (N,) f32
    sum_y_t: jnp.ndarray     # (N,) f32  sum of outcome over treated
    sum_y_c: jnp.ndarray     # (N,) f32

    def matched_counts(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        kt = jnp.where(self.keep, self.n_treated, 0.0)
        kc = jnp.where(self.keep, self.n_control, 0.0)
        return jnp.sum(kt), jnp.sum(kc)


@dataclasses.dataclass(frozen=True)
class CEMResult:
    """Matched subset + group stats. ``table`` has columns ``subclass`` (group
    id) and the validity mask narrowed to matched rows."""

    table: Table
    groups: CEMGroups
    codec: KeyCodec
    key_hi: jnp.ndarray
    key_lo: jnp.ndarray


def overlap_keep(group_valid: jnp.ndarray, n_treated: jnp.ndarray,
                 n_control: jnp.ndarray) -> jnp.ndarray:
    """The paper's overlap filter ``max(T) != min(T)`` on group stats: a
    group is matched iff it has >=1 treated and >=1 control valid unit."""
    return group_valid & (n_treated > 0) & (n_control > 0)


def update_overlap(keep: jnp.ndarray, group_valid: jnp.ndarray,
                   n_treated: jnp.ndarray, n_control: jnp.ndarray,
                   positions: jnp.ndarray) -> jnp.ndarray:
    """Incremental CEM: re-evaluate overlap only at ``positions`` (the group
    ids a delta batch touched), flipping groups in and out of the matched
    set in O(|positions|) instead of re-filtering every group."""
    new = (group_valid[positions] & (n_treated[positions] > 0)
           & (n_control[positions] > 0))
    return keep.at[positions].set(new)


def cem_from_keys(key_hi: jnp.ndarray, key_lo: jnp.ndarray,
                  treatment: jnp.ndarray, outcome: jnp.ndarray,
                  valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, CEMGroups]:
    """Jit-friendly CEM core.

    Returns (matched_valid, row_subclass, group stats). ``row_subclass`` is
    the group id per original row (meaningless where not matched).
    """
    g = groupby.group_by_key(key_hi, key_lo)
    w = valid.astype(jnp.float32)
    t = treatment.astype(jnp.float32) * w
    c = (1.0 - treatment.astype(jnp.float32)) * w
    y = outcome.astype(jnp.float32)
    sums = groupby.segment_sums(g, {
        "n_t": t, "n_c": c, "y_t": t * y, "y_c": c * y,
    })
    keep = overlap_keep(g.group_valid, sums["n_t"], sums["n_c"])
    row_keep = groupby.broadcast_to_rows(g, keep)
    matched_valid = valid & row_keep
    row_subclass = g.row_group()
    groups = CEMGroups(grouping=g, keep=keep,
                       n_treated=sums["n_t"], n_control=sums["n_c"],
                       sum_y_t=sums["y_t"], sum_y_c=sums["y_c"])
    return matched_valid, row_subclass, groups


def make_codec(specs: Mapping[str, CoarsenSpec]) -> KeyCodec:
    return KeyCodec.from_cardinalities(
        {name: spec.n_buckets for name, spec in specs.items()})


def pack_keys(table: Table, specs: Mapping[str, CoarsenSpec],
              codec: Optional[KeyCodec] = None,
              valid: Optional[jnp.ndarray] = None):
    """Coarsen + pack the covariates of ``table`` into (codec, hi, lo)."""
    codec = codec or make_codec(specs)
    buckets = coarsen_columns(table.columns, specs)
    v = table.valid if valid is None else valid
    hi, lo = codec.pack(buckets, v)
    return codec, hi, lo


def cem(table: Table, treatment: str, outcome: str,
        specs: Mapping[str, CoarsenSpec]) -> CEMResult:
    """User-facing CEM over a Table (the paper's Fig. 5(b) view)."""
    codec, hi, lo = pack_keys(table, specs)
    matched_valid, row_subclass, groups = cem_from_keys(
        hi, lo, table[treatment], table[outcome], table.valid)
    out = Table(dict(table.columns), matched_valid).with_columns(
        {"subclass": row_subclass})
    return CEMResult(table=out, groups=groups, codec=codec,
                     key_hi=hi, key_lo=lo)


def exact_matching(table: Table, treatment: str, outcome: str,
                   covariates: Mapping[str, int]) -> CEMResult:
    """EM = CEM with categorical (identity) coarsening; ``covariates`` maps
    name -> cardinality."""
    specs = {n: CoarsenSpec.categorical(c) for n, c in covariates.items()}
    return cem(table, treatment, outcome, specs)

"""WAL-shipping replication: follower reads, bounded staleness, failover.

One primary :class:`~repro.core.durability.DurableEngine` accepts every
write; N follower :class:`Replica` nodes mirror it by LOG, not by state:

  bootstrap   the primary's canonical cross-layout snapshot
              (``DurableEngine.export_bootstrap``) installs into a fresh
              engine of ANY layout via the same
              ``install_canonical`` path crash recovery uses — the
              follower starts bitwise equal to the primary at the
              snapshot's covered WAL seq;
  ship        :meth:`ReplicatedEngine.ship` streams the primary's WAL
              tail as raw record bytes (one :class:`~repro.core.wal.
              TailCursor` per follower — each tick scans only NEW bytes),
              pure host-side work that never touches a device buffer, so
              primary steady-state ingest stays 1 dispatch / 0 host
              syncs with shipping active;
  verify      a follower CRC-decodes and epoch/contiguity-checks every
              shipped record (:func:`verify_records`) BEFORE journaling
              it to its own log copy and BEFORE applying it (lint rule
              ZQL009 enforces the order statically) — a torn ship
              truncates to the valid prefix and is simply re-shipped;
  apply       verified records replay through the follower engine's
              NORMAL ingest path. Because estimates are deterministic
              functions of canonical group content alone, a replica at
              applied-seq s is bitwise identical to the primary at seq s
              — the lagging-oracle property the differential tests pin.

Bounded-staleness reads: every follower knows the primary's last seq and
its own applied seq; :class:`ReplicationRouter` spreads query waves round
robin across followers within ``max_lag_seqs`` / ``max_lag_secs`` (falling
back to the primary when none qualifies), and every
:class:`~repro.core.serving.ServedQuery` carries ``replica_lag``.

Failover: writes beat a :class:`~repro.runtime.fault_tolerance.
HeartbeatMonitor`; when the primary misses its timeout the monitor plans
a promotion (most durable WAL seq wins, ties to the lowest node id).
Promotion is an epoch CAS: the cluster epoch bumps exactly once — a
second promoter holding the same observed epoch gets
:class:`SplitBrainError` — and the deposed primary's log is FENCED at the
new epoch (:meth:`~repro.core.wal.BatchLog.fence`), so a zombie that
wakes up later has every append rejected with
:class:`~repro.core.wal.StaleEpochError` before any state mutates. The
candidate drains its received-but-unapplied tail, then its directory
(bootstrap checkpoint + shipped log — exactly a ``DurableEngine`` layout)
is re-opened as the new primary at the new epoch. Acknowledged records
the dead primary never shipped are lost, exactly like any asynchronous
log-shipping database: the promoted node equals a never-crashed twin *at
its own applied seq* — never a wrong answer, possibly an older one.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import wal as wal_mod
from repro.core.durability import DurableEngine, _unpack_snapshot
from repro.core.serving import ServedQuery, ServingEngine
from repro.core.wal import StaleEpochError, TailCursor
from repro.data.columnar import Table
from repro.launch import trace
from repro.runtime.fault_tolerance import HeartbeatMonitor

#: contract-lint scoping: dispatch/WAL/ship-verify rules apply here.
__engine_owned__ = True


class ReplicationError(RuntimeError):
    """Replication-tier protocol violation."""


class SplitBrainError(ReplicationError):
    """A promotion CAS failed: another node already took the epoch the
    promoter observed — exactly one promotion per epoch may win."""


class PrimaryDownError(ReplicationError):
    """A write arrived while the primary is dead and not yet replaced."""


def verify_records(records: Sequence[wal_mod.Record], max_epoch: int,
                   after_seq: int) -> List[wal_mod.Record]:
    """Gate shipped records before they are journaled or applied.

    Drops records at or below ``after_seq`` (idempotent re-ship after a
    torn delivery), then enforces: seqs contiguous from ``after_seq``,
    epochs non-decreasing, and no epoch above ``max_epoch`` (a record
    from the future means the channel lied about its term). CRC validity
    is already guaranteed by :func:`repro.core.wal.decode_records` — this
    is the second half of the verify-before-apply contract (ZQL009).
    """
    fresh = [r for r in records if r.seq > after_seq]
    prev_seq, prev_epoch = after_seq, 0
    for r in fresh:
        if r.seq != prev_seq + 1:
            raise wal_mod.WalCorruption(
                f"shipped records jump seq {prev_seq} -> {r.seq}; a gap "
                f"cannot be applied without breaking replay bit-identity")
        if r.epoch < prev_epoch:
            raise wal_mod.WalCorruption(
                f"shipped records decrease epoch {prev_epoch} -> "
                f"{r.epoch}")
        if r.epoch > max_epoch:
            raise StaleEpochError(
                f"shipped record at epoch {r.epoch} exceeds channel "
                f"epoch {max_epoch}")
        prev_seq, prev_epoch = r.seq, r.epoch
    return fresh


class Replica:
    """One follower node: a local engine (any layout), a durable copy of
    the shipped log, and apply progress.

    Directory layout is EXACTLY a :class:`DurableEngine`'s (``ckpt/``
    holds the bootstrap snapshot, ``wal/`` the shipped records with the
    primary's seq/epoch preserved), so a crashed follower rebuilds with
    the standard recovery path (:meth:`Replica.recover`) and a promoted
    follower's directory simply re-opens as the new primary's."""

    def __init__(self, engine, directory: str, node_id: int, *,
                 clock: Callable[[], float] = time.monotonic,
                 injector=None):
        self.engine = engine
        self.directory = directory
        self.node_id = node_id
        self.clock = clock
        self.injector = injector
        os.makedirs(directory, exist_ok=True)
        self.wal = wal_mod.BatchLog(os.path.join(directory, "wal"))
        self.ckpt_dir = os.path.join(directory, "ckpt")
        meta = self._load_meta()
        if self.wal.last_seq == 0 and meta.get("bootstrap_seq", 0) > 0:
            self.wal.set_base(meta["bootstrap_seq"],
                              meta.get("bootstrap_epoch", 0))
        #: cluster epoch as this node last learned it
        self.epoch = max(1, self.wal.last_epoch)
        self.applied_seq = 0
        self.primary_seq = 0        # primary's durable seq, as last shipped
        self.shipped_at = clock()   # last successful ship contact
        self.alive = True
        self._pending: List[wal_mod.Record] = []
        self.n_received = 0
        self.n_applied = 0
        self.n_stale_rejects = 0
        self.n_torn_ships = 0

    # -------------------------------------------------------- persistence
    def _meta_path(self) -> str:
        return os.path.join(self.directory, "replica.json")

    def _load_meta(self) -> dict:
        try:
            with open(self._meta_path()) as f:
                return json.load(f)
        except (IOError, OSError, ValueError):
            return {}

    def _save_meta(self, meta: dict) -> None:
        with open(self._meta_path(), "w") as f:
            json.dump(meta, f, sort_keys=True)

    def _point(self, name: str) -> None:
        if self.injector is not None:
            self.injector.fire(name)

    # ---------------------------------------------------------- bootstrap
    def bootstrap(self, arrays: Dict) -> int:
        """Install a primary bootstrap snapshot
        (``DurableEngine.export_bootstrap``) into the fresh local engine
        and persist it as checkpoint step 1, so this node can later
        recover — or be promoted — from its own directory alone.
        Returns the covered WAL seq."""
        snap, seq = _unpack_snapshot(arrays)
        epoch = 0
        if seq > 0:
            self.engine.install_canonical(snap)
            ckpt_mod.save(dict(arrays), 1, self.ckpt_dir, keep_last=2)
            epoch = self.wal.epoch
            self.wal.set_base(seq, epoch)
        self._save_meta({"node_id": self.node_id, "bootstrap_seq": seq,
                         "bootstrap_epoch": epoch})
        self.applied_seq = seq
        return seq

    @classmethod
    def recover(cls, engine, directory: str, node_id: int, *,
                clock: Callable[[], float] = time.monotonic,
                injector=None) -> "Replica":
        """Rebuild a crashed follower from its own directory: restore the
        bootstrap checkpoint, replay the locally journaled shipped log
        through the normal ingest path (both CRC-gated), re-open as a
        caught-up replica at applied = durable seq."""
        d = DurableEngine.recover(engine, directory)
        d.close()
        r = cls(engine, directory, node_id, clock=clock, injector=injector)
        r.applied_seq = r.wal.last_seq
        return r

    # -------------------------------------------------------------- ship
    def receive(self, data: bytes, ship_epoch: int) -> int:
        """Accept one shipped byte span: CRC-decode, verify epoch and
        contiguity, journal the fresh records to the local log (fsync),
        queue them for apply. Returns how many records were accepted.

        A span from a FENCED (stale-epoch) shipper is rejected outright —
        the defense-in-depth twin of the primary-side log fence. A torn
        span (truncated/corrupt suffix) accepts the valid prefix; the
        shipper re-sends the rest next tick."""
        if not self.alive:
            raise ReplicationError(f"replica {self.node_id} is down")
        if ship_epoch < self.epoch:
            self.n_stale_rejects += 1
            trace.record_replication(stale_rejects=1)
            raise StaleEpochError(
                f"ship at epoch {ship_epoch} rejected by replica "
                f"{self.node_id} at epoch {self.epoch}")
        records, _, clean = wal_mod.decode_records(data)
        if not clean:
            self.n_torn_ships += 1
            trace.record_replication(torn_ships=1)
        fresh = verify_records(records, max_epoch=ship_epoch,
                               after_seq=self.wal.last_seq)
        for rec in fresh:
            self.wal.append_record(rec, sync=False)
        self.wal.sync()
        self.epoch = max(self.epoch, ship_epoch)
        self._pending.extend(fresh)
        self.primary_seq = max(self.primary_seq, self.wal.last_seq)
        self.shipped_at = self.clock()
        self.n_received += len(fresh)
        return len(fresh)

    # ------------------------------------------------------------- apply
    def apply_step(self, n: Optional[int] = None) -> int:
        """Apply up to ``n`` received records (all, if None) through the
        normal ingest path and commit; returns how many remain queued.
        Records are re-verified against apply progress at this boundary —
        the journal fsync'd them, but epoch/contiguity must still hold
        from ``applied_seq`` (ZQL009)."""
        take = self._pending if n is None else self._pending[:n]
        batch = verify_records(take, max_epoch=self.epoch,
                               after_seq=self.applied_seq)
        done = 0
        try:
            for rec in batch:
                self._point("replica.pre-apply")
                self._apply_one(rec)
                self.applied_seq = rec.seq
                done += 1
                self._point("replica.post-apply")
        finally:
            # trim by seq, not count: a crash mid-batch must leave exactly
            # the unapplied suffix queued for the retry
            self._pending = [r for r in self._pending
                             if r.seq > self.applied_seq]
            if done:
                self.engine.commit()
                self.n_applied += done
                trace.record_replication(applied_records=done)
        return len(self._pending)

    def _apply_one(self, rec: wal_mod.Record) -> None:
        if rec.kind == wal_mod.KIND_EVICT:
            self.engine.evict(rec.evict_ttl())
            return
        cols, valid = rec.batch()
        self.engine.ingest(Table.from_numpy(cols, valid),
                           retract=rec.kind == wal_mod.KIND_RETRACT)

    def drain(self) -> None:
        """Apply everything received — the promotion prerequisite."""
        self.apply_step(None)

    # ----------------------------------------------------------- queries
    @property
    def replica_lag(self) -> int:
        """How many primary WAL seqs this node's applied state trails —
        the staleness bound the router enforces and every ServedQuery
        reports."""
        return max(0, self.primary_seq - self.applied_seq)

    def fresh(self, now: float, max_lag_seqs: int,
              max_lag_secs: float) -> bool:
        """Within the bounded-staleness envelope: close enough by seqs
        AND heard from the primary recently enough. A partitioned
        follower whose lag *looks* small still goes stale by TIME —
        lag is computed from the last ship, which may itself be old."""
        return (self.replica_lag <= max_lag_seqs
                and (now - self.shipped_at) <= max_lag_secs)

    def ate(self, *a, **kw):
        return self.engine.ate(*a, **kw)

    def ate_batch(self, specs):
        return self.engine.ate_batch(specs)

    def cached_estimate(self, *a, **kw):
        return self.engine.cached_estimate(*a, **kw)

    def matched_rows(self, *a, **kw):
        return self.engine.matched_rows(*a, **kw)

    def snapshot_version(self) -> int:
        return self.engine.snapshot_version()

    def __getattr__(self, name: str):
        return getattr(self.engine, name)


class ReplicationRouter:
    """Spreads read waves across healthy, staleness-bounded followers.

    Each :meth:`step` picks ONE target node — the next follower (round
    robin) whose :meth:`Replica.fresh` holds, else the primary
    (``n_primary_waves`` counts the fallback) — and drains the queued
    specs through that node's :class:`ServingEngine`, so every wave keeps
    the one-version-per-wave invariant on a single snapshot. Results are
    keyed by router ticket id; every answer carries ``replica_lag``."""

    def __init__(self, cluster: "ReplicatedEngine", n_slots: int = 64,
                 max_queue: Optional[int] = None):
        self.cluster = cluster
        self.n_slots = int(n_slots)
        self.max_queue = max_queue
        self._serving: Dict[int, ServingEngine] = {}
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        self._rr = 0
        self.n_replica_waves = 0
        self.n_primary_waves = 0

    def submit(self, spec, deadline: Optional[float] = None) -> int:
        qid = self._next_rid
        self._next_rid += 1
        self._queue.append((qid, spec, deadline))
        return qid

    def pending(self) -> int:
        return len(self._queue)

    @property
    def n_expired(self) -> int:
        return sum(s.n_expired for s in self._serving.values())

    def _serving_for(self, node, node_id: int) -> ServingEngine:
        s = self._serving.get(node_id)
        if s is None or s.engine is not node:
            # first contact, or the node was promoted/recovered since
            s = ServingEngine(node, n_slots=self.n_slots,
                              max_queue=self.max_queue,
                              clock=self.cluster.clock)
            self._serving[node_id] = s
        return s

    def _pick(self):
        now = self.cluster.clock()
        ids = sorted(self.cluster.replicas)
        for k in range(len(ids)):
            nid = ids[(self._rr + k) % len(ids)]
            rep = self.cluster.replicas[nid]
            if rep.alive and rep.fresh(now, self.cluster.max_lag_seqs,
                                       self.cluster.max_lag_secs):
                self._rr = (self._rr + k + 1) % len(ids)
                return nid, rep, True
        return self.cluster.primary_id, self.cluster.primary, False

    def step(self) -> Dict[int, ServedQuery]:
        """Route and serve everything currently queued on one node."""
        if not self._queue:
            return {}
        nid, node, is_replica = self._pick()
        serving = self._serving_for(node, nid)
        tickets: Dict[int, int] = {}
        while self._queue:
            rid, spec, deadline = self._queue.popleft()
            tickets[serving.submit(spec, deadline=deadline)] = rid
        out: Dict[int, ServedQuery] = {}
        while serving.pending():
            if is_replica:
                self.n_replica_waves += 1
            else:
                self.n_primary_waves += 1
            for qid, sq in serving.step().items():
                rid = tickets.pop(qid)
                out[rid] = dataclasses.replace(sq, qid=rid)
        return out

    def serve(self, specs: Sequence,
              deadline: Optional[float] = None) -> Dict[int, ServedQuery]:
        """Submit then drain; returns results keyed by ticket id in
        submit order (expired/shed queries are simply absent)."""
        [self.submit(s, deadline=deadline) for s in specs]
        out: Dict[int, ServedQuery] = {}
        while self.pending():
            out.update(self.step())
        return out


class ReplicatedEngine:
    """Primary + follower tier with WAL shipping and automatic failover.

    ``engines[0]`` becomes the primary (wrapped in a
    :class:`DurableEngine` under ``directory/node0``); each further
    engine — freshly constructed, ANY layout with the same schema
    fingerprint — becomes a follower bootstrapped from the primary's
    canonical snapshot. Writes go through the primary exactly as on an
    unreplicated :class:`DurableEngine` (same journaling, same hot-path
    guarantees) and additionally beat the heartbeat monitor;
    :meth:`ship` / :meth:`apply_all` / :meth:`tick` advance the
    followers; :attr:`router` serves bounded-staleness reads.

    ``clock`` is injectable: tests drive heartbeat timeouts and staleness
    deterministically. ``ship_filter`` (a ``(node_id, bytes) -> bytes``
    hook) lets the chaos harness tear shipped spans in flight."""

    def __init__(self, engines: Sequence, directory: str, *,
                 max_lag_seqs: int = 64, max_lag_secs: float = 5.0,
                 heartbeat_timeout_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 injector=None, saver=None, n_slots: int = 64,
                 max_queue: Optional[int] = None):
        engines = list(engines)
        if not engines:
            raise ValueError("ReplicatedEngine needs at least one engine")
        self.directory = directory
        self.clock = clock
        self.injector = injector
        self.saver = saver
        self.max_lag_seqs = int(max_lag_seqs)
        self.max_lag_secs = float(max_lag_secs)
        self.epoch = 1
        self.primary_id = 0
        self.primary = DurableEngine(
            engines[0], os.path.join(directory, "node0"), saver=saver,
            injector=injector, epoch=self.epoch)
        self._primary_dead = False
        self.monitor = HeartbeatMonitor(len(engines),
                                        timeout_s=heartbeat_timeout_s,
                                        clock=clock)
        self.monitor.beat(0, self.primary.wal.last_seq)
        self.replicas: Dict[int, Replica] = {}
        self._cursors: Dict[int, TailCursor] = {}
        for i, eng in enumerate(engines[1:], start=1):
            self._attach_replica(i, eng)
        self.ship_filter: Optional[Callable[[int, bytes], bytes]] = None
        self.n_failovers = 0
        self.router = ReplicationRouter(self, n_slots=n_slots,
                                        max_queue=max_queue)

    # ------------------------------------------------------------ members
    def _attach_replica(self, node_id: int, engine) -> Replica:
        rep = Replica(engine, os.path.join(self.directory,
                                           f"node{node_id}"),
                      node_id, clock=self.clock, injector=self.injector)
        rep.bootstrap(self.primary.export_bootstrap())
        rep.primary_seq = self.primary.wal.last_seq
        self.replicas[node_id] = rep
        self._cursors[node_id] = TailCursor(last_seq=rep.wal.last_seq)
        self.monitor.beat(node_id, rep.wal.last_seq)
        return rep

    def reattach_replica(self, node_id: int, engine) -> Replica:
        """Rejoin a crashed follower: rebuild it from its OWN directory
        (bootstrap checkpoint + locally journaled shipped log) into the
        given fresh engine, then resume shipping from its durable seq."""
        rep = Replica.recover(engine,
                              os.path.join(self.directory,
                                           f"node{node_id}"),
                              node_id, clock=self.clock,
                              injector=self.injector)
        rep.epoch = max(rep.epoch, self.epoch)
        rep.primary_seq = self.primary.wal.last_seq
        self.replicas[node_id] = rep
        self._cursors[node_id] = TailCursor(last_seq=rep.wal.last_seq)
        self.monitor.beat(node_id, rep.wal.last_seq)
        return rep

    def _point(self, name: str) -> None:
        if self.injector is not None:
            self.injector.fire(name)

    def _guard_primary(self) -> None:
        if self._primary_dead:
            raise PrimaryDownError(
                "primary is down and no follower has been promoted yet")

    # -------------------------------------------------------- write path
    # writes proxy to the primary DurableEngine unchanged (journal ->
    # dispatch -> commit-barrier fsync ordering and the hot-path
    # guarantees are its contract), plus a heartbeat per operation.
    def ingest(self, batch: Table, retract: bool = False):
        self._guard_primary()
        rep = self.primary.ingest(batch, retract=retract)
        self.monitor.beat(self.primary_id, self.primary.wal.last_seq)
        return rep

    def evict(self, ttl: int):
        self._guard_primary()
        out = self.primary.evict(ttl)
        self.monitor.beat(self.primary_id, self.primary.wal.last_seq)
        return out

    def commit(self):
        self._guard_primary()
        out = self.primary.commit()
        self.monitor.beat(self.primary_id, self.primary.wal.last_seq)
        return out

    def checkpoint(self, wait: bool = False) -> int:
        self._guard_primary()
        step = self.primary.checkpoint(wait=wait)
        self.monitor.beat(self.primary_id, self.primary.wal.last_seq)
        return step

    # -------------------------------------------------------------- ship
    def ship(self) -> int:
        """Stream the primary's WAL tail to every live follower; host
        bytes only, zero dispatches. Each follower has its own tail
        cursor, advanced past exactly what that follower durably
        accepted — a torn delivery re-ships the suffix next tick.
        Returns total records accepted across followers."""
        self._guard_primary()
        total = 0
        last = self.primary.wal.last_seq
        for nid in sorted(self.replicas):
            rep = self.replicas[nid]
            if not rep.alive:
                continue
            records, moved = self.primary.wal.read_tail(self._cursors[nid])
            data = wal_mod.encode_records(records)
            if self.ship_filter is not None:
                data = self.ship_filter(nid, data)
            self._point("ship.pre-send")
            n = rep.receive(data, self.epoch)
            self._point("ship.post-send")
            if not records or rep.wal.last_seq >= records[-1].seq:
                self._cursors[nid] = moved
            else:
                # partial acceptance (torn span): keep the byte position,
                # bump the dedup floor to what landed durably
                cur = self._cursors[nid]
                self._cursors[nid] = TailCursor(
                    cur.seg_start, cur.offset,
                    max(cur.last_seq, rep.wal.last_seq))
            rep.primary_seq = last
            self.monitor.beat(nid, rep.wal.last_seq)
            total += n
            trace.record_replication(ships=1, ship_records=n,
                                     ship_bytes=len(data))
        return total

    def apply_all(self, n: Optional[int] = None) -> int:
        """Advance every live follower's apply by up to ``n`` records
        (all, if None); returns total records still pending."""
        left = 0
        for nid in sorted(self.replicas):
            rep = self.replicas[nid]
            if not rep.alive:
                continue
            left += rep.apply_step(n)
            self.monitor.beat(nid, rep.wal.last_seq)
        return left

    def tick(self) -> Optional[int]:
        """One replication heartbeat: if the monitor declares the primary
        dead, fail over (returns the promoted node id); otherwise ship
        and apply. Liveness and promotion choice come from
        ``HeartbeatMonitor.plan`` — beats carry durable WAL seqs, so the
        plan's candidate IS the most-caught-up live follower."""
        for nid, rep in self.replicas.items():
            if rep.alive:        # live followers beat on every tick
                self.monitor.beat(nid, rep.wal.last_seq)
        plan = self.monitor.plan(primary=self.primary_id)
        if plan.action == "failover":
            return self.failover(plan.promote_to)
        if not self._primary_dead:
            self.ship()
            self.apply_all()
        return None

    # ---------------------------------------------------------- failover
    def kill_primary(self) -> DurableEngine:
        """Chaos hook: simulate primary process death. Writes start
        failing with :class:`PrimaryDownError`; heartbeats stop, so the
        next :meth:`tick` after the timeout fails over. Returns the dead
        handle — the ZOMBIE — so tests can prove its post-promotion
        appends are fenced."""
        zombie = self.primary
        self._primary_dead = True
        return zombie

    def kill_replica(self, node_id: int) -> Replica:
        """Chaos hook: simulate follower process death. It stops
        receiving ships and serving reads until
        :meth:`reattach_replica`."""
        rep = self.replicas[node_id]
        rep.alive = False
        return rep

    def failover(self, candidate: Optional[int] = None) -> int:
        """Promote the most-caught-up live follower (or ``candidate``).
        Returns the new primary's node id."""
        live = [nid for nid, r in sorted(self.replicas.items()) if r.alive]
        if not live:
            raise ReplicationError("no live follower to promote")
        if candidate is None or candidate not in live:
            candidate = max(live,
                            key=lambda nid:
                            (self.replicas[nid].wal.last_seq, -nid))
        return self.promote(candidate, expect_epoch=self.epoch)

    def promote(self, node_id: int, expect_epoch: int) -> int:
        """Epoch-CAS promotion of follower ``node_id``.

        Order matters and each boundary is a chaos crash point:
        fence-then-bump (the old primary's log rejects epochs below the
        new one BEFORE any new history exists), drain (the candidate
        applies its received tail — after this it is bitwise the
        never-crashed twin at its durable seq), then re-open the
        candidate's directory as the new primary at the new epoch.
        Exactly one promoter can win ``expect_epoch``; the rest get
        :class:`SplitBrainError`."""
        if expect_epoch != self.epoch:
            raise SplitBrainError(
                f"promotion CAS failed: observed epoch {expect_epoch}, "
                f"cluster already at {self.epoch}")
        rep = self.replicas[node_id]
        if not rep.alive:
            raise ReplicationError(f"cannot promote dead node {node_id}")
        new_epoch = expect_epoch + 1
        self._point("promote.pre-fence")
        self.primary.wal.fence(new_epoch)   # revoke the zombie's lease
        self.epoch = new_epoch
        self._point("promote.post-fence")
        rep.epoch = new_epoch
        rep.drain()
        self._point("promote.post-drain")
        rep.wal.close()
        self.primary = DurableEngine(rep.engine, rep.directory,
                                     saver=self.saver,
                                     injector=self.injector,
                                     epoch=new_epoch)
        if self.primary.wal.last_seq < rep.applied_seq:
            # nothing was ever shipped to this node: its log is empty and
            # all history lives in its bootstrap snapshot — keep numbering
            self.primary.wal.set_base(rep.applied_seq, new_epoch)
        self.primary_id = node_id
        self._primary_dead = False
        del self.replicas[node_id]
        del self._cursors[node_id]
        last = self.primary.wal.last_seq
        for nid, r in self.replicas.items():
            # fresh cursor on the NEW primary's log: the first ship
            # re-scans it once, the follower dedups by its durable seq
            self._cursors[nid] = TailCursor(last_seq=r.wal.last_seq)
            r.primary_seq = last
            if r.alive:
                # survivors learn the new term NOW, so a zombie's ship at
                # the old epoch is rejected even before the first re-ship
                r.epoch = max(r.epoch, new_epoch)
        self.monitor.beat(node_id, last)
        self.n_failovers += 1
        trace.record_replication(failovers=1)
        return node_id

    # ----------------------------------------------------------- queries
    # the primary's full query surface, for writers that read their own
    # writes; bounded-staleness follower reads go through self.router.
    def ate(self, *a, **kw):
        return self.primary.ate(*a, **kw)

    def ate_batch(self, specs):
        return self.primary.ate_batch(specs)

    def cached_estimate(self, *a, **kw):
        return self.primary.cached_estimate(*a, **kw)

    def matched_rows(self, *a, **kw):
        return self.primary.matched_rows(*a, **kw)

    def snapshot_version(self) -> int:
        return self.primary.snapshot_version()

    def __getattr__(self, name: str):
        return getattr(self.primary, name)

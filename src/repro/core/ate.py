"""Average-treatment-effect estimation (paper Eq. 1 / Eq. 4).

Given balanced groups b (CEM subclasses or propensity subclasses),

  tau_ATE = E_b[ E[Y|T=1, b] - E[Y|T=0, b] ]        (Eq. 4)

weighted by group probability n_b / N over the matched subset. We also
provide ATT weighting (treated-count weights — the standard CEM estimand)
and a per-unit weight vector ("cem weights") so any downstream weighted
estimator (e.g. weighted least squares) can consume the match.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import groupby
from repro.core.cem import CEMGroups


@dataclasses.dataclass(frozen=True)
class ATEEstimate:
    """Both estimands of one causal query, plus match diagnostics.

    Every query path — offline :func:`estimate_ate`, the online engines'
    ``ate()``, and the batched/serving path (``ate_batch``,
    :class:`repro.core.serving.QuerySpec`) — returns this same record;
    a ``QuerySpec``'s ``estimand`` only selects which field the serving
    layer reports (``QuerySpec.select``), so ATE and ATT twins of one
    subpopulation share a single estimate (and cache entry).

    ``state_version`` is the MVCC snapshot tag: online-engine estimates
    carry the committed state version they were answered at (-1 for the
    offline estimators, which have no versioned state). Two estimates with
    the same spec and the same ``state_version`` are bitwise identical."""

    ate: jnp.ndarray          # Eq. 4, group-probability weights
    att: jnp.ndarray          # treated-weighted
    n_matched_treated: jnp.ndarray
    n_matched_control: jnp.ndarray
    n_groups: jnp.ndarray
    variance: jnp.ndarray     # conservative within-group variance of ATE
    state_version: int = -1   # engine snapshot version (see core/online.py)


def _group_means(groups: CEMGroups):
    nt = jnp.where(groups.keep, groups.n_treated, 0.0)
    nc = jnp.where(groups.keep, groups.n_control, 0.0)
    mean_t = jnp.where(nt > 0, groups.sum_y_t / jnp.maximum(nt, 1e-9), 0.0)
    mean_c = jnp.where(nc > 0, groups.sum_y_c / jnp.maximum(nc, 1e-9), 0.0)
    return nt, nc, mean_t, mean_c


def _neyman_variance(keep, nt, nc, mean_t, mean_c, sum_yy_t, sum_yy_c,
                     sum_fn=jnp.sum):
    """Conservative within-group (Neyman) variance of the ATE from
    decomposable per-arm first and second moments."""
    var_t = sum_yy_t / jnp.maximum(nt, 1e-9) - mean_t ** 2
    var_c = sum_yy_c / jnp.maximum(nc, 1e-9) - mean_c ** 2
    n_b = nt + nc
    n_tot = jnp.maximum(sum_fn(n_b), 1e-9)
    se2_b = (var_t / jnp.maximum(nt, 1.0) + var_c / jnp.maximum(nc, 1.0))
    return sum_fn(jnp.where(keep, (n_b / n_tot) ** 2 * se2_b, 0.0))


def estimate_ate_from_stats(keep: jnp.ndarray, n_treated: jnp.ndarray,
                            n_control: jnp.ndarray, sum_y_t: jnp.ndarray,
                            sum_y_c: jnp.ndarray,
                            sum_yy_t: jnp.ndarray = None,
                            sum_yy_c: jnp.ndarray = None,
                            sum_fn=jnp.sum) -> ATEEstimate:
    """ATE/ATT straight from decomposable group stats (no row access).

    This is the estimator the online engine runs over materialized cuboid
    stat tables: O(#groups), independent of data size. With per-arm second
    moments (``sum_yy_t``/``sum_yy_c`` — the cuboid's ``yy``-family columns)
    the Neyman within-group variance is included; without them it is 0.

    ``sum_fn`` is the cross-group reduction. The online query pipelines
    pass the capacity-invariant canonical sum
    (:func:`repro.kernels.segment_stats.chunked_sum`), which makes the
    estimate a bitwise-deterministic function of the key-sorted group
    content ALONE — independent of padded vector length, partition count
    or capacity-growth history — so the replicated, partitioned, fused
    and batched (vmapped spec-table) query paths all return identical
    f32 bits for identical group stats."""
    nt = jnp.where(keep, n_treated, 0.0)
    nc = jnp.where(keep, n_control, 0.0)
    mean_t = jnp.where(nt > 0, sum_y_t / jnp.maximum(nt, 1e-9), 0.0)
    mean_c = jnp.where(nc > 0, sum_y_c / jnp.maximum(nc, 1e-9), 0.0)
    diff = mean_t - mean_c
    n_b = nt + nc
    n_tot = jnp.maximum(sum_fn(n_b), 1e-9)
    ate = sum_fn(jnp.where(keep, n_b * diff, 0.0)) / n_tot
    t_tot = jnp.maximum(sum_fn(nt), 1e-9)
    att = sum_fn(jnp.where(keep, nt * diff, 0.0)) / t_tot
    if sum_yy_t is None or sum_yy_c is None:
        var = jnp.float32(0.0)
    else:
        var = _neyman_variance(keep, nt, nc, mean_t, mean_c,
                               sum_yy_t, sum_yy_c, sum_fn=sum_fn)
    return ATEEstimate(ate=ate, att=att,
                       n_matched_treated=sum_fn(nt),
                       n_matched_control=sum_fn(nc),
                       n_groups=jnp.sum(keep.astype(jnp.int32)),
                       variance=var)


def estimate_ate(groups: CEMGroups,
                 y: jnp.ndarray = None, treatment: jnp.ndarray = None,
                 matched_valid: jnp.ndarray = None) -> ATEEstimate:
    """ATE/ATT from group stats. If (y, treatment, matched_valid) are given,
    a within-group variance estimate is included (else 0)."""
    est = estimate_ate_from_stats(groups.keep, groups.n_treated,
                                  groups.n_control, groups.sum_y_t,
                                  groups.sum_y_c)
    if y is None:
        return est
    nt, nc, mean_t, mean_c = _group_means(groups)
    g = groups.grouping
    w = matched_valid.astype(jnp.float32)
    t = treatment.astype(jnp.float32) * w
    c = (1.0 - treatment.astype(jnp.float32)) * w
    yf = y.astype(jnp.float32)
    sums = groupby.segment_sums(g, {"yy_t": t * yf * yf,
                                    "yy_c": c * yf * yf})
    var = _neyman_variance(groups.keep, nt, nc, mean_t, mean_c,
                           sums["yy_t"], sums["yy_c"])
    return dataclasses.replace(est, variance=var)


def cem_weights(groups: CEMGroups, treatment: jnp.ndarray,
                matched_valid: jnp.ndarray) -> jnp.ndarray:
    """Per-unit CEM weights (Iacus-King-Porro): treated units weight 1;
    control units in group b weight (n_t_b / n_c_b) * (N_c / N_t)."""
    g = groups.grouping
    nt_rows = groupby.broadcast_to_rows(g, groups.n_treated)
    nc_rows = groupby.broadcast_to_rows(g, groups.n_control)
    Nt, Nc = groups.matched_counts()
    t = treatment.astype(jnp.float32)
    w_control = (nt_rows / jnp.maximum(nc_rows, 1e-9)) * (Nc / jnp.maximum(Nt, 1e-9))
    w = jnp.where(t > 0, 1.0, w_control)
    return jnp.where(matched_valid, w, 0.0)


def difference_in_means(y: jnp.ndarray, treatment: jnp.ndarray,
                        valid: jnp.ndarray) -> jnp.ndarray:
    """Naive (confounded) estimator E[Y|T=1] - E[Y|T=0] — Eq. 2 applied
    without balancing; the paper's cautionary baseline."""
    w = valid.astype(jnp.float32)
    t = treatment.astype(jnp.float32) * w
    c = (1.0 - treatment.astype(jnp.float32)) * w
    yf = y.astype(jnp.float32)
    mean_t = jnp.sum(t * yf) / jnp.maximum(jnp.sum(t), 1e-9)
    mean_c = jnp.sum(c * yf) / jnp.maximum(jnp.sum(c), 1e-9)
    return mean_t - mean_c

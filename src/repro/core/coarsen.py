"""Covariate coarsening (the "C" of CEM).

The paper coarsens each continuous covariate by a user cutpoint vector (its
Fig. 5(a) CASE/WHEN view) or automatic equal-width/quantile binning, and
matches categoricals exactly. Here a :class:`CoarsenSpec` per covariate is
either categorical (cardinality) or a cutpoint array; ``coarsen`` maps values
to int32 bucket ids via ``searchsorted`` — the vectorized CASE/WHEN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CoarsenSpec:
    """How to coarsen one covariate.

    kind: "cutpoints" (continuous; buckets = len(cutpoints)+1)
          or "categorical" (values already in [0, cardinality)).
    """

    kind: str
    cutpoints: Optional[tuple] = None   # static tuple of floats, sorted
    cardinality: Optional[int] = None

    @property
    def n_buckets(self) -> int:
        if self.kind == "categorical":
            return int(self.cardinality)
        return len(self.cutpoints) + 1

    @staticmethod
    def categorical(cardinality: int) -> "CoarsenSpec":
        return CoarsenSpec(kind="categorical", cardinality=int(cardinality))

    @staticmethod
    def from_cutpoints(cutpoints: Sequence[float]) -> "CoarsenSpec":
        cp = tuple(float(c) for c in cutpoints)
        if list(cp) != sorted(cp):
            raise ValueError("cutpoints must be sorted")
        return CoarsenSpec(kind="cutpoints", cutpoints=cp)

    @staticmethod
    def equal_width(lo: float, hi: float, k: int) -> "CoarsenSpec":
        """k buckets of equal width over [lo, hi] (paper's §5.2 choice)."""
        if k < 1:
            raise ValueError("k >= 1")
        edges = np.linspace(lo, hi, k + 1)[1:-1]
        return CoarsenSpec.from_cutpoints(edges.tolist())

    @staticmethod
    def quantile(values: np.ndarray, k: int, valid: Optional[np.ndarray] = None
                 ) -> "CoarsenSpec":
        """k buckets at empirical quantiles (host-side; data-dependent)."""
        v = np.asarray(values, dtype=np.float64)
        if valid is not None:
            v = v[np.asarray(valid, dtype=bool)]
        qs = np.quantile(v, np.linspace(0, 1, k + 1)[1:-1])
        qs = np.unique(qs)
        return CoarsenSpec.from_cutpoints(qs.tolist())


def coarsen(x: jnp.ndarray, spec: CoarsenSpec) -> jnp.ndarray:
    """Map values to int32 bucket ids in [0, spec.n_buckets)."""
    if spec.kind == "categorical":
        return jnp.clip(x.astype(jnp.int32), 0, spec.cardinality - 1)
    cp = jnp.asarray(spec.cutpoints, dtype=jnp.float32)
    return jnp.searchsorted(cp, x.astype(jnp.float32), side="right").astype(
        jnp.int32)


def coarsen_columns(columns: Mapping[str, jnp.ndarray],
                    specs: Mapping[str, CoarsenSpec]) -> Dict[str, jnp.ndarray]:
    """Coarsen every spec'd column; returns {name: bucket ids}."""
    return {name: coarsen(columns[name], spec) for name, spec in specs.items()}

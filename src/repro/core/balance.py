"""Covariate-balance diagnostics (paper Eq. 5).

AWMD(x) = E_b[ | E[x | T=1, b] - E[x | T=0, b] | ], group-probability
weighted over retained groups — 0 for perfectly balanced groups (Eq. 3).
The "raw data" imbalance is the same quantity with a single global group.
"""
from __future__ import annotations

from typing import Dict, Mapping

import jax.numpy as jnp

from repro.core import groupby
from repro.core.cem import CEMGroups


def awmd(groups: CEMGroups, covariates: Mapping[str, jnp.ndarray],
         treatment: jnp.ndarray, matched_valid: jnp.ndarray
         ) -> Dict[str, jnp.ndarray]:
    """Absolute weighted mean difference per covariate over matched groups."""
    g = groups.grouping
    w = matched_valid.astype(jnp.float32)
    t = treatment.astype(jnp.float32) * w
    c = (1.0 - treatment.astype(jnp.float32)) * w
    cols = {}
    for name, x in covariates.items():
        xf = x.astype(jnp.float32)
        cols[f"xt_{name}"] = t * xf
        cols[f"xc_{name}"] = c * xf
    sums = groupby.segment_sums(g, cols)
    nt = jnp.where(groups.keep, groups.n_treated, 0.0)
    nc = jnp.where(groups.keep, groups.n_control, 0.0)
    n_b = nt + nc
    n_tot = jnp.maximum(jnp.sum(n_b), 1e-9)
    out = {}
    for name in covariates:
        mean_t = sums[f"xt_{name}"] / jnp.maximum(nt, 1e-9)
        mean_c = sums[f"xc_{name}"] / jnp.maximum(nc, 1e-9)
        d = jnp.abs(mean_t - mean_c)
        out[name] = jnp.sum(jnp.where(groups.keep, n_b * d, 0.0)) / n_tot
    return out


def raw_imbalance(covariates: Mapping[str, jnp.ndarray],
                  treatment: jnp.ndarray, valid: jnp.ndarray
                  ) -> Dict[str, jnp.ndarray]:
    """AWMD with one global group: |E[x|T=1] - E[x|T=0]| on the raw data."""
    w = valid.astype(jnp.float32)
    t = treatment.astype(jnp.float32) * w
    c = (1.0 - treatment.astype(jnp.float32)) * w
    nt = jnp.maximum(jnp.sum(t), 1e-9)
    nc = jnp.maximum(jnp.sum(c), 1e-9)
    out = {}
    for name, x in covariates.items():
        xf = x.astype(jnp.float32)
        out[name] = jnp.abs(jnp.sum(t * xf) / nt - jnp.sum(c * xf) / nc)
    return out

"""Bit-packed group keys.

SQL's ``GROUP BY cx_1, ..., cx_n`` becomes: pack the coarsened bucket ids
into a 63-bit key held as two uint32 words (TPUs have no native int64), then
lexicographically sort (hi, lo). The codec also supports *extracting* a
subset of fields and repacking under a sub-codec — that is exactly the
data-cube rollup of paper §4.2 (a coarser GROUP BY computed from a finer one).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Sequence, Tuple

import jax.numpy as jnp

U32 = jnp.uint32
INVALID_HI = jnp.uint32(0xFFFFFFFF)
INVALID_LO = jnp.uint32(0xFFFFFFFF)
_MAX_BITS = 63  # valid keys can never collide with the invalid marker


def _width(cardinality: int) -> int:
    return max(1, math.ceil(math.log2(max(2, cardinality))))


@dataclasses.dataclass(frozen=True)
class KeyCodec:
    """Packs named fields (each with a static cardinality) into (hi, lo) u32."""

    fields: Tuple[Tuple[str, int], ...]  # (name, cardinality), MSB-first

    def __post_init__(self):
        if self.total_bits > _MAX_BITS:
            raise ValueError(
                f"key needs {self.total_bits} bits > {_MAX_BITS}; coarsen more "
                f"aggressively or split the GROUP BY: {self.fields}")

    @staticmethod
    def from_cardinalities(cards: Mapping[str, int]) -> "KeyCodec":
        return KeyCodec(tuple((n, int(c)) for n, c in sorted(cards.items())))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.fields)

    @property
    def widths(self) -> Dict[str, int]:
        return {n: _width(c) for n, c in self.fields}

    @property
    def total_bits(self) -> int:
        return sum(self.widths.values())

    def offsets(self) -> Dict[str, int]:
        """Bit offset (from LSB of the 64-bit key) of each field."""
        offs, pos = {}, self.total_bits
        for n, _ in self.fields:
            pos -= self.widths[n]
            offs[n] = pos
        return offs

    # -- packing ---------------------------------------------------------
    def pack(self, buckets: Mapping[str, jnp.ndarray], valid: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """buckets[name] int32 in [0, card) -> (hi, lo) uint32 keys.

        Invalid rows get the all-ones marker so sorting pushes them last.
        """
        n = valid.shape[0]
        hi = jnp.zeros((n,), dtype=U32)
        lo = jnp.zeros((n,), dtype=U32)
        for name, _ in self.fields:
            w = self.widths[name]
            v = buckets[name].astype(U32)
            # (hi, lo) <<= w ; lo |= v      (w in [1, 31])
            hi = (hi << w) | (lo >> (32 - w))
            lo = (lo << w) | v
        hi = jnp.where(valid, hi, INVALID_HI)
        lo = jnp.where(valid, lo, INVALID_LO)
        return hi, lo

    # -- field extraction / rollup ----------------------------------------
    def extract(self, hi: jnp.ndarray, lo: jnp.ndarray, name: str
                ) -> jnp.ndarray:
        """Recover one field's bucket ids from packed keys (valid rows)."""
        off = self.offsets()[name]
        w = self.widths[name]
        mask = U32((1 << w) - 1)
        if off >= 32:
            return ((hi >> (off - 32)) & mask).astype(jnp.int32)
        if off + w <= 32:
            return ((lo >> off) & mask).astype(jnp.int32)
        lo_bits = 32 - off
        lo_part = lo >> off
        hi_part = (hi & U32((1 << (w - lo_bits)) - 1)) << lo_bits
        return ((hi_part | lo_part) & mask).astype(jnp.int32)

    def subcodec(self, names: Sequence[str]) -> "KeyCodec":
        keep = set(names)
        return KeyCodec(tuple((n, c) for n, c in self.fields if n in keep))

    def rollup(self, hi: jnp.ndarray, lo: jnp.ndarray, names: Sequence[str],
               valid: jnp.ndarray) -> Tuple["KeyCodec", jnp.ndarray, jnp.ndarray]:
        """Re-key onto a subset of fields (cube rollup). Returns sub-codec +
        packed sub-keys."""
        sub = self.subcodec(names)
        buckets = {n: self.extract(hi, lo, n) for n in sub.names}
        shi, slo = sub.pack(buckets, valid)
        return sub, shi, slo

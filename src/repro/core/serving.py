"""Multi-tenant query serving: a slot-based continuous batcher over the
online engines' batched query path.

ZaliQL's production shape is thousands of concurrent analysts asking
DIFFERENT subpopulation questions over the SAME materialized state — the
sufficient-statistic query-serving regime (PAPERS.md: Computational
Causal Inference; fast-causal-inference's SQLGateway). PR 5 made one
uncached ``ate()`` one compiled dispatch; this module makes a WINDOW of B
heterogeneous queries one compiled dispatch:

  submit() ──> FIFO queue ──> step():
     cache hits   -> answered host-side, NEVER occupy a slot
     duplicates   -> collapse onto the first occurrence's slot
     fresh specs  -> admitted into up to ``n_slots`` batch slots
                  -> encoded spec table -> ONE batched query dispatch
                     (``OnlineEngine.ate_batch`` ->
                      ``repro.core.fused.get_fused_query_batch``)
     results      -> per-subpopulation estimate cache (shared with
                     ``ate()``; invalidated per committed ingest delta)

The batcher generalizes :class:`repro.launch.serve.Batcher` (the LM
prefill/decode slot scheduler): same fixed-slot wave admission, but a
causal query completes in ONE program launch, so every wave frees every
slot, and the wave size is padded to a pow2 bucket
(``online._bucket_specs``) so arrival jitter never retraces the program.

Consistency: ONE VERSION PER WAVE — every query of one ``step()`` is
answered from, and tagged with, a single committed MVCC snapshot version
(``OnlineEngine.snapshot_version``). The version is captured before any
query is served; slots whose dispatch would straddle a commit are
requeued for the next wave rather than mixed in (``n_requeued``), and the
version is asserted unchanged across the batched dispatch. Cache entries
are invalidated by the engine's delta-predicate invalidation on every
committed ingest (see ``OnlineEngine._invalidate``), so a query admitted
after an ingest version bump re-dispatches instead of serving a stale
estimate. With ``overlap=True`` engines, serving proceeds against the
committed snapshot while ingest dispatches for the next versions are in
flight — ``commit()`` is the only point the served version moves.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.ate import ATEEstimate
from repro.core.online import _freeze_subpop

#: contract-lint scoping (tools/contract_check.py): this module is
#: engine-owned — dispatch/donation rules ZQL001-ZQL006 apply.
__engine_owned__ = True


def pow2_bucket(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the shared bucketing rule
    of every batched entry point (ingest rows, query specs, serve waves):
    compiled programs trace per padded size, so pow2 buckets cap the
    trace count of an irregular load at ~log2(max size)."""
    b = max(1, int(floor))
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One causal query as DATA: which view (``treatment``), which rows
    (``subpopulation`` — dim -> allowed coarsened buckets, conjunctive),
    which estimand (``"ate"`` or ``"att"``). The engine encodes this into
    a fixed-width uint32 spec row (``repro.core.fused.encode_query_spec``)
    so a batch of heterogeneous specs is just a device-resident table.

    ``subpopulation`` is stored in frozen ``((dim, (bucket, ...)), ...)``
    form, so specs are hashable — equal specs dedupe in flight and share
    one cache entry."""

    treatment: str
    subpopulation: Optional[Tuple] = None
    estimand: str = "ate"

    def __post_init__(self):
        if self.estimand not in ("ate", "att"):
            raise ValueError(f"unknown estimand {self.estimand!r}")
        object.__setattr__(self, "subpopulation",
                           _freeze_subpop(self.subpopulation))

    @staticmethod
    def make(treatment: str,
             subpopulation: Optional[Mapping[str, Sequence[int]]] = None,
             estimand: str = "ate") -> "QuerySpec":
        """Build a spec from the mapping form ``ate()`` accepts."""
        return QuerySpec(treatment, _freeze_subpop(subpopulation), estimand)

    def select(self, est: ATEEstimate) -> float:
        """This spec's answer from a full estimate — the host-side twin
        of the device program's ``value`` column (a pure selection of the
        same scalars, so both pick bit-identical numbers)."""
        return est.ate if self.estimand == "ate" else est.att


@dataclasses.dataclass(frozen=True)
class ServedQuery:
    """One completed query: the full estimate, the estimand-selected
    ``value``, and how it was answered (``cached`` = served from the
    host estimate cache without occupying a slot)."""

    qid: int
    spec: QuerySpec
    estimate: ATEEstimate
    value: float
    cached: bool
    state_version: int
    #: answered while the engine was still replaying its WAL tail after a
    #: crash recovery (``DurableEngine.degraded``) — the estimate reflects
    #: the restored snapshot, not yet the full journaled stream.
    degraded: bool = False
    #: how many primary WAL seqs behind the answering engine was at wave
    #: assembly (``repro.core.replication.Replica.replica_lag``); 0 when
    #: serving from the primary or an unreplicated engine. Bounded
    #: staleness: the router only routes to replicas within
    #: ``max_lag_seqs`` / ``max_lag_secs``, and every answer carries its
    #: actual lag so the client can judge freshness itself.
    replica_lag: int = 0


class ServingEngine:
    """Slot-based continuous batcher for causal queries.

    ``engine`` is an :class:`~repro.core.online.OnlineEngine` or
    :class:`~repro.core.online.PartitionedOnlineEngine`; ``n_slots``
    bounds the specs per batched dispatch (the wave is additionally
    padded to a pow2 bucket inside ``ate_batch``). Ingest can interleave
    freely with serving: the engine's estimate cache is invalidated per
    committed delta, so the next wave recomputes exactly the touched
    subpopulations.

    Counters: ``n_served`` (completed queries), ``n_cache_served``
    (answered from cache, zero dispatches), ``n_deduped`` (collapsed onto
    another in-flight slot), ``n_waves`` (batched dispatches issued),
    ``n_slots_used`` (total slots across waves — requests-per-dispatch =
    (n_served - n_cache_served) / n_waves), ``n_requeued`` (wave slots
    pushed back to the queue because a commit landed mid-wave — the
    one-version-per-wave invariant), ``n_shed`` (oldest queries dropped
    because the bounded queue overflowed), ``n_expired`` (queries whose
    deadline passed before wave assembly — dropped slot-free).

    ``max_queue`` bounds the submit queue (None = unbounded): when a new
    submit would exceed it the OLDEST pending query is shed and counted —
    backpressure for degraded-mode recovery, where replay throttles
    serving and an unbounded backlog would only answer stale questions.

    Degraded mode: when ``engine`` exposes a truthy ``degraded`` flag
    (``repro.core.durability.DurableEngine`` during post-crash WAL
    replay), every :class:`ServedQuery` of the wave is tagged
    ``degraded=True`` — answers come from the restored snapshot at its
    ``state_version``, honestly labeled as not yet caught up."""

    def __init__(self, engine, n_slots: int = 64,
                 max_queue: Optional[int] = None, clock=time.monotonic):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.engine = engine
        self.n_slots = int(n_slots)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.clock = clock
        self._queue: collections.deque = collections.deque()
        self._next_qid = 0
        self.n_served = 0
        self.n_cache_served = 0
        self.n_deduped = 0
        self.n_waves = 0
        self.n_slots_used = 0
        self.n_requeued = 0
        self.n_shed = 0
        self.n_expired = 0

    def submit(self, spec, deadline: Optional[float] = None) -> int:
        """Enqueue one query; returns its ticket id. ``spec`` is a
        :class:`QuerySpec` or anything ``QuerySpec.make`` accepts as
        ``(treatment, subpopulation)``. With a bounded queue the OLDEST
        pending query is shed (and ``n_shed`` bumped) to admit this one —
        its ticket id will simply never appear in a ``step()`` result.

        ``deadline`` is an absolute ``clock`` timestamp: a query whose
        deadline has passed by the time a wave assembles is dropped with
        ``n_expired`` bumped — an expired query never occupies a dispatch
        slot and never appears in a result (its caller stopped waiting)."""
        if not isinstance(spec, QuerySpec):
            treatment, sub = spec
            spec = QuerySpec.make(treatment, sub)
        qid = self._next_qid
        self._next_qid += 1
        if self.max_queue is not None:
            while len(self._queue) >= self.max_queue:
                self._queue.popleft()
                self.n_shed += 1
        self._queue.append((qid, spec, deadline))
        return qid

    def pending(self) -> int:
        """Queries submitted but not yet served."""
        return len(self._queue)

    def step(self) -> Dict[int, ServedQuery]:
        """One batch window: serve every queued cache hit (slot-free),
        admit up to ``n_slots`` unique uncached specs (identical
        in-flight specs collapse to one slot), run ONE batched dispatch,
        return every completed query keyed by ticket id. Queries beyond
        the slot budget stay queued for the next window.

        ONE-VERSION-PER-WAVE invariant: every ``ServedQuery`` of one
        ``step()`` is tagged with — and answered from — a single
        committed snapshot version. The version is captured up front
        (``engine.snapshot_version()``, which settles lazily pending
        evictions); if a commit lands between wave assembly and dispatch
        (e.g. a concurrent ingest thread committing mid-wave) the
        assembled slots are REQUEUED ahead of the backlog instead of
        dispatched — cache hits already served this step carried the old
        version honestly, and the requeued slots answer at the new
        version next step. After the dispatch the version is asserted
        unchanged, so a wave can never mix snapshots."""
        if not self._queue:
            return {}
        done: Dict[int, ServedQuery] = {}
        wave: List[Tuple[int, QuerySpec, Optional[float]]] = []
        wave_keys: Dict[Tuple, int] = {}
        back: collections.deque = collections.deque()
        n_dup = 0
        now = self.clock()
        version = self.engine.snapshot_version()
        degraded = bool(getattr(self.engine, "degraded", False))
        lag = int(getattr(self.engine, "replica_lag", 0))
        while self._queue:
            qid, spec, deadline = self._queue.popleft()
            if deadline is not None and deadline < now:
                self.n_expired += 1      # caller gave up: free, no slot
                continue
            hit = self.engine.cached_estimate(spec.treatment,
                                              spec.subpopulation)
            if hit is not None:
                self.n_cache_served += 1
                done[qid] = ServedQuery(qid, spec, hit, spec.select(hit),
                                        cached=True, state_version=version,
                                        degraded=degraded, replica_lag=lag)
                continue
            key = (spec.treatment, spec.subpopulation)
            if key not in wave_keys and len(wave_keys) >= self.n_slots:
                back.append((qid, spec, deadline))     # next window
                continue
            if key in wave_keys:
                n_dup += 1
            else:
                wave_keys[key] = len(wave_keys)
            wave.append((qid, spec, deadline))
        if wave and self.engine.snapshot_version() != version:
            # a commit straddled this wave: these slots would answer from
            # a NEWER snapshot than the cache hits above — requeue them
            # (ahead of the over-budget backlog, preserving FIFO order)
            self.n_requeued += len(wave)
            self._queue = collections.deque(wave)
            self._queue.extend(back)
            self.n_served += len(done)
            return done
        self._queue = back
        if wave:
            self.n_waves += 1
            self.n_deduped += n_dup
            self.n_slots_used += len(wave_keys)
            ests = self.engine.ate_batch([s for _, s, _ in wave])
            assert self.engine.snapshot_version() == version, (
                "one-version-per-wave violated: engine state committed "
                "during a batched query dispatch")
            for (qid, spec, _), est in zip(wave, ests):
                done[qid] = ServedQuery(qid, spec, est, spec.select(est),
                                        cached=False, state_version=version,
                                        degraded=degraded, replica_lag=lag)
        self.n_served += len(done)
        return done

    def serve(self, specs: Sequence) -> List[ServedQuery]:
        """Submit then fully drain, preserving input order — the batch
        analogue of calling :meth:`~repro.core.online.OnlineEngine.ate`
        per spec, at ~``ceil(unique uncached / n_slots)`` dispatches."""
        qids = [self.submit(s) for s in specs]
        results: Dict[int, ServedQuery] = {}
        while self.pending():
            results.update(self.step())
        return [results[q] for q in qids]


def run_poisson_load(serving: ServingEngine, specs: Sequence,
                     rate_qps: float, seed: int = 0
                     ) -> np.ndarray:
    """Replay ``specs`` against a live :class:`ServingEngine` with
    Poisson arrivals at ``rate_qps`` and return per-query latency
    (seconds, completion - arrival). The serving loop batches whatever
    has arrived each time a wave frees — the continuous-batching
    behavior the p50/p99 bench rows measure."""
    rng = np.random.default_rng(seed)
    n = len(specs)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, n))
    latency = np.zeros(n)
    submitted: Dict[int, int] = {}
    t0 = time.perf_counter()
    i = 0
    while len(submitted) < n or serving.pending():
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            submitted[serving.submit(specs[i])] = i
            i += 1
        if serving.pending():
            for qid in serving.step():
                fin = time.perf_counter() - t0
                latency[submitted[qid]] = fin - arrivals[submitted[qid]]
        elif i < n:
            time.sleep(min(1e-3, max(0.0, arrivals[i] - now)))
    return latency

"""Distributed ZaliQL: CEM/ATE and k-NN matching across a device mesh.

Two TPU-native communication patterns replace the single-node SQL engine
(design rationale in DESIGN.md §2):

COMBINE-BROADCAST GROUP-BY (CEM, subclassification, cuboids):
  1. each device groups its row shard locally (sort + segment stats — the
     paper's Fig. 5 view, per shard);
  2. the fixed-capacity local stat tables are `all_gather`ed over the data
     axis (stats are tiny relative to rows: #groups << #rows);
  3. every device re-combines the gathered tables (same group-by code) and
     now holds the REPLICATED global group stats -> overlap filter, ATE,
     AWMD are pure local math;
  4. row-level matched masks come from looking each row's key up in the
     broadcast table (binary search).
  Rows never move: no skew, no repartition, deterministic. Collective cost
  = capacity * n_stats * 4B per device, independent of data size.

RING k-NN JOIN (NNM):
  control shards circulate around the data axis via `ppermute` (ring-
  attention style) while each device folds every visiting shard into its
  queries' running top-k — the same merge loop as the knn_topk Pallas
  kernel, so compute overlaps the ring transfer on real hardware.

Both are shard_map programs over a 1-D "data" axis (the flattened
(pod, data) axes of the production mesh).
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import groupby
from repro.core.matching import BIG, _topk_merge
from repro.launch.trace import hot_path

#: contract-lint scoping (tools/contract_check.py): this module is
#: engine-owned — dispatch/donation rules ZQL001-ZQL006 apply.
__engine_owned__ = True


# ===================== combine-broadcast group-by ===========================
@hot_path
def _local_stat_table(hi, lo, stats: Dict[str, jnp.ndarray], capacity: int,
                      single_word: bool = False):
    g = groupby.group_by_key(hi, lo, single_word=single_word)
    sums = groupby.segment_sums(g, stats)
    return (g.group_hi[:capacity], g.group_lo[:capacity],
            {k: v[:capacity] for k, v in sums.items()},
            g.n_groups > capacity)


@hot_path
def _combine_gathered(ghi, glo, gstats: Dict[str, jnp.ndarray],
                      capacity: int, single_word: bool = False):
    """ghi/glo: (n_dev * capacity,) gathered keys (with invalid padding);
    re-group and sum."""
    g = groupby.group_by_key(ghi, glo, single_word=single_word)
    sums = groupby.segment_sums(g, gstats)
    return (g.group_hi[:capacity], g.group_lo[:capacity],
            {k: v[:capacity] for k, v in sums.items()},
            g.n_groups > capacity)


def make_distributed_cem(mesh, capacity: int = 8192,
                         axis: str = "data", key_bits: int = 64):
    """Returns a jitted function
        f(hi, lo, t, y, valid) -> (ate, att, variance, n_groups,
                                   n_matched_t, n_matched_c, matched_valid,
                                   overflow)
    with rows sharded over `axis` and scalar outputs replicated.

    The per-group state is the SAME decomposable stat schema the cube and
    the online engine materialize (``cube.stat_names`` for one treatment
    named "t": one/y/yy + t_t/yt_t/yyt_t, via ``cube.delta_stat_columns``)
    and the estimate comes from the shared
    :func:`repro.core.ate.estimate_ate_from_stats` — one definition of
    group stats and of the estimator across the offline cube, the online
    engine and the distributed path. The ``yy`` second moments make the
    Neyman within-group variance a free extra output.
    """
    from repro.core import cube as cube_mod
    from repro.core.ate import estimate_ate_from_stats
    from repro.core.cem import overlap_keep
    from repro.core.keys import INVALID_HI, INVALID_LO

    single_word = key_bits <= 31

    def shard_body(hi, lo, t, y, valid):
        stats = cube_mod.delta_stat_columns({"t": t, "y": y}, valid,
                                            ("t",), "y")
        lhi, llo, lstats, loverflow = _local_stat_table(
            hi, lo, stats, capacity, single_word=single_word)
        # gather stat tables from every device (tiny vs rows)
        ghi = jax.lax.all_gather(lhi, axis, tiled=True)
        glo = jax.lax.all_gather(llo, axis, tiled=True)
        gstats = {k: jax.lax.all_gather(v, axis, tiled=True)
                  for k, v in lstats.items()}
        chi, clo, cstats, coverflow = _combine_gathered(
            ghi, glo, gstats, capacity, single_word=single_word)
        gvalid = ~((chi == INVALID_HI) & (clo == INVALID_LO))
        nt = cstats["t_t"]
        nc = cstats["one"] - nt
        keep = overlap_keep(gvalid, nt, nc)
        yt = cstats["yt_t"]
        yc = cstats["y"] - yt
        est = estimate_ate_from_stats(
            keep, nt, nc, yt, yc,
            sum_yy_t=cstats["yyt_t"], sum_yy_c=cstats["yy"] - cstats["yyt_t"])
        # row-level matched mask: look up each local row in the (sorted)
        # global table
        pos, found = groupby.lookup_rows_in_table(hi, lo, chi, clo)
        matched = valid & found & keep[pos]
        overflow = loverflow | coverflow
        any_overflow = jax.lax.pmax(overflow.astype(jnp.int32), axis) > 0
        return (est.ate, est.att, est.variance, est.n_groups,
                est.n_matched_treated, est.n_matched_control, matched,
                any_overflow)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P(), P(), P(), P(), P(axis), P()),
        check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn)


# ===================== sharded online delta build ===========================
@hot_path
def _sharded_delta_body(columns, valid, *, codec, specs, treatments,
                        outcome, capacity, axis):
    """Per-device shard body of the sharded (replicated-views) delta build:
    coarsen/pack/locally-aggregate the row shard, truncate to ``capacity``,
    all-gather the tiny per-device tables, re-combine. Exposed standalone so
    the fused single-dispatch ingest program (``repro.core.fused``) can
    compose it under one jit; :func:`make_sharded_delta_build` wraps it for
    the standalone (planner-path) dispatch."""
    from repro.core import cube as cube_mod
    from repro.core.coarsen import coarsen_columns

    buckets = coarsen_columns(columns, specs)
    hi, lo = codec.pack(buckets, valid)
    cols = cube_mod.delta_stat_columns(columns, valid, treatments, outcome)
    lhi, llo, lstats, loverflow = _local_stat_table(hi, lo, cols, capacity)
    ghi = jax.lax.all_gather(lhi, axis, tiled=True)
    glo = jax.lax.all_gather(llo, axis, tiled=True)
    gstats = {k: jax.lax.all_gather(v, axis, tiled=True)
              for k, v in lstats.items()}
    # full-length re-combine: the gathered table is tiny, so no second
    # truncation (hence no combine-side overflow) is needed
    g = groupby.group_by_key(ghi, glo)
    sums = groupby.segment_sums(g, gstats)
    any_overflow = jax.lax.pmax(loverflow.astype(jnp.int32), axis) > 0
    return (g.group_hi, g.group_lo, sums, g.group_valid, g.n_groups,
            any_overflow)


def make_sharded_delta_build(mesh, specs: Mapping, treatments: Sequence[str],
                             outcome: str, capacity: int,
                             axis: str = "data"):
    """Delta-cuboid build for the ONLINE engine, sharded over ``axis``.

    Each device coarsens/packs/locally-aggregates its row shard of a
    streamed batch (the same stat schema as ``cube._build_fn``, via
    ``cube.delta_stat_columns``), truncates its local stat table to
    ``capacity`` slots, and the tiny per-device tables are ``all_gather``ed
    and re-combined with the existing combine-broadcast group-by — so every
    device ends up holding the REPLICATED global delta stat table and the
    downstream cuboid merge is identical to the single-chip path.

    Returns a jitted ``f(columns, valid) -> (hi, lo, stats, group_valid,
    n_groups, overflow)`` with rows sharded over ``axis`` and the combined
    table (length n_dev * capacity, valid groups first) replicated.
    ``overflow`` is set when any LOCAL shard had more distinct groups than
    ``capacity`` (the combined table is then incomplete and the caller must
    fall back to an exact host-side build).
    """
    import functools

    from repro.core.cem import make_codec

    codec = make_codec(specs)
    body = functools.partial(_sharded_delta_body, codec=codec,
                             specs=dict(specs),
                             treatments=tuple(treatments), outcome=outcome,
                             capacity=capacity, axis=axis)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=(P(), P(), P(), P(), P(), P()),
                   check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn)


# ===================== routed (partitioned) delta build =====================
@hot_path
def _routed_delta_body(columns, valid, *, codec, specs, treatments, outcome,
                       capacity, view_items, n_parts, n_dev, axis):
    """Per-device shard body of the routed delta build, generalized to
    ``n_parts = k * n_dev`` key-range partitions (k contiguous ranges per
    device). Per view: roll the local stat table up to the view's dims,
    bucket rows by OWNER DEVICE (``partition_ids(...) // k`` — partitions
    are contiguous hash ranges, so a device's k partitions are one
    contiguous range too), exchange buckets with one ``all_to_all``, then
    re-group what arrived into the k local partition tables. Exposed
    standalone so the fused single-dispatch ingest composes it; wrapped by
    :func:`make_routed_delta_build` for standalone dispatch."""
    from repro.core import cube as cube_mod
    from repro.core.coarsen import coarsen_columns
    from repro.core.keys import INVALID_HI, INVALID_LO

    base_name = view_items[0][0]
    k = n_parts // n_dev
    me = jax.lax.axis_index(axis)

    buckets = coarsen_columns(columns, specs)
    hi, lo = codec.pack(buckets, valid)
    cols = cube_mod.delta_stat_columns(columns, valid, treatments, outcome)
    lhi, llo, lstats, overflow = _local_stat_table(hi, lo, cols, capacity)
    lgv = ~((lhi == INVALID_HI) & (llo == INVALID_LO))
    deltas = {}
    n_full = jnp.int32(0)
    for name, dims in view_items:
        if name == base_name:
            vhi, vlo, vstats, vgv = lhi, llo, lstats, lgv
        else:
            roll = cube_mod._rollup_fn(codec, dims)
            vhi, vlo, vstats, vgv = roll(lhi, llo, lgv, lstats)
        # bucket by owner DEVICE, exchange buckets with one all-to-all
        pid = cube_mod.partition_ids(vhi, vlo, n_parts)
        dev = pid // jnp.int32(k)
        own = vgv[None, :] & (dev[None, :] == jnp.arange(n_dev)[:, None])
        bhi = jnp.where(own, vhi[None, :], INVALID_HI)
        blo = jnp.where(own, vlo[None, :], INVALID_LO)
        bstats = {c: jnp.where(own, v[None, :], 0.0)
                  for c, v in vstats.items()}
        rhi = jax.lax.all_to_all(bhi, axis, 0, 0, tiled=True).reshape(-1)
        rlo = jax.lax.all_to_all(blo, axis, 0, 0, tiled=True).reshape(-1)
        rstats = {c: jax.lax.all_to_all(v, axis, 0, 0,
                                        tiled=True).reshape(-1)
                  for c, v in bstats.items()}
        # re-group arrivals into the k LOCAL partition tables (partition
        # ownership is a pure function of the key, recomputed on arrival)
        rgv = ~((rhi == INVALID_HI) & (rlo == INVALID_LO))
        rpid = cube_mod.partition_ids(rhi, rlo, n_parts)
        parts_hi, parts_lo, parts_gv = [], [], []
        parts_stats = {c: [] for c in rstats}
        n_view = jnp.int32(0)
        for j in range(k):
            ownj = rgv & (rpid == me * k + j)
            phi = jnp.where(ownj, rhi, INVALID_HI)
            plo = jnp.where(ownj, rlo, INVALID_LO)
            g = groupby.group_by_key(phi, plo)
            sums = groupby.segment_sums(
                g, {c: jnp.where(ownj, v, 0.0) for c, v in rstats.items()})
            overflow = overflow | (g.n_groups > capacity)
            n_view = n_view + g.n_groups
            parts_hi.append(g.group_hi[:capacity])
            parts_lo.append(g.group_lo[:capacity])
            parts_gv.append(g.group_valid[:capacity])
            for c in rstats:
                parts_stats[c].append(sums[c][:capacity])
        if name == base_name:
            n_full = jax.lax.psum(n_view, axis)
        deltas[name] = (jnp.stack(parts_hi), jnp.stack(parts_lo),
                        {c: jnp.stack(v) for c, v in parts_stats.items()},
                        jnp.stack(parts_gv))
    any_overflow = jax.lax.pmax(overflow.astype(jnp.int32), axis) > 0
    return deltas, n_full, any_overflow


def make_routed_delta_build(mesh, specs: Mapping, treatments: Sequence[str],
                            outcome: str, capacity: int,
                            view_dims: Mapping[str, Sequence[str]],
                            axis: str = "data", n_parts: int = None):
    """Delta build for PARTITIONED materialized views: instead of
    all-gathering every per-device stat table to every device (the
    replicated path), each delta row is ROUTED to the device that owns its
    key-range partition via one all-to-all. ``n_parts`` (default: the
    data-axis size) may be any multiple of the device count — each device
    then owns ``k = n_parts / n_dev`` contiguous key ranges.

    ``view_dims`` maps view name -> dims; the FIRST entry is the base view
    and must list every dim (the others roll up from it). Returns a jitted
    ``f(columns, valid) -> (deltas, n_full, overflow)`` where
    ``deltas[name]`` is ``(hi, lo, stats, group_valid)`` with leading
    ``(n_parts, capacity)`` partition axes sharded over ``axis``,
    ``n_full`` is the total distinct base-granularity delta groups, and
    ``overflow`` means some local or routed table was truncated (caller
    must fall back to the exact host build)."""
    import functools

    from repro.core import cube as cube_mod
    from repro.core.cem import make_codec

    codec = make_codec(specs)
    n_dev = int(mesh.shape[axis])
    if n_parts is None:
        n_parts = n_dev
    if n_parts % n_dev != 0:
        raise ValueError(f"n_parts={n_parts} must be a multiple of the "
                         f"data-axis size {n_dev}")
    view_items = tuple((name, tuple(dims))
                       for name, dims in view_dims.items())
    if set(view_items[0][1]) != set(codec.names):
        raise ValueError("first view_dims entry must cover every dim")
    body = functools.partial(_routed_delta_body, codec=codec,
                             specs=dict(specs),
                             treatments=tuple(treatments), outcome=outcome,
                             capacity=capacity, view_items=view_items,
                             n_parts=n_parts, n_dev=n_dev, axis=axis)

    from jax.experimental.shard_map import shard_map
    part = P(axis, None)
    out_deltas = {name: (part, part,
                         {k: part for k in cube_mod.stat_names(treatments)},
                         part)
                  for name, _ in view_items}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=(out_deltas, P(), P()),
                   check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn)


# ===================== routed row lookup (partitioned views) ================
@hot_path
def _routed_lookup_body(columns, valid, t_hi, t_lo, keep, *, codec, specs,
                        n_parts, n_dev, axis):
    """Per-device shard body of the ROUTED row lookup: the device-resident
    ``matched_rows`` probe against key-range partitioned views with no
    full reassembly. Each device packs its row shard's (coarsened) keys,
    hashes every row to its OWNER partition, exchanges probe keys with one
    ``all_to_all``, answers the arrivals with a partition-local binary
    search in its k resident tables (``groupby.lookup_rows_in_parts``) and
    routes the boolean answers back with a second ``all_to_all`` — so
    RESIDENT state stays ~1/N per device and no device ever materializes
    the whole view. Probe buffers are currently dense per destination
    (each device searches all n_dev * n_local arrival slots, most of
    them masked invalid), so per-device probe COMPUTE is O(total probe
    rows); compacting probes per destination before routing is the
    documented ROADMAP follow-up. Exposed standalone so the fused query
    programs (:func:`repro.core.fused.get_fused_rowlookup`) compose it
    under one jit."""
    from repro.core import cube as cube_mod
    from repro.core.coarsen import coarsen_columns
    from repro.core.keys import INVALID_HI, INVALID_LO

    k = n_parts // n_dev
    me = jax.lax.axis_index(axis)
    buckets = coarsen_columns(columns, specs)
    hi, lo = codec.pack(buckets, valid)
    pid = cube_mod.partition_ids(hi, lo, n_parts)
    dev = pid // jnp.int32(k)
    own = valid[None, :] & (dev[None, :] == jnp.arange(n_dev)[:, None])
    bhi = jnp.where(own, hi[None, :], INVALID_HI)
    blo = jnp.where(own, lo[None, :], INVALID_LO)
    rhi = jax.lax.all_to_all(bhi, axis, 0, 0, tiled=True).reshape(-1)
    rlo = jax.lax.all_to_all(blo, axis, 0, 0, tiled=True).reshape(-1)
    rvalid = ~((rhi == INVALID_HI) & (rlo == INVALID_LO))
    rpid = cube_mod.partition_ids(rhi, rlo, n_parts)
    j = jnp.clip(rpid - me * jnp.int32(k), 0, k - 1)
    pos, found = groupby.lookup_rows_in_parts(rhi, rlo, j, t_hi, t_lo)
    matched = rvalid & found & keep[j, pos]
    back = jax.lax.all_to_all(matched.reshape(n_dev, -1), axis, 0, 0,
                              tiled=True)
    # row d of `back` = this device's rows as answered by owner device d;
    # every probe row was routed to exactly one owner
    return jnp.any(back.reshape(n_dev, -1), axis=0)


def make_routed_row_lookup(mesh, specs: Mapping, view_dims: Sequence[str],
                           n_parts: int, axis: str = "data"):
    """Standalone jitted routed row lookup (the fused query pipeline wraps
    :func:`_routed_lookup_body` itself; this factory serves benchmarks and
    ad-hoc probes). Returns ``f(columns, valid, t_hi, t_lo, keep) ->
    matched`` with rows sharded over ``axis`` and the (n_parts, C) view
    state sharded per partition. Row count must divide the axis size (the
    engine pads)."""
    import functools

    from repro.core.cem import make_codec

    vspecs = {d: specs[d] for d in view_dims}
    codec = make_codec(vspecs)
    n_dev = int(mesh.shape[axis])
    if n_parts % n_dev != 0:
        raise ValueError(f"n_parts={n_parts} must be a multiple of the "
                         f"data-axis size {n_dev}")
    body = functools.partial(_routed_lookup_body, codec=codec, specs=vspecs,
                             n_parts=n_parts, n_dev=n_dev, axis=axis)
    from jax.experimental.shard_map import shard_map
    part = P(axis, None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(axis), part, part, part),
                   out_specs=P(axis),
                   check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn, label="query")


# ============================= ring k-NN ====================================
def make_ring_knn(mesh, k: int, axis: str = "data"):
    """Returns jitted f(Q, C, c_valid) -> (dist, idx): for each query row,
    the k nearest controls ANYWHERE on the mesh. Q, C row-sharded over
    `axis`; outputs sharded like Q; idx are global control row ids."""

    def shard_body(Q, C, cv):
        n_dev = jax.lax.psum(1, axis)
        me = jax.lax.axis_index(axis)
        nc_local = C.shape[0]
        qn = jnp.sum(Q * Q, axis=1, keepdims=True)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def fold(carry, hop):
            run_d, run_i, Cb, cvb = carry
            owner = (me - hop) % n_dev          # whose shard we hold now
            cn = jnp.sum(Cb * Cb, axis=1)[None, :]
            d2 = jnp.maximum(qn + cn - 2.0 * (Q @ Cb.T), 0.0)
            d2 = jnp.where(cvb[None, :].astype(bool), d2, BIG)
            base = owner * nc_local
            idx = base + jnp.arange(nc_local, dtype=jnp.int32)[None, :]
            idx = jnp.broadcast_to(idx, d2.shape)
            bk = min(k, nc_local)
            nd, np_ = jax.lax.top_k(-d2, bk)
            ni = jnp.take_along_axis(idx, np_, axis=1)
            run_d, run_i = _topk_merge(run_d, run_i, -nd, ni, k)
            # pass the control shard along the ring
            Cb = jax.lax.ppermute(Cb, axis, perm)
            cvb = jax.lax.ppermute(cvb, axis, perm)
            return (run_d, run_i, Cb, cvb), None

        run_d = jnp.full((Q.shape[0], k), BIG, jnp.float32)
        run_i = jnp.full((Q.shape[0], k), -1, jnp.int32)
        (run_d, run_i, _, _), _ = jax.lax.scan(
            fold, (run_d, run_i, C, cv.astype(jnp.int32)),
            jnp.arange(n_dev))
        return jnp.sqrt(run_d), run_i

    from jax.experimental.shard_map import shard_map
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis)),
                   check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn)


# ===================== distributed propensity (Newton) ======================
def make_distributed_newton(mesh, n_iter: int = 32, ridge: float = 1e-4,
                            axis: str = "data"):
    """Batch-sharded logistic regression: per-device fused grad/Hessian
    partials (the logistic_grad kernel's math) + psum — exact Newton."""

    def shard_body(X, t, m):
        d = X.shape[1]

        def step(w, _):
            logits = X @ w
            p = jax.nn.sigmoid(logits)
            r = m * (p - t)
            g = X.T @ r
            s = m * p * (1.0 - p)
            H = (X * s[:, None]).T @ X
            g = jax.lax.psum(g, axis) + ridge * w
            H = jax.lax.psum(H, axis) + ridge * jnp.eye(d)
            return w - jnp.linalg.solve(H, g), None

        w0 = jnp.zeros((X.shape[1],), jnp.float32)
        w, _ = jax.lax.scan(step, w0, None, length=n_iter)
        return w

    from jax.experimental.shard_map import shard_map
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=P(),
                   check_rep=False)
    from repro.launch.trace import counted_jit
    return counted_jit(fn)

"""Distributed ZaliQL: CEM/ATE and k-NN matching across a device mesh.

Two TPU-native communication patterns replace the single-node SQL engine
(design rationale in DESIGN.md §2):

COMBINE-BROADCAST GROUP-BY (CEM, subclassification, cuboids):
  1. each device groups its row shard locally (sort + segment stats — the
     paper's Fig. 5 view, per shard);
  2. the fixed-capacity local stat tables are `all_gather`ed over the data
     axis (stats are tiny relative to rows: #groups << #rows);
  3. every device re-combines the gathered tables (same group-by code) and
     now holds the REPLICATED global group stats -> overlap filter, ATE,
     AWMD are pure local math;
  4. row-level matched masks come from looking each row's key up in the
     broadcast table (binary search).
  Rows never move: no skew, no repartition, deterministic. Collective cost
  = capacity * n_stats * 4B per device, independent of data size.

RING k-NN JOIN (NNM):
  control shards circulate around the data axis via `ppermute` (ring-
  attention style) while each device folds every visiting shard into its
  queries' running top-k — the same merge loop as the knn_topk Pallas
  kernel, so compute overlaps the ring transfer on real hardware.

Both are shard_map programs over a 1-D "data" axis (the flattened
(pod, data) axes of the production mesh).
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import groupby
from repro.core.matching import BIG, _topk_merge


# ===================== combine-broadcast group-by ===========================
def _local_stat_table(hi, lo, stats: Dict[str, jnp.ndarray], capacity: int,
                      single_word: bool = False):
    g = groupby.group_by_key(hi, lo, single_word=single_word)
    sums = groupby.segment_sums(g, stats)
    return (g.group_hi[:capacity], g.group_lo[:capacity],
            {k: v[:capacity] for k, v in sums.items()},
            g.n_groups > capacity)


def _combine_gathered(ghi, glo, gstats: Dict[str, jnp.ndarray],
                      capacity: int, single_word: bool = False):
    """ghi/glo: (n_dev * capacity,) gathered keys (with invalid padding);
    re-group and sum."""
    g = groupby.group_by_key(ghi, glo, single_word=single_word)
    sums = groupby.segment_sums(g, gstats)
    return (g.group_hi[:capacity], g.group_lo[:capacity],
            {k: v[:capacity] for k, v in sums.items()},
            g.n_groups > capacity)


def make_distributed_cem(mesh, capacity: int = 8192,
                         axis: str = "data", key_bits: int = 64):
    """Returns a jitted function
        f(hi, lo, t, y, valid) -> (ate, att, n_groups, n_matched_t,
                                   n_matched_c, matched_valid, overflow)
    with rows sharded over `axis` and scalar outputs replicated.
    """

    single_word = key_bits <= 31

    def shard_body(hi, lo, t, y, valid):
        w = valid.astype(jnp.float32)
        tf = t.astype(jnp.float32) * w
        cf = (1.0 - t.astype(jnp.float32)) * w
        yf = y.astype(jnp.float32)
        stats = {"n_t": tf, "n_c": cf, "y_t": tf * yf, "y_c": cf * yf}
        lhi, llo, lstats, loverflow = _local_stat_table(
            hi, lo, stats, capacity, single_word=single_word)
        # gather stat tables from every device (tiny vs rows)
        ghi = jax.lax.all_gather(lhi, axis, tiled=True)
        glo = jax.lax.all_gather(llo, axis, tiled=True)
        gstats = {k: jax.lax.all_gather(v, axis, tiled=True)
                  for k, v in lstats.items()}
        chi, clo, cstats, coverflow = _combine_gathered(
            ghi, glo, gstats, capacity, single_word=single_word)
        keep = (~((chi == jnp.uint32(0xFFFFFFFF))
                  & (clo == jnp.uint32(0xFFFFFFFF)))
                & (cstats["n_t"] > 0) & (cstats["n_c"] > 0))
        nt = jnp.where(keep, cstats["n_t"], 0.0)
        nc = jnp.where(keep, cstats["n_c"], 0.0)
        mean_t = jnp.where(nt > 0, cstats["y_t"] / jnp.maximum(nt, 1e-9), 0.)
        mean_c = jnp.where(nc > 0, cstats["y_c"] / jnp.maximum(nc, 1e-9), 0.)
        diff = mean_t - mean_c
        n_b = nt + nc
        n_tot = jnp.maximum(jnp.sum(n_b), 1e-9)
        ate = jnp.sum(n_b * diff) / n_tot
        att = jnp.sum(nt * diff) / jnp.maximum(jnp.sum(nt), 1e-9)
        n_groups = jnp.sum(keep.astype(jnp.int32))
        # row-level matched mask: look up each local row in the (sorted)
        # global table
        pos, found = groupby.lookup_rows_in_table(hi, lo, chi, clo)
        matched = valid & found & keep[pos]
        overflow = loverflow | coverflow
        any_overflow = jax.lax.pmax(overflow.astype(jnp.int32), axis) > 0
        return (ate, att, n_groups, jnp.sum(nt), jnp.sum(nc), matched,
                any_overflow)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P(), P(), P(), P(axis), P()),
        check_rep=False)
    return jax.jit(fn)


# ===================== sharded online delta build ===========================
def make_sharded_delta_build(mesh, specs: Mapping, treatments: Sequence[str],
                             outcome: str, capacity: int,
                             axis: str = "data"):
    """Delta-cuboid build for the ONLINE engine, sharded over ``axis``.

    Each device coarsens/packs/locally-aggregates its row shard of a
    streamed batch (the same stat schema as ``cube._build_fn``, via
    ``cube.delta_stat_columns``), truncates its local stat table to
    ``capacity`` slots, and the tiny per-device tables are ``all_gather``ed
    and re-combined with the existing combine-broadcast group-by — so every
    device ends up holding the REPLICATED global delta stat table and the
    downstream cuboid merge is identical to the single-chip path.

    Returns a jitted ``f(columns, valid) -> (hi, lo, stats, group_valid,
    n_groups, overflow)`` with rows sharded over ``axis`` and the combined
    table (length n_dev * capacity, valid groups first) replicated.
    ``overflow`` is set when any LOCAL shard had more distinct groups than
    ``capacity`` (the combined table is then incomplete and the caller must
    fall back to an exact host-side build).
    """
    from repro.core import cube as cube_mod
    from repro.core.cem import make_codec
    from repro.core.coarsen import coarsen_columns

    codec = make_codec(specs)
    specs = dict(specs)
    treatments = tuple(treatments)

    def shard_body(columns, valid):
        buckets = coarsen_columns(columns, specs)
        hi, lo = codec.pack(buckets, valid)
        cols = cube_mod.delta_stat_columns(columns, valid, treatments,
                                           outcome)
        lhi, llo, lstats, loverflow = _local_stat_table(
            hi, lo, cols, capacity)
        ghi = jax.lax.all_gather(lhi, axis, tiled=True)
        glo = jax.lax.all_gather(llo, axis, tiled=True)
        gstats = {k: jax.lax.all_gather(v, axis, tiled=True)
                  for k, v in lstats.items()}
        # full-length re-combine: the gathered table is tiny, so no second
        # truncation (hence no combine-side overflow) is needed
        g = groupby.group_by_key(ghi, glo)
        sums = groupby.segment_sums(g, gstats)
        any_overflow = jax.lax.pmax(loverflow.astype(jnp.int32), axis) > 0
        return (g.group_hi, g.group_lo, sums, g.group_valid, g.n_groups,
                any_overflow)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=(P(), P(), P(), P(), P(), P()),
                   check_rep=False)
    return jax.jit(fn)


# ============================= ring k-NN ====================================
def make_ring_knn(mesh, k: int, axis: str = "data"):
    """Returns jitted f(Q, C, c_valid) -> (dist, idx): for each query row,
    the k nearest controls ANYWHERE on the mesh. Q, C row-sharded over
    `axis`; outputs sharded like Q; idx are global control row ids."""

    def shard_body(Q, C, cv):
        n_dev = jax.lax.psum(1, axis)
        me = jax.lax.axis_index(axis)
        nc_local = C.shape[0]
        qn = jnp.sum(Q * Q, axis=1, keepdims=True)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def fold(carry, hop):
            run_d, run_i, Cb, cvb = carry
            owner = (me - hop) % n_dev          # whose shard we hold now
            cn = jnp.sum(Cb * Cb, axis=1)[None, :]
            d2 = jnp.maximum(qn + cn - 2.0 * (Q @ Cb.T), 0.0)
            d2 = jnp.where(cvb[None, :].astype(bool), d2, BIG)
            base = owner * nc_local
            idx = base + jnp.arange(nc_local, dtype=jnp.int32)[None, :]
            idx = jnp.broadcast_to(idx, d2.shape)
            bk = min(k, nc_local)
            nd, np_ = jax.lax.top_k(-d2, bk)
            ni = jnp.take_along_axis(idx, np_, axis=1)
            run_d, run_i = _topk_merge(run_d, run_i, -nd, ni, k)
            # pass the control shard along the ring
            Cb = jax.lax.ppermute(Cb, axis, perm)
            cvb = jax.lax.ppermute(cvb, axis, perm)
            return (run_d, run_i, Cb, cvb), None

        run_d = jnp.full((Q.shape[0], k), BIG, jnp.float32)
        run_i = jnp.full((Q.shape[0], k), -1, jnp.int32)
        (run_d, run_i, _, _), _ = jax.lax.scan(
            fold, (run_d, run_i, C, cv.astype(jnp.int32)),
            jnp.arange(n_dev))
        return jnp.sqrt(run_d), run_i

    from jax.experimental.shard_map import shard_map
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis)),
                   check_rep=False)
    return jax.jit(fn)


# ===================== distributed propensity (Newton) ======================
def make_distributed_newton(mesh, n_iter: int = 32, ridge: float = 1e-4,
                            axis: str = "data"):
    """Batch-sharded logistic regression: per-device fused grad/Hessian
    partials (the logistic_grad kernel's math) + psum — exact Newton."""

    def shard_body(X, t, m):
        d = X.shape[1]

        def step(w, _):
            logits = X @ w
            p = jax.nn.sigmoid(logits)
            r = m * (p - t)
            g = X.T @ r
            s = m * p * (1.0 - p)
            H = (X * s[:, None]).T @ X
            g = jax.lax.psum(g, axis) + ridge * w
            H = jax.lax.psum(H, axis) + ridge * jnp.eye(d)
            return w - jnp.linalg.solve(H, g), None

        w0 = jnp.zeros((X.shape[1],), jnp.float32)
        w, _ = jax.lax.scan(step, w0, None, length=n_iter)
        return w

    from jax.experimental.shard_map import shard_map
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=P(),
                   check_rep=False)
    return jax.jit(fn)

"""Distance measures for matching (paper Fig. 1).

- Propensity-score distance |E(x_i) - E(x_j)|  (1-D!)
- Mahalanobis distance (x_i - x_j)' Sigma^{-1} (x_j - x_j)
- Coarsened distance (0 if same coarsened cell, inf otherwise) — that case
  is CEM and handled by repro.core.cem.

Mahalanobis is expressed in an MXU-friendly form: with L = chol(Sigma^{-1}),
d(i,j) = ||L^T x_i - L^T x_j||^2, so a one-time feature rotation turns it
into squared Euclidean distance and the matching kernel only ever computes
||u_i - u_j||^2 = |u_i|^2 + |u_j|^2 - 2 u_i.u_j  (a matmul).
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.data.columnar import Table


def masked_covariance(X: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    w = valid.astype(jnp.float32)[:, None]
    n = jnp.maximum(jnp.sum(w), 2.0)
    mean = jnp.sum(X * w, axis=0) / n
    Xc = (X - mean) * w
    return Xc.T @ Xc / (n - 1.0)


def mahalanobis_transform(X: jnp.ndarray, valid: jnp.ndarray,
                          ridge: float = 1e-6) -> jnp.ndarray:
    """Rotate features so Euclidean distance == Mahalanobis distance."""
    d = X.shape[1]
    sigma = masked_covariance(X, valid) + ridge * jnp.eye(d)
    sigma_inv = jnp.linalg.inv(sigma)
    L = jnp.linalg.cholesky(sigma_inv)
    return X.astype(jnp.float32) @ L


def features(table: Table, names: Sequence[str]) -> jnp.ndarray:
    return jnp.stack([table[n].astype(jnp.float32) for n in names], axis=-1)


def pairwise_sqdist(U: jnp.ndarray, V: jnp.ndarray) -> jnp.ndarray:
    """(n, d) x (m, d) -> (n, m) squared Euclidean distances via matmul."""
    un = jnp.sum(U * U, axis=1, keepdims=True)
    vn = jnp.sum(V * V, axis=1, keepdims=True)
    return jnp.maximum(un + vn.T - 2.0 * (U @ V.T), 0.0)


def ps_distance_features(ps: jnp.ndarray) -> jnp.ndarray:
    """Propensity distance as 1-D Euclidean features."""
    return ps.astype(jnp.float32)[:, None]

"""Data-cube aggregation for CEM over many treatments (paper §4.2).

CEM for one treatment is a GROUP BY over its covariates. CEM for all
2^|X| conjunctive treatments is the group-by *lattice* — so the classic
cube optimizations apply: materialize a base cuboid once, and compute every
coarser group-by from its smallest materialized ancestor instead of the
base relation.

A :class:`Cuboid` is a group-stat table: packed keys + decomposable
aggregates (counts/sums per treatment arm). Everything CEM/ATE need is
decomposable (min/max/sum/count), so rollups are exact. The same stat-table
shape is what `repro.core.distributed` all-gathers across chips — the cube
and the distributed combine are literally one mechanism.

A :class:`PartitionedCuboid` is the scale-out form of the same table: the
key space is split into contiguous ranges of a 32-bit avalanche-hash space
(:func:`partition_ids`) and each partition holds its own sorted stat table,
stacked along a leading ``(n_parts, capacity)`` axis. On a device mesh that
leading axis is sharded over the data axis, so every device owns 1/N of the
materialized state instead of a full replica; deltas are ROUTED to the
owning partition (all-to-all on key range) and merges/compaction/eviction
run per-partition. Any group key lives in exactly one partition, so
per-group stats are identical to the replicated layout — the partitioning
changes where state lives, never what it contains.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groupby
from repro.core.cem import CEMGroups, make_codec, overlap_keep
from repro.core.coarsen import CoarsenSpec, coarsen_columns
from repro.core.keys import INVALID_HI, INVALID_LO, KeyCodec
from repro.data.columnar import Table, _round_capacity
from repro.launch.trace import counted_jit


@dataclasses.dataclass(frozen=True)
class Cuboid:
    """Group-stat table over a set of dims (coarsened covariates).

    stats: per-group decomposable sums:
      "one"  -> n rows, "y" -> sum outcome, "yy" -> sum outcome^2, and per
      treatment t: f"t_{t}" -> n treated, f"yt_{t}" -> sum outcome over
      treated, f"yyt_{t}" -> sum outcome^2 over treated. The second moments
      make the Neyman within-group variance computable from stats alone.
    """

    codec: KeyCodec
    key_hi: jnp.ndarray
    key_lo: jnp.ndarray
    stats: Dict[str, jnp.ndarray]
    group_valid: jnp.ndarray
    treatments: Tuple[str, ...]

    @property
    def capacity(self) -> int:
        return int(self.key_hi.shape[0])

    @property
    def dims(self) -> Tuple[str, ...]:
        return self.codec.names

    def n_groups(self) -> jnp.ndarray:
        return jnp.sum(self.group_valid.astype(jnp.int32))


def stat_names(treatments: Sequence[str]) -> Tuple[str, ...]:
    """The decomposable stat columns a cuboid carries for ``treatments``."""
    names = ["one", "y", "yy"]
    for t in treatments:
        names += [f"t_{t}", f"yt_{t}", f"yyt_{t}"]
    return tuple(names)


def delta_stat_columns(columns: Mapping[str, jnp.ndarray], valid: jnp.ndarray,
                       treatments: Sequence[str], outcome: str
                       ) -> Dict[str, jnp.ndarray]:
    """Per-row contributions to every cuboid stat (masked by validity).

    Shared between the single-device cuboid build and the per-device shard
    body of the distributed delta build — one definition of the stat schema.
    """
    w = valid.astype(jnp.float32)
    y = columns[outcome].astype(jnp.float32)
    cols = {"one": w, "y": w * y, "yy": w * y * y}
    for t in treatments:
        tv = columns[t].astype(jnp.float32) * w
        cols[f"t_{t}"] = tv
        cols[f"yt_{t}"] = tv * y
        cols[f"yyt_{t}"] = tv * y * y
    return cols


def empty_cuboid(codec: KeyCodec, treatments: Sequence[str],
                 capacity: int = 1024) -> Cuboid:
    """All-invalid cuboid of ``capacity`` slots — the seed state of online
    delta maintenance (first ingest takes the re-sort merge path)."""
    return Cuboid(
        codec=codec,
        key_hi=jnp.full((capacity,), INVALID_HI, dtype=jnp.uint32),
        key_lo=jnp.full((capacity,), INVALID_LO, dtype=jnp.uint32),
        stats={k: jnp.zeros((capacity,), jnp.float32)
               for k in stat_names(treatments)},
        group_valid=jnp.zeros((capacity,), bool),
        treatments=tuple(treatments))


def delta_build_body(columns, valid, *, codec, specs, treatments, outcome):
    """coarsen -> pack -> group -> segment-sum: THE build body of a
    base/delta stat table. One definition shared by the jitted offline
    build (:func:`_build_fn`) and the fused single-dispatch ingest
    programs (``repro.core.fused``), so a semantic change here propagates
    to every pipeline. Returns (hi, lo, sums, group_valid, n_groups)."""
    buckets = coarsen_columns(columns, specs)
    hi, lo = codec.pack(buckets, valid)
    g = groupby.group_by_key(hi, lo)
    cols = delta_stat_columns(columns, valid, treatments, outcome)
    sums = groupby.segment_sums(g, cols)
    return g.group_hi, g.group_lo, sums, g.group_valid, g.n_groups


@functools.lru_cache(maxsize=256)
def _build_fn(codec: KeyCodec, specs_items: Tuple, treatments: Tuple[str, ...],
              outcome: str):
    """Jitted group+aggregate body of build_cuboid, cached per schema.

    Online ingest builds a delta cuboid per batch; eagerly that is dozens
    of small dispatches (~15ms) dominating the per-batch cost. Schema and
    shapes are stable across a stream, so one trace amortizes away."""
    specs = dict(specs_items)

    @counted_jit
    def fn(columns, valid):
        hi, lo, sums, gv, _ = delta_build_body(
            columns, valid, codec=codec, specs=specs,
            treatments=treatments, outcome=outcome)
        return hi, lo, sums, gv
    return fn


def build_cuboid(table: Table, specs: Mapping[str, CoarsenSpec],
                 treatments: Sequence[str], outcome: str) -> Cuboid:
    """Base cuboid: group the relation by ALL dims, store decomposable stats."""
    codec = make_codec(specs)
    fn = _build_fn(codec, tuple(sorted(specs.items())), tuple(treatments),
                   outcome)
    hi, lo, sums, gv = fn(dict(table.columns), table.valid)
    return Cuboid(codec=codec, key_hi=hi, key_lo=lo, stats=sums,
                  group_valid=gv, treatments=tuple(treatments))


@functools.lru_cache(maxsize=256)
def _rollup_fn(codec: KeyCodec, dims: Tuple[str, ...]):
    """Jitted re-key + re-aggregate body of rollup, cached per (codec, dims)
    — same rationale as :func:`_build_fn`."""
    sub = codec.subcodec(dims)

    @counted_jit
    def fn(key_hi, key_lo, group_valid, stats):
        buckets = {n: codec.extract(key_hi, key_lo, n) for n in sub.names}
        shi, slo = sub.pack(buckets, group_valid)
        g = groupby.group_by_key(shi, slo)
        sums = groupby.segment_sums(g, stats)
        return g.group_hi, g.group_lo, sums, g.group_valid
    return fn


def rollup(cuboid: Cuboid, dims: Sequence[str]) -> Cuboid:
    """Coarser cuboid over a subset of dims, computed from ``cuboid`` (not
    the base relation). Cost scales with cuboid capacity, not data size."""
    missing = set(dims) - set(cuboid.dims)
    if missing:
        raise ValueError(f"dims {missing} not in cuboid {cuboid.dims}")
    fn = _rollup_fn(cuboid.codec, tuple(dims))
    shi, slo, sums, gv = fn(cuboid.key_hi, cuboid.key_lo,
                            cuboid.group_valid, dict(cuboid.stats))
    return Cuboid(codec=cuboid.codec.subcodec(dims), key_hi=shi, key_lo=slo,
                  stats=sums, group_valid=gv, treatments=cuboid.treatments)


def compact_cuboid(cuboid: Cuboid, granule: int = 1024,
                   keep_mask: np.ndarray = None) -> Cuboid:
    """Host-side shrink to ~n_groups rows (materialization for reuse).

    ``keep_mask`` (host bool, per group) additionally drops groups — the
    online engine's eviction path. Padding uses the canonical invalid-key
    marker so binary-search lookups keep treating dead slots as absent.
    """
    gv = np.asarray(cuboid.group_valid)
    if keep_mask is not None:
        gv = gv & np.asarray(keep_mask)
    idx = np.nonzero(gv)[0]
    cap = _round_capacity(len(idx), granule)
    pad = cap - len(idx)

    def take(a, fill=0):
        out = np.asarray(a)[idx]
        return np.pad(out, [(0, pad)] + [(0, 0)] * (out.ndim - 1),
                      constant_values=fill)

    return Cuboid(
        codec=cuboid.codec,
        key_hi=jnp.asarray(take(cuboid.key_hi, fill=np.uint32(INVALID_HI))),
        key_lo=jnp.asarray(take(cuboid.key_lo, fill=np.uint32(INVALID_LO))),
        stats={k: jnp.asarray(take(v)) for k, v in cuboid.stats.items()},
        group_valid=jnp.asarray(np.pad(np.ones(len(idx), bool), (0, pad))),
        treatments=cuboid.treatments)


def delta_cuboid(batch: Table, specs: Mapping[str, CoarsenSpec],
                 treatments: Sequence[str], outcome: str,
                 granule: int = 256) -> Cuboid:
    """Stat table of ONE streamed batch, compacted small: the unit of online
    delta maintenance. Cost is O(batch), never O(total data)."""
    return compact_cuboid(build_cuboid(batch, specs, treatments, outcome),
                          granule=granule)


def scatter_merge_stats(base_stats: Mapping[str, jnp.ndarray],
                        pos: jnp.ndarray,
                        delta_stats: Mapping[str, jnp.ndarray],
                        use_pallas: bool = False) -> Dict[str, jnp.ndarray]:
    """Fast-path stat merge: scatter-add delta rows at known positions,
    optionally through the MXU one-hot kernel."""
    if use_pallas:
        from repro.kernels.ops import scatter_merge_op
        names = sorted(base_stats)
        table = jnp.stack([base_stats[k] for k in names], axis=1)
        vals = jnp.stack([delta_stats[k] for k in names], axis=1)
        merged = scatter_merge_op(table, pos, vals)
        return {k: merged[:, j] for j, k in enumerate(names)}
    return groupby.scatter_add_stats(base_stats, pos, delta_stats)


def merge_delta(base: Cuboid, delta: Cuboid, granule: int = 1024,
                use_pallas: bool = False, fast: bool = None
                ) -> Tuple[Cuboid, jnp.ndarray, bool]:
    """Fold a delta stat table into a materialized cuboid.

    Fast path (every valid delta key already exists in ``base``): scatter-add
    the delta stats at the looked-up positions — O(|delta groups|) work and
    the merged cuboid keeps ``base``'s row layout, so incrementally
    maintained per-group state (e.g. CEM keep masks) stays aligned.

    Slow path (new group keys, including the first merge into an empty
    cuboid): re-sort merge — the same combine ``repro.core.distributed``
    uses to fold per-chip stat tables — with geometric capacity growth.

    ``fast`` injects a path decision computed elsewhere: the fused online
    engine plans every merge of an ingest on device and reads all verdicts
    back in ONE sync (its fast-path merges then bypass this function
    entirely, so only ``fast=False`` re-sort merges land here). ``fast=None``
    decides locally with a blocking device->host read.

    Returns (merged, positions of delta groups in merged, fast_path).
    """
    if base.codec.fields != delta.codec.fields:
        raise ValueError("codec mismatch in merge_delta")
    if set(base.stats) != set(delta.stats):
        raise ValueError("stat-column mismatch in merge_delta")
    if fast is None or fast:
        pos, found = groupby.lookup_rows_in_table(
            delta.key_hi, delta.key_lo, base.key_hi, base.key_lo)
        if fast is None:
            fast = bool((np.asarray(found)
                         | ~np.asarray(delta.group_valid)).all())
    if fast:
        stats = scatter_merge_stats(base.stats, pos, delta.stats,
                                    use_pallas=use_pallas)
        return dataclasses.replace(base, stats=stats), pos, True
    cat_hi = jnp.concatenate([base.key_hi, delta.key_hi])
    cat_lo = jnp.concatenate([base.key_lo, delta.key_lo])
    cat_stats = {k: jnp.concatenate([base.stats[k], delta.stats[k]])
                 for k in base.stats}
    g = groupby.group_by_key(cat_hi, cat_lo)
    sums = groupby.segment_sums(g, cat_stats)
    merged_full = Cuboid(codec=base.codec, key_hi=g.group_hi,
                         key_lo=g.group_lo, stats=sums,
                         group_valid=g.group_valid,
                         treatments=base.treatments)
    # never shrink: growth is geometric in multiples of the old capacity
    out = compact_cuboid(merged_full, granule=max(granule, base.capacity))
    pos2, _ = groupby.lookup_rows_in_table(
        delta.key_hi, delta.key_lo, out.key_hi, out.key_lo)
    return out, pos2, False


def cem_groups_from_cuboid(cuboid: Cuboid, treatment: str) -> CEMGroups:
    """CEM group stats for one treatment straight from a cuboid whose dims
    are exactly that treatment's covariates (use :func:`rollup` first)."""
    nt = cuboid.stats[f"t_{treatment}"]
    n = cuboid.stats["one"]
    nc = n - nt
    yt = cuboid.stats[f"yt_{treatment}"]
    yc = cuboid.stats["y"] - yt
    keep = overlap_keep(cuboid.group_valid, nt, nc)
    # CEMGroups wants a Grouping; cuboid-level estimation never touches the
    # row-level fields, so install an inert one.
    dummy = groupby.Grouping(
        perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        inv_perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        seg_ids=jnp.zeros((cuboid.capacity,), jnp.int32),
        group_hi=cuboid.key_hi, group_lo=cuboid.key_lo,
        group_valid=cuboid.group_valid,
        n_groups=cuboid.n_groups())
    return CEMGroups(grouping=dummy, keep=keep, n_treated=nt, n_control=nc,
                     sum_y_t=yt, sum_y_c=yc)


def smallest_ancestor(targets: Mapping[str, Sequence[str]],
                      materialized: Mapping[str, Cuboid]
                      ) -> Dict[str, str]:
    """Cube planning: for each target group-by, pick the smallest
    materialized cuboid whose dims are a superset (classic cube heuristic)."""
    plan = {}
    for tname, dims in targets.items():
        need = set(dims)
        best = None
        for cname, cub in materialized.items():
            if need <= set(cub.dims):
                size = int(cub.n_groups())
                if best is None or size < best[0]:
                    best = (size, cname)
        if best is None:
            raise ValueError(f"no materialized ancestor covers {tname}: {dims}")
        plan[tname] = best[1]
    return plan


# ===================== key-range partitioned views ==========================
def _hash32(hi: jnp.ndarray, lo: jnp.ndarray):
    """32-bit avalanche hash of a packed (hi, lo) key — murmur3 finalizer.

    Pure u32 arithmetic so numpy (host routing fallback) and jnp (jitted
    routing) produce identical assignments bit for bit."""
    h = lo ^ (hi * np.uint32(0x9E3779B1))
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    return h ^ (h >> np.uint32(16))


def partition_ids(hi: jnp.ndarray, lo: jnp.ndarray, n_parts: int):
    """Owning partition of each key: partition p owns the p-th contiguous
    range of the hash space, computed as ``(hash * n_parts) >> 32`` via an
    exact u32 multiply-high (no float rounding, any ``n_parts`` < 2^16,
    identical under numpy and jnp). Hashing first balances load even when
    raw keys cluster; contiguous ranges keep the assignment a key-RANGE
    partition of the hashed space."""
    if n_parts == 1:
        return (hi * np.uint32(0)).astype(jnp.int32)
    if n_parts >= 1 << 16:
        raise ValueError(f"n_parts {n_parts} >= 2^16")
    h = _hash32(hi, lo)
    a = h >> np.uint32(16)
    b = h & np.uint32(0xFFFF)
    n = np.uint32(n_parts)
    t = a * n + ((b * n) >> np.uint32(16))
    return (t >> np.uint32(16)).astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedCuboid:
    """Key-range partitioned group-stat table: partition p (row p of every
    array) holds the sorted stat table of the keys whose hash falls in its
    range. Same stat schema as :class:`Cuboid`; the leading axis is what a
    mesh shards over its data axis. Registered as a pytree so whole tables
    can be device_put with a partition sharding in one call."""

    codec: KeyCodec
    key_hi: jnp.ndarray                # (P, C) u32
    key_lo: jnp.ndarray                # (P, C) u32
    stats: Dict[str, jnp.ndarray]      # (P, C) f32
    group_valid: jnp.ndarray           # (P, C) bool
    treatments: Tuple[str, ...]

    def tree_flatten(self):
        names = tuple(sorted(self.stats))
        children = (self.key_hi, self.key_lo, self.group_valid,
                    *(self.stats[n] for n in names))
        return children, (self.codec, self.treatments, names)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codec, treatments, names = aux
        key_hi, key_lo, group_valid, *stat_vals = children
        return cls(codec=codec, key_hi=key_hi, key_lo=key_lo,
                   stats=dict(zip(names, stat_vals)),
                   group_valid=group_valid, treatments=treatments)

    @property
    def n_parts(self) -> int:
        return int(self.key_hi.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.key_hi.shape[1])

    @property
    def dims(self) -> Tuple[str, ...]:
        return self.codec.names

    def n_groups(self) -> jnp.ndarray:
        return jnp.sum(self.group_valid.astype(jnp.int32))

    def part(self, p: int) -> Cuboid:
        """Partition p as a plain (host-side) Cuboid — the unit the
        per-partition merge/compaction paths operate on."""
        return Cuboid(codec=self.codec, key_hi=self.key_hi[p],
                      key_lo=self.key_lo[p],
                      stats={k: v[p] for k, v in self.stats.items()},
                      group_valid=self.group_valid[p],
                      treatments=self.treatments)


def _pad_cuboid(cuboid: Cuboid, capacity: int) -> Cuboid:
    """Host-side pad to ``capacity`` slots (invalid-key marker, zero stats)
    so per-partition tables of different sizes stack rectangularly."""
    pad = capacity - cuboid.capacity
    if pad < 0:
        raise ValueError("cannot shrink in _pad_cuboid")
    if pad == 0:
        return cuboid
    return Cuboid(
        codec=cuboid.codec,
        key_hi=jnp.pad(cuboid.key_hi, (0, pad),
                       constant_values=np.uint32(INVALID_HI)),
        key_lo=jnp.pad(cuboid.key_lo, (0, pad),
                       constant_values=np.uint32(INVALID_LO)),
        stats={k: jnp.pad(v, (0, pad)) for k, v in cuboid.stats.items()},
        group_valid=jnp.pad(cuboid.group_valid, (0, pad)),
        treatments=cuboid.treatments)


def pad_partitioned(pcub: PartitionedCuboid,
                    capacity: int) -> PartitionedCuboid:
    """Pad every partition of a (P, C) table to ``capacity`` slots along
    the slot axis (invalid-key marker, zero stats) — the growth step of the
    fused single-dispatch ingest, which merges at a fixed per-partition
    capacity and recompiles when a re-sort would not fit."""
    pad = capacity - pcub.capacity
    if pad < 0:
        raise ValueError("cannot shrink in pad_partitioned")
    if pad == 0:
        return pcub
    w = ((0, 0), (0, pad))
    return PartitionedCuboid(
        codec=pcub.codec,
        key_hi=jnp.pad(pcub.key_hi, w,
                       constant_values=np.uint32(INVALID_HI)),
        key_lo=jnp.pad(pcub.key_lo, w,
                       constant_values=np.uint32(INVALID_LO)),
        stats={k: jnp.pad(v, w) for k, v in pcub.stats.items()},
        group_valid=jnp.pad(pcub.group_valid, w),
        treatments=pcub.treatments)


def stack_partitions(parts: Sequence[Cuboid]) -> PartitionedCuboid:
    """Stack per-partition tables (padded to the max capacity) into one
    PartitionedCuboid — the common exit of every host-side per-partition
    rebuild (slow-path merge, compaction, eviction)."""
    cap = max(p.capacity for p in parts)
    parts = [_pad_cuboid(p, cap) for p in parts]
    return PartitionedCuboid(
        codec=parts[0].codec,
        key_hi=jnp.stack([p.key_hi for p in parts]),
        key_lo=jnp.stack([p.key_lo for p in parts]),
        stats={k: jnp.stack([p.stats[k] for p in parts])
               for k in parts[0].stats},
        group_valid=jnp.stack([p.group_valid for p in parts]),
        treatments=parts[0].treatments)


def partition_cuboid(cuboid: Cuboid, n_parts: int,
                     granule: int = 1024) -> PartitionedCuboid:
    """Host-side split of a replicated cuboid into its key-range partitions
    (each partition keeps global sorted order, so per-partition tables stay
    binary-searchable)."""
    pid = np.asarray(partition_ids(np.asarray(cuboid.key_hi),
                                   np.asarray(cuboid.key_lo), n_parts))
    gv = np.asarray(cuboid.group_valid)
    parts = []
    for p in range(n_parts):
        keep = gv & (pid == p)
        parts.append(compact_cuboid(cuboid, granule=granule, keep_mask=keep))
    return stack_partitions(parts)


@counted_jit
def _canonical_fn(key_hi, key_lo, stats):
    """Flatten (P, C) partition tables and re-sort into ONE canonical
    globally key-sorted table. Keys are distinct across partitions, so the
    segment sums are an exact gather — no float reassociation."""
    hi = key_hi.reshape(-1)
    lo = key_lo.reshape(-1)
    g = groupby.group_by_key(hi, lo)
    sums = groupby.segment_sums(g, {k: v.reshape(-1)
                                    for k, v in stats.items()})
    return g.group_hi, g.group_lo, sums, g.group_valid


def unpartition_cuboid(pcub: PartitionedCuboid) -> Cuboid:
    """Reassemble the replicated (canonically sorted) view of a partitioned
    cuboid — the deterministic cross-partition reduce queries run on. The
    stat vectors are tiny relative to rows, so this is O(total groups)."""
    hi, lo, sums, gv = _canonical_fn(pcub.key_hi, pcub.key_lo,
                                     dict(pcub.stats))
    return Cuboid(codec=pcub.codec, key_hi=hi, key_lo=lo, stats=sums,
                  group_valid=gv, treatments=pcub.treatments)


@functools.partial(counted_jit, static_argnames=("treatment",))
def _canonical_view_fn(key_hi, key_lo, stats, *, treatment):
    """One-dispatch canonical assembly of a partitioned VIEW: flatten +
    re-sort the (P, C) partition tables AND recompute the overlap mask in
    the same program — the planner-era (``query_pipeline="assemble"``)
    baseline, now free of the eager ``overlap_keep`` ops that used to
    trail the reassembly dispatch."""
    hi = key_hi.reshape(-1)
    lo = key_lo.reshape(-1)
    g = groupby.group_by_key(hi, lo)
    sums = groupby.segment_sums(g, {k: v.reshape(-1)
                                    for k, v in stats.items()})
    nt = sums[f"t_{treatment}"]
    keep = overlap_keep(g.group_valid, nt, sums["one"] - nt)
    return g.group_hi, g.group_lo, sums, g.group_valid, keep


def unpartition_view(pcub: PartitionedCuboid, treatment: str
                     ) -> Tuple[Cuboid, jnp.ndarray]:
    """(canonical cuboid, overlap keep) of one partitioned view in ONE
    compiled dispatch — the assembled form ``cem_groups`` and the
    ``assemble`` query baseline run on."""
    hi, lo, sums, gv, keep = _canonical_view_fn(
        pcub.key_hi, pcub.key_lo, dict(pcub.stats), treatment=treatment)
    return Cuboid(codec=pcub.codec, key_hi=hi, key_lo=lo, stats=sums,
                  group_valid=gv, treatments=pcub.treatments), keep


def slice_cuboid(cuboid: Cuboid, capacity: int) -> Cuboid:
    """Shrink a COMPACTED cuboid (valid groups in a key-sorted prefix —
    what the fused eviction program leaves behind) to ``capacity`` slots.
    The capacity-shrink pass after TTL eviction uses this to reclaim the
    memory of long-lived streams whose live set collapsed; the next fused
    ingest recompiles at the smaller granule count."""
    if capacity >= cuboid.capacity:
        return cuboid
    return Cuboid(
        codec=cuboid.codec,
        key_hi=cuboid.key_hi[:capacity], key_lo=cuboid.key_lo[:capacity],
        stats={k: v[:capacity] for k, v in cuboid.stats.items()},
        group_valid=cuboid.group_valid[:capacity],
        treatments=cuboid.treatments)


def slice_partitioned(pcub: PartitionedCuboid,
                      capacity: int) -> PartitionedCuboid:
    """Per-partition analogue of :func:`slice_cuboid`: shrink every
    partition's slot axis of a compacted (P, C) table to ``capacity``."""
    if capacity >= pcub.capacity:
        return pcub
    return PartitionedCuboid(
        codec=pcub.codec,
        key_hi=pcub.key_hi[:, :capacity], key_lo=pcub.key_lo[:, :capacity],
        stats={k: v[:, :capacity] for k, v in pcub.stats.items()},
        group_valid=pcub.group_valid[:, :capacity],
        treatments=pcub.treatments)


@functools.partial(counted_jit, static_argnames=("n_parts",))
def route_delta(hi, lo, stats, gv, n_parts: int):
    """Route a delta stat table to its owner partitions (single-device
    path; the mesh path routes with an all-to-all in
    ``repro.core.distributed.make_routed_delta_build``).

    Returns (hi, lo, stats, group_valid) with a leading ``n_parts`` axis:
    row p is partition p's share of the delta, re-grouped and key-sorted.
    Exact: each key lands in exactly one partition, so per-group sums are
    gathers, not re-summations."""
    pid = partition_ids(hi, lo, n_parts)

    def one(p):
        own = gv & (pid == p)
        phi = jnp.where(own, hi, INVALID_HI)
        plo = jnp.where(own, lo, INVALID_LO)
        g = groupby.group_by_key(phi, plo)
        sums = groupby.segment_sums(
            g, {k: jnp.where(own, v, 0.0) for k, v in stats.items()})
        return g.group_hi, g.group_lo, sums, g.group_valid

    return jax.vmap(one)(jnp.arange(n_parts))


def scatter_merge_stats_parts(base_stats: Mapping[str, jnp.ndarray],
                              pos: jnp.ndarray,
                              delta_stats: Mapping[str, jnp.ndarray],
                              use_pallas: bool = False
                              ) -> Dict[str, jnp.ndarray]:
    """Partition-local fast-path merge: scatter-add each partition's delta
    rows into its own stat table ((P, C) tables, (P, B) positions). No
    cross-partition traffic — the routing already delivered every delta row
    to its owner."""
    if use_pallas:
        from repro.kernels.ops import scatter_merge_parts_op
        names = sorted(base_stats)
        table = jnp.stack([base_stats[k] for k in names], axis=2)
        vals = jnp.stack([delta_stats[k] for k in names], axis=2)
        merged = scatter_merge_parts_op(table, pos, vals)
        return {k: merged[:, :, j] for j, k in enumerate(names)}
    return jax.vmap(groupby.scatter_add_stats)(dict(base_stats), pos,
                                               dict(delta_stats))


def merge_delta_parts(pcub: PartitionedCuboid, d_hi, d_lo, d_stats, d_gv,
                      granule: int = 1024
                      ) -> Tuple[PartitionedCuboid, jnp.ndarray]:
    """Slow-path (re-sort) merge of a routed delta into a partitioned
    cuboid: each partition re-sort-merges independently (growth events are
    rare and partition-local), then the tables re-stack at the max
    capacity. Returns (merged, per-partition positions of delta groups)."""
    parts = []
    for p in range(pcub.n_parts):
        delta_p = Cuboid(codec=pcub.codec, key_hi=d_hi[p], key_lo=d_lo[p],
                         stats={k: v[p] for k, v in d_stats.items()},
                         group_valid=d_gv[p], treatments=pcub.treatments)
        merged, _, _ = merge_delta(pcub.part(p), delta_p, granule=granule,
                                   fast=False)
        parts.append(merged)
    out = stack_partitions(parts)
    pos, _ = jax.vmap(groupby.lookup_rows_in_table)(
        d_hi, d_lo, out.key_hi, out.key_lo)
    return out, pos


def compact_partitioned(pcub: PartitionedCuboid, granule: int = 1024,
                        keep_mask: np.ndarray = None) -> PartitionedCuboid:
    """Host-side per-partition shrink (the partitioned eviction path);
    ``keep_mask`` is (P, C) over partition slots."""
    parts = []
    for p in range(pcub.n_parts):
        km = None if keep_mask is None else np.asarray(keep_mask)[p]
        parts.append(compact_cuboid(pcub.part(p), granule=granule,
                                    keep_mask=km))
    return stack_partitions(parts)


def filter_cuboid(cuboid: Cuboid, dim: str, bucket_values: Sequence[int]
                  ) -> Cuboid:
    """Sub-population restriction (paper §4.2 offline setting): keep only
    groups whose ``dim`` bucket is in ``bucket_values`` (e.g. airport=SFO)."""
    vals = cuboid.codec.extract(cuboid.key_hi, cuboid.key_lo, dim)
    ok = jnp.zeros_like(cuboid.group_valid)
    for b in bucket_values:
        ok = ok | (vals == b)
    gv = cuboid.group_valid & ok
    stats = {k: jnp.where(gv, v, 0.0) for k, v in cuboid.stats.items()}
    return Cuboid(codec=cuboid.codec, key_hi=cuboid.key_hi,
                  key_lo=cuboid.key_lo, stats=stats, group_valid=gv,
                  treatments=cuboid.treatments)

"""Data-cube aggregation for CEM over many treatments (paper §4.2).

CEM for one treatment is a GROUP BY over its covariates. CEM for all
2^|X| conjunctive treatments is the group-by *lattice* — so the classic
cube optimizations apply: materialize a base cuboid once, and compute every
coarser group-by from its smallest materialized ancestor instead of the
base relation.

A :class:`Cuboid` is a group-stat table: packed keys + decomposable
aggregates (counts/sums per treatment arm). Everything CEM/ATE need is
decomposable (min/max/sum/count), so rollups are exact. The same stat-table
shape is what `repro.core.distributed` all-gathers across chips — the cube
and the distributed combine are literally one mechanism.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groupby
from repro.core.cem import CEMGroups, make_codec, overlap_keep
from repro.core.coarsen import CoarsenSpec, coarsen_columns
from repro.core.keys import INVALID_HI, INVALID_LO, KeyCodec
from repro.data.columnar import Table, _round_capacity


@dataclasses.dataclass(frozen=True)
class Cuboid:
    """Group-stat table over a set of dims (coarsened covariates).

    stats: per-group decomposable sums:
      "one"  -> n rows, "y" -> sum outcome, "yy" -> sum outcome^2, and per
      treatment t: f"t_{t}" -> n treated, f"yt_{t}" -> sum outcome over
      treated, f"yyt_{t}" -> sum outcome^2 over treated. The second moments
      make the Neyman within-group variance computable from stats alone.
    """

    codec: KeyCodec
    key_hi: jnp.ndarray
    key_lo: jnp.ndarray
    stats: Dict[str, jnp.ndarray]
    group_valid: jnp.ndarray
    treatments: Tuple[str, ...]

    @property
    def capacity(self) -> int:
        return int(self.key_hi.shape[0])

    @property
    def dims(self) -> Tuple[str, ...]:
        return self.codec.names

    def n_groups(self) -> jnp.ndarray:
        return jnp.sum(self.group_valid.astype(jnp.int32))


def stat_names(treatments: Sequence[str]) -> Tuple[str, ...]:
    """The decomposable stat columns a cuboid carries for ``treatments``."""
    names = ["one", "y", "yy"]
    for t in treatments:
        names += [f"t_{t}", f"yt_{t}", f"yyt_{t}"]
    return tuple(names)


def delta_stat_columns(columns: Mapping[str, jnp.ndarray], valid: jnp.ndarray,
                       treatments: Sequence[str], outcome: str
                       ) -> Dict[str, jnp.ndarray]:
    """Per-row contributions to every cuboid stat (masked by validity).

    Shared between the single-device cuboid build and the per-device shard
    body of the distributed delta build — one definition of the stat schema.
    """
    w = valid.astype(jnp.float32)
    y = columns[outcome].astype(jnp.float32)
    cols = {"one": w, "y": w * y, "yy": w * y * y}
    for t in treatments:
        tv = columns[t].astype(jnp.float32) * w
        cols[f"t_{t}"] = tv
        cols[f"yt_{t}"] = tv * y
        cols[f"yyt_{t}"] = tv * y * y
    return cols


def empty_cuboid(codec: KeyCodec, treatments: Sequence[str],
                 capacity: int = 1024) -> Cuboid:
    """All-invalid cuboid of ``capacity`` slots — the seed state of online
    delta maintenance (first ingest takes the re-sort merge path)."""
    return Cuboid(
        codec=codec,
        key_hi=jnp.full((capacity,), INVALID_HI, dtype=jnp.uint32),
        key_lo=jnp.full((capacity,), INVALID_LO, dtype=jnp.uint32),
        stats={k: jnp.zeros((capacity,), jnp.float32)
               for k in stat_names(treatments)},
        group_valid=jnp.zeros((capacity,), bool),
        treatments=tuple(treatments))


@functools.lru_cache(maxsize=256)
def _build_fn(codec: KeyCodec, specs_items: Tuple, treatments: Tuple[str, ...],
              outcome: str):
    """Jitted group+aggregate body of build_cuboid, cached per schema.

    Online ingest builds a delta cuboid per batch; eagerly that is dozens
    of small dispatches (~15ms) dominating the per-batch cost. Schema and
    shapes are stable across a stream, so one trace amortizes away."""
    specs = dict(specs_items)

    @jax.jit
    def fn(columns, valid):
        buckets = coarsen_columns(columns, specs)
        hi, lo = codec.pack(buckets, valid)
        g = groupby.group_by_key(hi, lo)
        cols = delta_stat_columns(columns, valid, treatments, outcome)
        sums = groupby.segment_sums(g, cols)
        return g.group_hi, g.group_lo, sums, g.group_valid
    return fn


def build_cuboid(table: Table, specs: Mapping[str, CoarsenSpec],
                 treatments: Sequence[str], outcome: str) -> Cuboid:
    """Base cuboid: group the relation by ALL dims, store decomposable stats."""
    codec = make_codec(specs)
    fn = _build_fn(codec, tuple(sorted(specs.items())), tuple(treatments),
                   outcome)
    hi, lo, sums, gv = fn(dict(table.columns), table.valid)
    return Cuboid(codec=codec, key_hi=hi, key_lo=lo, stats=sums,
                  group_valid=gv, treatments=tuple(treatments))


@functools.lru_cache(maxsize=256)
def _rollup_fn(codec: KeyCodec, dims: Tuple[str, ...]):
    """Jitted re-key + re-aggregate body of rollup, cached per (codec, dims)
    — same rationale as :func:`_build_fn`."""
    sub = codec.subcodec(dims)

    @jax.jit
    def fn(key_hi, key_lo, group_valid, stats):
        buckets = {n: codec.extract(key_hi, key_lo, n) for n in sub.names}
        shi, slo = sub.pack(buckets, group_valid)
        g = groupby.group_by_key(shi, slo)
        sums = groupby.segment_sums(g, stats)
        return g.group_hi, g.group_lo, sums, g.group_valid
    return fn


def rollup(cuboid: Cuboid, dims: Sequence[str]) -> Cuboid:
    """Coarser cuboid over a subset of dims, computed from ``cuboid`` (not
    the base relation). Cost scales with cuboid capacity, not data size."""
    missing = set(dims) - set(cuboid.dims)
    if missing:
        raise ValueError(f"dims {missing} not in cuboid {cuboid.dims}")
    fn = _rollup_fn(cuboid.codec, tuple(dims))
    shi, slo, sums, gv = fn(cuboid.key_hi, cuboid.key_lo,
                            cuboid.group_valid, dict(cuboid.stats))
    return Cuboid(codec=cuboid.codec.subcodec(dims), key_hi=shi, key_lo=slo,
                  stats=sums, group_valid=gv, treatments=cuboid.treatments)


def compact_cuboid(cuboid: Cuboid, granule: int = 1024,
                   keep_mask: np.ndarray = None) -> Cuboid:
    """Host-side shrink to ~n_groups rows (materialization for reuse).

    ``keep_mask`` (host bool, per group) additionally drops groups — the
    online engine's eviction path. Padding uses the canonical invalid-key
    marker so binary-search lookups keep treating dead slots as absent.
    """
    gv = np.asarray(cuboid.group_valid)
    if keep_mask is not None:
        gv = gv & np.asarray(keep_mask)
    idx = np.nonzero(gv)[0]
    cap = _round_capacity(len(idx), granule)
    pad = cap - len(idx)

    def take(a, fill=0):
        out = np.asarray(a)[idx]
        return np.pad(out, [(0, pad)] + [(0, 0)] * (out.ndim - 1),
                      constant_values=fill)

    return Cuboid(
        codec=cuboid.codec,
        key_hi=jnp.asarray(take(cuboid.key_hi, fill=np.uint32(INVALID_HI))),
        key_lo=jnp.asarray(take(cuboid.key_lo, fill=np.uint32(INVALID_LO))),
        stats={k: jnp.asarray(take(v)) for k, v in cuboid.stats.items()},
        group_valid=jnp.asarray(np.pad(np.ones(len(idx), bool), (0, pad))),
        treatments=cuboid.treatments)


def delta_cuboid(batch: Table, specs: Mapping[str, CoarsenSpec],
                 treatments: Sequence[str], outcome: str,
                 granule: int = 256) -> Cuboid:
    """Stat table of ONE streamed batch, compacted small: the unit of online
    delta maintenance. Cost is O(batch), never O(total data)."""
    return compact_cuboid(build_cuboid(batch, specs, treatments, outcome),
                          granule=granule)


def scatter_merge_stats(base_stats: Mapping[str, jnp.ndarray],
                        pos: jnp.ndarray,
                        delta_stats: Mapping[str, jnp.ndarray],
                        use_pallas: bool = False) -> Dict[str, jnp.ndarray]:
    """Fast-path stat merge: scatter-add delta rows at known positions,
    optionally through the MXU one-hot kernel."""
    if use_pallas:
        from repro.kernels.ops import scatter_merge_op
        names = sorted(base_stats)
        table = jnp.stack([base_stats[k] for k in names], axis=1)
        vals = jnp.stack([delta_stats[k] for k in names], axis=1)
        merged = scatter_merge_op(table, pos, vals)
        return {k: merged[:, j] for j, k in enumerate(names)}
    return groupby.scatter_add_stats(base_stats, pos, delta_stats)


def merge_delta(base: Cuboid, delta: Cuboid, granule: int = 1024,
                use_pallas: bool = False, fast: bool = None
                ) -> Tuple[Cuboid, jnp.ndarray, bool]:
    """Fold a delta stat table into a materialized cuboid.

    Fast path (every valid delta key already exists in ``base``): scatter-add
    the delta stats at the looked-up positions — O(|delta groups|) work and
    the merged cuboid keeps ``base``'s row layout, so incrementally
    maintained per-group state (e.g. CEM keep masks) stays aligned.

    Slow path (new group keys, including the first merge into an empty
    cuboid): re-sort merge — the same combine ``repro.core.distributed``
    uses to fold per-chip stat tables — with geometric capacity growth.

    ``fast`` injects a path decision computed elsewhere: the fused online
    engine plans every merge of an ingest on device and reads all verdicts
    back in ONE sync (its fast-path merges then bypass this function
    entirely, so only ``fast=False`` re-sort merges land here). ``fast=None``
    decides locally with a blocking device->host read.

    Returns (merged, positions of delta groups in merged, fast_path).
    """
    if base.codec.fields != delta.codec.fields:
        raise ValueError("codec mismatch in merge_delta")
    if set(base.stats) != set(delta.stats):
        raise ValueError("stat-column mismatch in merge_delta")
    if fast is None or fast:
        pos, found = groupby.lookup_rows_in_table(
            delta.key_hi, delta.key_lo, base.key_hi, base.key_lo)
        if fast is None:
            fast = bool((np.asarray(found)
                         | ~np.asarray(delta.group_valid)).all())
    if fast:
        stats = scatter_merge_stats(base.stats, pos, delta.stats,
                                    use_pallas=use_pallas)
        return dataclasses.replace(base, stats=stats), pos, True
    cat_hi = jnp.concatenate([base.key_hi, delta.key_hi])
    cat_lo = jnp.concatenate([base.key_lo, delta.key_lo])
    cat_stats = {k: jnp.concatenate([base.stats[k], delta.stats[k]])
                 for k in base.stats}
    g = groupby.group_by_key(cat_hi, cat_lo)
    sums = groupby.segment_sums(g, cat_stats)
    merged_full = Cuboid(codec=base.codec, key_hi=g.group_hi,
                         key_lo=g.group_lo, stats=sums,
                         group_valid=g.group_valid,
                         treatments=base.treatments)
    # never shrink: growth is geometric in multiples of the old capacity
    out = compact_cuboid(merged_full, granule=max(granule, base.capacity))
    pos2, _ = groupby.lookup_rows_in_table(
        delta.key_hi, delta.key_lo, out.key_hi, out.key_lo)
    return out, pos2, False


def cem_groups_from_cuboid(cuboid: Cuboid, treatment: str) -> CEMGroups:
    """CEM group stats for one treatment straight from a cuboid whose dims
    are exactly that treatment's covariates (use :func:`rollup` first)."""
    nt = cuboid.stats[f"t_{treatment}"]
    n = cuboid.stats["one"]
    nc = n - nt
    yt = cuboid.stats[f"yt_{treatment}"]
    yc = cuboid.stats["y"] - yt
    keep = overlap_keep(cuboid.group_valid, nt, nc)
    # CEMGroups wants a Grouping; cuboid-level estimation never touches the
    # row-level fields, so install an inert one.
    dummy = groupby.Grouping(
        perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        inv_perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        seg_ids=jnp.zeros((cuboid.capacity,), jnp.int32),
        group_hi=cuboid.key_hi, group_lo=cuboid.key_lo,
        group_valid=cuboid.group_valid,
        n_groups=cuboid.n_groups())
    return CEMGroups(grouping=dummy, keep=keep, n_treated=nt, n_control=nc,
                     sum_y_t=yt, sum_y_c=yc)


def smallest_ancestor(targets: Mapping[str, Sequence[str]],
                      materialized: Mapping[str, Cuboid]
                      ) -> Dict[str, str]:
    """Cube planning: for each target group-by, pick the smallest
    materialized cuboid whose dims are a superset (classic cube heuristic)."""
    plan = {}
    for tname, dims in targets.items():
        need = set(dims)
        best = None
        for cname, cub in materialized.items():
            if need <= set(cub.dims):
                size = int(cub.n_groups())
                if best is None or size < best[0]:
                    best = (size, cname)
        if best is None:
            raise ValueError(f"no materialized ancestor covers {tname}: {dims}")
        plan[tname] = best[1]
    return plan


def filter_cuboid(cuboid: Cuboid, dim: str, bucket_values: Sequence[int]
                  ) -> Cuboid:
    """Sub-population restriction (paper §4.2 offline setting): keep only
    groups whose ``dim`` bucket is in ``bucket_values`` (e.g. airport=SFO)."""
    vals = cuboid.codec.extract(cuboid.key_hi, cuboid.key_lo, dim)
    ok = jnp.zeros_like(cuboid.group_valid)
    for b in bucket_values:
        ok = ok | (vals == b)
    gv = cuboid.group_valid & ok
    stats = {k: jnp.where(gv, v, 0.0) for k, v in cuboid.stats.items()}
    return Cuboid(codec=cuboid.codec, key_hi=cuboid.key_hi,
                  key_lo=cuboid.key_lo, stats=stats, group_valid=gv,
                  treatments=cuboid.treatments)

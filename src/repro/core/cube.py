"""Data-cube aggregation for CEM over many treatments (paper §4.2).

CEM for one treatment is a GROUP BY over its covariates. CEM for all
2^|X| conjunctive treatments is the group-by *lattice* — so the classic
cube optimizations apply: materialize a base cuboid once, and compute every
coarser group-by from its smallest materialized ancestor instead of the
base relation.

A :class:`Cuboid` is a group-stat table: packed keys + decomposable
aggregates (counts/sums per treatment arm). Everything CEM/ATE need is
decomposable (min/max/sum/count), so rollups are exact. The same stat-table
shape is what `repro.core.distributed` all-gathers across chips — the cube
and the distributed combine are literally one mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import groupby
from repro.core.ate import ATEEstimate
from repro.core.cem import CEMGroups, make_codec
from repro.core.coarsen import CoarsenSpec, coarsen_columns
from repro.core.keys import KeyCodec
from repro.data.columnar import Table, _round_capacity


@dataclasses.dataclass(frozen=True)
class Cuboid:
    """Group-stat table over a set of dims (coarsened covariates).

    stats: per-group decomposable sums:
      "one"  -> n rows, "y" -> sum outcome, and per treatment t:
      f"t_{t}" -> n treated, f"yt_{t}" -> sum outcome over treated.
    """

    codec: KeyCodec
    key_hi: jnp.ndarray
    key_lo: jnp.ndarray
    stats: Dict[str, jnp.ndarray]
    group_valid: jnp.ndarray
    treatments: Tuple[str, ...]

    @property
    def capacity(self) -> int:
        return int(self.key_hi.shape[0])

    @property
    def dims(self) -> Tuple[str, ...]:
        return self.codec.names

    def n_groups(self) -> jnp.ndarray:
        return jnp.sum(self.group_valid.astype(jnp.int32))


def build_cuboid(table: Table, specs: Mapping[str, CoarsenSpec],
                 treatments: Sequence[str], outcome: str) -> Cuboid:
    """Base cuboid: group the relation by ALL dims, store decomposable stats."""
    codec = make_codec(specs)
    buckets = coarsen_columns(table.columns, specs)
    hi, lo = codec.pack(buckets, table.valid)
    g = groupby.group_by_key(hi, lo)
    w = table.valid.astype(jnp.float32)
    y = table[outcome].astype(jnp.float32)
    cols = {"one": w, "y": w * y}
    for t in treatments:
        tv = table[t].astype(jnp.float32) * w
        cols[f"t_{t}"] = tv
        cols[f"yt_{t}"] = tv * y
    sums = groupby.segment_sums(g, cols)
    return Cuboid(codec=codec, key_hi=g.group_hi, key_lo=g.group_lo,
                  stats=sums, group_valid=g.group_valid,
                  treatments=tuple(treatments))


def rollup(cuboid: Cuboid, dims: Sequence[str]) -> Cuboid:
    """Coarser cuboid over a subset of dims, computed from ``cuboid`` (not
    the base relation). Cost scales with cuboid capacity, not data size."""
    missing = set(dims) - set(cuboid.dims)
    if missing:
        raise ValueError(f"dims {missing} not in cuboid {cuboid.dims}")
    sub, shi, slo = cuboid.codec.rollup(cuboid.key_hi, cuboid.key_lo, dims,
                                        cuboid.group_valid)
    g = groupby.group_by_key(shi, slo)
    sums = groupby.segment_sums(g, cuboid.stats)
    return Cuboid(codec=sub, key_hi=g.group_hi, key_lo=g.group_lo,
                  stats=sums, group_valid=g.group_valid,
                  treatments=cuboid.treatments)


def compact_cuboid(cuboid: Cuboid, granule: int = 1024) -> Cuboid:
    """Host-side shrink to ~n_groups rows (materialization for reuse)."""
    gv = np.asarray(cuboid.group_valid)
    idx = np.nonzero(gv)[0]
    cap = _round_capacity(len(idx), granule)
    pad = cap - len(idx)

    def take(a, fill=0):
        out = np.asarray(a)[idx]
        return np.pad(out, [(0, pad)] + [(0, 0)] * (out.ndim - 1),
                      constant_values=fill)

    return Cuboid(
        codec=cuboid.codec,
        key_hi=jnp.asarray(take(cuboid.key_hi, fill=np.uint32(0xFFFFFFFF))),
        key_lo=jnp.asarray(take(cuboid.key_lo, fill=np.uint32(0xFFFFFFFF))),
        stats={k: jnp.asarray(take(v)) for k, v in cuboid.stats.items()},
        group_valid=jnp.asarray(np.pad(np.ones(len(idx), bool), (0, pad))),
        treatments=cuboid.treatments)


def cem_groups_from_cuboid(cuboid: Cuboid, treatment: str) -> CEMGroups:
    """CEM group stats for one treatment straight from a cuboid whose dims
    are exactly that treatment's covariates (use :func:`rollup` first)."""
    nt = cuboid.stats[f"t_{treatment}"]
    n = cuboid.stats["one"]
    nc = n - nt
    yt = cuboid.stats[f"yt_{treatment}"]
    yc = cuboid.stats["y"] - yt
    keep = cuboid.group_valid & (nt > 0) & (nc > 0)
    # CEMGroups wants a Grouping; cuboid-level estimation never touches the
    # row-level fields, so install an inert one.
    dummy = groupby.Grouping(
        perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        inv_perm=jnp.zeros((cuboid.capacity,), jnp.int32),
        seg_ids=jnp.zeros((cuboid.capacity,), jnp.int32),
        group_hi=cuboid.key_hi, group_lo=cuboid.key_lo,
        group_valid=cuboid.group_valid,
        n_groups=cuboid.n_groups())
    return CEMGroups(grouping=dummy, keep=keep, n_treated=nt, n_control=nc,
                     sum_y_t=yt, sum_y_c=yc)


def smallest_ancestor(targets: Mapping[str, Sequence[str]],
                      materialized: Mapping[str, Cuboid]
                      ) -> Dict[str, str]:
    """Cube planning: for each target group-by, pick the smallest
    materialized cuboid whose dims are a superset (classic cube heuristic)."""
    plan = {}
    for tname, dims in targets.items():
        need = set(dims)
        best = None
        for cname, cub in materialized.items():
            if need <= set(cub.dims):
                size = int(cub.n_groups())
                if best is None or size < best[0]:
                    best = (size, cname)
        if best is None:
            raise ValueError(f"no materialized ancestor covers {tname}: {dims}")
        plan[tname] = best[1]
    return plan


def filter_cuboid(cuboid: Cuboid, dim: str, bucket_values: Sequence[int]
                  ) -> Cuboid:
    """Sub-population restriction (paper §4.2 offline setting): keep only
    groups whose ``dim`` bucket is in ``bucket_values`` (e.g. airport=SFO)."""
    vals = cuboid.codec.extract(cuboid.key_hi, cuboid.key_lo, dim)
    ok = jnp.zeros_like(cuboid.group_valid)
    for b in bucket_values:
        ok = ok | (vals == b)
    gv = cuboid.group_valid & ok
    stats = {k: jnp.where(gv, v, 0.0) for k, v in cuboid.stats.items()}
    return Cuboid(codec=cuboid.codec, key_hi=cuboid.key_hi,
                  key_lo=cuboid.key_lo, stats=stats, group_valid=gv,
                  treatments=cuboid.treatments)

"""Serving steps: prefill + decode, and a host-side batched generate loop.

`make_prefill`/`make_decode` return jit-able pure functions; `generate`
drives them for the examples and tests (greedy or temperature sampling).
decode_32k / long_500k dry-run cells lower `decode_step` — one new token
against a seq_len-deep cache — per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward, init_cache


def make_prefill(cfg, max_seq: int):
    def prefill(params, batch: Dict[str, jnp.ndarray]) -> Tuple[Any, jnp.ndarray]:
        b = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["inputs_embeds"].shape[0])
        cache = init_cache(cfg, b, max_seq)
        logits, cache, _ = forward(params, cfg, batch, cache=cache,
                                   cache_pos=jnp.zeros((b,), jnp.int32))
        return cache, logits[:, -1]
    return prefill


def make_decode(cfg):
    def decode_step(params, cache, token: jnp.ndarray, pos: jnp.ndarray,
                    extras: Optional[Dict[str, jnp.ndarray]] = None
                    ) -> Tuple[jnp.ndarray, Any]:
        batch = {"tokens": token[:, None]}
        if extras:
            batch.update(extras)
        if cfg.rope_type == "mrope":
            p = pos[None, :, None]
            batch["positions"] = jnp.broadcast_to(p, (3,) + p.shape[1:])
        logits, cache, _ = forward(params, cfg, batch, cache=cache,
                                   cache_pos=pos)
        return logits[:, 0], cache
    return decode_step


def generate(params, cfg, batch: Dict[str, jnp.ndarray], n_new: int,
             max_seq: int, temperature: float = 0.0, seed: int = 0
             ) -> jnp.ndarray:
    """Host loop: prefill prompt, decode n_new tokens (greedy / sampled)."""
    prompt = batch["tokens"]
    b, s = prompt.shape
    prefill = jax.jit(make_prefill(cfg, max_seq))
    decode = jax.jit(make_decode(cfg))
    extras = {k: v for k, v in batch.items() if k in ("enc_out", "frames")}
    cache, last = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = (jnp.argmax(last, -1) if temperature == 0.0 else
           jax.random.categorical(key, last / temperature, -1)
           ).astype(jnp.int32)
    for i in range(n_new):
        out.append(tok)
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos, extras or None)
        key, sub = jax.random.split(key)
        tok = (jnp.argmax(logits, -1) if temperature == 0.0 else
               jax.random.categorical(sub, logits / temperature, -1)
               ).astype(jnp.int32)
    return jnp.stack(out, axis=1)

"""Training step: next-token CE (+ MoE aux), remat, microbatch accumulation.

`make_train_step(cfg)` returns a pure (state, batch) -> (state, metrics)
function suitable for jit/pjit with shardings from launch/sharding.py.
Microbatching splits the per-call batch and accumulates grads in a scan
(constant memory in microbatch count).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.optim import AdamWConfig, get_optimizer
from repro.optim.schedule import warmup_cosine


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def loss_fn(params, cfg, batch: Dict[str, jnp.ndarray], aux_weight: float
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, _, aux = forward(params, cfg, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.roll(batch["tokens"], -1, axis=1)
    ce = cross_entropy(logits, labels, batch.get("loss_mask"))
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def init_state(params, cfg) -> Dict[str, Any]:
    opt_init, _ = get_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg, opt_cfg: AdamWConfig = AdamWConfig(),
                    microbatches: int = 1, aux_weight: float = 0.01,
                    warmup: int = 100, total_steps: int = 10000,
                    grad_shardings=None):
    """grad_shardings: optional pytree of NamedSharding matching params.
    REQUIRED at scale with microbatch accumulation: the f32 grad
    accumulator lives in the scan carry, which GSPMD otherwise happily
    replicates (observed: 3.5 TiB/device on arctic-480b)."""
    _, opt_update = get_optimizer(cfg.optimizer)

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, aux_weight)
        return loss, parts, constrain(grads)

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def split(x):
                b = x.shape[-2] if x.ndim >= 2 and x.shape[0] == 3 else \
                    x.shape[0]
                # split along batch dim (handles (B, ...) and (3, B, S))
                if x.ndim >= 2 and x.shape[0] == 3:
                    return x.reshape(x.shape[0], microbatches,
                                     b // microbatches, *x.shape[2:]
                                     ).swapaxes(0, 1)
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_a, grads_a = carry
                loss, parts, grads = grads_of(params, mb)
                grads_a = constrain(jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), grads_a, grads))
                return (loss_a + loss, grads_a), parts["ce"]

            acc_dt = (jnp.bfloat16 if getattr(cfg, "grad_accum_dtype", "")
                      == "bfloat16" else jnp.float32)
            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (loss_sum, grads), ces = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), micro)
            loss = loss_sum / microbatches
            ce = jnp.mean(ces)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, parts, grads = grads_of(params, batch)
            ce = parts["ce"]

        lr_scale = warmup_cosine(state["step"], warmup=warmup,
                                 total=total_steps)
        new_params, new_opt, om = opt_update(grads, state["opt"], params,
                                             opt_cfg, lr_scale)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "ce": ce, "grad_norm": om["grad_norm"],
                   "lr_scale": lr_scale}
        return new_state, metrics

    return train_step

from repro.train.train_step import (cross_entropy, init_state, loss_fn,
                                    make_train_step)
from repro.train.serve_step import generate, make_decode, make_prefill

__all__ = ["cross_entropy", "init_state", "loss_fn", "make_train_step",
           "generate", "make_decode", "make_prefill"]

from repro.checkpoint.ckpt import (AsyncSaver, latest_step, restore, save)

__all__ = ["AsyncSaver", "latest_step", "restore", "save"]

"""Sharded, integrity-checked, async checkpointing with elastic restore.

Layout per step:
  <dir>/step_<n>.tmp/            (written first, renamed atomically)
  <dir>/step_<n>/
    shard_<p>.npz                one file per host process (p = process id)
    manifest.json                step, tree paths, shapes, dtypes, crc32s,
                                 mesh metadata, framework versions

Properties needed at 1000+ nodes, all implemented and tested:
  * atomic publish (tmp dir + rename; readers never see partial state)
  * per-array CRC32 validated on restore (corrupt shard -> clear error)
  * keep-last-k garbage collection
  * async save (background thread, returns a handle; train loop overlaps)
  * elastic restore: arrays are re-device_put under a NEW mesh/sharding —
    restart on a different topology (runtime/elastic.py picks it)
"""
from __future__ import annotations

import json
import os
import random
import re
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import trace

# A published step dir is exactly "step_<digits>"; in-flight writes live in
# "step_<digits>.tmp<p>".  Both _gc and latest_step must use THIS pattern:
# a suffix test like endswith(".tmp") misses ".tmp0"/".tmp1", so a crashed
# save would leak its tmp dir forever AND (sorting after "step_N") push the
# newest good checkpoint out of the keep-last window.
_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^step_(\d+)\.tmp(\d+)$")


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(state, step: int, directory: str, process_index: int = 0,
         keep_last: int = 3) -> str:
    """Synchronous checkpoint write. Returns the published path."""
    os.makedirs(directory, exist_ok=True)
    _clean_orphans(directory, process_index)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)

    leaves = _tree_paths(state)
    arrays = {}
    meta = {"step": int(step), "arrays": {}, "time": time.time(),
            "jax_version": jax.__version__}
    for key, leaf in leaves:
        a = np.asarray(leaf)
        arrays[key] = a
        meta["arrays"][key] = {
            "shape": list(a.shape), "dtype": str(a.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
        }
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"),
             **{k.replace("/", "__"): v for k, v in arrays.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_last)
    return final


class AsyncSaver:
    """Background-thread checkpoint writer; at most one in flight.

    Transient ``OSError``s during the background write (NFS hiccup, disk
    pressure) are retried up to ``max_retries`` times with exponential
    backoff and jitter before the save is declared failed.  Retries and
    terminal failures are counted on the instance (``n_retries`` /
    ``n_failures``) and in the process-global ``launch.trace`` event
    accounting (``ckpt_save_retry`` / ``ckpt_save_failure``) — the writer
    runs off-thread, so the thread-local dispatch counters never see it.
    """

    def __init__(self, max_retries: int = 3, backoff: float = 0.05,
                 jitter: float = 0.5, seed: int = 0):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None
        self.error: Optional[BaseException] = None
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.jitter = float(jitter)
        self.n_retries = 0
        self.n_failures = 0
        self._rng = random.Random(seed)

    def save(self, state, step: int, directory: str, **kw):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def run():
            delay = self.backoff
            last: Optional[BaseException] = None
            for attempt in range(self.max_retries + 1):
                try:
                    self.last_path = save(host_state, step, directory, **kw)
                    return
                except OSError as e:       # transient: retry with backoff
                    last = e
                    if attempt == self.max_retries:
                        break
                    self.n_retries += 1
                    trace.record_event("ckpt_save_retry")
                    time.sleep(delay * (1.0 + self.jitter
                                        * self._rng.random()))
                    delay *= 2.0
                except BaseException as e:  # surfaced on next wait()
                    last = e
                    break
            self.error = last               # surfaced on next wait()
            self.n_failures += 1
            trace.record_event("ckpt_save_failure")

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            e, self.error = self.error, None
            raise e


def _clean_orphans(directory: str, process_index: int) -> None:
    """Remove tmp dirs this process abandoned (crash mid-save).  Only OUR
    process_index suffix is touched — another process may be mid-write."""
    for d in os.listdir(directory):
        m = _TMP_RE.match(d)
        if m and m.group(2) == str(process_index):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _gc(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory) if _STEP_RE.match(d))
    for d in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             for m in [_STEP_RE.match(d)] if m]
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None, template=None,
            shardings=None, validate: bool = True):
    """Restore a checkpoint. template (pytree) rebuilds structure/dtypes;
    shardings (same-structure pytree of jax.sharding.Sharding or None)
    re-places arrays — pass shardings from a NEW mesh for elastic restart."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    shard_files = sorted(f for f in os.listdir(path) if f.endswith(".npz"))
    arrays: Dict[str, np.ndarray] = {}
    for sf in shard_files:
        with np.load(os.path.join(path, sf)) as z:
            for k in z.files:
                arrays[k.replace("__", "/")] = z[k]
    if validate:
        for key, info in meta["arrays"].items():
            a = arrays[key]
            crc = zlib.crc32(np.ascontiguousarray(a).tobytes())
            if crc != info["crc32"]:
                raise IOError(f"checkpoint corruption: CRC mismatch for "
                              f"{key} in {path}")
    if template is None:
        return meta, arrays
    keys = [k for k, _ in _tree_paths(template)]
    flat_t, treedef = jax.tree.flatten(template)
    flat_s = (treedef.flatten_up_to(shardings) if shardings is not None
              else [None] * len(flat_t))
    out = []
    for key, leaf, sh in zip(keys, flat_t, flat_s):
        a = arrays[key].astype(leaf.dtype if hasattr(leaf, "dtype")
                               else arrays[key].dtype)
        out.append(jax.device_put(a, sh) if sh is not None
                   else jnp.asarray(a))
    return meta, treedef.unflatten(out)

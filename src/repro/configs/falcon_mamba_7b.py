"""[ssm] falcon-mamba-7b: 64L d_model=4096 attn-free, vocab 65024,
ssm_state=16 — Mamba1 arch [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=65024,
    attn_type="none", ssm_state=16, ssm_variant="mamba1", ssm_expand=2,
    supports_decode=True, subquadratic=True)

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, applicable_shapes
from repro.configs.registry import REGISTRY, get_config

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "applicable_shapes",
           "REGISTRY", "get_config"]

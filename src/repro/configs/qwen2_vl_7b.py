"""[vlm] qwen2-vl-7b: qwen2-7b backbone + M-RoPE (t/h/w sections
16/24/24 over head_dim/2) [arXiv:2409.12191]. Vision frontend STUBBED:
input_specs() provides patch embeddings + 3-D positions."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
    attn_type="gqa", qkv_bias=True, rope_type="mrope",
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    modality_frontend="vision")

"""Assigned architecture pool: one module per arch (configs/<id>.py),
aggregated here. Known spec discrepancies are documented in DESIGN.md
§Arch-applicability."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from repro.configs.qwen3_1_7b import CONFIG as QWEN3_1_7B
from repro.configs.qwen2_7b import CONFIG as QWEN2_7B
from repro.configs.qwen3_4b import CONFIG as QWEN3_4B
from repro.configs.mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B

REGISTRY = {c.name: c for c in [
    FALCON_MAMBA_7B, DEEPSEEK_V2_LITE, ARCTIC_480B, SEAMLESS_M4T_LARGE_V2,
    QWEN3_1_7B, QWEN2_7B, QWEN3_4B, MISTRAL_NEMO_12B, ZAMBA2_7B,
    QWEN2_VL_7B,
]}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]

"""[audio] seamless-m4t-large-v2: enc-dec 24L d=1024 16H d_ff=8192,
vocab 256206 [arXiv:2308.11596]. Audio frontend STUBBED: input_specs()
provides precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=8192, vocab_size=256208,  # 256206 padded +2 so vocab % TP(16) == 0
    attn_type="gqa",
    modality_frontend="audio")

"""Model configuration schema for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"   # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_type: str = "standard"      # standard | mrope
    mrope_sections: Tuple[int, ...] = ()   # head_dim/2 split for t/h/w

    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN parallel to MoE
    first_dense_layers: int = 0      # deepseek: leading dense layers
    first_dense_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "gather"     # gather (optimized) | scatter (naive
                                     # baseline, kept for §Perf ablation)

    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0               # mamba2 heads
    ssm_variant: str = ""            # mamba1 | mamba2

    # hybrid (zamba2)
    hybrid_attn_every: int = 0       # shared attn block after every k ssm blocks

    # encoder-decoder (seamless)
    n_encoder_layers: int = 0

    # numerics / memory policy
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = False
    remat: bool = True
    optimizer: str = "adamw"         # adamw | adamw_int8
    grad_accum_dtype: str = "float32"  # bfloat16 for memory-starved giants
    seq_parallel: bool = True        # Megatron-style sequence parallelism;
                                     # measured regression on hybrid-SSM and
                                     # tiny models -> per-arch opt-out

    # capability flags for the shape grid
    supports_decode: bool = True
    subquadratic: bool = False       # eligible for long_500k
    modality_frontend: str = ""      # "" | audio | vision (stubbed)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink(v, lo, div):
            return max(lo, v // div) if v else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.hybrid_attn_every == 0
                         else 2 * self.hybrid_attn_every + 1),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(max(1, self.n_kv_heads // max(1, self.n_heads // 4)),
                           4),
            head_dim=32,
            d_ff=256,
            first_dense_d_ff=256 if self.first_dense_layers else 0,
            vocab_size=512,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 8),
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            moe_capacity_factor=16.0,  # dropless at smoke scale: prefill ==
                                       # decode token-for-token
            param_dtype="float32",
            dtype="float32",
            remat=False,
        )


# ---- shapes grid (assigned) -------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig):
    """The (arch x shape) grid rules from the assignment: long_500k only for
    sub-quadratic archs; decode only for archs with a decode step."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        if s.kind == "decode" and not cfg.supports_decode:
            continue
        out.append(s)
    return out

"""[hybrid] zamba2-7b: 81 Mamba2 blocks (state 64) + shared attention
block applied every 6 layers (32H kv=32, d_ff=14336), vocab 32000
[arXiv:2411.15242]. Layout: 13 x (6 mamba + shared attn) + 3 tail mamba;
the shared block reuses ONE parameter set (per-application LoRA deltas of
the released model are omitted — DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab_size=32000,
    attn_type="gqa", ssm_state=64, ssm_variant="mamba2", ssm_expand=2,
    ssm_heads=112, hybrid_attn_every=6, subquadratic=True,
    seq_parallel=False)  # measured 0.79x regression with seq-par (EXPERIMENTS §Perf)

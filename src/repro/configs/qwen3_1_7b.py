"""[dense] qwen3-1.7b: 28L d=2048 16H GQA kv=8 d_ff=6144 vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144, vocab_size=151936,
    attn_type="gqa", qk_norm=True, rope_theta=1e6,
    seq_parallel=False)  # tiny model: seq-par overhead beats its win

"""[moe] deepseek-v2-lite-16b: 27L d=2048 16H, MLA kv_lora=512,
2 shared + 64 routed experts top-6, moe_d_ff=1408, layer0 dense
[arXiv:2405.04434; hf]. NOTE: the assignment line also says "160 routed"
(the full V2 number); the Lite checkpoint has 64 — see DESIGN.md."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=10944, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128, n_experts=64, n_shared_experts=2, moe_top_k=6,
    moe_d_ff=1408, first_dense_layers=1, first_dense_d_ff=10944,
    rope_theta=1e4)

"""[moe] arctic-480b: 35L d=7168 56H GQA kv=8, 128 experts top-2 +
dense residual (d_ff=4864), vocab 32000 [hf:Snowflake/snowflake-arctic-base].
bf16 params + int8 Adam states so the optimizer fits one pod (DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=4864, vocab_size=32000,
    attn_type="gqa", n_experts=128, moe_top_k=2, moe_d_ff=4864,
    dense_residual=True, param_dtype="bfloat16", optimizer="adamw_int8",
    grad_accum_dtype="bfloat16")
